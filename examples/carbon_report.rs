//! Carbon report: the paper's full evaluation sweep (Figs. 6, 7, 8) as a
//! single operator-facing report, plus JSON output for dashboards.
//!
//! Run: `cargo run --release --example carbon_report [-- <duration_s>]`

use carbon_sim::carbon::EmbodiedModel;
use carbon_sim::experiments::{fig6, fig7, fig8, run_matrix, Scale};
use carbon_sim::util::json::Value;

fn main() {
    let duration: f64 =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(60.0);
    let mut scale = Scale::paper();
    scale.duration_s = duration;
    println!(
        "sweep: rates {:?} rps × cores {:?} × 3 policies, {duration}s traces, 22 machines",
        scale.rates, scale.core_counts
    );
    let t0 = std::time::Instant::now();
    let cells = run_matrix(&scale);
    println!("ran {} simulations in {:.1}s", cells.len() * 3, t0.elapsed().as_secs_f64());

    let rows6 = fig6::rows(&cells, 2.6);
    let rows7 = fig7::rows(&cells, &EmbodiedModel::paper_default());
    let rows8 = fig8::rows(&cells);
    fig6::print(&rows6);
    fig7::print(&rows7);
    fig8::print(&rows8);

    // Machine-readable dump.
    let json = Value::Arr(
        rows7
            .iter()
            .map(|r| {
                Value::obj(vec![
                    ("cores", r.cores.into()),
                    ("rate", r.rate.into()),
                    ("policy", r.policy.as_str().into()),
                    ("yearly_kg_p99", r.yearly_kg_p99.into()),
                    ("reduction_pct_p99", r.reduction_pct_p99.into()),
                    ("reduction_pct_p50", r.reduction_pct_p50.into()),
                    ("lifetime_yr_p99", r.lifetime_yr_p99.into()),
                ])
            })
            .collect(),
    );
    let path = std::env::temp_dir().join("carbon_report.json");
    std::fs::write(&path, json.to_string_pretty()).expect("write report");
    println!("\nmachine-readable report: {}", path.display());

    for (name, violations) in [
        ("fig6", fig6::check_shape(&rows6)),
        ("fig7", fig7::check_shape(&rows7)),
        ("fig8", fig8::check_shape(&rows8)),
    ] {
        if violations.is_empty() {
            println!("{name} shape: OK");
        } else {
            println!("{name} shape violations: {violations:?}");
        }
    }
}
