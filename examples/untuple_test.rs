fn main() -> anyhow::Result<()> {
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file("/tmp/aging_notuple.hlo.txt")?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
    let a = xla::Literal::vec1(&[0.01f32;6]).reshape(&[2,3])?;
    let outs = exe.execute::<xla::Literal>(&[a.clone(), a.clone(), a.clone(), a])?;
    println!("replicas={} outputs={}", outs.len(), outs[0].len());
    for (i, b) in outs[0].iter().enumerate() {
        let lit = b.to_literal_sync()?;
        println!("out{i}: elems={}", lit.element_count());
    }
    Ok(())
}
