//! Quickstart: simulate a small LLM inference cluster under the paper's
//! aging-aware core management and compare it with the linux baseline.
//!
//! Run: `cargo run --release --example quickstart`

use carbon_sim::carbon::EmbodiedModel;
use carbon_sim::cluster::{Cluster, ClusterConfig};
use carbon_sim::trace::azure::{AzureTraceGen, TraceParams, Workload};
use carbon_sim::util::stats::{self, Summary};

fn main() {
    // 1. Synthesize an Azure-like trace: 60 requests/s for one minute.
    let trace = AzureTraceGen::new(TraceParams {
        rate_rps: 60.0,
        duration_s: 60.0,
        workload: Workload::Mixed,
        seed: 7,
    })
    .generate();
    println!("trace: {} requests over {:.0}s", trace.requests.len(), trace.duration_s);

    // 2. Run the same silicon + trace under both policies (paired).
    let base_cfg = ClusterConfig::default(); // 22 machines, 40-core CPUs
    let f0 = base_cfg.sample_f0();
    let mut results = Vec::new();
    for policy in ["linux", "proposed"] {
        let cfg = ClusterConfig {
            policy: policy.into(),
            f0_override: Some(f0.clone()),
            ..base_cfg.clone()
        };
        // `Cluster::run` is wall-clock-free; callers that want wall time
        // stamp it themselves.
        let wall_start = std::time::Instant::now();
        let mut r = Cluster::new(cfg).run(&trace);
        r.wall_time_s = wall_start.elapsed().as_secs_f64();
        println!(
            "\n[{policy}] completed {} requests, {} events in {:.2}s wall",
            r.completed_requests, r.events_processed, r.wall_time_s
        );
        let e2e = r.e2e_summary();
        println!("  E2E latency p50/p99      {:.2} / {:.2} s", e2e.p50, e2e.p99);
        let fred = Summary::of(&r.mean_fred_per_machine());
        println!("  mean freq degradation    {:.2} MHz (p50 across machines)", fred.p50 * 1e3);
        let idle = Summary::of(&r.pooled_idle_samples());
        println!("  normalized idle p1/p90   {:.3} / {:.3}", idle.p1, idle.p90);
        results.push(r);
    }

    // 3. Embodied-carbon verdict (the paper's Fig. 7 arithmetic).
    let model = EmbodiedModel::paper_default();
    let linux_fred = results[0].mean_fred_per_machine();
    let prop_fred = results[1].mean_fred_per_machine();
    let base_p50 = stats::percentile(&linux_fred, 50.0);
    let tech_p50 = stats::percentile(&prop_fred, 50.0);
    println!(
        "\nembodied carbon: {:.2} -> {:.2} kgCO2eq/server/yr  ({:.1}% reduction @p50, lifetime {:.1}y -> {:.1}y)",
        model.yearly_kg(model.base_lifetime_yr),
        model.yearly_kg_for(base_p50, tech_p50),
        model.reduction_pct(base_p50, tech_p50),
        model.base_lifetime_yr,
        model.extended_lifetime_yr(base_p50, tech_p50),
    );
}
