//! Aging explorer: interactively inspect the NBTI + process-variation
//! substrate the whole paper rests on —
//!   (a) frequency-degradation curves under different duty schedules,
//!   (b) the effect of age halting (C6) vs merely unallocated cores,
//!   (c) a sampled process-variation chip map,
//!   (d) the PJRT aging_step artifact cross-check (if built).
//!
//! Run: `cargo run --release --example aging_explorer`

use carbon_sim::cpu::{
    aging::SECONDS_PER_YEAR, AgingOps, AgingParams, CState, Core, ProcVarParams, ProcVarSampler,
    TemperatureModel,
};
use carbon_sim::util::rng::Rng;
use carbon_sim::util::stats;

fn main() {
    let aging = AgingParams::paper_default();
    let temps = TemperatureModel::paper_default();

    println!("== (a) 10-year frequency loss vs duty schedule ==");
    println!("{:>6} {:>14} {:>14} {:>14} {:>14}", "year", "allocated(%)", "active-idle(%)", "50% C6(%)", "94% C6(%)");
    for year in [1, 2, 3, 5, 10] {
        let t = year as f64 * SECONDS_PER_YEAR;
        let adf_alloc = aging.adf(temps.steady_k(CState::C0, true), 1.0);
        let adf_sys = aging.adf(temps.steady_k(CState::C0, false), aging.unallocated_stress);
        let allocated = aging.rel_reduction(aging.dvth_step(0.0, adf_alloc, t));
        let active_idle = aging.rel_reduction(aging.dvth_step(0.0, adf_sys, t));
        let half = aging.rel_reduction(aging.dvth_step(0.0, adf_alloc, t * 0.5));
        let tiny = aging.rel_reduction(aging.dvth_step(0.0, adf_alloc, t * 0.06));
        println!(
            "{:>6} {:>14.2} {:>14.2} {:>14.2} {:>14.2}",
            year, allocated * 100.0, active_idle * 100.0, half * 100.0, tiny * 100.0
        );
    }
    println!("(30% at year 10 for the allocated column is the calibration datum)");

    println!("\n== (b) age halting vs even-out over one simulated month ==");
    let ops = AgingOps::new(&aging, &temps);
    let month = SECONDS_PER_YEAR / 12.0;
    let mut always_on = Core::new(0, 2.6);
    let mut halted = Core::new(1, 2.6);
    let steps = 1000;
    for i in 0..steps {
        let t0 = i as f64 * month / steps as f64;
        let t1 = (i + 1) as f64 * month / steps as f64;
        always_on.advance(t1, &ops);
        // `halted` spends 90% of each window in C6.
        halted.set_state(CState::C0, t0, &ops);
        halted.advance(t0 + 0.1 * (t1 - t0), &ops);
        halted.set_state(CState::C6, t0 + 0.1 * (t1 - t0), &ops);
        halted.advance(t1, &ops);
    }
    println!(
        "always-active core: -{:.1} MHz | 90%-halted core: -{:.1} MHz  ({:.1}x less aging)",
        always_on.freq_reduction_ghz(&ops) * 1e3,
        halted.freq_reduction_ghz(&ops) * 1e3,
        always_on.freq_reduction_ghz(&ops) / halted.freq_reduction_ghz(&ops)
    );

    println!("\n== (c) process-variation chip sample (40 cores) ==");
    let sampler = ProcVarSampler::new(ProcVarParams::paper_default());
    let f0 = sampler.sample_chip(&mut Rng::new(1234), 40);
    let s = stats::Summary::of(&f0);
    println!(
        "f0: mean {:.3} GHz, min {:.3}, max {:.3}, CV {:.3}%",
        s.mean,
        s.min,
        s.max,
        stats::coeff_of_variation(&f0) * 100.0
    );
    for row in 0..5 {
        let line: Vec<String> =
            (0..8).map(|c| format!("{:.2}", f0[row * 8 + c])).collect();
        println!("  {}", line.join(" "));
    }

    println!("\n== (d) PJRT aging_step cross-check ==");
    match pjrt_check() {
        Ok(err) => println!("rust vs Pallas-kernel artifact: max |Δf| = {err:.2e} GHz ✓"),
        Err(e) => println!("skipped ({e:#}) — run `make artifacts`"),
    }
}

fn pjrt_check() -> anyhow::Result<f64> {
    use carbon_sim::runtime::{AgingStepPjrt, Runtime};
    let dir = Runtime::default_artifacts_dir();
    anyhow::ensure!(Runtime::artifacts_available(&dir), "artifacts missing");
    let rt = Runtime::cpu(dir)?;
    let step = AgingStepPjrt::load(&rt)?;
    let aging = AgingParams::paper_default();
    let n = step.machines * step.cores;
    let mut rng = Rng::new(9);
    let dvth: Vec<f32> = (0..n).map(|_| rng.range_f64(0.0, 0.05) as f32).collect();
    let adf: Vec<f32> = (0..n).map(|_| rng.range_f64(0.001, 0.01) as f32).collect();
    let tau: Vec<f32> =
        (0..n).map(|_| if rng.bool(0.3) { 0.0 } else { rng.range_f64(1.0, 1e5) as f32 }).collect();
    let f0: Vec<f32> = (0..n).map(|_| rng.range_f64(2.4, 2.7) as f32).collect();
    let (new_dvth, freqs) = step.step(&dvth, &adf, &tau, &f0)?;
    let mut max_err = 0.0f64;
    for i in 0..n {
        let expect_dvth = if tau[i] > 0.0 {
            aging.dvth_step(dvth[i] as f64, adf[i] as f64, tau[i] as f64)
        } else {
            dvth[i] as f64
        };
        let expect_f = aging.freq_ghz(f0[i] as f64, expect_dvth);
        max_err = max_err.max((freqs[i] as f64 - expect_f).abs());
        max_err = max_err.max((new_dvth[i] as f64 - expect_dvth).abs());
    }
    anyhow::ensure!(max_err < 1e-4, "mismatch {max_err}");
    Ok(max_err)
}
