//! End-to-end driver (deliverable (b)/EXPERIMENTS.md §E2E): load the
//! AOT-compiled transformer and serve real batched requests through the
//! full three-layer stack —
//!
//!   L3 Rust server (router → dynamic batcher → PJRT worker, with the
//!      paper's core manager running live in shadow mode)
//!   L2 JAX transformer (prefill + decode graphs)
//!   L1 Pallas decode-attention kernel (lowered into the decode HLO)
//!
//! and report latency/throughput plus the shadow core-management stats.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example serve_llm [-- <n_requests> <max_new>]

use std::time::Instant;

use carbon_sim::runtime::Runtime;
use carbon_sim::serving::{ServeRequest, Server, ServerConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(24);
    let max_new: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(48);

    let dir = Runtime::default_artifacts_dir();
    if !Runtime::artifacts_available(&dir) {
        eprintln!("artifacts not found in {dir:?} — run `make artifacts` first");
        std::process::exit(1);
    }

    println!("loading model from {dir:?} ...");
    let server = Server::start(ServerConfig {
        policy: "proposed".into(),
        shadow_cores: 40,
        ..Default::default()
    })
    .expect("server start");

    let prompts = [
        "The inference cluster runs twenty-two machines with H100 GPUs.",
        "Aging-aware core management halts NBTI stress in idle cores.",
        "Selective core idling parks the most-aged cores first.",
        "Embodied carbon amortizes over the hardware refresh cycle.",
        "Dynamic batching groups requests inside a ten millisecond window.",
        "The reaction function reacts faster to oversubscription.",
    ];

    println!("submitting {n_requests} requests (max {max_new} new tokens each) ...");
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n_requests)
        .map(|i| {
            server.submit(ServeRequest {
                id: i as u64,
                prompt: prompts[i % prompts.len()].to_string(),
                max_new_tokens: max_new,
            })
        })
        .collect();
    let mut total_tokens = 0usize;
    for rx in rxs {
        let resp = rx.recv().expect("response");
        total_tokens += resp.generated_tokens;
        if resp.id < 4 {
            println!(
                "  req {:>3}: {:>3} prompt toks → {:>3} gen toks  ttft {:>7.1} ms  e2e {:>7.1} ms",
                resp.id,
                resp.prompt_tokens,
                resp.generated_tokens,
                resp.ttft_s * 1e3,
                resp.e2e_s * 1e3
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\nall {n_requests} requests served: {total_tokens} tokens in {wall:.2}s ({:.1} tok/s)\n",
        total_tokens as f64 / wall
    );
    server.shutdown().print();
}
