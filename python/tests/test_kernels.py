"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; every case asserts allclose against
ref.py. This is the core correctness signal for the kernel layer.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.aging_update import nbti_update
from compile.kernels.attention import decode_attention
from compile.kernels.ref import decode_attention_ref, freq_from_dvth_ref, nbti_update_ref

# ----------------------------------------------------------------- attention


def _attn_case(b, s, h, d, dtype, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    lengths = jnp.asarray(rng.integers(1, s + 1, size=(b,)), jnp.int32)
    return q, k, v, lengths


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    s=st.sampled_from([1, 2, 8, 17, 32]),
    h=st.integers(1, 4),
    d=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31),
)
def test_decode_attention_matches_ref(b, s, h, d, seed):
    q, k, v, lengths = _attn_case(b, s, h, d, jnp.float32, seed)
    out = decode_attention(q, k, v, lengths)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_dtypes(dtype):
    q, k, v, lengths = _attn_case(2, 16, 2, 8, dtype, 7)
    out = decode_attention(q, k, v, lengths)
    ref = decode_attention_ref(q, k, v, lengths)
    assert out.dtype == jnp.float32  # accumulates in f32
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)


def test_decode_attention_length_one_is_value():
    # With a single valid position, attention must return v[:, 0].
    q, k, v, _ = _attn_case(3, 8, 2, 4, jnp.float32, 1)
    lengths = jnp.ones((3,), jnp.int32)
    out = decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(out, np.swapaxes(np.asarray(v[:, 0]), 1, 1), rtol=1e-6)


def test_decode_attention_ignores_padding():
    # Garbage beyond `lengths` must not change the output.
    q, k, v, lengths = _attn_case(2, 16, 2, 8, jnp.float32, 3)
    out1 = decode_attention(q, k, v, lengths)
    mask = (np.arange(16)[None, :, None, None] >= np.asarray(lengths)[:, None, None, None])
    k2 = jnp.asarray(np.where(mask, 1e6, np.asarray(k)), jnp.float32)
    v2 = jnp.asarray(np.where(mask, -1e6, np.asarray(v)), jnp.float32)
    out2 = decode_attention(q, k2, v2, lengths)
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)


def test_decode_attention_probs_convexity():
    # Output is a convex combination of values: bounded by per-head extrema.
    q, k, v, lengths = _attn_case(2, 12, 3, 8, jnp.float32, 11)
    out = np.asarray(decode_attention(q, k, v, lengths))
    v_np = np.asarray(v)
    for b in range(2):
        valid = v_np[b, : int(lengths[b])]  # [s, h, d]
        assert (out[b] <= valid.max(axis=0) + 1e-5).all()
        assert (out[b] >= valid.min(axis=0) - 1e-5).all()


# ----------------------------------------------------------------- aging


def _aging_case(m, c, seed, frac_halted=0.3):
    rng = np.random.default_rng(seed)
    dvth = jnp.asarray(rng.uniform(0.0, 0.1, (m, c)), jnp.float32)
    adf = jnp.asarray(rng.uniform(1e-3, 1e-2, (m, c)), jnp.float32)
    tau = rng.uniform(0.1, 1e5, (m, c)) * (rng.uniform(size=(m, c)) > frac_halted)
    tau = jnp.asarray(tau, jnp.float32)
    f0 = jnp.asarray(rng.uniform(2.3, 2.8, (m, c)), jnp.float32)
    return dvth, adf, tau, f0


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 30),
    c=st.sampled_from([1, 8, 40, 80]),
    seed=st.integers(0, 2**31),
)
def test_nbti_update_matches_ref(m, c, seed):
    dvth, adf, tau, f0 = _aging_case(m, c, seed)
    new_dvth, f = nbti_update(dvth, adf, tau, f0)
    ref_dvth = nbti_update_ref(dvth, adf, tau, 1.0 / 6.0)
    ref_f = freq_from_dvth_ref(f0, ref_dvth, 1.0, 0.3)
    np.testing.assert_allclose(new_dvth, ref_dvth, rtol=1e-6)
    np.testing.assert_allclose(f, ref_f, rtol=1e-6)


def test_nbti_halted_cores_frozen():
    dvth, adf, _, f0 = _aging_case(4, 16, 5)
    tau = jnp.zeros((4, 16), jnp.float32)  # everything in C6
    new_dvth, f = nbti_update(dvth, adf, tau, f0)
    np.testing.assert_allclose(new_dvth, dvth, rtol=0, atol=0)
    np.testing.assert_allclose(f, freq_from_dvth_ref(f0, dvth, 1.0, 0.3), rtol=1e-6)


def test_nbti_monotone_in_tau():
    dvth, adf, _, _ = _aging_case(2, 8, 9)
    f0 = jnp.full((2, 8), 2.6, jnp.float32)
    tau_small = jnp.full((2, 8), 10.0, jnp.float32)
    tau_big = jnp.full((2, 8), 1e6, jnp.float32)
    d_small, f_small = nbti_update(dvth, adf, tau_small, f0)
    d_big, f_big = nbti_update(dvth, adf, tau_big, f0)
    assert (np.asarray(d_big) > np.asarray(d_small)).all()
    assert (np.asarray(f_big) < np.asarray(f_small)).all()


def test_nbti_composition_matches_single_step():
    # Two half-intervals == one full interval (the recursion's key law).
    dvth, adf, _, f0 = _aging_case(3, 10, 13, frac_halted=0.0)
    tau = jnp.full((3, 10), 5e4, jnp.float32)
    half, _ = nbti_update(dvth, adf, tau / 2, f0)
    twice, _ = nbti_update(half, adf, tau / 2, f0)
    once, _ = nbti_update(dvth, adf, tau, f0)
    np.testing.assert_allclose(twice, once, rtol=1e-4)
