"""L2 correctness: transformer shapes, masking, and the prefill/decode
consistency law (stepwise decode over the KV cache must reproduce the
full-sequence pass)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.model import ModelConfig, decode_step, init_params, param_spec, prefill


def tiny_cfg():
    return ModelConfig(vocab=32, d_model=32, n_heads=2, n_layers=2, d_ff=64, max_seq=16, batch=3)


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    params = init_params(cfg, seed=1)
    return cfg, params


def test_param_spec_matches_init(setup):
    cfg, params = setup
    spec = param_spec(cfg)
    assert len(spec) == len(params)
    for (name, shape), arr in zip(spec, params):
        assert arr.shape == tuple(shape), name
        assert arr.dtype == jnp.float32
    assert sum(int(np.prod(s)) for _, s in spec) == cfg.n_params()


def test_prefill_shapes(setup):
    cfg, params = setup
    tokens = jnp.zeros((cfg.batch, cfg.max_seq), jnp.int32)
    lengths = jnp.asarray([1, 5, 16], jnp.int32)
    logits, k, v = prefill(cfg, params, tokens, lengths)
    assert logits.shape == (cfg.batch, cfg.vocab)
    assert k.shape == (cfg.n_layers, cfg.batch, cfg.max_seq, cfg.n_heads, cfg.head_dim)
    assert v.shape == k.shape
    assert np.isfinite(np.asarray(logits)).all()


def test_prefill_padding_invariance(setup):
    # Tokens beyond `lengths` must not affect the last-position logits.
    cfg, params = setup
    rng = np.random.default_rng(0)
    base = rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.max_seq))
    lengths = jnp.asarray([4, 9, 12], jnp.int32)
    t1 = jnp.asarray(base, jnp.int32)
    garbage = base.copy()
    for b, ln in enumerate([4, 9, 12]):
        garbage[b, ln:] = rng.integers(0, cfg.vocab, size=cfg.max_seq - ln)
    t2 = jnp.asarray(garbage, jnp.int32)
    l1, _, _ = prefill(cfg, params, t1, lengths)
    l2, _, _ = prefill(cfg, params, t2, lengths)
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)


def test_decode_step_shapes(setup):
    cfg, params = setup
    kv_shape = (cfg.n_layers, cfg.batch, cfg.max_seq, cfg.n_heads, cfg.head_dim)
    k = jnp.zeros(kv_shape, jnp.float32)
    v = jnp.zeros(kv_shape, jnp.float32)
    tokens = jnp.zeros((cfg.batch,), jnp.int32)
    lengths = jnp.asarray([0, 3, 7], jnp.int32)
    logits, k2, v2 = decode_step(cfg, params, k, v, tokens, lengths)
    assert logits.shape == (cfg.batch, cfg.vocab)
    assert k2.shape == kv_shape and v2.shape == kv_shape


def test_decode_reproduces_prefill(setup):
    """Feeding tokens one by one through decode_step must produce the same
    final logits (and KV cache) as one prefill pass — the end-to-end law
    that guarantees the Rust serving stack's decode loop is sound."""
    cfg, params = setup
    rng = np.random.default_rng(42)
    seq_len = 6
    toks = rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.max_seq))
    tokens = jnp.asarray(toks, jnp.int32)
    lengths = jnp.full((cfg.batch,), seq_len, jnp.int32)
    pf_logits, pf_k, pf_v = prefill(cfg, params, tokens, lengths)

    kv_shape = (cfg.n_layers, cfg.batch, cfg.max_seq, cfg.n_heads, cfg.head_dim)
    k = jnp.zeros(kv_shape, jnp.float32)
    v = jnp.zeros(kv_shape, jnp.float32)
    logits = None
    for pos in range(seq_len):
        step_tokens = tokens[:, pos]
        step_lengths = jnp.full((cfg.batch,), pos, jnp.int32)
        logits, k, v = decode_step(cfg, params, k, v, step_tokens, step_lengths)

    np.testing.assert_allclose(logits, pf_logits, rtol=2e-4, atol=2e-4)
    # KV caches agree on the filled region.
    np.testing.assert_allclose(
        np.asarray(k)[:, :, :seq_len], np.asarray(pf_k)[:, :, :seq_len], rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(v)[:, :, :seq_len], np.asarray(pf_v)[:, :, :seq_len], rtol=2e-4, atol=2e-4
    )


def test_decode_batch_isolation(setup):
    # Changing sequence b's token must not change sequence b'!=b's logits.
    cfg, params = setup
    kv_shape = (cfg.n_layers, cfg.batch, cfg.max_seq, cfg.n_heads, cfg.head_dim)
    rng = np.random.default_rng(3)
    k = jnp.asarray(rng.normal(size=kv_shape), jnp.float32)
    v = jnp.asarray(rng.normal(size=kv_shape), jnp.float32)
    lengths = jnp.asarray([2, 4, 6], jnp.int32)
    t1 = jnp.asarray([1, 2, 3], jnp.int32)
    t2 = jnp.asarray([9, 2, 3], jnp.int32)  # only batch 0 differs
    l1, _, _ = decode_step(cfg, params, k, v, t1, lengths)
    l2, _, _ = decode_step(cfg, params, k, v, t2, lengths)
    assert not np.allclose(np.asarray(l1)[0], np.asarray(l2)[0])
    np.testing.assert_allclose(np.asarray(l1)[1:], np.asarray(l2)[1:], rtol=1e-6)


def test_decode_chunk_matches_stepwise(setup):
    """decode_chunk must reproduce n sequential decode_step calls,
    including per-slot budget freezing."""
    import jax
    from compile.model import decode_chunk

    cfg, params = setup
    rng = np.random.default_rng(5)
    kv_shape = (cfg.n_layers, cfg.batch, cfg.max_seq, cfg.n_heads, cfg.head_dim)
    k = jnp.asarray(rng.normal(size=kv_shape) * 0.1, jnp.float32)
    v = jnp.asarray(rng.normal(size=kv_shape) * 0.1, jnp.float32)
    tokens = jnp.asarray([3, 7, 11], jnp.int32)
    lengths = jnp.asarray([2, 4, 6], jnp.int32)
    remaining = jnp.asarray([5, 2, 0], jnp.int32)  # slot 2 already done
    n_steps = 4

    out, ck, cv_, clens, crem = decode_chunk(
        cfg, params, k, v, tokens, lengths, remaining, n_steps=n_steps
    )

    # Reference: sequential single steps with the same freeze logic.
    rk, rv, cur, lens, rem = k, v, tokens, lengths, remaining
    ref_out = np.full((cfg.batch, n_steps), -1, np.int32)
    for i in range(n_steps):
        logits, rk, rv = decode_step(cfg, params, rk, rv, cur, lens)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        active = np.asarray(rem) > 0
        nxt = np.where(active, nxt, np.asarray(cur))
        ref_out[:, i] = np.where(active, nxt, -1)
        lens = jnp.asarray(np.where(active, np.minimum(np.asarray(lens) + 1, cfg.max_seq - 1), np.asarray(lens)), jnp.int32)
        rem = jnp.asarray(np.where(active, np.asarray(rem) - 1, np.asarray(rem)), jnp.int32)
        cur = jnp.asarray(nxt, jnp.int32)

    np.testing.assert_array_equal(np.asarray(out), ref_out)
    np.testing.assert_array_equal(np.asarray(clens), np.asarray(lens))
    np.testing.assert_array_equal(np.asarray(crem), np.asarray(rem))
    np.testing.assert_allclose(np.asarray(ck), np.asarray(rk), rtol=1e-5, atol=1e-5)
    # Slot 2 never generated anything.
    assert (np.asarray(out)[2] == -1).all()
