"""AOT pipeline smoke tests: lowering emits parseable HLO text, the weight
export matches the manifest contract Rust relies on."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile.aot import export_weights, lower_aging, lower_model, to_hlo_text
from compile.model import ModelConfig, param_spec


def tiny_cfg():
    return ModelConfig(vocab=16, d_model=16, n_heads=2, n_layers=1, d_ff=32, max_seq=8, batch=2)


def test_lower_aging_emits_hlo_text():
    text = lower_aging(3, 4)
    assert "HloModule" in text
    assert "ENTRY" in text
    # 4 inputs, tuple of 2 outputs.
    assert "f32[3,4]" in text


def test_lower_model_emits_hlo_text():
    pf, dc, dck = lower_model(tiny_cfg())
    for text in (pf, dc, dck):
        assert "HloModule" in text and "ENTRY" in text
    # Decode signature includes the KV cache shape.
    assert "f32[1,2,8,2,8]" in dc
    assert "f32[1,2,8,2,8]" in dck


def test_export_weights_layout(tmp_path):
    cfg = tiny_cfg()
    table, total = export_weights(cfg, str(tmp_path), seed=0)
    spec = param_spec(cfg)
    assert len(table) == len(spec)
    assert total == cfg.n_params()
    data = np.fromfile(tmp_path / "weights.bin", dtype="<f4")
    assert data.size == total
    # Offsets are contiguous and ordered.
    off = 0
    for entry, (name, shape) in zip(table, spec):
        assert entry["name"] == name
        assert entry["offset"] == off
        off += int(np.prod(shape))
    # Norm gains are exported as ones (spot-check the contract).
    ln1 = next(e for e in table if e["name"].endswith("ln1"))
    chunk = data[ln1["offset"] : ln1["offset"] + ln1["shape"][0]]
    np.testing.assert_array_equal(chunk, np.ones_like(chunk))


def test_export_is_deterministic(tmp_path):
    cfg = tiny_cfg()
    export_weights(cfg, str(tmp_path), seed=0)
    a = np.fromfile(tmp_path / "weights.bin", dtype="<f4")
    export_weights(cfg, str(tmp_path), seed=0)
    b = np.fromfile(tmp_path / "weights.bin", dtype="<f4")
    np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_cli_end_to_end(tmp_path):
    """Run the module CLI as `make artifacts` does (small aging grid)."""
    env = dict(os.environ)
    repo_py = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--machines", "2", "--cores", "4"],
        check=True,
        cwd=repo_py,
        env=env,
    )
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    for name in manifest["artifacts"]:
        assert (tmp_path / name).exists(), name
    assert manifest["aging"] == {"machines": 2, "cores": 4, "n": 1.0 / 6.0,
                                 "vdd": 1.0, "vth": 0.3}
