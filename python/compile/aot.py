"""AOT pipeline: lower the L2 graphs to HLO **text** + export weights.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to --out-dir, default ../artifacts):
  prefill.hlo.txt     (params..., tokens[B,S], lengths[B]) -> (logits, k, v)
  decode.hlo.txt      (params..., k, v, tokens[B], lengths[B]) -> (logits, k', v')
  aging_step.hlo.txt  (dvth[M,C], adf, tau, f0) -> (dvth', f)
  weights.bin         all params, f32 little-endian, param_spec order
  manifest.json       config + param table (name/shape/offset) + aging dims

Usage: python -m compile.aot [--out-dir DIR] [--machines M] [--cores C]
"""

import argparse
import functools
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (
    ModelConfig,
    aging_step,
    decode_chunk,
    decode_step,
    init_params,
    param_spec,
    prefill,
)

#: Decode steps fused into one dispatch (§Perf L2 optimization).
DECODE_CHUNK = 8


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(cfg: ModelConfig):
    """Lower prefill + decode with concrete example shapes."""
    n_params = len(param_spec(cfg))
    p_spec = [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in param_spec(cfg)
    ]
    tokens_pf = jax.ShapeDtypeStruct((cfg.batch, cfg.max_seq), jnp.int32)
    tokens_dc = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    lengths = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    kv = jax.ShapeDtypeStruct(
        (cfg.n_layers, cfg.batch, cfg.max_seq, cfg.n_heads, cfg.head_dim), jnp.float32
    )

    def prefill_fn(*args):
        params = list(args[:n_params])
        tokens, lens = args[n_params], args[n_params + 1]
        return prefill(cfg, params, tokens, lens)

    def decode_fn(*args):
        params = list(args[:n_params])
        k, v, tokens, lens = args[n_params:]
        return decode_step(cfg, params, k, v, tokens, lens)

    def decode_chunk_fn(*args):
        params = list(args[:n_params])
        k, v, tokens, lens, rem = args[n_params:]
        return decode_chunk(cfg, params, k, v, tokens, lens, rem, n_steps=DECODE_CHUNK)

    pf = jax.jit(prefill_fn).lower(*p_spec, tokens_pf, lengths)
    dc = jax.jit(decode_fn).lower(*p_spec, kv, kv, tokens_dc, lengths)
    dck = jax.jit(decode_chunk_fn).lower(*p_spec, kv, kv, tokens_dc, lengths, lengths)
    return to_hlo_text(pf), to_hlo_text(dc), to_hlo_text(dck)


def lower_aging(machines: int, cores: int):
    spec = jax.ShapeDtypeStruct((machines, cores), jnp.float32)
    fn = functools.partial(aging_step)
    lowered = jax.jit(fn).lower(spec, spec, spec, spec)
    return to_hlo_text(lowered)


def export_weights(cfg: ModelConfig, out_dir: str, seed: int):
    params = init_params(cfg, seed=seed)
    table = []
    offset = 0
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for (name, shape), arr in zip(param_spec(cfg), params):
            data = np.asarray(arr, dtype="<f4")
            assert data.shape == tuple(shape)
            f.write(data.tobytes())
            table.append({"name": name, "shape": list(shape), "offset": offset})
            offset += data.size
    return table, offset


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--machines", type=int, default=22, help="aging grid: cluster machines")
    ap.add_argument("--cores", type=int, default=40, help="aging grid: cores per CPU")
    ap.add_argument("--seed", type=int, default=0, help="weight init seed")
    # Back-compat with the scaffold Makefile (`--out artifacts/model.hlo.txt`).
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    out_dir = os.path.abspath(out_dir)
    os.makedirs(out_dir, exist_ok=True)

    cfg = ModelConfig()
    print(f"model: {cfg.n_params()/1e6:.2f}M params, lowering prefill+decode ...")
    pf_text, dc_text, dck_text = lower_model(cfg)
    with open(os.path.join(out_dir, "prefill.hlo.txt"), "w") as f:
        f.write(pf_text)
    with open(os.path.join(out_dir, "decode.hlo.txt"), "w") as f:
        f.write(dc_text)
    with open(os.path.join(out_dir, "decode_chunk.hlo.txt"), "w") as f:
        f.write(dck_text)

    print(f"aging grid: {args.machines} x {args.cores}, lowering aging_step ...")
    ag_text = lower_aging(args.machines, args.cores)
    with open(os.path.join(out_dir, "aging_step.hlo.txt"), "w") as f:
        f.write(ag_text)

    print("exporting weights ...")
    table, total = export_weights(cfg, out_dir, args.seed)

    manifest = {
        "config": cfg.to_dict(),
        "decode_chunk": DECODE_CHUNK,
        "params": table,
        "total_floats": total,
        "aging": {"machines": args.machines, "cores": args.cores,
                  "n": 1.0 / 6.0, "vdd": 1.0, "vth": 0.3},
        "artifacts": ["prefill.hlo.txt", "decode.hlo.txt", "decode_chunk.hlo.txt",
                      "aging_step.hlo.txt", "weights.bin"],
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    sizes = {
        name: os.path.getsize(os.path.join(out_dir, name)) for name in manifest["artifacts"]
    }
    print("artifacts written to", out_dir)
    for name, size in sizes.items():
        print(f"  {name:<22} {size/1e6:8.2f} MB")


if __name__ == "__main__":
    main()
