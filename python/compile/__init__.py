"""Build-time compile path (L1 Pallas kernels + L2 JAX graphs + AOT).

Python runs ONCE at `make artifacts` and never on the request path: the
Rust coordinator loads the lowered HLO-text artifacts through PJRT.
"""
