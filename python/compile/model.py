"""L2: the JAX model graphs lowered to the AOT artifacts.

Two families:

1. A small GPT-style transformer served by the Rust stack — `prefill`
   (causal full-sequence pass producing the KV cache) and `decode_step`
   (single-token step whose attention is the L1 Pallas kernel, so the
   kernel lowers into the same HLO artifact).
2. `aging_step` — the cluster-wide batched NBTI update built on the
   `aging_update` Pallas kernel.

Weights are randomly initialized at AOT time with a fixed seed (no network
access to fetch published checkpoints — see DESIGN.md substitutions) and
exported to artifacts/weights.bin + manifest.json; the Rust runtime feeds
them back as PJRT execution arguments, exactly as a real serving system
feeds checkpoints.
"""

import numpy as np
import jax
import jax.numpy as jnp

from .kernels.attention import decode_attention

# ------------------------------------------------------------------ config


class ModelConfig:
    """Hyperparameters of the served transformer (GPT-style)."""

    def __init__(
        self,
        vocab=256,
        d_model=256,
        n_heads=4,
        n_layers=4,
        d_ff=1024,
        max_seq=128,
        batch=4,
    ):
        self.vocab = vocab
        self.d_model = d_model
        self.n_heads = n_heads
        self.n_layers = n_layers
        self.d_ff = d_ff
        self.max_seq = max_seq
        self.batch = batch
        assert d_model % n_heads == 0
        self.head_dim = d_model // n_heads

    def to_dict(self):
        return {
            "vocab": self.vocab,
            "d_model": self.d_model,
            "n_heads": self.n_heads,
            "n_layers": self.n_layers,
            "d_ff": self.d_ff,
            "max_seq": self.max_seq,
            "batch": self.batch,
        }

    def n_params(self):
        d, v, f = self.d_model, self.vocab, self.d_ff
        per_layer = 2 * d + 4 * d * d + 2 * d * f
        return v * d + self.max_seq * d + self.n_layers * per_layer + d


# ------------------------------------------------------------------ params


def param_spec(cfg):
    """Ordered (name, shape) list — the flattening contract with Rust."""
    spec = [("embed", (cfg.vocab, cfg.d_model)), ("pos", (cfg.max_seq, cfg.d_model))]
    for i in range(cfg.n_layers):
        spec += [
            (f"l{i}.ln1", (cfg.d_model,)),
            (f"l{i}.wq", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wk", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wv", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wo", (cfg.d_model, cfg.d_model)),
            (f"l{i}.ln2", (cfg.d_model,)),
            (f"l{i}.w1", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.w2", (cfg.d_ff, cfg.d_model)),
        ]
    spec.append(("lnf", (cfg.d_model,)))
    return spec


def init_params(cfg, seed=0):
    """Random init (fixed seed): list of f32 arrays matching param_spec."""
    rng = np.random.default_rng(seed)
    params = []
    for name, shape in param_spec(cfg):
        if name.endswith(("ln1", "ln2", "lnf")):
            arr = np.ones(shape, np.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            arr = rng.normal(0.0, fan_in**-0.5, size=shape).astype(np.float32)
        params.append(jnp.asarray(arr))
    return params


def _unpack(cfg, params):
    """params list -> (embed, pos, layers[...], lnf)."""
    it = iter(params)
    embed, pos = next(it), next(it)
    layers = []
    for _ in range(cfg.n_layers):
        layers.append(
            dict(
                ln1=next(it), wq=next(it), wk=next(it), wv=next(it), wo=next(it),
                ln2=next(it), w1=next(it), w2=next(it),
            )
        )
    lnf = next(it)
    return embed, pos, layers, lnf


def _rmsnorm(x, g):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * g


def _split_heads(x, cfg):
    # [..., d_model] -> [..., H, Dh]
    return x.reshape(x.shape[:-1] + (cfg.n_heads, cfg.head_dim))


# ------------------------------------------------------------------ prefill


def prefill(cfg, params, tokens, lengths):
    """Full-sequence causal pass.

    Args:
      tokens:  [B, S] int32 (padded with anything beyond lengths).
      lengths: [B] int32 valid lengths (1..S).

    Returns:
      (logits [B, vocab] at each sequence's last valid position,
       k_cache [L, B, S, H, Dh], v_cache [L, B, S, H, Dh])
    """
    embed, pos, layers, lnf = _unpack(cfg, params)
    b, s = tokens.shape
    x = embed[tokens] + pos[None, :s, :]
    causal = jnp.tril(jnp.ones((s, s), bool))
    pad = jnp.arange(s)[None, :] < lengths[:, None]  # [B, S] valid keys
    mask = causal[None, None, :, :] & pad[:, None, None, :]
    ks, vs = [], []
    for layer in layers:
        h = _rmsnorm(x, layer["ln1"])
        q = _split_heads(h @ layer["wq"], cfg)  # [B,S,H,Dh]
        k = _split_heads(h @ layer["wk"], cfg)
        v = _split_heads(h @ layer["wv"], cfg)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(cfg.head_dim)
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
        x = x + attn.reshape(b, s, cfg.d_model) @ layer["wo"]
        h2 = _rmsnorm(x, layer["ln2"])
        x = x + jax.nn.gelu(h2 @ layer["w1"]) @ layer["w2"]
        ks.append(k)
        vs.append(v)
    x = _rmsnorm(x, lnf)
    logits_all = x @ embed.T  # tied head: [B, S, V]
    last = jnp.clip(lengths - 1, 0, s - 1)
    logits = jnp.take_along_axis(logits_all, last[:, None, None], axis=1)[:, 0, :]
    k_cache = jnp.stack(ks)  # [L, B, S, H, Dh]
    v_cache = jnp.stack(vs)
    return logits, k_cache, v_cache


# ------------------------------------------------------------------ decode


def decode_step(cfg, params, k_cache, v_cache, tokens, lengths):
    """One decode step: append `tokens` at positions `lengths`, attend via
    the Pallas decode kernel over lengths+1 context, return next logits.

    Args:
      k_cache, v_cache: [L, B, S, H, Dh].
      tokens:  [B] int32 token to feed this step.
      lengths: [B] int32 current context length (the new token's position).

    Returns:
      (logits [B, vocab], new k_cache, new v_cache)
    """
    embed, pos, layers, lnf = _unpack(cfg, params)
    b = tokens.shape[0]
    positions = jnp.clip(lengths, 0, cfg.max_seq - 1)
    x = embed[tokens] + pos[positions]  # [B, d]
    new_ks, new_vs = [], []
    # Scatter via one-hot blend. (§Perf note: a per-sequence
    # dynamic_update_slice row-write was tried and measured *slower* on
    # the CPU backend — XLA materializes a full cache copy for the scatter
    # and loses the fusion it finds for the blend; see EXPERIMENTS.md.)
    onehot = jax.nn.one_hot(positions, cfg.max_seq, dtype=jnp.float32)  # [B, S]
    for li, layer in enumerate(layers):
        h = _rmsnorm(x, layer["ln1"])
        q = _split_heads(h @ layer["wq"], cfg)  # [B,H,Dh]
        k_new = _split_heads(h @ layer["wk"], cfg)  # [B,H,Dh]
        v_new = _split_heads(h @ layer["wv"], cfg)
        # Scatter the new K/V into position `lengths[b]` for each sequence.
        k_l = k_cache[li] * (1.0 - onehot[:, :, None, None]) + onehot[:, :, None, None] * k_new[:, None, :, :]
        v_l = v_cache[li] * (1.0 - onehot[:, :, None, None]) + onehot[:, :, None, None] * v_new[:, None, :, :]
        # L1 Pallas kernel: attend over the (lengths+1)-long context.
        attn = decode_attention(q, k_l, v_l, lengths + 1)  # [B,H,Dh]
        x = x + attn.reshape(b, cfg.d_model) @ layer["wo"]
        h2 = _rmsnorm(x, layer["ln2"])
        x = x + jax.nn.gelu(h2 @ layer["w1"]) @ layer["w2"]
        new_ks.append(k_l)
        new_vs.append(v_l)
    x = _rmsnorm(x, lnf)
    logits = x @ embed.T
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


# ------------------------------------------------------------------ aging


def aging_step(dvth, adf, tau, f0, n=1.0 / 6.0, vdd=1.0, vth=0.3):
    """Cluster-wide NBTI update (L1 kernel): see kernels/aging_update.py."""
    from .kernels.aging_update import nbti_update

    return nbti_update(dvth, adf, tau, f0, n=n, vdd=vdd, vth=vth)


# ------------------------------------------------------------ chunked decode


def decode_chunk(cfg, params, k_cache, v_cache, tokens, lengths, remaining, n_steps=8):
    """Run `n_steps` greedy decode steps inside one XLA computation.

    §Perf optimization: the PJRT runtime pays a host<->device KV-cache
    round trip per dispatch (this XLA build returns tuples as a single
    host-materialized buffer), so the serving stack decodes in chunks —
    one dispatch per `n_steps` tokens instead of per token.

    Slots with `remaining <= 0` are frozen: their length stops advancing,
    their cache position is rewritten harmlessly in place, and their
    output positions are filled with -1 sentinels.

    Returns:
      (out_tokens [B, n_steps] int32 (-1 where inactive),
       k_cache, v_cache, new_lengths, new_remaining)
    """
    def body(i, carry):
        k, v, cur, lens, rem, out = carry
        logits, k2, v2 = decode_step(cfg, params, k, v, cur, lens)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        active = rem > 0
        nxt = jnp.where(active, nxt, cur)
        out = jax.lax.dynamic_update_slice_in_dim(
            out, jnp.where(active, nxt, -1)[:, None], i, axis=1
        )
        lens2 = jnp.where(active, jnp.minimum(lens + 1, cfg.max_seq - 1), lens)
        rem2 = jnp.where(active, rem - 1, rem)
        return (k2, v2, nxt, lens2, rem2, out)

    out0 = jnp.full((cfg.batch, n_steps), -1, jnp.int32)
    k, v, cur, lens, rem, out = jax.lax.fori_loop(
        0, n_steps, body, (k_cache, v_cache, tokens, lengths, remaining, out0)
    )
    del cur
    return out, k, v, lens, rem
