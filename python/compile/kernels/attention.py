"""L1 Pallas kernel: single-step decode attention over a padded KV cache.

TPU-oriented design (see DESIGN.md §Hardware-Adaptation): the grid is
(batch,); each program instance streams one sequence's KV cache
HBM->VMEM through its BlockSpec and reduces **all heads at once** in a
single pass — the flash-attention decode pattern re-expressed as a
BlockSpec schedule instead of CUDA threadblocks, with the head dimension
vectorized onto the VPU/MXU lanes.

§Perf note (EXPERIMENTS.md): the first version used a (batch, heads)
grid, one head per program instance. Under interpret mode each instance
pays interpreter overhead, which dominated the decode step (11 ms of a
15 ms step at B=4, H=4). Folding heads into the instance (grid (B,),
4x fewer instances, head-vectorized math) cut the kernel to ~1/4 of
that with identical numerics — and is *also* the better real-TPU layout:
[S, H·Dh] tiles feed the MXU contraction directly.

VMEM footprint per program instance (budget, v5e ~16 MiB/core):
  q block   H * D floats          =   1 KiB (H=4, D=64, f32)
  k block   S * H * D floats      = 128 KiB (S=128)
  v block   S * H * D floats      = 128 KiB
well under budget; S can grow to ~8k before VMEM pressure.

On the CPU backend we must lower with interpret=True (real TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute); numerics
are identical, which is what python/tests asserts against ref.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_attn_kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref, *, seq_len):
    """One batch-row program instance.

    Block shapes:
      lengths_ref: [1]          (per-sequence valid length)
      q_ref:       [1, H, D]
      k_ref:       [1, S, H, D]
      v_ref:       [1, S, H, D]
      o_ref:       [1, H, D]
    """
    q = q_ref[0].astype(jnp.float32)  # [H, D]
    k = k_ref[0].astype(jnp.float32)  # [S, H, D]
    v = v_ref[0].astype(jnp.float32)  # [S, H, D]
    length = lengths_ref[0]

    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    # scores[h, s] = q[h, :] . k[s, h, :]
    scores = jnp.einsum("hd,shd->hs", q, k) * scale
    mask = (jnp.arange(seq_len) < length)[None, :]  # [1, S]
    scores = jnp.where(mask, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m) * mask  # [H, S]
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("hs,shd->hd", p, v) / denom  # [H, D]
    o_ref[0] = out


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k_cache, v_cache, lengths, interpret=True):
    """Pallas decode attention.

    Args:
      q:        [B, H, D] current-token queries.
      k_cache:  [B, S, H, D] padded key cache.
      v_cache:  [B, S, H, D] padded value cache.
      lengths:  [B] int32 valid lengths (>= 1).
      interpret: must stay True on CPU PJRT (Mosaic unavailable).

    Returns:
      [B, H, D] f32 attention outputs.
    """
    b, h, d = q.shape
    s = k_cache.shape[1]
    kernel = functools.partial(_decode_attn_kernel, seq_len=s)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),  # lengths[b]
            pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),  # q[b]
            pl.BlockSpec((1, s, h, d), lambda i: (i, 0, 0, 0)),  # k[b]
            pl.BlockSpec((1, s, h, d), lambda i: (i, 0, 0, 0)),  # v[b]
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)
