"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact counterpart here written
with plain jax.numpy. pytest (python/tests/test_kernels.py) sweeps shapes
and dtypes with hypothesis and asserts allclose between kernel and oracle —
this is the core correctness signal of the L1 layer.
"""

import jax.numpy as jnp

# ---------------------------------------------------------------- attention


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """Single-step decode attention over a padded KV cache.

    Args:
      q:        [B, H, D]   query for the current token.
      k_cache:  [B, S, H, D] keys   (only positions < lengths[b] are valid).
      v_cache:  [B, S, H, D] values.
      lengths:  [B] int32    valid context length per sequence.

    Returns:
      [B, H, D] attention output, f32.
    """
    q = q.astype(jnp.float32)
    k = k_cache.astype(jnp.float32)
    v = v_cache.astype(jnp.float32)
    d = q.shape[-1]
    # scores[b, h, s] = q[b, h, :] . k[b, s, h, :]
    scores = jnp.einsum("bhd,bshd->bhs", q, k) / jnp.sqrt(jnp.float32(d))
    s = k.shape[1]
    mask = jnp.arange(s)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs * mask
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhs,bshd->bhd", probs, v)


# ---------------------------------------------------------------- NBTI aging


def nbti_update_ref(dvth, adf, tau, n):
    """Reaction-diffusion NBTI recursion, vectorized over cores.

    dvth' = adf * ((dvth / adf)^(1/n) + tau)^n  where tau > 0,
    dvth' = dvth                                 where tau == 0 (age-halted).

    Args:
      dvth: [...] accumulated threshold-voltage shift (V).
      adf:  [...] aging-and-duty factor for the interval.
      tau:  [...] interval length in seconds (0 for C6 / frozen cores).
      n:    scalar time exponent (1/6).
    """
    dvth = dvth.astype(jnp.float32)
    adf = adf.astype(jnp.float32)
    tau = tau.astype(jnp.float32)
    eq_time = jnp.where(dvth > 0.0, (dvth / adf) ** (1.0 / n), 0.0)
    stepped = adf * (eq_time + tau) ** n
    return jnp.where(tau > 0.0, stepped, dvth)


def freq_from_dvth_ref(f0, dvth, vdd, vth):
    """f(t) = f0 * (1 - dvth / (vdd - vth))   — Eq. (1) of the paper."""
    return f0.astype(jnp.float32) * (1.0 - dvth.astype(jnp.float32) / (vdd - vth))
