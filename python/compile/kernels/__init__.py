"""L1 Pallas kernels + pure-jnp oracles.

`attention.decode_attention` — decode-step attention over a padded KV
cache (used by the L2 transformer's decode graph).
`aging_update.nbti_update` — cluster-wide batched NBTI aging update.
`ref` — jnp oracles both are tested against.
"""

from . import aging_update, attention, ref  # noqa: F401
