"""L1 Pallas kernel: batched NBTI aging update for a whole cluster.

The paper's periodic `adjust_sleeping_cores` pass is "an opportunity to
accurately calculate degraded core frequency due to aging" (Section 5).
This kernel performs that calculation for every core of every CPU in the
cluster in one shot: the reaction-diffusion recursion

    dvth' = ADF * ((dvth / ADF)^(1/n) + tau)^n     (tau > 0)
    dvth' = dvth                                   (tau = 0, age-halted C6)
    f     = f0 * (1 - dvth' / (Vdd - Vth))

vectorized over a [n_cpus, n_cores] state grid. The grid dimension is the
CPU (machine) index; each program instance updates one CPU's cores as a
VMEM-resident row — the natural TPU mapping of the paper's per-core loop
(VPU elementwise math, no MXU needed). interpret=True for CPU PJRT.

The Rust coordinator loads the lowered HLO (artifacts/aging_step.hlo.txt)
and can run its cluster-wide aging refresh through PJRT; the pure-Rust
implementation in `cpu::aging` is cross-validated against this kernel by
rust/tests/runtime_pjrt.rs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _aging_kernel(dvth_ref, adf_ref, tau_ref, f0_ref, dvth_out_ref, f_out_ref, *, n, vdd, vth):
    dvth = dvth_ref[...]
    adf = adf_ref[...]
    tau = tau_ref[...]
    f0 = f0_ref[...]
    eq_time = jnp.where(dvth > 0.0, (dvth / adf) ** (1.0 / n), 0.0)
    stepped = adf * (eq_time + tau) ** n
    new_dvth = jnp.where(tau > 0.0, stepped, dvth)
    dvth_out_ref[...] = new_dvth
    f_out_ref[...] = f0 * (1.0 - new_dvth / (vdd - vth))


@functools.partial(jax.jit, static_argnames=("n", "vdd", "vth", "interpret"))
def nbti_update(dvth, adf, tau, f0, n=1.0 / 6.0, vdd=1.0, vth=0.3, interpret=True):
    """Batched NBTI update.

    Args:
      dvth: [M, C] f32 accumulated threshold shifts (V).
      adf:  [M, C] f32 per-interval aging factors.
      tau:  [M, C] f32 interval lengths (s); 0 marks age-halted (C6) cores.
      f0:   [M, C] f32 initial (process-variation) frequencies (GHz).
      n, vdd, vth: model constants (static).

    Returns:
      (new_dvth [M, C], freq [M, C]) both f32.
    """
    m, c = dvth.shape
    kernel = functools.partial(_aging_kernel, n=n, vdd=vdd, vth=vth)
    row = pl.BlockSpec((1, c), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(m,),
        in_specs=[row, row, row, row],
        out_specs=[row, row],
        out_shape=[
            jax.ShapeDtypeStruct((m, c), jnp.float32),
            jax.ShapeDtypeStruct((m, c), jnp.float32),
        ],
        interpret=interpret,
    )(dvth, adf, tau, f0)
