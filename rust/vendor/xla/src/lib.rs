//! Stub of the `xla` PJRT FFI bindings.
//!
//! The offline build environment does not ship the XLA/PJRT shared
//! libraries, so this crate provides the exact type/method surface the
//! workspace uses (`PjRtClient`, `PjRtLoadedExecutable`, `PjRtBuffer`,
//! `Literal`, `HloModuleProto`, `XlaComputation`) with every runtime entry
//! point returning [`Error::unavailable`]. Code paths that need PJRT —
//! `carbon-sim serve`, `--pjrt-aging`, the artifact cross-validation
//! tests — degrade to a clear "PJRT unavailable" error instead of failing
//! to link; the pure-Rust simulator, the sweep engine, and every figure
//! runner never touch this crate at runtime.
//!
//! Dropping the real bindings in place of this stub requires no changes to
//! the callers: the signatures below mirror the real crate.

use std::fmt;

/// XLA/PJRT error type.
#[derive(Clone, Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT unavailable (carbon-sim was built against the vendored xla stub; \
             install the real XLA FFI bindings to enable this path)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// A host-side literal (tensor value).
#[derive(Clone, Debug)]
pub struct Literal {
    elems: usize,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(data: &[T]) -> Literal {
        Literal { elems: data.len() }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.elems {
            return Err(Error(format!("reshape {:?} incompatible with {} elements", dims, self.elems)));
        }
        Ok(self.clone())
    }

    pub fn element_count(&self) -> usize {
        self.elems
    }

    /// Split a 2-tuple literal.
    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(Error::unavailable("Literal::to_tuple2"))
    }

    /// Split a 3-tuple literal.
    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        Err(Error::unavailable("Literal::to_tuple3"))
    }

    /// Decompose an n-tuple literal.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::decompose_tuple"))
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!("parsing HLO text {path:?}")))
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with host-literal arguments.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }

    /// Execute with device-buffer arguments.
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// A PJRT client bound to a device plugin.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    /// Compile a computation for this client's device.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    /// Upload a host buffer to the device.
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT unavailable"), "{e}");
    }

    #[test]
    fn literal_shape_bookkeeping_works() {
        let l = Literal::vec1(&[0.0f32; 6]);
        assert_eq!(l.element_count(), 6);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
    }
}
