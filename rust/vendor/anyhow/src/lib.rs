//! Vendored, dependency-free reimplementation of the subset of the
//! `anyhow` API this workspace uses. The offline toolchain has no registry
//! access, so the real crate cannot be fetched; this shim keeps the same
//! call sites compiling and behaving equivalently:
//!
//! * [`Error`] — a context chain of messages (`{e}` prints the outermost
//!   context, `{e:#}` the full `outer: ...: root` chain, like anyhow).
//! * [`Result<T>`] — `Result<T, Error>` with a defaulted error parameter.
//! * [`Context`] — `.context(...)` / `.with_context(...)` on `Result` and
//!   `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the usual constructor macros.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what lets the blanket `From` conversion
//! and the `Context` impls coexist.

use std::fmt;

/// Error type: an ordered chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    fn wrap(mut self, context: String) -> Error {
        self.chain.insert(0, context);
        self
    }

    /// The context chain, outermost first (root cause last).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// Any `std::error::Error` converts into [`Error`], so `?` works on
/// `io::Error`, channel errors, the vendored `xla::Error`, etc.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::msg(error)
    }
}

/// `anyhow::Result<T>` — plain `Result` with the error defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    /// Sealed conversion helper: implemented for every std error AND for
    /// [`crate::Error`] itself (disjoint because `Error` is not a
    /// `std::error::Error`). This is what lets `.context(...)` apply to
    /// both `Result<_, io::Error>` and `Result<_, anyhow::Error>`.
    pub trait ToError {
        fn to_error(self) -> crate::Error;
    }

    impl<E> ToError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn to_error(self) -> crate::Error {
            crate::Error::msg(self)
        }
    }

    impl ToError for crate::Error {
        fn to_error(self) -> crate::Error {
            self
        }
    }
}

/// `.context(...)` / `.with_context(...)` extension trait.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: private::ToError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| private::ToError::to_error(e).wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| private::ToError::to_error(e).wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, format string, or error value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: `",
                ::std::stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::io::Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn context_chains_and_formats() {
        let e = io_fail().context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let e: Error = anyhow!("root {}", 7);
        let r: Result<()> = Err(e);
        let e2 = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e2:#}"), "outer: root 7");
        let o: Option<u32> = None;
        assert_eq!(format!("{}", o.context("missing").unwrap_err()), "missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        assert_eq!(format!("{}", inner().unwrap_err()), "gone");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 3);
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert!(format!("{}", f(3).unwrap_err()).contains("condition failed"));
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
    }
}
