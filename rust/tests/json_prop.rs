//! Round-trip fuzz tests for the dependency-free JSON module
//! (`util::json`). The spill/resume machinery and the streaming report
//! assembler both rest on serialize→parse→serialize being the identity —
//! including on the writer's non-finite extension tokens (`NaN`,
//! `Infinity`, `-Infinity`), deep nesting, escape-heavy strings, and
//! integers near the `u64` range. Comparisons use serialized strings,
//! not `Value == Value`: the derived `PartialEq` is false for NaN, which
//! is exactly the case the round trip must preserve.

use carbon_sim::util::json::{parse, Value};
use carbon_sim::util::proptest::{check, forall, Check, Gen};

/// A random string mixing plain ASCII with the characters the escaper
/// has to handle: quotes, backslashes, control characters, multibyte
/// and astral unicode.
fn gen_string(g: &mut Gen) -> String {
    const POOL: &[&str] = &[
        "a",
        "Z",
        "7",
        " ",
        "_",
        "\"",
        "\\",
        "/",
        "\n",
        "\t",
        "\r",
        "\u{8}",
        "\u{c}",
        "\u{1}",
        "\u{1f}",
        "é",
        "π",
        "字",
        "\u{1f600}",
        "\u{10ffff}",
        "\u{0}",
    ];
    let n = g.size(0, 12);
    (0..n).map(|_| POOL[g.rng.usize(POOL.len())]).collect()
}

/// A random number spanning the writer's three emission paths: integral
/// (printed as `i64`), general floats (shortest round-trip `{}`), and
/// the non-finite tokens. Includes the 1e15 integral cutoff, `u64`-range
/// magnitudes, subnormals, and negative zero.
fn gen_num(g: &mut Gen) -> f64 {
    match g.size(0, 9) {
        0 => f64::NAN,
        1 => f64::INFINITY,
        2 => f64::NEG_INFINITY,
        3 => -0.0,
        4 => g.rng.next_u64() as f64,
        5 => -(g.rng.next_u64() as f64),
        6 => g.f64(-1e18, 1e18).trunc(),
        7 => g.f64(-1.0, 1.0) * 1e-300,
        _ => g.f64(-1e6, 1e6),
    }
}

/// A random `Value` tree, depth-limited so case size stays bounded.
fn gen_value(g: &mut Gen, depth: usize) -> Value {
    let top = if depth == 0 { 3 } else { 5 };
    match g.size(0, top) {
        0 => Value::Null,
        1 => Value::Bool(g.bool()),
        2 => Value::Num(gen_num(g)),
        3 => Value::Str(gen_string(g)),
        4 => {
            let n = g.size(0, 4);
            Value::Arr((0..n).map(|_| gen_value(g, depth - 1)).collect())
        }
        _ => {
            let n = g.size(0, 4);
            // Duplicate random keys are fine: the BTreeMap keeps the
            // last one, and the round trip is checked on what remains.
            Value::Obj((0..n).map(|_| (gen_string(g), gen_value(g, depth - 1))).collect())
        }
    }
}

#[test]
fn compact_roundtrip_is_the_identity() {
    forall(400, 201, |g| {
        let v = gen_value(g, 4);
        let s1 = v.to_string_compact();
        let v2 = match parse(&s1) {
            Ok(v2) => v2,
            Err(e) => return Check::Fail(format!("parse failed: {e}\ninput: {s1}")),
        };
        let s2 = v2.to_string_compact();
        check(s1 == s2, format!("compact not a fixed point:\n{s1}\n{s2}"))
    });
}

#[test]
fn pretty_roundtrip_is_the_identity() {
    forall(400, 202, |g| {
        let v = gen_value(g, 4);
        let pretty = v.to_string_pretty();
        let v2 = match parse(&pretty) {
            Ok(v2) => v2,
            Err(e) => return Check::Fail(format!("parse failed: {e}\ninput: {pretty}")),
        };
        if v2.to_string_pretty() != pretty {
            return Check::Fail(format!("pretty not a fixed point:\n{pretty}"));
        }
        // Pretty and compact must describe the same value.
        let (c1, c2) = (v.to_string_compact(), v2.to_string_compact());
        check(c1 == c2, format!("pretty/compact disagree:\n{c1}\n{c2}"))
    });
}

#[test]
fn write_pretty_at_reparses_to_the_same_value() {
    forall(300, 203, |g| {
        let v = gen_value(g, 3);
        let indent = g.size(0, 4);
        let mut frag = String::new();
        v.write_pretty_at(&mut frag, indent);
        let v2 = match parse(&frag) {
            Ok(v2) => v2,
            Err(e) => {
                return Check::Fail(format!("fragment at indent {indent}: {e}\n{frag}"));
            }
        };
        check(
            v2.to_string_compact() == v.to_string_compact(),
            format!("fragment at indent {indent} changed the value:\n{frag}"),
        )
    });
}

#[test]
fn u64_range_integers_survive_the_integral_fast_path() {
    // The writer prints integral |x| < 1e15 through an `i64` cast; every
    // such value is exactly representable, so the round trip must be
    // bit-exact. Above the cutoff the shortest-round-trip `{}` path
    // takes over — still lossless for any finite f64.
    forall(600, 204, |g| {
        let x = gen_num(g);
        let v = Value::Num(x);
        let s = v.to_string_compact();
        let back = match parse(&s) {
            Ok(b) => b,
            Err(e) => return Check::Fail(format!("'{s}' unparseable: {e}")),
        };
        let y = match back.as_f64() {
            Some(y) => y,
            None => return Check::Fail(format!("'{s}' parsed to a non-number")),
        };
        // -0.0 legitimately collapses to 0 through the i64 fast path;
        // everything else must round-trip to the identical float (NaN
        // compared via serialization).
        let same = y.to_bits() == x.to_bits()
            || (x == 0.0 && y == 0.0)
            || (x.is_nan() && y.is_nan());
        check(same, format!("{x:?} -> '{s}' -> {y:?}"))
    });
}
