// simlint fixture: a pragma naming a rule that does not exist is a
// `simlint-pragma` finding and suppresses nothing. The file is
// otherwise violation-free, so exactly one finding must be reported.

fn compute() -> u64 {
    // simlint: allow(no-flaky-clocks) -- typo'd rule name
    41 + 1
}
