// simlint fixture: near-misses for `schema-version-sync` — must stay
// clean. Stamping the constant is the sanctioned idiom, and readers
// with integer defaults are not emitters.

fn to_json(&self) -> Value {
    Value::obj(vec![
        ("kind", "sweep-cells".into()),
        ("schema_version", OUTPUT_SCHEMA_VERSION.into()),
    ])
}

fn read_version(v: &Value) -> usize {
    v.usize_or("schema_version", 0)
}
