// simlint fixture: must trigger `no-map-iteration` (twice).
// Not compiled — only lexed by the lint pass.

use std::collections::{HashMap, HashSet};

struct Registry {
    by_id: HashMap<u64, String>,
}

impl Registry {
    fn dump(&self) -> Vec<String> {
        self.by_id.values().cloned().collect()
    }
}

fn total(seen: HashSet<u64>) -> u64 {
    let mut sum = 0;
    for v in &seen {
        sum += v;
    }
    sum
}
