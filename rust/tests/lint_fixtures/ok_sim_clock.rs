// simlint fixture: near-misses for `no-wall-clock` — must stay clean.
// A simulated clock's own `now()` is not a wall-clock read; the rule
// matches the `Instant::now` / `SystemTime::now` path shapes only.

struct SimClock {
    now_s: f64,
}

impl SimClock {
    fn now(&self) -> f64 {
        self.now_s
    }
}

fn sample(clock: &SimClock) -> f64 {
    // Instant::now() in a comment is invisible to the rules.
    clock.now()
}
