// simlint fixture: must trigger `no-stray-threads` (twice).
// Not compiled — only lexed by the lint pass.

use std::thread;

fn fan_out(jobs: Vec<u64>) {
    let handle = thread::spawn(move || jobs.len());
    handle.join().unwrap();
    thread::scope(|s| {
        let _ = s;
    });
}
