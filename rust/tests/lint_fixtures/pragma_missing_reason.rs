// simlint fixture: a pragma without the mandatory ` -- <reason>` is a
// `simlint-pragma` finding and suppresses nothing, so this file must
// report BOTH the malformed pragma and the `no-wall-clock` violation.

use std::time::Instant;

fn demo_latency() -> f64 {
    // simlint: allow(no-wall-clock)
    let t0 = Instant::now();
    run_demo();
    t0.elapsed().as_secs_f64()
}
