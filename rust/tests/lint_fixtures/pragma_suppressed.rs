// simlint fixture: a real violation under a well-formed suppression
// pragma — must stay clean (the pragma covers the line below it).

use std::time::Instant;

fn demo_latency() -> f64 {
    // simlint: allow(no-wall-clock) -- demo latency is the demo's output
    let t0 = Instant::now();
    run_demo();
    t0.elapsed().as_secs_f64()
}
