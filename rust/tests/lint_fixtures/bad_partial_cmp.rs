// simlint fixture: must trigger `no-float-partial-cmp` (twice).
// Not compiled — only lexed by the lint pass.

fn sort_scores(xs: &mut Vec<f64>) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

fn best(xs: &[f64]) -> Option<&f64> {
    xs.iter().max_by(|a, b| f64::partial_cmp(a, b).expect("no NaN"))
}
