// simlint fixture: must trigger `schema-version-sync` (emitter half) —
// a "schema_version" key stamped with a numeric literal instead of
// `experiments::OUTPUT_SCHEMA_VERSION`.

fn to_json(&self) -> Value {
    Value::obj(vec![
        ("kind", "sweep-cells".into()),
        ("schema_version", 5.into()),
        ("n_cells", self.n_cells.into()),
    ])
}
