// simlint fixture: near-misses for `no-float-partial-cmp` — must stay
// clean. Defining `fn partial_cmp` in a PartialOrd impl is not a call,
// and comment/string mentions are invisible to the rules.

use std::cmp::Ordering;

struct Wrapped(u64);

impl PartialOrd for Wrapped {
    // a.partial_cmp(b).unwrap() in a comment is not a call site.
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.0.cmp(&other.0))
    }
}

const HINT: &str = "never a.partial_cmp(b).unwrap() on floats";

fn sort_scores(xs: &mut Vec<f64>) {
    xs.sort_by(f64::total_cmp);
}
