// simlint fixture: near-misses for `no-map-iteration` — must stay
// clean. Keyed access on a hash map is allowed, and BTreeMap iteration
// is deterministic.

use std::collections::{BTreeMap, HashMap};

struct Tasks {
    task_core: HashMap<u64, usize>,
    ordered: BTreeMap<u64, usize>,
}

impl Tasks {
    fn lookup(&self, task: u64) -> Option<usize> {
        self.task_core.get(&task).copied()
    }

    fn assign(&mut self, task: u64, core: usize) {
        self.task_core.insert(task, core);
    }

    fn walk(&self) -> usize {
        let mut n = self.task_core.len();
        for (_, v) in &self.ordered {
            n += v;
        }
        n
    }
}
