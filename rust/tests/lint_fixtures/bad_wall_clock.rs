// simlint fixture: must trigger `no-wall-clock` (twice).
// Not compiled — only lexed by the lint pass.

use std::time::{Instant, SystemTime};

fn measure() -> f64 {
    let t0 = Instant::now();
    expensive();
    t0.elapsed().as_secs_f64()
}

fn stamp() -> SystemTime {
    SystemTime::now()
}
