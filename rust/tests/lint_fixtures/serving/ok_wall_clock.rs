// simlint fixture: a wall-clock read inside a `serving/` directory —
// allowlisted, must stay clean. The live serving stack measures real
// latency by design.

use std::time::Instant;

fn request_latency() -> f64 {
    let t0 = Instant::now();
    handle();
    t0.elapsed().as_secs_f64()
}
