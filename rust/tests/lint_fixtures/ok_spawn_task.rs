// simlint fixture: near-misses for `no-stray-threads` — must stay
// clean. `spawn_task` is a different identifier, and bare `spawn` not
// called as a method/path is not a spawn site.

struct Manager;

impl Manager {
    fn spawn_task(&mut self, task: u64) -> u64 {
        task
    }
}

fn drive(mgr: &mut Manager) {
    // thread::spawn in a comment is invisible to the rules.
    let spawn = 3;
    mgr.spawn_task(spawn);
}
