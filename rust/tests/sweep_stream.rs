//! Streaming-sweep contract tests: the disk-backed engine must produce
//! reports **byte-identical** to the in-memory engine at any thread
//! count, and an interrupted run resumed from its truncated
//! `cells.jsonl` must converge to the same bytes as an uninterrupted
//! run.

use std::fs;
use std::path::PathBuf;

use carbon_sim::experiments::sweep::{self, Format, SweepSpec};
use carbon_sim::experiments::sweep_stream::{self, CELLS_FILE};
use carbon_sim::trace::azure::Workload;

fn tiny_spec() -> SweepSpec {
    SweepSpec {
        rates: vec![5.0],
        core_counts: vec![8],
        policies: vec!["linux".into(), "least-aged".into(), "proposed".into()],
        workloads: vec![Workload::Mixed, Workload::Bursty],
        replicas: 1,
        duration_s: 4.0,
        n_prompt: 1,
        n_token: 2,
        seed: 77,
    }
}

/// Fresh scratch dir under the system temp root.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("carbon_sim_sweep_stream").join(name);
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn streamed_json_report_is_byte_identical_to_in_memory_at_any_thread_count() {
    let spec = tiny_spec();
    let expected = sweep::run(&spec, 1).unwrap().render(Format::Json);
    for threads in [1, 4] {
        let dir = scratch(&format!("json_t{threads}"));
        let s =
            sweep_stream::run_streaming(&spec, threads, &dir, Format::Json, false, false).unwrap();
        assert_eq!(s.n_cells, spec.n_cells());
        assert_eq!(s.n_run, spec.n_cells());
        assert_eq!(s.n_resumed, 0);
        let streamed = fs::read_to_string(&s.report_path).unwrap();
        assert_eq!(streamed, expected, "streamed JSON diverged at {threads} threads");
        // The spill holds one header plus one row per cell.
        let spill = fs::read_to_string(dir.join(CELLS_FILE)).unwrap();
        assert_eq!(spill.lines().count(), 1 + spec.n_cells());
        assert!(spill.lines().next().unwrap().contains(&spec.spec_hash()));
    }
}

#[test]
fn streamed_csv_report_is_byte_identical_to_in_memory() {
    let spec = tiny_spec();
    let expected = sweep::run(&spec, 1).unwrap().render(Format::Csv);
    let dir = scratch("csv");
    let s = sweep_stream::run_streaming(&spec, 3, &dir, Format::Csv, false, false).unwrap();
    assert_eq!(fs::read_to_string(&s.report_path).unwrap(), expected);
}

#[test]
fn resume_after_interrupt_skips_done_cells_and_matches_uninterrupted_bytes() {
    let spec = tiny_spec();
    let n = spec.n_cells();

    // Uninterrupted reference run.
    let ref_dir = scratch("resume_ref");
    let r = sweep_stream::run_streaming(&spec, 2, &ref_dir, Format::Json, false, false).unwrap();
    let expected = fs::read(&r.report_path).unwrap();

    // "Interrupted" run: keep the header + the first k completed rows and
    // a half-written in-flight line, exactly what a kill leaves behind.
    let dir = scratch("resume_cut");
    sweep_stream::run_streaming(&spec, 2, &dir, Format::Json, false, false).unwrap();
    let cells_path = dir.join(CELLS_FILE);
    let full = fs::read_to_string(&cells_path).unwrap();
    let lines: Vec<&str> = full.lines().collect();
    assert_eq!(lines.len(), 1 + n);
    let k = 2;
    let mut cut: String =
        lines[..1 + k].iter().map(|l| format!("{l}\n")).collect();
    cut.push_str("{\"index\": 999, \"truncated in-fli"); // no trailing newline
    fs::write(&cells_path, cut).unwrap();
    fs::remove_file(dir.join("report.json")).unwrap();

    let s = sweep_stream::run_streaming(&spec, 2, &dir, Format::Json, true, false).unwrap();
    assert_eq!(s.n_resumed, k, "resume must skip exactly the intact rows");
    assert_eq!(s.n_run, n - k);
    assert_eq!(
        fs::read(&s.report_path).unwrap(),
        expected,
        "resumed report must be byte-identical to an uninterrupted run"
    );
    // The compacted spill is complete again.
    let spill = fs::read_to_string(&cells_path).unwrap();
    assert_eq!(spill.lines().count(), 1 + n);
}

#[test]
fn resume_with_a_different_spec_is_refused() {
    let spec = tiny_spec();
    let dir = scratch("resume_wrong_spec");
    sweep_stream::run_streaming(&spec, 1, &dir, Format::Json, false, false).unwrap();
    let mut other = tiny_spec();
    other.seed = 78;
    let err =
        sweep_stream::run_streaming(&other, 1, &dir, Format::Json, true, false).unwrap_err();
    assert!(err.contains("hash mismatch"), "{err}");
}

#[test]
fn resume_on_a_complete_spill_runs_nothing_and_reproduces_the_report() {
    let spec = tiny_spec();
    let dir = scratch("resume_noop");
    let first = sweep_stream::run_streaming(&spec, 2, &dir, Format::Json, false, false).unwrap();
    let expected = fs::read(&first.report_path).unwrap();
    let again = sweep_stream::run_streaming(&spec, 2, &dir, Format::Json, true, false).unwrap();
    assert_eq!(again.n_run, 0);
    assert_eq!(again.n_resumed, spec.n_cells());
    assert_eq!(fs::read(&again.report_path).unwrap(), expected);
}

#[test]
fn resume_into_an_empty_dir_just_runs_everything() {
    let spec = tiny_spec();
    let dir = scratch("resume_fresh");
    let s = sweep_stream::run_streaming(&spec, 2, &dir, Format::Json, true, false).unwrap();
    assert_eq!(s.n_run, spec.n_cells());
    assert_eq!(s.n_resumed, 0);
}

#[test]
fn assemble_refuses_an_incomplete_spill() {
    let spec = tiny_spec();
    let dir = scratch("assemble_incomplete");
    sweep_stream::run_streaming(&spec, 1, &dir, Format::Json, false, false).unwrap();
    let cells_path = dir.join(CELLS_FILE);
    let full = fs::read_to_string(&cells_path).unwrap();
    let cut: String = full.lines().take(2).map(|l| format!("{l}\n")).collect();
    fs::write(&cells_path, cut).unwrap();
    let err = sweep_stream::assemble_report(
        &cells_path,
        &spec,
        Format::Json,
        &dir.join("report2.json"),
    )
    .unwrap_err();
    assert!(err.contains("--resume"), "{err}");
}
