//! Streaming-sweep contract tests: the disk-backed engine must produce
//! reports **byte-identical** to the in-memory engine at any thread
//! count, an interrupted run resumed from its truncated `cells.jsonl`
//! must converge to the same bytes as an uninterrupted run, and
//! non-finite metric values must survive the spill round-trip losslessly
//! (a NaN rewritten as `null` would silently diverge the resumed report
//! from the in-memory path).

use std::fs;
use std::path::PathBuf;

use carbon_sim::experiments::sweep::{self, Format, ShardSpec, SweepSpec};
use carbon_sim::experiments::sweep_stream::{self, CELLS_FILE};
use carbon_sim::trace::azure::Workload;
use carbon_sim::util::json::{parse, Value};

fn tiny_spec() -> SweepSpec {
    SweepSpec {
        rates: vec![5.0],
        core_counts: vec![8],
        policies: vec!["linux".into(), "least-aged".into(), "proposed".into()],
        workloads: vec![Workload::Mixed, Workload::Bursty],
        replicas: 1,
        duration_s: 4.0,
        n_prompt: 1,
        n_token: 2,
        seed: 77,
        fleet: None,
        lifecycle: None,
    }
}

/// Fresh scratch dir under the system temp root.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("carbon_sim_sweep_stream").join(name);
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run the full (unsharded) grid through the streaming engine.
fn stream_full(
    spec: &SweepSpec,
    threads: usize,
    dir: &std::path::Path,
    format: Format,
    resume: bool,
) -> Result<sweep_stream::StreamSummary, String> {
    sweep_stream::run_streaming(spec, threads, dir, &ShardSpec::full(), format, resume, false)
}

#[test]
fn streamed_json_report_is_byte_identical_to_in_memory_at_any_thread_count() {
    let spec = tiny_spec();
    let expected = sweep::run(&spec, 1).unwrap().render(Format::Json);
    for threads in [1, 4] {
        let dir = scratch(&format!("json_t{threads}"));
        let s = stream_full(&spec, threads, &dir, Format::Json, false).unwrap();
        assert_eq!(s.n_cells, spec.n_cells());
        assert_eq!(s.n_run, spec.n_cells());
        assert_eq!(s.n_resumed, 0);
        let report_path = s.report_path.expect("full run assembles a report");
        let streamed = fs::read_to_string(&report_path).unwrap();
        assert_eq!(streamed, expected, "streamed JSON diverged at {threads} threads");
        // The spill holds one header plus one row per cell.
        let spill = fs::read_to_string(dir.join(CELLS_FILE)).unwrap();
        assert_eq!(spill.lines().count(), 1 + spec.n_cells());
        assert!(spill.lines().next().unwrap().contains(&spec.spec_hash()));
    }
}

#[test]
fn streamed_csv_report_is_byte_identical_to_in_memory() {
    let spec = tiny_spec();
    let expected = sweep::run(&spec, 1).unwrap().render(Format::Csv);
    let dir = scratch("csv");
    let s = stream_full(&spec, 3, &dir, Format::Csv, false).unwrap();
    assert_eq!(fs::read_to_string(s.report_path.unwrap()).unwrap(), expected);
}

#[test]
fn spill_header_embeds_the_spec_and_reparses_to_the_same_grid() {
    let spec = tiny_spec();
    let dir = scratch("header_spec");
    stream_full(&spec, 2, &dir, Format::Json, false).unwrap();
    let spill = fs::read_to_string(dir.join(CELLS_FILE)).unwrap();
    let header = parse(spill.lines().next().unwrap()).unwrap();
    assert_eq!(
        header.usize_or("schema_version", 0),
        carbon_sim::experiments::OUTPUT_SCHEMA_VERSION
    );
    // The embedded spec reconstructs the exact grid (spills are
    // self-contained: `merge` needs no --spec file).
    let embedded = header.get("spec").expect("header embeds the spec");
    let rebuilt = carbon_sim::config::sweep_from_value(embedded).unwrap();
    assert_eq!(rebuilt.spec_hash(), spec.spec_hash());
    // Unsharded spills carry no shard fields (backward-compatible form).
    assert!(header.get("shard_index").is_none());
    assert!(header.get("shard_count").is_none());
}

#[test]
fn resume_after_interrupt_skips_done_cells_and_matches_uninterrupted_bytes() {
    let spec = tiny_spec();
    let n = spec.n_cells();

    // Uninterrupted reference run.
    let ref_dir = scratch("resume_ref");
    let r = stream_full(&spec, 2, &ref_dir, Format::Json, false).unwrap();
    let expected = fs::read(r.report_path.unwrap()).unwrap();

    // "Interrupted" run: keep the header + the first k completed rows and
    // a half-written in-flight line, exactly what a kill leaves behind.
    let dir = scratch("resume_cut");
    stream_full(&spec, 2, &dir, Format::Json, false).unwrap();
    let cells_path = dir.join(CELLS_FILE);
    let full = fs::read_to_string(&cells_path).unwrap();
    let lines: Vec<&str> = full.lines().collect();
    assert_eq!(lines.len(), 1 + n);
    let k = 2;
    let mut cut: String =
        lines[..1 + k].iter().map(|l| format!("{l}\n")).collect();
    cut.push_str("{\"index\": 999, \"truncated in-fli"); // no trailing newline
    fs::write(&cells_path, cut).unwrap();
    fs::remove_file(dir.join("report.json")).unwrap();

    let s = stream_full(&spec, 2, &dir, Format::Json, true).unwrap();
    assert_eq!(s.n_resumed, k, "resume must skip exactly the intact rows");
    assert_eq!(s.n_run, n - k);
    assert_eq!(
        fs::read(s.report_path.unwrap()).unwrap(),
        expected,
        "resumed report must be byte-identical to an uninterrupted run"
    );
    // The compacted spill is complete again.
    let spill = fs::read_to_string(&cells_path).unwrap();
    assert_eq!(spill.lines().count(), 1 + n);
}

#[test]
fn resume_with_a_different_spec_is_refused() {
    let spec = tiny_spec();
    let dir = scratch("resume_wrong_spec");
    stream_full(&spec, 1, &dir, Format::Json, false).unwrap();
    let mut other = tiny_spec();
    other.seed = 78;
    let err = stream_full(&other, 1, &dir, Format::Json, true).unwrap_err();
    assert!(err.contains("hash mismatch"), "{err}");
}

#[test]
fn resume_on_a_complete_spill_runs_nothing_and_reproduces_the_report() {
    let spec = tiny_spec();
    let dir = scratch("resume_noop");
    let first = stream_full(&spec, 2, &dir, Format::Json, false).unwrap();
    let expected = fs::read(first.report_path.unwrap()).unwrap();
    let again = stream_full(&spec, 2, &dir, Format::Json, true).unwrap();
    assert_eq!(again.n_run, 0);
    assert_eq!(again.n_resumed, spec.n_cells());
    assert_eq!(fs::read(again.report_path.unwrap()).unwrap(), expected);
}

#[test]
fn resume_into_an_empty_dir_just_runs_everything() {
    let spec = tiny_spec();
    let dir = scratch("resume_fresh");
    let s = stream_full(&spec, 2, &dir, Format::Json, true).unwrap();
    assert_eq!(s.n_run, spec.n_cells());
    assert_eq!(s.n_resumed, 0);
}

#[test]
fn assemble_refuses_an_incomplete_spill() {
    let spec = tiny_spec();
    let dir = scratch("assemble_incomplete");
    stream_full(&spec, 1, &dir, Format::Json, false).unwrap();
    let cells_path = dir.join(CELLS_FILE);
    let full = fs::read_to_string(&cells_path).unwrap();
    let cut: String = full.lines().take(2).map(|l| format!("{l}\n")).collect();
    fs::write(&cells_path, cut).unwrap();
    let err = sweep_stream::assemble_report(
        &cells_path,
        &spec,
        Format::Json,
        &dir.join("report2.json"),
    )
    .unwrap_err();
    assert!(err.contains("--resume"), "{err}");
}

/// Inject a non-finite value into one spill row's metric field and
/// re-serialize the row compactly (what a run whose cell produced that
/// value would have written).
fn poison_row(cells_path: &std::path::Path, field: &str, value: f64) {
    let full = fs::read_to_string(cells_path).unwrap();
    let mut lines: Vec<String> = full.lines().map(|l| l.to_string()).collect();
    let row = parse(&lines[1]).unwrap();
    let mut obj = match row {
        Value::Obj(o) => o,
        _ => panic!("spill row must be an object"),
    };
    assert!(obj.contains_key(field), "row has no field '{field}'");
    obj.insert(field.to_string(), Value::Num(value));
    lines[1] = Value::Obj(obj).to_string_compact();
    fs::write(cells_path, lines.join("\n") + "\n").unwrap();
}

#[test]
fn nonfinite_metrics_roundtrip_through_spill_and_reports_losslessly() {
    let spec = tiny_spec();
    let dir = scratch("nan_roundtrip");
    stream_full(&spec, 1, &dir, Format::Json, false).unwrap();
    let cells_path = dir.join(CELLS_FILE);
    poison_row(&cells_path, "ttft_p99_s", f64::NAN);

    // JSON: the assembled report carries the NaN token, and this crate's
    // parser restores it as a NaN number — not null, not a string.
    let json_path = dir.join("report_nan.json");
    sweep_stream::assemble_report(&cells_path, &spec, Format::Json, &json_path).unwrap();
    let body = fs::read_to_string(&json_path).unwrap();
    assert!(body.contains("\"ttft_p99_s\": NaN"), "{body}");
    let v = parse(&body).unwrap();
    let cell = &v.get("cells").unwrap().as_arr().unwrap()[0];
    assert!(cell.get("ttft_p99_s").unwrap().as_f64().unwrap().is_nan());

    // And a second spill round-trip of the same row is byte-stable (the
    // property `null`-rewriting used to break).
    let again = dir.join("report_nan2.json");
    sweep_stream::assemble_report(&cells_path, &spec, Format::Json, &again).unwrap();
    assert_eq!(fs::read(&json_path).unwrap(), fs::read(&again).unwrap());

    // CSV: the NaN lands as a bare NaN field in the right column.
    poison_row(&cells_path, "idle_p50", f64::NEG_INFINITY);
    let csv_path = dir.join("report_nan.csv");
    sweep_stream::assemble_report(&cells_path, &spec, Format::Csv, &csv_path).unwrap();
    let csv = fs::read_to_string(&csv_path).unwrap();
    let header: Vec<&str> = csv.lines().next().unwrap().split(',').collect();
    let row: Vec<&str> = csv.lines().nth(1).unwrap().split(',').collect();
    assert_eq!(row.len(), header.len());
    let ttft_col = header.iter().position(|&c| c == "ttft_p99_s").unwrap();
    let idle_col = header.iter().position(|&c| c == "idle_p50").unwrap();
    assert_eq!(row[ttft_col], "NaN");
    assert_eq!(row[idle_col], "-Infinity");
}
