//! Sweep-level byte-identity across queue implementations.
//!
//! The queue kind is an execution detail: it appears nowhere in the
//! sweep spec, the spec hash, or the report JSON, and the simulation it
//! drives is event-for-event identical (pinned by
//! `tests/queue_differential.rs`). Therefore a sweep report rendered
//! under `--queue heap` must be **byte-identical** to one rendered under
//! `--queue calendar`, at any thread count — the same guarantee the
//! engine already makes across thread counts, extended across
//! schedulers. CI enforces the same property end-to-end with a `cmp` of
//! two `carbon-sim sweep` runs.

use carbon_sim::experiments::sweep::{self, Format, SweepSpec};
use carbon_sim::sim::QueueKind;

#[test]
fn smoke_sweep_reports_are_byte_identical_across_queues_and_threads() {
    let spec = SweepSpec { duration_s: 4.0, ..SweepSpec::smoke() };
    let baseline = sweep::run_with_queue(&spec, 1, QueueKind::Heap)
        .expect("heap sweep runs")
        .render(Format::Json);
    assert!(baseline.contains("\"cells\""), "report looks wrong:\n{baseline}");
    for (threads, queue) in
        [(1, QueueKind::Calendar), (4, QueueKind::Calendar), (4, QueueKind::Heap)]
    {
        let report = sweep::run_with_queue(&spec, threads, queue)
            .expect("sweep runs")
            .render(Format::Json);
        assert_eq!(
            baseline, report,
            "report under {queue:?} @ {threads} thread(s) diverged from heap @ 1 thread"
        );
    }
}

#[test]
fn csv_rendering_is_also_queue_invariant() {
    let spec = SweepSpec { duration_s: 4.0, ..SweepSpec::smoke() };
    let heap = sweep::run_with_queue(&spec, 2, QueueKind::Heap)
        .expect("heap sweep runs")
        .render(Format::Csv);
    let cal = sweep::run_with_queue(&spec, 2, QueueKind::Calendar)
        .expect("calendar sweep runs")
        .render(Format::Csv);
    assert_eq!(heap, cal);
}
