//! Property tests for the package's structure-of-arrays fast path.
//!
//! Two contracts the SoA layout must uphold under *arbitrary* schedules,
//! not just the hand-picked ones in the unit tests:
//!
//! 1. **Aging parity.** Random interleavings of assign / release / C6
//!    park / wake / Algorithm-2 adjusts must keep every core's lazy ΔVth
//!    snapshot within 1e-12 relative of the closed-form
//!    `AgingParams::dvth_step` recursion applied interval-by-interval.
//! 2. **FIFO oversubscription.** Under random arrivals and random
//!    (including mid-queue) finishes, promotion to dedicated cores must
//!    follow arrival order exactly — the regression `swap_remove_back`
//!    broke.

use std::collections::VecDeque;

use carbon_sim::cpu::{AgingParams, CState, CpuPackage, TemperatureModel};
use carbon_sim::policy::{by_name, CoreManager, CorePolicy};
use carbon_sim::util::proptest::{check, forall, Check};
use carbon_sim::util::rng::Rng;

fn pkg(n: usize) -> CpuPackage {
    CpuPackage::uniform(n, AgingParams::paper_default(), TemperatureModel::paper_default())
}

/// Advance the scalar reference model to `now`: one `dvth_step` per core
/// at the operating point the core held since the last advance.
fn advance_reference(cpu: &CpuPackage, ref_dvth: &mut [f64], last_t: &mut f64, now: f64) {
    let tau = now - *last_t;
    if tau <= 0.0 {
        return;
    }
    for core in cpu.core_views() {
        let i = core.id();
        match core.state() {
            CState::C6 => {} // age-halted: ΔVth frozen
            CState::C0 => {
                let adf = if core.is_allocated() {
                    cpu.ops.adf_alloc
                } else {
                    cpu.ops.adf_unalloc
                };
                ref_dvth[i] = cpu.aging.dvth_step(ref_dvth[i], adf, tau);
            }
        }
    }
    *last_t = now;
}

#[test]
fn random_schedules_keep_dvth_within_1e12_of_closed_form() {
    forall(60, 0x50A, |g| {
        let n = g.size(2, 24).max(2);
        let mut cpu = pkg(n);
        let mut policy = by_name("proposed").unwrap();
        let mut ref_dvth = vec![0.0f64; n];
        let mut ref_t = 0.0f64;
        let mut now = 0.0f64;
        let mut live: Vec<u64> = Vec::new();
        let mut next_task = 0u64;
        for _ in 0..g.size(20, 120) {
            now += g.f64(0.0, 3600.0);
            // The reference integrates the interval at the *pre-mutation*
            // operating points, exactly like the package's lazy advances.
            advance_reference(&cpu, &mut ref_dvth, &mut ref_t, now);
            match g.size(0, 9) {
                0..=3 => {
                    // Assign a task to a random free active core.
                    let free = cpu.free_active_count();
                    if free > 0 {
                        let k = g.size(0, free - 1);
                        let c = cpu.free_active_cores().nth(k).unwrap().id();
                        cpu.assign(c, next_task, now);
                        live.push(next_task);
                        next_task += 1;
                    }
                }
                4..=6 => {
                    // Release a random live task.
                    if !live.is_empty() {
                        let idx = g.size(0, live.len() - 1);
                        let t = live.swap_remove(idx);
                        cpu.finish_task(t, now);
                    }
                }
                7 => {
                    // Park a random free active core.
                    let frees: Vec<usize> = cpu.free_active_cores().map(|c| c.id()).collect();
                    if !frees.is_empty() {
                        let c = frees[g.size(0, frees.len() - 1)];
                        cpu.set_state(c, CState::C6, now);
                    }
                }
                8 => {
                    // Wake a random sleeper.
                    let sleepers: Vec<usize> = cpu
                        .core_views()
                        .filter(|c| c.state() == CState::C6)
                        .map(|c| c.id())
                        .collect();
                    if !sleepers.is_empty() {
                        let c = sleepers[g.size(0, sleepers.len() - 1)];
                        cpu.set_state(c, CState::C0, now);
                    }
                }
                _ => policy.adjust(&mut cpu, now),
            }
        }
        now += g.f64(0.0, 3600.0);
        advance_reference(&cpu, &mut ref_dvth, &mut ref_t, now);
        cpu.advance_all(now);
        for core in cpu.core_views() {
            let fast = core.dvth();
            let reference = ref_dvth[core.id()];
            let err = (fast - reference).abs();
            if err > 1e-12 * reference.max(1e-15) {
                return Check::Fail(format!(
                    "core {}: fast dvth {fast} vs reference {reference} (err {err:e})",
                    core.id()
                ));
            }
        }
        Check::Pass
    });
}

#[test]
fn oversub_promotion_follows_arrival_order_under_random_finishes() {
    forall(150, 0xF1F0, |g| {
        let n = g.size(1, 4).max(1);
        let cpu = pkg(n);
        let mut m = CoreManager::new(cpu, by_name("linux").unwrap(), Rng::new(17));
        // Reference model: pinned tasks (any order) + a strict FIFO queue.
        let mut running: Vec<u64> = Vec::new();
        let mut queued: VecDeque<u64> = VecDeque::new();
        let mut next_task = 0u64;
        let mut now = 0.0f64;
        for _ in 0..g.size(10, 150) {
            now += g.f64(0.0, 0.5);
            let total = running.len() + queued.len();
            if total == 0 || g.size(0, 9) < 6 {
                // Arrival: runs immediately iff a free active core exists.
                let will_queue = !m.cpu.has_free_active_core();
                m.start_task(next_task, now);
                if will_queue {
                    queued.push_back(next_task);
                } else {
                    running.push(next_task);
                }
                next_task += 1;
            } else {
                // Finish a uniformly random task — running or mid-queue.
                let k = g.size(0, total - 1);
                if k < running.len() {
                    let t = running.swap_remove(k);
                    m.finish_task(t, now);
                    // The freed core promotes the *oldest* queued task.
                    if let Some(p) = queued.pop_front() {
                        running.push(p);
                    }
                } else {
                    let t = queued.remove(k - running.len()).unwrap();
                    m.finish_task(t, now); // mid-queue: no promotion
                }
            }
            let got: Vec<u64> = m.cpu.oversub.iter().copied().collect();
            let want: Vec<u64> = queued.iter().copied().collect();
            if got != want {
                return Check::Fail(format!("queue diverged: sim {got:?} vs fifo {want:?}"));
            }
        }
        check(m.cpu.running_tasks() == running.len() + queued.len(), "task count diverged")
    });
}
