//! Sweep-engine determinism and workload-scenario property tests.
//!
//! The engine's contract: per-cell seeds derive from `(spec.seed,
//! scenario index)`, never from execution order, and the pool returns
//! results in cell order — so the aggregated report is **byte-identical
//! at any thread count**. The scenario generators must uphold the trace
//! invariants (sorted arrivals, positive tokens, empirical rate near the
//! configured mean) for arbitrary parameters.

use carbon_sim::experiments::sweep::{self, SweepSpec};
use carbon_sim::trace::azure::{AzureTraceGen, TraceParams, Workload};
use carbon_sim::util::proptest::{check, forall, Check};
use carbon_sim::util::stats;

fn tiny_spec() -> SweepSpec {
    SweepSpec {
        rates: vec![4.0, 8.0],
        core_counts: vec![8],
        policies: vec!["linux".into(), "proposed".into()],
        workloads: vec![Workload::Mixed, Workload::Bursty],
        replicas: 1,
        duration_s: 6.0,
        n_prompt: 1,
        n_token: 2,
        seed: 1234,
        fleet: None,
        lifecycle: None,
    }
}

#[test]
fn json_and_csv_identical_at_1_2_and_8_threads() {
    let spec = tiny_spec();
    let base = sweep::run(&spec, 1).unwrap();
    let base_json = base.to_json().to_string_pretty();
    let base_csv = base.to_csv();
    assert!(!base.cells.is_empty());
    for threads in [2, 8] {
        let r = sweep::run(&spec, threads).unwrap();
        assert_eq!(
            r.to_json().to_string_pretty(),
            base_json,
            "JSON diverged at {threads} threads"
        );
        assert_eq!(r.to_csv(), base_csv, "CSV diverged at {threads} threads");
    }
}

#[test]
fn repeated_runs_are_byte_identical() {
    let spec = tiny_spec();
    let a = sweep::run(&spec, 3).unwrap().to_json().to_string_pretty();
    let b = sweep::run(&spec, 3).unwrap().to_json().to_string_pretty();
    assert_eq!(a, b);
}

#[test]
fn different_root_seed_changes_results() {
    let mut spec = tiny_spec();
    let a = sweep::run(&spec, 2).unwrap().to_json().to_string_pretty();
    spec.seed = 4321;
    let b = sweep::run(&spec, 2).unwrap().to_json().to_string_pretty();
    assert_ne!(a, b);
}

#[test]
fn report_json_parses_back_with_expected_shape() {
    let spec = tiny_spec();
    let report = sweep::run(&spec, 4).unwrap();
    let v = carbon_sim::util::json::parse(&report.to_json().to_string_pretty()).unwrap();
    let cells = v.get("cells").and_then(|c| c.as_arr()).expect("cells array");
    assert_eq!(cells.len(), spec.n_cells());
    assert_eq!(v.usize_or("n_cells", 0), spec.n_cells());
    for cell in cells {
        assert!(cell.get("policy").is_some());
        assert!(cell.get("workload").is_some());
        assert!(cell.f64_or("sim_duration_s", -1.0) > 0.0);
        // Seeds are serialized as strings to survive the f64 round-trip.
        assert!(cell.get("seed").and_then(|s| s.as_str()).is_some());
    }
}

// ------------------------------------------------------- trace properties

/// Shared invariant block for a generated trace.
fn trace_invariants(
    t: &carbon_sim::trace::Trace,
    rate: f64,
    rel_tol: f64,
    label: &str,
) -> Check {
    if let Err(e) = t.validate() {
        return check(false, format!("[{label}] invariant broken: {e}"));
    }
    for r in &t.requests {
        if r.prompt_tokens == 0 || r.output_tokens == 0 {
            return check(false, format!("[{label}] zero-token request {}", r.id));
        }
    }
    let achieved = t.rate_rps();
    check(
        (achieved - rate).abs() <= rel_tol * rate,
        format!("[{label}] rate {achieved:.2} vs target {rate:.2} (tol {rel_tol})"),
    )
}

#[test]
fn diurnal_traces_uphold_invariants() {
    forall(40, 0xD1, |g| {
        let rate = 10.0 + g.f64(0.0, 60.0);
        let seed = g.size(0, 100_000) as u64;
        let t = AzureTraceGen::new(TraceParams {
            rate_rps: rate,
            duration_s: 240.0,
            workload: Workload::Diurnal,
            seed,
        })
        .generate();
        trace_invariants(&t, rate, 0.25, "diurnal")
    });
}

#[test]
fn bursty_traces_uphold_invariants() {
    forall(40, 0xB2, |g| {
        let rate = 10.0 + g.f64(0.0, 60.0);
        let seed = g.size(0, 100_000) as u64;
        let t = AzureTraceGen::new(TraceParams {
            rate_rps: rate,
            duration_s: 400.0,
            workload: Workload::Bursty,
            seed,
        })
        .generate();
        // MMPP on/off cycling has far higher count variance than a
        // homogeneous process (~10% relative std at this duration):
        // allow a wide band — the point is "near the mean", not tight.
        trace_invariants(&t, rate, 0.5, "bursty")
    });
}

#[test]
fn long_context_traces_uphold_invariants() {
    forall(40, 0x1C, |g| {
        let rate = 10.0 + g.f64(0.0, 60.0);
        let seed = g.size(0, 100_000) as u64;
        let t = AzureTraceGen::new(TraceParams {
            rate_rps: rate,
            duration_s: 180.0,
            workload: Workload::LongContext,
            seed,
        })
        .generate();
        if let Check::Fail(m) = trace_invariants(&t, rate, 0.25, "long-context") {
            return Check::Fail(m);
        }
        // Long-context marginals: median prompt must dwarf conversation's.
        let prompts: Vec<f64> = t.requests.iter().map(|r| r.prompt_tokens as f64).collect();
        check(
            stats::percentile(&prompts, 50.0) > 3000.0,
            format!("[long-context] median prompt {}", stats::percentile(&prompts, 50.0)),
        )
    });
}

#[test]
fn bursty_interarrivals_are_overdispersed_vs_poisson() {
    // The defining property of the MMPP scenario: CV of interarrival
    // gaps exceeds the exponential's CV of 1.
    let mut cvs = Vec::new();
    for seed in 0..5u64 {
        let t = AzureTraceGen::new(TraceParams {
            rate_rps: 50.0,
            duration_s: 400.0,
            workload: Workload::Bursty,
            seed,
        })
        .generate();
        let gaps: Vec<f64> =
            t.requests.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s).collect();
        cvs.push(stats::coeff_of_variation(&gaps));
    }
    let mean_cv = stats::mean(&cvs);
    assert!(mean_cv > 1.2, "mean interarrival CV {mean_cv} not overdispersed");
}
