//! Golden parity for the §Perf hot-path refactor: the transcendental-free
//! equivalent-stress-time fast path (`Core::advance` + lazy `Core::dvth`)
//! must reproduce the retained closed-form reference
//! (`AgingParams::dvth_step`, one recursion step per interval at the
//! interval's ADF) to 1e-12 *relative* error over randomized
//! assign/release/C6 schedules.

use carbon_sim::cpu::{AgingOps, AgingParams, CState, Core, TemperatureModel};
use carbon_sim::util::proptest::{check, forall, Check};

struct Fixture {
    aging: AgingParams,
    ops: AgingOps,
    adf_alloc: f64,
    adf_unalloc: f64,
}

fn fixture() -> Fixture {
    let aging = AgingParams::paper_default();
    let temps = TemperatureModel::paper_default();
    let ops = AgingOps::new(&aging, &temps);
    let adf_alloc = aging.adf(temps.steady_k(CState::C0, true), 1.0);
    let adf_unalloc =
        aging.adf(temps.steady_k(CState::C0, false), aging.unallocated_stress);
    Fixture { aging, ops, adf_alloc, adf_unalloc }
}

#[test]
fn eq_time_fast_path_matches_closed_form_over_random_schedules() {
    let fx = fixture();
    forall(250, 0xFA57_A61, |g| {
        let mut core = Core::new(0, 2.6);
        let mut dvth_ref = 0.0f64;
        let mut now = 0.0f64;
        let mut task_id = 1u64;
        let n_steps = g.size(5, 120);
        for _ in 0..n_steps {
            // Dwell at the current operating point, then step both paths.
            let tau = g.f64(0.0, 5.0e5);
            now += tau;
            if core.state == CState::C0 {
                let adf = if core.is_allocated() { fx.adf_alloc } else { fx.adf_unalloc };
                dvth_ref = fx.aging.dvth_step(dvth_ref, adf, tau);
            }
            core.advance(now, &fx.ops);
            // Random configuration change at `now` (the core is already
            // advanced, so the internal advance is a no-op).
            match g.size(0, 5) {
                0 | 1 => {
                    if core.is_allocated() {
                        core.release(now, &fx.ops);
                    } else if core.state == CState::C0 {
                        core.assign(task_id, now, &fx.ops);
                        task_id += 1;
                    }
                }
                2 => {
                    if core.state == CState::C6 {
                        core.set_state(CState::C0, now, &fx.ops);
                    } else if !core.is_allocated() {
                        core.set_state(CState::C6, now, &fx.ops);
                    }
                }
                _ => {}
            }
            let dvth_fast = core.dvth(&fx.ops);
            if dvth_ref > 0.0 {
                let rel = (dvth_fast - dvth_ref).abs() / dvth_ref;
                if rel > 1e-12 {
                    return check(
                        false,
                        format!(
                            "rel err {rel:.3e} after {now:.0}s: fast={dvth_fast} ref={dvth_ref}"
                        ),
                    );
                }
            } else if dvth_fast != 0.0 {
                return check(false, format!("ref is 0 but fast is {dvth_fast}"));
            }
        }
        Check::Pass
    });
}

#[test]
fn fast_path_frequency_matches_reference_formula() {
    // Frequency reads go through AgingOps; they must equal the retained
    // AgingParams::freq_ghz applied to the reference ΔVth.
    let fx = fixture();
    let mut core = Core::new(0, 2.6);
    core.assign(1, 0.0, &fx.ops);
    core.advance(3.0e7, &fx.ops);
    let dvth_ref = fx.aging.dvth_step(0.0, fx.adf_alloc, 3.0e7);
    let f_ref = fx.aging.freq_ghz(2.6, dvth_ref);
    let f_fast = core.freq_ghz(&fx.ops);
    assert!(
        (f_fast - f_ref).abs() / f_ref < 1e-12,
        "fast={f_fast} ref={f_ref}"
    );
}

#[test]
fn ten_year_calibration_survives_the_fast_path() {
    // 10 years of continuous allocated stress must still cost 30% of f0
    // (the model's calibration datum) through the eq-time representation.
    let fx = fixture();
    let mut core = Core::new(0, 2.6);
    core.assign(1, 0.0, &fx.ops);
    core.advance(fx.aging.calib_lifetime_s, &fx.ops);
    let red = core.freq_reduction_ghz(&fx.ops) / 2.6;
    assert!((red - 0.30).abs() < 1e-9, "reduction={red}");
}
