//! Property-based integration tests over the core-management invariants —
//! random task arrival/finish/adjust interleavings must never violate the
//! §3 system model's rules, for any policy.

use carbon_sim::cpu::{AgingParams, CState, CpuPackage, TemperatureModel};
use carbon_sim::policy::{by_name, CoreManager, ALL_POLICIES};
use carbon_sim::util::proptest::{check, forall, Check};
use carbon_sim::util::rng::Rng;

fn mgr(n: usize, policy: &str, seed: u64) -> CoreManager {
    let cpu =
        CpuPackage::uniform(n, AgingParams::paper_default(), TemperatureModel::paper_default());
    CoreManager::new(cpu, by_name(policy).unwrap(), Rng::new(seed))
}

/// Drive a random schedule and verify structural invariants after every op.
fn run_schedule(policy: &'static str) {
    forall(150, 0xC0FEE ^ policy.len() as u64, |g| {
        let n_cores = g.size(1, 64).max(1);
        let n_ops = g.size(10, 300);
        let mut m = mgr(n_cores, policy, 7);
        let mut live: Vec<u64> = Vec::new();
        let mut next_task = 0u64;
        let mut now = 0.0f64;
        for _ in 0..n_ops {
            now += g.f64(0.0, 0.5);
            match g.size(0, 9) {
                // 50%: start a task
                0..=4 => {
                    m.start_task(next_task, now);
                    live.push(next_task);
                    next_task += 1;
                }
                // 30%: finish a random live task
                5..=7 => {
                    if !live.is_empty() {
                        let idx = g.size(0, live.len() - 1);
                        let t = live.swap_remove(idx);
                        m.finish_task(t, now);
                    }
                }
                // 20%: periodic adjust
                _ => m.adjust(now),
            }
            if let Check::Fail(msg) = structural_invariants(&m, live.len(), policy) {
                return Check::Fail(msg);
            }
        }
        // Drain everything: all cores must end task-free.
        for t in live {
            m.finish_task(t, now + 1.0);
        }
        check(m.cpu.running_tasks() == 0, format!("[{policy}] drain left tasks behind"))
    });
}

/// Structural invariants that must hold between manager calls, for any
/// policy: task accounting, the C-state partition, no allocated C6 core,
/// and no observable unpromoted oversubscription.
fn structural_invariants(m: &CoreManager, live: usize, policy: &str) -> Check {
    let cpu = &m.cpu;
    if cpu.running_tasks() != live {
        return check(
            false,
            format!("[{policy}] task accounting: running {} != live {live}", cpu.running_tasks()),
        );
    }
    if cpu.active_count() + cpu.c6_count() != cpu.n_cores() {
        return check(false, format!("[{policy}] C-state partition broken"));
    }
    if cpu.active_count() == 0 && live > 0 {
        return check(false, format!("[{policy}] all cores asleep with live tasks"));
    }
    for core in cpu.core_views() {
        if core.task().is_some() && core.state() == CState::C6 {
            return check(false, format!("[{policy}] allocated core {} in C6", core.id()));
        }
    }
    if !cpu.oversub.is_empty() && cpu.has_free_active_core() {
        return check(false, format!("[{policy}] unpromoted oversub with free cores"));
    }
    Check::Pass
}

/// Drive a policy with arrivals from a *bursty* (MMPP) trace: ON bursts
/// hammer the working set far above the mean rate — exactly the regime
/// where Selective Core Idling's reaction lag can oversubscribe — and
/// OFF valleys shrink it again. Invariants must hold through both.
fn run_bursty_trace(policy: &'static str) {
    use carbon_sim::trace::azure::{AzureTraceGen, TraceParams, Workload};
    forall(20, 0xB0B ^ policy.len() as u64, |g| {
        let rate = 10.0 + g.f64(0.0, 50.0);
        let n_cores = g.size(4, 48).max(1);
        let trace = AzureTraceGen::new(TraceParams {
            rate_rps: rate,
            duration_s: 15.0,
            workload: Workload::Bursty,
            seed: g.size(0, 10_000) as u64,
        })
        .generate();
        let mut m = mgr(n_cores, policy, 21);
        // Completion events keyed in integer microseconds so the heap is
        // Ord; service times 10–300 ms.
        let mut completions = std::collections::BinaryHeap::new();
        let mut live: Vec<u64> = Vec::new();
        let mut next_adjust_us: u64 = 1_000_000;
        for (id, r) in trace.requests.iter().enumerate() {
            let arrive_us = (r.arrival_s * 1e6) as u64;
            // Drain completions and adjust ticks before this arrival.
            while let Some(std::cmp::Reverse((t_us, task))) = completions.peek().copied() {
                if t_us > arrive_us {
                    break;
                }
                completions.pop();
                while next_adjust_us <= t_us {
                    m.adjust(next_adjust_us as f64 / 1e6);
                    next_adjust_us += 1_000_000;
                }
                m.finish_task(task, t_us as f64 / 1e6);
                live.retain(|&t| t != task);
                if let Check::Fail(msg) = structural_invariants(&m, live.len(), policy) {
                    return Check::Fail(msg);
                }
            }
            while next_adjust_us <= arrive_us {
                m.adjust(next_adjust_us as f64 / 1e6);
                next_adjust_us += 1_000_000;
            }
            let task = id as u64;
            m.start_task(task, r.arrival_s);
            live.push(task);
            let service_us = (g.f64(0.01, 0.3) * 1e6) as u64;
            completions.push(std::cmp::Reverse((arrive_us + service_us, task)));
            if let Check::Fail(msg) = structural_invariants(&m, live.len(), policy) {
                return Check::Fail(msg);
            }
        }
        // Drain everything left.
        let end_s = trace.duration_s + 1.0;
        while let Some(std::cmp::Reverse((_, task))) = completions.pop() {
            m.finish_task(task, end_s);
        }
        check(m.cpu.running_tasks() == 0, format!("[{policy}] bursty drain left tasks"))
    });
}

#[test]
fn invariants_proposed() {
    run_schedule("proposed");
}

#[test]
fn invariants_linux() {
    run_schedule("linux");
}

#[test]
fn invariants_least_aged() {
    run_schedule("least-aged");
}

#[test]
fn bursty_invariants_proposed() {
    run_bursty_trace("proposed");
}

#[test]
fn bursty_invariants_linux() {
    run_bursty_trace("linux");
}

#[test]
fn bursty_invariants_least_aged() {
    run_bursty_trace("least-aged");
}

#[test]
fn aging_monotonicity_under_any_schedule() {
    // Whatever the policy does, every core's ΔVth must be non-decreasing
    // and its frequency non-increasing over time.
    forall(60, 0xA6E, |g| {
        let policy = ALL_POLICIES[g.size(0, 2)];
        let mut m = mgr(16, policy, 3);
        let mut now = 0.0;
        let mut prev_dvth: Vec<f64> = vec![0.0; 16];
        let mut next_task = 0u64;
        let mut live = Vec::new();
        for _ in 0..50 {
            now += g.f64(0.1, 10.0);
            if g.bool() {
                m.start_task(next_task, now);
                live.push(next_task);
                next_task += 1;
            } else if let Some(t) = live.pop() {
                m.finish_task(t, now);
            }
            m.adjust(now);
            m.cpu.advance_all(now);
            for (i, core) in m.cpu.core_views().enumerate() {
                let dvth = core.dvth();
                if dvth < prev_dvth[i] - 1e-15 {
                    return check(
                        false,
                        format!("[{policy}] core {i} dvth decreased: {} -> {dvth}", prev_dvth[i]),
                    );
                }
                prev_dvth[i] = dvth;
            }
        }
        check(true, "")
    });
}

#[test]
fn proposed_halts_aging_in_parked_cores() {
    // A core parked in C6 must not accumulate ΔVth while parked.
    let mut m = mgr(8, "proposed", 5);
    m.adjust(1.0); // parks 7 cores
    let parked: Vec<usize> =
        m.cpu.core_views().filter(|c| c.state() == CState::C6).map(|c| c.id()).collect();
    assert!(!parked.is_empty());
    let before: Vec<f64> = parked.iter().map(|&i| m.cpu.core(i).dvth()).collect();
    m.cpu.advance_all(3600.0);
    for (k, &i) in parked.iter().enumerate() {
        assert_eq!(m.cpu.core(i).dvth(), before[k], "parked core {i} aged");
    }
}

#[test]
fn working_set_scales_with_offered_load() {
    // Sweep load levels; the converged working set must be monotone-ish
    // in the load (within the reaction function's deadband).
    let mut prev_active = 1;
    for load in [2usize, 8, 16, 28] {
        let mut m = mgr(40, "proposed", 11);
        for t in 0..load as u64 {
            m.start_task(t, 0.0);
        }
        for step in 1..60 {
            m.adjust(step as f64);
        }
        let active = m.cpu.active_count();
        assert!(active >= load, "load {load}: working set {active} below load");
        assert!(active <= load + 4, "load {load}: working set {active} too generous");
        assert!(active >= prev_active, "working set not monotone in load");
        prev_active = active;
    }
}
