//! Property tests for the statistics foundations (`util::stats`) on the
//! in-tree `forall` harness. These are the invariants the sweep and
//! search engines lean on: percentiles that interpolate monotonically and
//! never leave the data range, NaN handling that is consistent between
//! [`percentile`] and [`Summary::of`], a streaming [`Welford`] that
//! agrees with the batch formulas, and a [`Histogram`] that never loses
//! a sample. `CARBON_SIM_PROPTEST_CASES` raises the case count (CI runs
//! these suites at depth); `CARBON_SIM_PROPTEST_SEED` replays a failure.

use carbon_sim::util::proptest::{check, forall, Check};
use carbon_sim::util::stats::{
    mean, percentile, percentile_sorted, variance, Histogram, Summary, Welford,
};

/// Absolute-plus-relative tolerance: float noise grows with magnitude.
fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[test]
fn percentile_sorted_is_monotone_and_bounded() {
    forall(500, 101, |g| {
        let n = g.size(1, 128);
        let mut v = g.vec_f64(n, -1e6, 1e6);
        v.sort_by(f64::total_cmp);
        let (mut p_lo, mut p_hi) = (g.f64(0.0, 100.0), g.f64(0.0, 100.0));
        if p_lo > p_hi {
            std::mem::swap(&mut p_lo, &mut p_hi);
        }
        let (q_lo, q_hi) = (percentile_sorted(&v, p_lo), percentile_sorted(&v, p_hi));
        let (min, max) = (v[0], *v.last().unwrap());
        // Linear interpolation can overshoot a segment endpoint by float
        // noise, so monotonicity and the bounds get an epsilon.
        let eps = 1e-9 * (1.0 + max.abs().max(min.abs()));
        if q_lo > q_hi + eps {
            return Check::Fail(format!(
                "not monotone: p{p_lo}={q_lo} > p{p_hi}={q_hi} on {n} samples"
            ));
        }
        for (p, q) in [(p_lo, q_lo), (p_hi, q_hi)] {
            if q < min - eps || q > max + eps {
                return Check::Fail(format!("p{p}={q} outside [{min}, {max}]"));
            }
        }
        let (q0, q100) = (percentile_sorted(&v, 0.0), percentile_sorted(&v, 100.0));
        check(
            q0 == min && q100 == max,
            format!("endpoints: p0={q0} p100={q100} vs [{min}, {max}]"),
        )
    });
}

#[test]
fn percentile_is_permutation_invariant_and_matches_summary() {
    forall(500, 102, |g| {
        let n = g.size(0, 96);
        let mut xs = g.vec_f64(n, -1e3, 1e3);
        // Lace in NaNs: both functions must exclude the same samples.
        for x in xs.iter_mut() {
            if g.rng.bool(0.15) {
                *x = f64::NAN;
            }
        }
        let mut shuffled = xs.clone();
        g.rng.shuffle(&mut shuffled);
        for p in [0.0, 1.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
            let (a, b) = (percentile(&xs, p), percentile(&shuffled, p));
            // Bitwise equality: both sort the same filtered values, so
            // the interpolation is the identical float expression.
            if a.to_bits() != b.to_bits() {
                return Check::Fail(format!("p{p}: {a} (original) != {b} (shuffled)"));
            }
        }
        let s = Summary::of(&xs);
        let nan_count = xs.iter().filter(|x| x.is_nan()).count();
        if s.n + s.nan_count != xs.len() || s.nan_count != nan_count {
            return Check::Fail(format!(
                "counts: n={} nan={} over {} inputs ({nan_count} NaN)",
                s.n, s.nan_count, xs.len()
            ));
        }
        for (label, summary_q, p) in [
            ("p1", s.p1, 1.0),
            ("p25", s.p25, 25.0),
            ("p50", s.p50, 50.0),
            ("p75", s.p75, 75.0),
            ("p90", s.p90, 90.0),
            ("p99", s.p99, 99.0),
        ] {
            let direct = percentile(&xs, p);
            if summary_q.to_bits() != direct.to_bits() {
                return Check::Fail(format!("{label}: Summary {summary_q} != percentile {direct}"));
            }
        }
        check(
            s.min == percentile(&xs, 0.0) && s.max == percentile(&xs, 100.0),
            format!("min/max: [{}, {}]", s.min, s.max),
        )
    });
}

#[test]
fn welford_matches_batch_mean_and_variance() {
    forall(500, 103, |g| {
        let n = g.size(1, 256);
        // An offset stresses the naive-sum cancellation Welford avoids.
        let offset = g.f64(-1e5, 1e5);
        let xs: Vec<f64> = g.vec_f64(n, -100.0, 100.0).iter().map(|x| x + offset).collect();
        let mut w = Welford::default();
        for &x in &xs {
            w.add(x);
        }
        if w.count() != n as u64 {
            return Check::Fail(format!("count {} != {n}", w.count()));
        }
        let (bm, bv) = (mean(&xs), variance(&xs));
        if !close(w.mean(), bm, 1e-9) {
            return Check::Fail(format!("mean: streaming {} vs batch {bm}", w.mean()));
        }
        check(
            close(w.variance(), bv, 1e-9),
            format!("variance: streaming {} vs batch {bv} (n={n})", w.variance()),
        )
    });
}

#[test]
fn histogram_conserves_samples_and_normalizes() {
    forall(500, 104, |g| {
        let lo = g.f64(-50.0, 50.0);
        let hi = lo + g.f64(0.0, 100.0) + 1e-3;
        let nbins = g.size(1, 24);
        let mut h = Histogram::new(lo, hi, nbins);
        let n = g.size(0, 200);
        let mut fed = 0u64;
        for _ in 0..n {
            // Mix in-range values with the edge cases the doc promises
            // to handle: out-of-range, ±Inf (clamped), NaN (counted).
            let x = match g.size(0, 9) {
                0 => f64::NAN,
                1 => f64::INFINITY,
                2 => f64::NEG_INFINITY,
                _ => g.f64(lo - 20.0, hi + 20.0),
            };
            h.add(x);
            fed += 1;
        }
        if h.count + h.nan_count != fed {
            return Check::Fail(format!(
                "lost samples: count={} nan={} fed={fed}",
                h.count, h.nan_count
            ));
        }
        let binned: u64 = h.bins.iter().sum();
        if binned != h.count {
            return Check::Fail(format!("bins sum {binned} != count {}", h.count));
        }
        let d = h.density();
        if d.len() != nbins {
            return Check::Fail(format!("density has {} bins, expected {nbins}", d.len()));
        }
        let total: f64 = d.iter().sum();
        if h.count > 0 {
            check(close(total, 1.0, 1e-9), format!("density sums to {total} (count={})", h.count))
        } else {
            check(total == 0.0, format!("empty histogram density sums to {total}"))
        }
    });
}
