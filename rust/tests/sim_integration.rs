//! Cluster-simulator integration tests at (reduced) paper scale: the full
//! 22-machine cluster, all policies paired on identical silicon, with the
//! paper's qualitative results asserted end-to-end.

use carbon_sim::carbon::EmbodiedModel;
use carbon_sim::cluster::{Cluster, ClusterConfig};
use carbon_sim::experiments::{fig6, fig7, fig8, run_paired, Scale};
use carbon_sim::trace::azure::{AzureTraceGen, TraceParams, Workload};
use carbon_sim::util::stats;

fn short_paper_scale() -> Scale {
    let mut s = Scale::paper();
    s.duration_s = 30.0;
    s.rates = vec![60.0];
    s.core_counts = vec![40];
    s
}

#[test]
fn paper_cluster_end_to_end_shapes() {
    let scale = short_paper_scale();
    let cell = run_paired(&scale, 40, 60.0);
    let cells = vec![cell];

    // Fig. 6 orderings.
    let rows6 = fig6::rows(&cells, 2.6);
    assert!(fig6::check_shape(&rows6).is_empty(), "{:?}", fig6::check_shape(&rows6));

    // Fig. 7: meaningful carbon reduction at full cluster size.
    let rows7 = fig7::rows(&cells, &EmbodiedModel::paper_default());
    assert!(fig7::check_shape(&rows7).is_empty(), "{:?}", fig7::check_shape(&rows7));
    let prop = rows7.iter().find(|r| r.policy == "proposed").unwrap();
    assert!(
        prop.reduction_pct_p99 > 15.0,
        "p99 reduction {:.1}% too small at paper scale",
        prop.reduction_pct_p99
    );
    assert!(prop.reduction_pct_p50 > 30.0);
    assert!(prop.lifetime_yr_p99 > 3.5);

    // Fig. 8 availability shape.
    let rows8 = fig8::rows(&cells);
    assert!(fig8::check_shape(&rows8).is_empty(), "{:?}", fig8::check_shape(&rows8));
}

#[test]
fn service_quality_impact_is_bounded() {
    // Paper: "less than 10% impact to the inference service quality".
    // Compare E2E latency under proposed vs linux on the same trace.
    let scale = short_paper_scale();
    let cell = run_paired(&scale, 40, 60.0);
    let linux_e2e = cell.result("linux").e2e_summary();
    let prop_e2e = cell.result("proposed").e2e_summary();
    let impact = (prop_e2e.p50 - linux_e2e.p50) / linux_e2e.p50;
    assert!(impact < 0.10, "p50 E2E impact {:.1}% exceeds 10%", impact * 100.0);
    // And the oversubscription depth stays within the paper's bound.
    let idle = stats::Summary::of(&cell.result("proposed").pooled_idle_samples());
    assert!(idle.p1 >= -0.101, "oversubscription p1 {} beyond -0.1", idle.p1);
}

#[test]
fn deterministic_at_cluster_scale() {
    let cfg = ClusterConfig { cores_per_cpu: 40, ..ClusterConfig::default() };
    let trace = AzureTraceGen::new(TraceParams {
        rate_rps: 50.0,
        duration_s: 15.0,
        workload: Workload::Mixed,
        seed: 2,
    })
    .generate();
    let a = Cluster::new(cfg.clone()).run(&trace);
    let b = Cluster::new(cfg).run(&trace);
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.freq, b.freq);
    assert_eq!(a.collector.e2e, b.collector.e2e);
}

#[test]
fn eighty_core_vms_also_hold_shapes() {
    let mut scale = short_paper_scale();
    scale.core_counts = vec![80];
    let cell = run_paired(&scale, 80, 60.0);
    let rows8 = fig8::rows(&[cell]);
    assert!(fig8::check_shape(&rows8).is_empty(), "{:?}", fig8::check_shape(&rows8));
    // Higher core count -> oversubscription severity improves (paper §6.2).
    let prop = rows8.iter().find(|r| r.policy == "proposed").unwrap();
    assert!(prop.idle.p1 >= -0.1);
}

#[test]
fn throughput_sweep_is_stable() {
    // The simulator keeps up with offered load across the paper's sweep
    // (cluster designed iso-throughput for these rates).
    for rate in [40.0, 100.0] {
        let trace = AzureTraceGen::new(TraceParams {
            rate_rps: rate,
            duration_s: 20.0,
            workload: Workload::Mixed,
            seed: 3,
        })
        .generate();
        let r = Cluster::new(ClusterConfig::default()).run(&trace);
        assert_eq!(r.completed_requests, trace.requests.len());
        // E2E latency stays sane (no runaway queueing) at both ends.
        assert!(r.e2e_summary().p50 < 60.0, "rate {rate}: p50 {}", r.e2e_summary().p50);
    }
}
