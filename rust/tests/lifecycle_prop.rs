//! Lifecycle property wall: randomized fleets, fault injections, and
//! retirement schedules must uphold three conservation laws.
//!
//! 1. **Carbon conservation** — once every service window is closed, the
//!    ledger's amortized total equals the total embodied charge to a
//!    relative 1e-9: amortization redistributes kilograms over time, it
//!    never creates or destroys them.
//! 2. **Task conservation** — no request is lost or double-completed
//!    across maintenance drains, core failures, and machine retirement:
//!    every simulated request completes exactly once, and at the manager
//!    level the pinned + oversubscribed task multiset always equals the
//!    set of started-but-unfinished tasks.
//! 3. **Failed-core quarantine** — a permanently failed core never holds
//!    a task and never leaves C6, under any policy, through arbitrary
//!    start/finish/fail/replace churn.

use carbon_sim::carbon::FleetLedger;
use carbon_sim::cluster::{
    Cluster, ClusterConfig, CoreFailure, FleetConfig, LifecycleConfig, MachineGroup,
    MaintenanceWindow,
};
use carbon_sim::cpu::{AgingParams, CState, CpuPackage, TemperatureModel};
use carbon_sim::policy::{by_name, CoreManager, ALL_POLICIES};
use carbon_sim::trace::azure::{AzureTraceGen, TraceParams, Workload};
use carbon_sim::util::proptest::{check, forall, Check};
use carbon_sim::util::rng::Rng;

// ---------------------------------------------------- carbon conservation

#[test]
fn fully_closed_ledgers_conserve_the_embodied_charge() {
    forall(300, 0xCA12B0, |g| {
        let mut ledger = FleetLedger::new();
        let n_machines = 1 + g.size(0, 5);
        let mut now = 0.0;
        for m in 0..n_machines {
            ledger.commission(m, g.f64(1.0, 500.0), g.f64(0.5, 5.0), g.f64(0.0, 4.0), now);
        }
        // Random retire → replace cycles at strictly increasing times (a
        // zero-length service window would amortize nothing by fiat).
        for _ in 0..g.size(0, 12) {
            now += g.f64(1.0, 1e7);
            let m = g.size(0, n_machines - 1);
            if ledger.retire(m, now) {
                ledger.commission(m, g.f64(1.0, 500.0), g.f64(0.5, 5.0), 0.0, now);
            }
        }
        // Close every window and compare the totals.
        now += g.f64(1.0, 1e7);
        for m in 0..n_machines {
            ledger.retire(m, now);
        }
        let charged = ledger.total_charged_kg();
        let amortized = ledger.amortized_total_kg(now);
        let rel = ((charged - amortized) / charged).abs();
        check(
            rel < 1e-9,
            format!(
                "conservation violated: charged {charged} kg, amortized {amortized} kg \
                 (rel {rel:.3e}, {} records)",
                ledger.records.len()
            ),
        )
    });
}

// ------------------------------------------------------ task conservation

#[test]
fn every_request_completes_exactly_once_under_random_fleet_events() {
    // Whole-simulator property: randomized two-group fleets with
    // maintenance windows, scripted + stochastic core failures, and
    // age-triggered retirement, across every policy. Few cases — each
    // runs 3 × a full simulation — but each case is a different fleet.
    forall(6, 0x71FE, |g| {
        let n_prompt = 1 + g.size(0, 1);
        let n_token = 1 + g.size(0, 1);
        let n = n_prompt + n_token;
        let cores = 4 + g.size(0, 4);
        let duration = 3.0 + g.f64(0.0, 2.0);
        let seed = g.size(0, 1_000_000) as u64;

        let split = 1 + g.size(0, n - 2);
        let fleet = FleetConfig {
            groups: vec![
                MachineGroup {
                    count: split,
                    cores,
                    commission_age_yr: g.f64(0.0, 2.0),
                    ..MachineGroup::default()
                },
                MachineGroup {
                    count: n - split,
                    cores: 4 + g.size(0, 4),
                    generation: "gen2".into(),
                    // Straddles the 3-year age limit: some fleets retire
                    // this group at the first check, some never do.
                    commission_age_yr: g.f64(2.5, 3.5),
                    ..MachineGroup::default()
                },
            ],
        };
        let lifecycle = LifecycleConfig {
            maintenance: (0..g.size(0, 2))
                .map(|_| MaintenanceWindow {
                    machine: g.size(0, n - 1),
                    start_s: g.f64(0.0, duration),
                    duration_s: 0.1 + g.f64(0.0, duration),
                })
                .collect(),
            failures: (0..g.size(0, 3))
                .map(|_| CoreFailure {
                    machine: g.size(0, n - 1),
                    core: g.size(0, 3),
                    time_s: g.f64(0.0, duration),
                })
                .collect(),
            // Absurdly high rate on purpose: the exponential draws land
            // inside the few simulated seconds, exercising the stochastic
            // failure path hard.
            failure_rate_per_core_year: g.f64(0.0, 3.0e6),
            age_limit_yr: Some(3.0),
            dvth_guard_band_v: if g.bool() { Some(0.05) } else { None },
            check_period_s: 0.5 + g.f64(0.0, 2.0),
            replacement_group: g.size(0, 1),
        };

        let trace = AzureTraceGen::new(TraceParams {
            rate_rps: 2.0 + g.f64(0.0, 4.0),
            duration_s: duration,
            workload: Workload::Mixed,
            seed: seed ^ 0xABCD,
        })
        .generate();

        for policy in ALL_POLICIES {
            let cfg = ClusterConfig {
                n_prompt,
                n_token,
                cores_per_cpu: cores,
                policy: policy.to_string(),
                seed,
                fleet: Some(fleet.clone()),
                lifecycle: Some(lifecycle.clone()),
                ..ClusterConfig::default()
            };
            let mut cluster = Cluster::new(cfg);
            let result = cluster.run(&trace);
            if result.completed_requests != trace.requests.len() {
                return Check::Fail(format!(
                    "[{policy}] {} of {} requests completed (fleet={fleet:?}, \
                     lifecycle={lifecycle:?})",
                    result.completed_requests,
                    trace.requests.len()
                ));
            }
            let rt = cluster.lifecycle.as_ref().expect("fleet run has lifecycle state");
            // Ledger invariants: one open window per machine slot, one
            // record per initial commission + one per retirement, and the
            // reported summary agrees with the ledger's counters.
            for m in 0..n {
                if rt.ledger.open_record(m).is_none() {
                    return Check::Fail(format!("[{policy}] machine {m} has no open window"));
                }
            }
            if rt.ledger.records.len() != n + rt.retirements as usize {
                return Check::Fail(format!(
                    "[{policy}] {} ledger records for {n} slots + {} retirements",
                    rt.ledger.records.len(),
                    rt.retirements
                ));
            }
            let summary = result.lifecycle.expect("fleet run reports a lifecycle summary");
            if summary.retirements != rt.retirements
                || summary.core_failures != rt.core_failures
                || summary.rerouted != rt.rerouted
            {
                return Check::Fail(format!("[{policy}] summary diverged from runtime counters"));
            }
            // Failed-core quarantine at end of run, on every machine.
            for mach in &cluster.machines {
                for c in mach.mgr.cpu.core_views() {
                    if c.failed() && (c.task().is_some() || c.state() != CState::C6) {
                        return Check::Fail(format!(
                            "[{policy}] failed core {} on machine {} holds task {:?} in {:?}",
                            c.id(),
                            mach.id,
                            c.task(),
                            c.state()
                        ));
                    }
                }
            }
        }
        Check::Pass
    });
}

// ------------------------------------------------- failed-core quarantine

#[test]
fn failed_cores_never_hold_tasks_through_arbitrary_churn() {
    forall(60, 0xFA11, |g| {
        let policy = ALL_POLICIES[g.size(0, ALL_POLICIES.len() - 1)];
        let n = 2 + g.size(0, 10);
        let cpu = CpuPackage::uniform(
            n,
            AgingParams::paper_default(),
            TemperatureModel::paper_default(),
        );
        let mut mgr =
            CoreManager::new(cpu, by_name(policy).unwrap(), Rng::new(g.size(0, 10_000) as u64));
        let mut next_task: u64 = 0;
        let mut active: Vec<u64> = Vec::new();
        let mut now = 0.0;
        for _ in 0..g.size(5, 60) {
            now += 0.05;
            match g.size(0, 9) {
                0..=3 => {
                    mgr.start_task(next_task, now);
                    active.push(next_task);
                    next_task += 1;
                }
                4..=6 => {
                    if !active.is_empty() {
                        let i = g.size(0, active.len() - 1);
                        mgr.finish_task(active.swap_remove(i), now);
                    }
                }
                7 | 8 => {
                    // Deliberately allows stale/repeat indices: fail_core
                    // must be a no-op on out-of-range or already-failed
                    // cores.
                    mgr.fail_core(g.size(0, n + 2), now);
                }
                _ => {
                    // Machine retirement: swap in a fresh package (maybe a
                    // different SKU core count) and a fresh policy.
                    let n2 = 2 + g.size(0, 10);
                    let fresh = CpuPackage::uniform(
                        n2,
                        AgingParams::paper_default(),
                        TemperatureModel::paper_default(),
                    );
                    mgr.replace_package(fresh, by_name(policy).unwrap(), now);
                }
            }
            mgr.adjust(now);
            for c in mgr.cpu.core_views() {
                if c.failed() && c.task().is_some() {
                    return Check::Fail(format!(
                        "[{policy}] failed core {} holds task {:?}",
                        c.id(),
                        c.task()
                    ));
                }
                if c.failed() && c.state() != CState::C6 {
                    return Check::Fail(format!(
                        "[{policy}] failed core {} is in {:?}, not C6",
                        c.id(),
                        c.state()
                    ));
                }
            }
            // Task conservation at the manager level: pinned + queued is
            // exactly the started-but-unfinished multiset.
            let mut seen: Vec<u64> = mgr.cpu.core_views().filter_map(|c| c.task()).collect();
            seen.extend(mgr.cpu.oversub.iter().copied());
            seen.sort_unstable();
            let mut expect = active.clone();
            expect.sort_unstable();
            if seen != expect {
                return Check::Fail(format!(
                    "[{policy}] task multiset diverged: pinned+queued {seen:?} vs active \
                     {expect:?}"
                ));
            }
        }
        Check::Pass
    });
}
