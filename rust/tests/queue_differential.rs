//! Differential property test: the calendar queue and the binary heap
//! must be observationally indistinguishable.
//!
//! The determinism guarantee the sweep engine is built on (byte-identical
//! reports at any thread count, and now under either `--queue` kind) only
//! holds if both implementations produce the **exact** same
//! `(time, seq, payload)` pop stream for the same operation stream —
//! including FIFO order among same-timestamp events and identical clamp
//! accounting. This test drives both through randomized schedules that
//! specifically stress the calendar's hard cases: dense same-timestamp
//! bursts (many events in one bucket), far-future events (backlog spill
//! and wheel rotation), mid-run pops (cursor advancement), populations
//! past the resize threshold (wheel rebuild), and the periodic tick
//! train merging with ordinary events.

use carbon_sim::sim::{QueueKind, Scheduler, SchedulerImpl};
use carbon_sim::util::proptest::{check, forall, Check, Gen};

/// Apply one randomized operation schedule to both queues and compare
/// every observable: pop streams, clocks, counters, and stats.
fn run_case(g: &mut Gen, max_ops: usize) -> Check {
    let mut heap: SchedulerImpl<u64> = SchedulerImpl::new(QueueKind::Heap);
    let mut cal: SchedulerImpl<u64> = SchedulerImpl::new(QueueKind::Calendar);

    // Periodic slots armed up front about half the time, mirroring how
    // the cluster arms Adjust/Sample before the event loop starts.
    let mut armed = 0usize;
    if g.bool() {
        let p = (g.f64(0.0, 2.0) * 16.0).floor() / 16.0 + 0.05;
        heap.arm_periodic(0, p, p, u64::MAX);
        cal.arm_periodic(0, p, p, u64::MAX);
        armed += 1;
    }
    if g.bool() {
        let p = (g.f64(0.0, 1.0) * 16.0).floor() / 16.0 + 0.1;
        heap.arm_periodic(1, p, p, u64::MAX - 1);
        cal.arm_periodic(1, p, p, u64::MAX - 1);
        armed += 1;
    }

    let n_ops = g.size(1, max_ops);
    let mut payload = 0u64;
    for _ in 0..n_ops {
        if g.bool() {
            // Quantizing to 1/8s makes same-timestamp collisions common;
            // the streams must agree on FIFO order inside each collision.
            let mut t = (g.f64(0.0, 30.0) * 8.0).floor() / 8.0;
            if g.rng.usize(10) == 0 {
                // Far future: lands in the calendar's sorted backlog.
                t += g.f64(50.0, 500.0);
            }
            let burst = 1 + g.rng.usize(4);
            for _ in 0..burst {
                let th = heap.push(heap.now().max(t), payload);
                let tc = cal.push(cal.now().max(t), payload);
                if th != tc {
                    return Check::Fail(format!("push returned {th} vs {tc}"));
                }
                payload += 1;
            }
        } else {
            let (h, c) = (heap.pop(), cal.pop());
            if h != c {
                return Check::Fail(format!("mid-run pop diverged: {h:?} vs {c:?}"));
            }
        }
        if heap.len() != cal.len() {
            return Check::Fail(format!("len diverged: {} vs {}", heap.len(), cal.len()));
        }
    }

    // Drain the remaining pending events. The armed periodic slots rearm
    // forever, so "drained" means only the train is left (len == armed);
    // train firings in between keep the drain honest about merge order.
    while heap.len() > armed {
        let (h, c) = (heap.pop(), cal.pop());
        if h != c {
            return Check::Fail(format!("drain pop diverged: {h:?} vs {c:?}"));
        }
        if h.is_none() {
            break;
        }
    }

    if heap.now() != cal.now() {
        return Check::Fail(format!("clocks diverged: {} vs {}", heap.now(), cal.now()));
    }
    if heap.processed() != cal.processed() {
        return Check::Fail(format!(
            "processed diverged: {} vs {}",
            heap.processed(),
            cal.processed()
        ));
    }
    check(
        heap.stats() == cal.stats(),
        format!("stats diverged: {:?} vs {:?}", heap.stats(), cal.stats()),
    )
}

#[test]
fn pop_streams_are_identical_on_random_schedules() {
    forall(120, 0xD1FF, |g| run_case(g, 200));
}

#[test]
fn pop_streams_survive_wheel_resizes() {
    // Enough pushes per case to cross the calendar's grow threshold
    // (items > 2 × buckets) several times, forcing full rebuilds.
    forall(12, 0xB16, |g| run_case(g, 1500));
}

#[test]
fn dense_same_timestamp_bursts_stay_fifo() {
    let mut heap: SchedulerImpl<u64> = SchedulerImpl::new(QueueKind::Heap);
    let mut cal: SchedulerImpl<u64> = SchedulerImpl::new(QueueKind::Calendar);
    // 2000 events over just 4 distinct timestamps: each bucket holds a
    // long same-time run whose relative order is pure seq FIFO.
    for i in 0..2000u64 {
        let t = 1.0 + (i % 4) as f64;
        heap.push(t, i);
        cal.push(t, i);
    }
    let mut last: Option<(f64, u64)> = None;
    for _ in 0..2000 {
        let h = heap.pop();
        let c = cal.pop();
        assert_eq!(h, c);
        let (t, payload) = h.expect("2000 events were pushed");
        if let Some((lt, lp)) = last {
            assert!(t >= lt, "time went backwards: {lt} -> {t}");
            if t == lt {
                assert!(payload > lp, "FIFO violated at t={t}: {lp} then {payload}");
            }
        }
        last = Some((t, payload));
    }
    assert!(heap.pop().is_none() && cal.pop().is_none());
}
