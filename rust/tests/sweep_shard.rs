//! Sharded-sweep + merge contract tests: N shard spills, produced
//! independently (as if on N machines), must reassemble into reports
//! **byte-identical** to a single-machine run of the full grid, and the
//! merge must reject incomplete, overlapping, or mismatched shard sets
//! with errors that name the offending spill or cell indexes.

use std::fs;
use std::path::PathBuf;

use carbon_sim::experiments::merge::merge_spills;
use carbon_sim::experiments::sweep::{self, Format, ShardSpec, SweepSpec};
use carbon_sim::experiments::sweep_stream::{self, CELLS_FILE};
use carbon_sim::experiments::OUTPUT_SCHEMA_VERSION;
use carbon_sim::trace::azure::Workload;
use carbon_sim::util::json::parse;

fn tiny_spec() -> SweepSpec {
    SweepSpec {
        rates: vec![5.0],
        core_counts: vec![8],
        policies: vec!["linux".into(), "proposed".into()],
        workloads: vec![Workload::Mixed, Workload::Bursty],
        replicas: 1,
        duration_s: 3.0,
        n_prompt: 1,
        n_token: 1,
        seed: 31,
        fleet: None,
        lifecycle: None,
    }
}

/// Fresh scratch dir under the system temp root.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("carbon_sim_sweep_shard").join(name);
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn run_shard(spec: &SweepSpec, dir: &std::path::Path, shard: ShardSpec, resume: bool) {
    sweep_stream::run_streaming(spec, 2, dir, &shard, Format::Json, resume, false).unwrap();
}

/// Run every shard of a K-way split under `root`, returning the dirs.
fn run_split(spec: &SweepSpec, root: &std::path::Path, count: usize) -> Vec<PathBuf> {
    (0..count)
        .map(|k| {
            let dir = root.join(format!("s{k}"));
            fs::create_dir_all(&dir).unwrap();
            run_shard(spec, &dir, ShardSpec::new(k, count).unwrap(), false);
            dir
        })
        .collect()
}

#[test]
fn three_way_split_merges_byte_identical_to_the_unsharded_run() {
    let spec = tiny_spec();
    let root = scratch("threeway");

    // Single-machine references: streamed and in-memory.
    let full_dir = root.join("full");
    let s = sweep_stream::run_streaming(
        &spec,
        2,
        &full_dir,
        &ShardSpec::full(),
        Format::Json,
        false,
        false,
    )
    .unwrap();
    let expected_json = fs::read(s.report_path.unwrap()).unwrap();
    assert_eq!(
        expected_json,
        sweep::run(&spec, 1).unwrap().render(Format::Json).into_bytes(),
        "streamed full run must match the in-memory render"
    );

    let dirs = run_split(&spec, &root, 3);
    // Each shard spill records its assignment and holds only owned rows.
    let n = spec.n_cells();
    let mut total_rows = 0;
    for (k, dir) in dirs.iter().enumerate() {
        let shard = ShardSpec::new(k, 3).unwrap();
        let spill = fs::read_to_string(dir.join(CELLS_FILE)).unwrap();
        let header = parse(spill.lines().next().unwrap()).unwrap();
        assert_eq!(header.usize_or("shard_index", 99), k, "{spill}");
        assert_eq!(header.usize_or("shard_count", 99), 3);
        let rows: Vec<usize> = spill
            .lines()
            .skip(1)
            .map(|l| parse(l).unwrap().usize_or("index", usize::MAX))
            .collect();
        assert_eq!(rows.len(), shard.owned_count(n));
        assert!(rows.iter().all(|&i| shard.owns(i)), "shard {k} spilled a foreign cell");
        total_rows += rows.len();
    }
    assert_eq!(total_rows, n, "shards must partition the grid");

    // Merge → byte-identical JSON report, and a complete unsharded spill.
    let merged = root.join("merged");
    let m = merge_spills(&dirs, &merged, Format::Json).unwrap();
    assert_eq!(m.n_spills, 3);
    assert_eq!(m.n_cells, n);
    assert_eq!(fs::read(&m.report_path).unwrap(), expected_json);
    let merged_spill = fs::read_to_string(&m.cells_path).unwrap();
    assert_eq!(merged_spill.lines().count(), 1 + n);
    let merged_header = parse(merged_spill.lines().next().unwrap()).unwrap();
    assert!(merged_header.get("shard_index").is_none(), "merged spill is unsharded");

    // The merged dir now behaves like a single-machine out-dir: CSV
    // assembles from it too, matching the in-memory CSV byte-for-byte.
    let m2 = merge_spills(&dirs, &root.join("merged_csv"), Format::Csv).unwrap();
    assert_eq!(
        fs::read_to_string(&m2.report_path).unwrap(),
        sweep::run(&spec, 1).unwrap().render(Format::Csv)
    );
}

#[test]
fn merge_of_a_single_full_spill_reproduces_its_report() {
    let spec = tiny_spec();
    let root = scratch("single_full");
    let full_dir = root.join("full");
    let s = sweep_stream::run_streaming(
        &spec,
        2,
        &full_dir,
        &ShardSpec::full(),
        Format::Json,
        false,
        false,
    )
    .unwrap();
    let expected = fs::read(s.report_path.unwrap()).unwrap();
    let m = merge_spills(&[full_dir], &root.join("merged"), Format::Json).unwrap();
    assert_eq!(fs::read(&m.report_path).unwrap(), expected);
}

#[test]
fn merge_rejects_a_missing_shard_listing_missing_cells() {
    let spec = tiny_spec();
    let root = scratch("missing_shard");
    let dirs = run_split(&spec, &root, 3);
    // Drop shard 1: its cells (index % 3 == 1) must be reported.
    let err =
        merge_spills(&[dirs[0].clone(), dirs[2].clone()], &root.join("merged"), Format::Json)
            .unwrap_err();
    assert!(err.contains("incomplete shard set"), "{err}");
    assert!(err.contains("cells missing"), "{err}");
    let shard1 = ShardSpec::new(1, 3).unwrap();
    let first_missing = (0..spec.n_cells()).find(|&i| shard1.owns(i)).unwrap();
    assert!(err.contains(&format!("{first_missing}")), "{err}");
}

#[test]
fn merge_rejects_overlapping_coverage_listing_duplicate_cells() {
    let spec = tiny_spec();
    let root = scratch("overlap");
    let dirs = run_split(&spec, &root, 2);
    // The same shard passed twice is full overlap.
    let err = merge_spills(
        &[dirs[0].clone(), dirs[1].clone(), dirs[0].clone()],
        &root.join("merged"),
        Format::Json,
    )
    .unwrap_err();
    assert!(err.contains("overlapping shard coverage"), "{err}");
    assert!(err.contains("cell 0"), "{err}");
}

#[test]
fn merge_rejects_a_mismatched_spec_hash_naming_the_spill() {
    let spec = tiny_spec();
    let root = scratch("wrong_hash");
    let dirs = run_split(&spec, &root, 2);
    // Shard 1 re-run from a *different* grid (other seed).
    let mut other = tiny_spec();
    other.seed = 32;
    let foreign = root.join("foreign");
    run_shard(&other, &foreign, ShardSpec::new(1, 2).unwrap(), false);
    let err = merge_spills(&[dirs[0].clone(), foreign.clone()], &root.join("merged"), Format::Json)
        .unwrap_err();
    assert!(err.contains("spec hash mismatch"), "{err}");
    assert!(err.contains("foreign"), "error must name the offending spill: {err}");
}

#[test]
fn truncated_shard_tail_is_finished_by_resume_then_merges_clean() {
    let spec = tiny_spec();
    let root = scratch("truncated_tail");
    let full_dir = root.join("full");
    let s = sweep_stream::run_streaming(
        &spec,
        2,
        &full_dir,
        &ShardSpec::full(),
        Format::Json,
        false,
        false,
    )
    .unwrap();
    let expected = fs::read(s.report_path.unwrap()).unwrap();
    let dirs = run_split(&spec, &root, 2);

    // Interrupt shard 1: drop its last complete row and leave a
    // half-written line, exactly what a kill leaves behind.
    let cells = dirs[1].join(CELLS_FILE);
    let spill = fs::read_to_string(&cells).unwrap();
    let lines: Vec<&str> = spill.lines().collect();
    let mut cut: String =
        lines[..lines.len() - 1].iter().map(|l| format!("{l}\n")).collect();
    cut.push_str("{\"index\": 3, \"truncated in-fl"); // no trailing newline
    fs::write(&cells, cut).unwrap();

    // Merging the interrupted shard set fails, pointing at --resume.
    let err = merge_spills(&dirs, &root.join("merged_early"), Format::Json).unwrap_err();
    assert!(err.contains("incomplete shard set"), "{err}");
    assert!(err.contains("--resume"), "{err}");

    // Resume composes with --shard: finish shard 1, then merge clean.
    run_shard(&spec, &dirs[1], ShardSpec::new(1, 2).unwrap(), true);
    let m = merge_spills(&dirs, &root.join("merged"), Format::Json).unwrap();
    assert_eq!(fs::read(&m.report_path).unwrap(), expected);
}

#[test]
fn shard_resume_refuses_a_spill_from_another_shard_or_the_full_grid() {
    let spec = tiny_spec();
    let root = scratch("resume_wrong_shard");
    let dir = root.join("s0");
    run_shard(&spec, &dir, ShardSpec::new(0, 2).unwrap(), false);
    // Resuming the 0/2 spill as shard 1/2 must be refused…
    let err = sweep_stream::run_streaming(
        &spec,
        1,
        &dir,
        &ShardSpec::new(1, 2).unwrap(),
        Format::Json,
        true,
        false,
    )
    .unwrap_err();
    assert!(err.contains("shard 0/2"), "{err}");
    assert!(err.contains("1/2"), "{err}");
    // …and so must resuming it as an unsharded run.
    let err2 = sweep_stream::run_streaming(
        &spec,
        1,
        &dir,
        &ShardSpec::full(),
        Format::Json,
        true,
        false,
    )
    .unwrap_err();
    assert!(err2.contains("shard 0/2"), "{err2}");
}

#[test]
fn shard_resume_skips_only_the_shards_own_done_cells() {
    let spec = tiny_spec();
    let root = scratch("shard_resume_counts");
    let shard = ShardSpec::new(1, 2).unwrap();
    let dir = root.join("s1");
    run_shard(&spec, &dir, shard, false);
    let owned = shard.owned_count(spec.n_cells());
    assert_eq!(owned, 2, "shard 1/2 of the 4-cell grid owns cells 1 and 3");

    // Keep the header + one row, truncate the rest mid-line.
    let cells = dir.join(CELLS_FILE);
    let spill = fs::read_to_string(&cells).unwrap();
    let mut cut: String =
        spill.lines().take(2).map(|l| format!("{l}\n")).collect();
    cut.push_str("{\"ind");
    fs::write(&cells, cut).unwrap();

    let s = sweep_stream::run_streaming(
        &spec, 2, &dir, &shard, Format::Json, true, false,
    )
    .unwrap();
    assert_eq!(s.n_cells, owned);
    assert_eq!(s.n_resumed, 1);
    assert_eq!(s.n_run, owned - 1);
    assert!(s.report_path.is_none(), "a shard run must not assemble a report");
    // The finished shard spill is whole again.
    let spill = fs::read_to_string(&cells).unwrap();
    assert_eq!(spill.lines().count(), 1 + owned);
}

#[test]
fn corrupt_shard_header_fields_are_rejected_not_coerced() {
    // A negative or fractional shard field must fail loudly — the
    // lenient as-usize cast would saturate it into a plausible shard.
    let spec = tiny_spec();
    let root = scratch("corrupt_header");
    let dir = root.join("s0");
    run_shard(&spec, &dir, ShardSpec::new(0, 2).unwrap(), false);
    let cells = dir.join(CELLS_FILE);
    let spill = fs::read_to_string(&cells).unwrap();
    let poisoned = spill.replacen("\"shard_index\":0", "\"shard_index\":-1", 1);
    assert_ne!(poisoned, spill, "header must contain the shard_index field");
    fs::write(&cells, poisoned).unwrap();
    let err = merge_spills(&[dir.clone()], &root.join("merged"), Format::Json).unwrap_err();
    assert!(err.contains("shard_index"), "{err}");
    let err2 = sweep_stream::run_streaming(
        &spec,
        1,
        &dir,
        &ShardSpec::new(0, 2).unwrap(),
        Format::Json,
        true,
        false,
    )
    .unwrap_err();
    assert!(err2.contains("shard_index"), "{err2}");
}

#[test]
fn version_2_spills_are_still_accepted_and_version_1_refused() {
    // The spill format is unchanged since schema_version 2 (3 only added
    // the orchestrate manifest), so relabelled v2 spills must keep
    // merging and resuming — days of shard work must not be orphaned by
    // a label bump. v1 really differs (no embedded spec) and stays out.
    let spec = tiny_spec();
    let root = scratch("v2_compat");
    let dirs = run_split(&spec, &root, 2);
    let cells = dirs[0].join(CELLS_FILE);
    let spill = fs::read_to_string(&cells).unwrap();
    let v2 = spill.replacen(
        &format!("\"schema_version\":{OUTPUT_SCHEMA_VERSION}"),
        "\"schema_version\":2",
        1,
    );
    assert_ne!(v2, spill, "header must carry the current schema_version");
    fs::write(&cells, v2).unwrap();

    let m = merge_spills(&dirs, &root.join("merged"), Format::Json).unwrap();
    assert_eq!(m.n_cells, spec.n_cells());
    let s = sweep_stream::run_streaming(
        &spec,
        1,
        &dirs[0],
        &ShardSpec::new(0, 2).unwrap(),
        Format::Json,
        true,
        false,
    )
    .unwrap();
    assert_eq!(s.n_run, 0, "a v2 spill resumes without re-running anything");

    // Resume compaction preserved the v2 header; relabel it down to 1.
    let spill = fs::read_to_string(&cells).unwrap();
    let v1 = spill.replacen("\"schema_version\":2", "\"schema_version\":1", 1);
    assert_ne!(v1, spill);
    fs::write(&cells, v1).unwrap();
    let err = merge_spills(&dirs, &root.join("merged_v1"), Format::Json).unwrap_err();
    assert!(err.contains("schema_version 1"), "{err}");
}

#[test]
fn a_more_shards_than_cells_split_still_merges() {
    // 2 cells over 3 shards: shard 2 owns nothing — its spill is
    // header-only, and the merge must still reassemble cleanly.
    let mut spec = tiny_spec();
    spec.workloads = vec![Workload::Mixed];
    spec.duration_s = 2.0;
    assert_eq!(spec.n_cells(), 2);
    let root = scratch("tiny_grid_many_shards");
    let dirs = run_split(&spec, &root, 3);
    let empty_spill = fs::read_to_string(dirs[2].join(CELLS_FILE)).unwrap();
    assert_eq!(empty_spill.lines().count(), 1, "shard 2 of 3 owns no cell of a 2-cell grid");
    let m = merge_spills(&dirs, &root.join("merged"), Format::Json).unwrap();
    assert_eq!(m.n_cells, 2);
}
