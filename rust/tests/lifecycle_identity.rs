//! Lifecycle identity wall: the fleet/lifecycle subsystem must be
//! invisible unless configured.
//!
//! Two contracts are pinned here. First, a spec **without** a `fleet`
//! block renders byte-identical reports at any thread count and under
//! either queue implementation, and its records carry none of the
//! lifecycle keys — the pre-lifecycle schema, to the byte. Second, the
//! differential contract: a fleet of ONE default-generation group
//! covering every machine consumes the exact RNG streams the no-fleet
//! path does (`ProcVarSampler::sample_chip` draws a fixed `n_chip²`
//! gaussians per chip regardless of core count), so its report must
//! equal the no-fleet report *exactly* apart from the five lifecycle
//! summary keys.

use carbon_sim::cluster::{ClusterConfig, FleetConfig, MachineGroup};
use carbon_sim::experiments::sweep::{
    self, csv_columns, Format, SweepSpec, CSV_COLUMNS, LIFECYCLE_CSV_COLUMNS,
};
use carbon_sim::sim::QueueKind;
use carbon_sim::trace::azure::Workload;
use carbon_sim::util::json::{parse, Value};

fn tiny_spec() -> SweepSpec {
    SweepSpec {
        rates: vec![5.0],
        core_counts: vec![8],
        policies: vec!["linux".into(), "proposed".into()],
        workloads: vec![Workload::Mixed],
        replicas: 1,
        duration_s: 5.0,
        n_prompt: 1,
        n_token: 2,
        seed: 2024,
        fleet: None,
        lifecycle: None,
    }
}

/// One default-generation group covering the whole cluster — the
/// configuration that must be a perfect no-op.
fn uniform_fleet(spec: &SweepSpec) -> FleetConfig {
    FleetConfig {
        groups: vec![MachineGroup {
            count: spec.n_prompt + spec.n_token,
            cores: spec.core_counts[0],
            ..MachineGroup::default()
        }],
    }
}

#[test]
fn no_fleet_reports_are_byte_identical_at_any_threads_and_either_queue() {
    let spec = tiny_spec();
    let base = sweep::run_with_queue(&spec, 1, QueueKind::Heap).unwrap();
    let json = base.render(Format::Json);
    let csv = base.render(Format::Csv);
    for threads in [1, 2, 4] {
        for queue in [QueueKind::Heap, QueueKind::Calendar] {
            let r = sweep::run_with_queue(&spec, threads, queue).unwrap();
            assert_eq!(
                r.render(Format::Json),
                json,
                "JSON diverged at {threads} threads under {queue:?}"
            );
            assert_eq!(
                r.render(Format::Csv),
                csv,
                "CSV diverged at {threads} threads under {queue:?}"
            );
        }
    }
}

#[test]
fn no_fleet_records_keep_the_pre_lifecycle_schema() {
    let spec = tiny_spec();
    assert_eq!(csv_columns(&spec), CSV_COLUMNS.to_vec(), "no fleet, no extra columns");
    let report = sweep::run(&spec, 2).unwrap();
    let csv = report.render(Format::Csv);
    assert_eq!(csv.lines().next().unwrap(), CSV_COLUMNS.join(","));
    let v = parse(&report.render(Format::Json)).unwrap();
    let spec_json = v.get("spec").expect("report embeds the spec");
    assert!(spec_json.get("fleet").is_none(), "no-fleet spec JSON must omit 'fleet'");
    assert!(spec_json.get("lifecycle").is_none(), "no-fleet spec JSON must omit 'lifecycle'");
    for cell in v.get("cells").unwrap().as_arr().unwrap() {
        for key in LIFECYCLE_CSV_COLUMNS {
            assert!(cell.get(key).is_none(), "no-fleet cell record must not carry '{key}'");
        }
    }
}

#[test]
fn a_single_default_group_samples_the_exact_no_fleet_silicon() {
    let cfg = ClusterConfig {
        n_prompt: 1,
        n_token: 2,
        cores_per_cpu: 8,
        seed: 99,
        ..ClusterConfig::default()
    };
    let fleet_cfg = ClusterConfig {
        fleet: Some(FleetConfig {
            groups: vec![MachineGroup { count: 3, cores: 8, ..MachineGroup::default() }],
        }),
        ..cfg.clone()
    };
    assert_eq!(
        cfg.sample_f0(),
        fleet_cfg.sample_f0(),
        "a default-generation fleet group must consume the no-fleet gaussian stream"
    );
}

#[test]
fn a_default_fleet_report_equals_the_no_fleet_report_minus_lifecycle_keys() {
    let plain_spec = tiny_spec();
    let fleet_spec = SweepSpec { fleet: Some(uniform_fleet(&plain_spec)), ..tiny_spec() };
    let plain = sweep::run(&plain_spec, 2).unwrap();
    let fleet = sweep::run(&fleet_spec, 2).unwrap();

    let pv = parse(&plain.render(Format::Json)).unwrap();
    let fv = parse(&fleet.render(Format::Json)).unwrap();
    let pcells = pv.get("cells").unwrap().as_arr().unwrap();
    let fcells = fv.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(pcells.len(), fcells.len());
    for (p, f) in pcells.iter().zip(fcells) {
        // With no lifecycle block, nothing can have happened...
        assert_eq!(f.usize_or("lifecycle_retirements", 99), 0);
        assert_eq!(f.usize_or("lifecycle_core_failures", 99), 0);
        assert_eq!(f.usize_or("lifecycle_rerouted", 99), 0);
        let frac = f.f64_or("active_capacity_fraction", -1.0);
        assert!((0.0..=1.0).contains(&frac), "active_capacity_fraction={frac}");
        // ...but the ledger still amortizes the fleet's embodied carbon
        // at the planned rate: 3 machines × 278.3 kg / 3 yr.
        let yearly = f.f64_or("lifecycle_yearly_embodied_kg", 0.0);
        assert!((yearly - 278.3).abs() < 1e-6, "yearly={yearly}");
        // Stripping exactly the lifecycle keys recovers the no-fleet
        // record byte-for-byte (serialized comparison survives NaNs).
        let mut stripped = f.as_obj().unwrap().clone();
        for key in LIFECYCLE_CSV_COLUMNS {
            assert!(stripped.remove(*key).is_some(), "fleet cell record must carry '{key}'");
        }
        assert_eq!(
            Value::Obj(stripped).to_string_compact(),
            p.to_string_compact(),
            "historic keys diverged under the default fleet"
        );
    }

    // CSV: each fleet row extends the matching no-fleet row by exactly
    // the lifecycle columns.
    let pcsv = plain.render(Format::Csv);
    let fcsv = fleet.render(Format::Csv);
    assert_eq!(pcsv.lines().count(), fcsv.lines().count());
    let n_base = CSV_COLUMNS.len();
    for (pl, fl) in pcsv.lines().zip(fcsv.lines()) {
        let fields: Vec<&str> = fl.split(',').collect();
        assert_eq!(fields.len(), n_base + LIFECYCLE_CSV_COLUMNS.len());
        assert_eq!(fields[..n_base].join(","), pl);
    }
}
