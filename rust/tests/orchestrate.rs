//! Orchestrator contract tests: `orchestrate` must drive N real shard
//! child processes from one spec and converge on reports **byte-identical**
//! to a single-machine run — including after a shard is killed mid-run
//! and the orchestrate is resumed — and a failing launcher must exhaust
//! its retries and surface the shard's stderr tail.

use std::fs;
use std::path::{Path, PathBuf};

use carbon_sim::experiments::orchestrate::{
    self, OrchestrateConfig, MANIFEST_FILE,
};
use carbon_sim::experiments::sweep::{self, Format, SweepSpec};
use carbon_sim::experiments::sweep_stream::CELLS_FILE;
use carbon_sim::trace::azure::Workload;
use carbon_sim::util::json::{parse, Value};

fn bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_carbon-sim"))
}

/// 4 cells: 2 policies × (mixed, bursty). Small enough that a shard
/// child finishes in well under a second.
fn tiny_spec() -> SweepSpec {
    SweepSpec {
        rates: vec![5.0],
        core_counts: vec![8],
        policies: vec!["linux".into(), "proposed".into()],
        workloads: vec![Workload::Mixed, Workload::Bursty],
        replicas: 1,
        duration_s: 3.0,
        n_prompt: 1,
        n_token: 1,
        seed: 31,
        fleet: None,
        lifecycle: None,
    }
}

/// Fresh scratch dir under the system temp root.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("carbon_sim_orchestrate").join(name);
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write the spec file the shard children will re-read. The canonical
/// JSON round-trips through `config::sweep_from_file` to the same hash.
fn write_spec(dir: &Path, spec: &SweepSpec) -> PathBuf {
    let path = dir.join("spec.json");
    let mut body = spec.to_json().to_string_pretty();
    body.push('\n');
    fs::write(&path, body).unwrap();
    path
}

fn cfg(spec: &SweepSpec, spec_path: &Path, shards: usize) -> OrchestrateConfig {
    OrchestrateConfig {
        spec: spec.clone(),
        spec_path: spec_path.to_path_buf(),
        shards,
        workers: 0,
        retries: 1,
        threads_per_shard: 1,
        format: Format::Json,
        launcher: None,
        program: bin(),
        resume: false,
        verbose: false,
    }
}

/// The single-machine reference bytes for `report.json`.
fn reference_json(spec: &SweepSpec) -> Vec<u8> {
    sweep::run(spec, 1).unwrap().render(Format::Json).into_bytes()
}

/// Rewrite one shard's manifest status in place (simulating the state a
/// killed orchestrator leaves behind).
fn set_shard_status(manifest_path: &Path, k: usize, status: &str) {
    let mut v = parse(&fs::read_to_string(manifest_path).unwrap()).unwrap();
    let Value::Obj(obj) = &mut v else { panic!("manifest is not an object") };
    let Some(Value::Arr(shards)) = obj.get_mut("shards") else {
        panic!("manifest has no shards array")
    };
    let Value::Obj(entry) = &mut shards[k] else { panic!("shard entry is not an object") };
    entry.insert("status".to_string(), Value::Str(status.to_string()));
    let mut body = v.to_string_pretty();
    body.push('\n');
    fs::write(manifest_path, body).unwrap();
}

#[test]
fn three_shards_merge_byte_identical_to_the_single_machine_run() {
    let spec = tiny_spec();
    let root = scratch("threeway");
    let spec_path = write_spec(&root, &spec);
    let out = root.join("out");

    let s = orchestrate::run(&cfg(&spec, &spec_path, 3), &out).unwrap();
    assert_eq!((s.n_shards, s.n_skipped, s.n_launched), (3, 0, 3));
    assert_eq!(fs::read(&s.report_path).unwrap(), reference_json(&spec));

    // The merged spill is a full, unsharded one.
    let merged = fs::read_to_string(&s.cells_path).unwrap();
    assert_eq!(merged.lines().count(), 1 + spec.n_cells());
    assert!(!merged.lines().next().unwrap().contains("shard_index"), "{merged}");

    // Manifest: every shard done in one attempt, mapped to its out-dir.
    let m = parse(&fs::read_to_string(out.join(MANIFEST_FILE)).unwrap()).unwrap();
    assert_eq!(m.str_or("kind", ""), "orchestrate");
    assert_eq!(m.str_or("spec_hash", ""), spec.spec_hash());
    assert_eq!(m.usize_or("shard_count", 0), 3);
    let shards = m.get("shards").and_then(|s| s.as_arr()).unwrap();
    assert_eq!(shards.len(), 3);
    for (k, entry) in shards.iter().enumerate() {
        assert_eq!(entry.str_or("status", ""), "done", "shard {k}");
        assert_eq!(entry.usize_or("attempts", 0), 1, "shard {k}");
        assert_eq!(entry.usize_or("exit_code", 99), 0, "shard {k}");
        assert_eq!(entry.str_or("out_dir", ""), format!("shard-{k}"));
        assert!(out.join(format!("shard-{k}")).join(CELLS_FILE).exists());
    }
}

#[test]
fn one_shard_degenerates_to_a_single_child_full_run() {
    let spec = tiny_spec();
    let root = scratch("single");
    let spec_path = write_spec(&root, &spec);
    let s = orchestrate::run(&cfg(&spec, &spec_path, 1), &root.join("out")).unwrap();
    assert_eq!(fs::read(&s.report_path).unwrap(), reference_json(&spec));
}

#[test]
fn killed_shard_mid_run_then_resume_converges_on_identical_bytes() {
    let spec = tiny_spec();
    let root = scratch("kill_resume");
    let spec_path = write_spec(&root, &spec);
    let out = root.join("out");
    let expected = reference_json(&spec);

    let first = orchestrate::run(&cfg(&spec, &spec_path, 2), &out).unwrap();
    assert_eq!(fs::read(&first.report_path).unwrap(), expected);

    // Simulate a kill while shard 1 was in flight: its spill loses the
    // last complete row and gains a half-written line, and the manifest
    // still says "running".
    let cells = out.join("shard-1").join(CELLS_FILE);
    let spill = fs::read_to_string(&cells).unwrap();
    let lines: Vec<&str> = spill.lines().collect();
    assert_eq!(lines.len(), 1 + 2, "shard 1/2 of the 4-cell grid owns 2 cells");
    let mut cut: String = lines[..lines.len() - 1].iter().map(|l| format!("{l}\n")).collect();
    cut.push_str("{\"index\": 3, \"truncated in-fl"); // no trailing newline
    fs::write(&cells, cut).unwrap();
    set_shard_status(&out.join(MANIFEST_FILE), 1, "running");
    fs::remove_file(first.report_path).unwrap();

    let mut resume_cfg = cfg(&spec, &spec_path, 2);
    resume_cfg.resume = true;
    let s = orchestrate::run(&resume_cfg, &out).unwrap();
    assert_eq!((s.n_skipped, s.n_launched), (1, 1), "only the killed shard relaunches");
    assert_eq!(fs::read(&s.report_path).unwrap(), expected);

    let m = parse(&fs::read_to_string(out.join(MANIFEST_FILE)).unwrap()).unwrap();
    let shards = m.get("shards").and_then(|s| s.as_arr()).unwrap();
    assert_eq!(shards[0].usize_or("attempts", 0), 1, "finished shard untouched");
    assert_eq!(shards[1].usize_or("attempts", 0), 2, "killed shard relaunched once");
    assert_eq!(shards[1].str_or("status", ""), "done");
    // The intact row was reused: the resumed shard spill is whole again.
    assert_eq!(fs::read_to_string(&cells).unwrap().lines().count(), 1 + 2);
}

#[test]
fn deleted_shard_dir_heals_on_resume_despite_a_done_manifest() {
    let spec = tiny_spec();
    let root = scratch("deleted_dir");
    let spec_path = write_spec(&root, &spec);
    let out = root.join("out");
    let expected = reference_json(&spec);
    orchestrate::run(&cfg(&spec, &spec_path, 2), &out).unwrap();

    // The manifest says done, but the spill is gone — the spill is the
    // ground truth, so --resume must re-run that shard.
    fs::remove_dir_all(out.join("shard-0")).unwrap();
    let mut resume_cfg = cfg(&spec, &spec_path, 2);
    resume_cfg.resume = true;
    let s = orchestrate::run(&resume_cfg, &out).unwrap();
    assert_eq!((s.n_skipped, s.n_launched), (1, 1));
    assert_eq!(fs::read(&s.report_path).unwrap(), expected);
}

#[test]
fn failing_launcher_exhausts_retries_and_surfaces_the_stderr_tail() {
    let spec = tiny_spec();
    let root = scratch("bad_launcher");
    let spec_path = write_spec(&root, &spec);
    let out = root.join("out");

    let mut bad = cfg(&spec, &spec_path, 2);
    bad.retries = 1;
    bad.launcher =
        Some("echo starting {shard} from {spec} into {out_dir}; echo boom-{shard} >&2; exit 3"
            .to_string());
    let err = orchestrate::run(&bad, &out).unwrap_err();
    assert!(err.contains("2 of 2 shard(s) failed"), "{err}");
    assert!(err.contains("exit code 3"), "{err}");
    assert!(err.contains("boom-0/2"), "stderr tail must be surfaced: {err}");
    assert!(err.contains("boom-1/2"), "stderr tail must be surfaced: {err}");
    assert!(err.contains("--resume"), "{err}");

    // The manifest parked both shards as failed with the evidence.
    let m = parse(&fs::read_to_string(out.join(MANIFEST_FILE)).unwrap()).unwrap();
    let shards = m.get("shards").and_then(|s| s.as_arr()).unwrap();
    for (k, entry) in shards.iter().enumerate() {
        assert_eq!(entry.str_or("status", ""), "failed", "shard {k}");
        assert_eq!(entry.usize_or("attempts", 0), 2, "1 launch + 1 retry");
        assert_eq!(entry.usize_or("exit_code", 99), 3);
        let tail = entry.get("stderr_tail").and_then(|t| t.as_arr()).unwrap();
        assert_eq!(tail.len(), 1);
        assert_eq!(tail[0].as_str().unwrap(), format!("boom-{k}/2"));
    }

    // A --resume with a working launcher heals the run completely.
    let mut good = cfg(&spec, &spec_path, 2);
    good.resume = true;
    let s = orchestrate::run(&good, &out).unwrap();
    assert_eq!(s.n_launched, 2);
    assert_eq!(fs::read(&s.report_path).unwrap(), reference_json(&spec));
    let m = parse(&fs::read_to_string(out.join(MANIFEST_FILE)).unwrap()).unwrap();
    let shards = m.get("shards").and_then(|s| s.as_arr()).unwrap();
    for entry in shards {
        assert_eq!(entry.str_or("status", ""), "done");
        assert_eq!(entry.usize_or("attempts", 0), 3, "attempts accumulate across runs");
        assert!(entry.get("stderr_tail").is_none(), "tail cleared on success");
    }
}

#[test]
fn launcher_template_driving_the_real_binary_matches_the_reference() {
    let spec = tiny_spec();
    let root = scratch("template");
    let spec_path = write_spec(&root, &spec);

    let mut c = cfg(&spec, &spec_path, 2);
    c.launcher = Some(format!(
        "\"{}\" sweep --spec \"{{spec}}\" --shard {{shard}} --out-dir \"{{out_dir}}\" \
         --threads 1 --resume --quiet",
        bin().display()
    ));
    let s = orchestrate::run(&c, &root.join("out")).unwrap();
    assert_eq!(fs::read(&s.report_path).unwrap(), reference_json(&spec));
}

#[test]
fn async_launcher_that_returns_early_fails_verification() {
    // A launcher that exits 0 without producing the spill (sbatch-style
    // fire-and-forget) must not be trusted: verification fails it.
    let spec = tiny_spec();
    let root = scratch("async_launcher");
    let spec_path = write_spec(&root, &spec);
    let mut c = cfg(&spec, &spec_path, 2);
    c.retries = 0;
    c.launcher = Some("echo queued {shard}; exit 0".to_string());
    let err = orchestrate::run(&c, &root.join("out")).unwrap_err();
    assert!(err.contains("exit 0 but"), "{err}");
}
