//! Three-layer integration tests: the Rust runtime loading and executing
//! the AOT artifacts (L1 Pallas kernels + L2 JAX graphs) through PJRT,
//! cross-validated against the pure-Rust models.
//!
//! These tests need `make artifacts`; they skip (with a note) when the
//! artifacts are absent so `cargo test` works standalone.

use carbon_sim::cpu::AgingParams;
use carbon_sim::runtime::{AgingStepPjrt, Manifest, Runtime, ServedModel};
use carbon_sim::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    // Tests run from the workspace root.
    let dir = Runtime::default_artifacts_dir();
    if Runtime::artifacts_available(&dir) {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not found in {dir:?} (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_is_consistent_with_weights() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).expect("manifest");
    let w = m.load_weights(&dir).expect("weights");
    assert_eq!(w.len(), m.params.len());
    for (entry, data) in m.params.iter().zip(w.iter()) {
        assert_eq!(entry.n_elems(), data.len(), "{}", entry.name);
        assert!(data.iter().all(|x| x.is_finite()), "{} has non-finite weights", entry.name);
    }
    assert_eq!(m.model.vocab, 256);
    assert!(m.aging.machines > 0 && m.aging.cores > 0);
}

#[test]
fn aging_step_artifact_matches_rust_model() {
    // The L1 Pallas kernel (via PJRT) and cpu::aging must agree bitwise-ish.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu(&dir).expect("pjrt client");
    let step = AgingStepPjrt::load(&rt).expect("aging exe");
    let aging = AgingParams::paper_default();
    let n = step.machines * step.cores;
    let mut rng = Rng::new(42);
    let dvth: Vec<f32> = (0..n).map(|_| rng.range_f64(0.0, 0.08) as f32).collect();
    let adf: Vec<f32> = (0..n).map(|_| rng.range_f64(5e-4, 2e-2) as f32).collect();
    let tau: Vec<f32> = (0..n)
        .map(|_| if rng.bool(0.25) { 0.0 } else { rng.range_f64(1.0, 3e7) as f32 })
        .collect();
    let f0: Vec<f32> = (0..n).map(|_| rng.range_f64(2.3, 2.8) as f32).collect();

    let (new_dvth, freq) = step.step(&dvth, &adf, &tau, &f0).expect("step");
    assert_eq!(new_dvth.len(), n);
    for i in 0..n {
        let expect_dvth = if tau[i] > 0.0 {
            aging.dvth_step(dvth[i] as f64, adf[i] as f64, tau[i] as f64)
        } else {
            dvth[i] as f64
        };
        let expect_f = aging.freq_ghz(f0[i] as f64, expect_dvth);
        assert!(
            (new_dvth[i] as f64 - expect_dvth).abs() < 5e-4,
            "dvth[{i}] pjrt={} rust={}",
            new_dvth[i],
            expect_dvth
        );
        assert!(
            (freq[i] as f64 - expect_f).abs() < 5e-3,
            "freq[{i}] pjrt={} rust={}",
            freq[i],
            expect_f
        );
    }
}

#[test]
fn served_model_prefill_and_decode_run() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu(&dir).expect("pjrt client");
    let model = ServedModel::load(rt).expect("model load");
    let d = model.dims;

    let mut tokens = vec![0i32; d.batch * d.max_seq];
    for (i, t) in tokens.iter_mut().enumerate().take(d.max_seq) {
        *t = (i % 200) as i32; // sequence 0 gets a real prompt
    }
    let lengths: Vec<i32> = (0..d.batch).map(|b| (4 + 3 * b) as i32).collect();
    let pf = model.prefill(&tokens, &lengths).expect("prefill");
    assert_eq!(pf.logits.len(), d.batch * d.vocab);
    assert_eq!(pf.k_cache.len(), d.kv_elems());
    assert!(pf.logits.iter().all(|x| x.is_finite()));

    let next = model.argmax_tokens(&pf.logits);
    assert_eq!(next.len(), d.batch);
    assert!(next.iter().all(|&t| (0..d.vocab as i32).contains(&t)));

    let dc = model
        .decode(&pf.k_cache, &pf.v_cache, &next, &lengths)
        .expect("decode");
    assert_eq!(dc.logits.len(), d.batch * d.vocab);
    assert!(dc.logits.iter().all(|x| x.is_finite()));
    // The KV cache must change where the new token was written.
    assert_ne!(pf.k_cache, dc.k_cache);
}

#[test]
fn decode_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu(&dir).expect("pjrt client");
    let model = ServedModel::load(rt).expect("model load");
    let d = model.dims;
    let tokens = vec![1i32; d.batch * d.max_seq];
    let lengths = vec![5i32; d.batch];
    let pf = model.prefill(&tokens, &lengths).expect("prefill");
    let next = vec![7i32; d.batch];
    let a = model.decode(&pf.k_cache, &pf.v_cache, &next, &lengths).expect("decode");
    let b = model.decode(&pf.k_cache, &pf.v_cache, &next, &lengths).expect("decode");
    assert_eq!(a.logits, b.logits);
    assert_eq!(a.k_cache, b.k_cache);
}

#[test]
fn serving_stack_end_to_end_smoke() {
    let Some(dir) = artifacts_dir() else { return };
    use carbon_sim::serving::{ServeRequest, Server, ServerConfig};
    let server = Server::start(ServerConfig {
        artifacts_dir: dir,
        policy: "proposed".into(),
        shadow_cores: 16,
        ..Default::default()
    })
    .expect("server");
    let rx1 = server.submit(ServeRequest {
        id: 1,
        prompt: "hello aging-aware world".into(),
        max_new_tokens: 8,
    });
    let rx2 = server.submit(ServeRequest {
        id: 2,
        prompt: "second request".into(),
        max_new_tokens: 4,
    });
    let r1 = rx1.recv().expect("resp1");
    let r2 = rx2.recv().expect("resp2");
    assert_eq!(r1.generated_tokens, 8);
    assert_eq!(r2.generated_tokens, 4);
    assert!(r1.ttft_s > 0.0 && r1.e2e_s >= r1.ttft_s);
    let report = server.shutdown();
    assert_eq!(report.requests, 2);
    assert_eq!(report.generated_tokens, 12);
    assert!(report.shadow.tasks_started > 0);
}

#[test]
fn decode_chunk_matches_single_steps() {
    // The fused-chunk artifact must reproduce token-by-token decode.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu(&dir).expect("pjrt client");
    let model = ServedModel::load(rt).expect("model load");
    let d = model.dims;
    let chunk = model.decode_chunk_steps;
    assert!(chunk > 0, "artifacts must include decode_chunk");

    let mut tokens = vec![0i32; d.batch * d.max_seq];
    for (i, t) in tokens.iter_mut().enumerate().take(d.max_seq) {
        *t = (13 + i % 101) as i32;
    }
    let lengths: Vec<i32> = (0..d.batch).map(|b| (3 + 2 * b) as i32).collect();
    let pf = model.prefill(&tokens, &lengths).expect("prefill");
    let first = model.argmax_tokens(&pf.logits);
    let budgets: Vec<i32> = (0..d.batch).map(|b| (chunk as i32).min(2 + b as i32)).collect();

    // Reference path: single-step decode with manual freeze logic.
    let (mut k, mut v) = (pf.k_cache.clone(), pf.v_cache.clone());
    let mut cur = first.clone();
    let mut lens = lengths.clone();
    let mut rem = budgets.clone();
    let mut ref_tokens: Vec<Vec<i32>> = vec![Vec::new(); d.batch];
    for _ in 0..chunk {
        let out = model.decode(&k, &v, &cur, &lens).expect("decode");
        let next = model.argmax_tokens(&out.logits);
        k = out.k_cache;
        v = out.v_cache;
        for b in 0..d.batch {
            if rem[b] > 0 {
                ref_tokens[b].push(next[b]);
                cur[b] = next[b];
                lens[b] += 1;
                rem[b] -= 1;
            }
        }
    }

    // Chunked path.
    let out = model
        .decode_chunk(&pf.k_cache, &pf.v_cache, &first, &lengths, &budgets)
        .expect("decode_chunk");
    for b in 0..d.batch {
        let got: Vec<i32> = (0..chunk)
            .map(|s| out.tokens[b * chunk + s])
            .filter(|&t| t >= 0)
            .collect();
        assert_eq!(got, ref_tokens[b], "slot {b}");
        assert_eq!(out.lengths[b], lens[b], "slot {b} length");
        assert_eq!(out.remaining[b], rem[b], "slot {b} remaining");
    }
    // KV caches agree closely.
    for (a, b) in out.k_cache.iter().zip(k.iter()) {
        assert!((a - b).abs() < 1e-4);
    }
}
