//! Launcher smoke tests: every CLI subcommand must run end-to-end.

use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_carbon-sim")
}

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(bin()).args(args).output().expect("spawn carbon-sim");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_lists_subcommands() {
    let (ok, text) = run(&["--help"]);
    assert!(ok);
    for cmd in ["simulate", "figure", "trace-gen", "serve", "aging-demo"] {
        assert!(text.contains(cmd), "missing {cmd} in help");
    }
}

#[test]
fn unknown_subcommand_fails() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown subcommand"));
}

#[test]
fn simulate_runs_small() {
    let (ok, text) = run(&[
        "simulate",
        "--rate",
        "5",
        "--duration",
        "5",
        "--cores",
        "8",
        "--prompt-machines",
        "1",
        "--token-machines",
        "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("requests completed"));
    assert!(text.contains("mean fred"));
}

#[test]
fn simulate_with_config_file() {
    let dir = std::env::temp_dir().join("carbon_sim_cli_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("c.json");
    std::fs::write(&cfg, r#"{"cores_per_cpu": 8, "n_prompt": 1, "n_token": 1, "policy": "linux"}"#)
        .unwrap();
    let (ok, text) = run(&[
        "simulate",
        "--config",
        cfg.to_str().unwrap(),
        "--rate",
        "3",
        "--duration",
        "4",
    ]);
    assert!(ok, "{text}");
    // The printed rate is the trace's *achieved* rate, so match loosely.
    assert!(text.contains("(linux @"), "{text}");
    assert!(text.contains("8 cores)"), "{text}");
}

#[test]
fn simulate_rejects_bad_config() {
    let dir = std::env::temp_dir().join("carbon_sim_cli_cfg2");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("bad.json");
    std::fs::write(&cfg, r#"{"policy": "nope"}"#).unwrap();
    let (ok, text) = run(&["simulate", "--config", cfg.to_str().unwrap()]);
    assert!(!ok);
    assert!(text.contains("config error"));
}

#[test]
fn figures_smoke_scale() {
    // Analytic figures are instant; simulation figures use smoke scale.
    for fig in ["1", "4", "5"] {
        let (ok, text) = run(&["figure", "--fig", fig, "--scale", "smoke"]);
        assert!(ok, "fig {fig}: {text}");
        assert!(text.contains(&format!("Fig {fig}")), "fig {fig}: {text}");
    }
    let (ok, text) = run(&["figure", "--fig", "8", "--scale", "smoke", "--duration", "5"]);
    assert!(ok, "{text}");
    assert!(text.contains("normalized idle"));
}

#[test]
fn trace_gen_writes_loadable_file() {
    let dir = std::env::temp_dir().join("carbon_sim_cli_trace");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.jsonl");
    let (ok, text) = run(&[
        "trace-gen",
        "--rate",
        "20",
        "--duration",
        "5",
        "--out",
        path.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    let trace = carbon_sim::trace::loader::load(&path).expect("loadable trace");
    assert!(!trace.requests.is_empty());
    // And it can be replayed.
    let (ok2, text2) =
        run(&["simulate", "--trace", path.to_str().unwrap(), "--cores", "8",
              "--prompt-machines", "1", "--token-machines", "1"]);
    assert!(ok2, "{text2}");
}

#[test]
fn aging_demo_prints_calibration() {
    let (ok, text) = run(&["aging-demo", "--years", "10"]);
    assert!(ok);
    // Year 10 always-on must show the 30% calibration datum.
    let year10 = text.lines().find(|l| l.trim_start().starts_with("10 ")).unwrap();
    assert!(year10.contains("30.00"), "{year10}");
}
