//! Launcher smoke tests: every CLI subcommand must run end-to-end.

use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_carbon-sim")
}

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(bin()).args(args).output().expect("spawn carbon-sim");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_lists_subcommands() {
    let (ok, text) = run(&["--help"]);
    assert!(ok);
    for cmd in [
        "simulate",
        "sweep",
        "orchestrate",
        "merge",
        "bench",
        "lint",
        "figure",
        "trace-gen",
        "serve",
        "aging-demo",
    ] {
        assert!(text.contains(cmd), "missing {cmd} in help");
    }
}

#[test]
fn unknown_subcommand_fails() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown subcommand"));
}

#[test]
fn simulate_runs_small() {
    let (ok, text) = run(&[
        "simulate",
        "--rate",
        "5",
        "--duration",
        "5",
        "--cores",
        "8",
        "--prompt-machines",
        "1",
        "--token-machines",
        "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("requests completed"));
    assert!(text.contains("mean fred"));
}

#[test]
fn simulate_with_config_file() {
    let dir = std::env::temp_dir().join("carbon_sim_cli_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("c.json");
    std::fs::write(&cfg, r#"{"cores_per_cpu": 8, "n_prompt": 1, "n_token": 1, "policy": "linux"}"#)
        .unwrap();
    let (ok, text) = run(&[
        "simulate",
        "--config",
        cfg.to_str().unwrap(),
        "--rate",
        "3",
        "--duration",
        "4",
    ]);
    assert!(ok, "{text}");
    // The printed rate is the trace's *achieved* rate, so match loosely.
    assert!(text.contains("(linux @"), "{text}");
    assert!(text.contains("8 cores)"), "{text}");
}

#[test]
fn simulate_rejects_bad_config() {
    let dir = std::env::temp_dir().join("carbon_sim_cli_cfg2");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("bad.json");
    std::fs::write(&cfg, r#"{"policy": "nope"}"#).unwrap();
    let (ok, text) = run(&["simulate", "--config", cfg.to_str().unwrap()]);
    assert!(!ok);
    assert!(text.contains("config error"));
}

#[test]
fn figures_smoke_scale() {
    // Analytic figures are instant; simulation figures use smoke scale.
    for fig in ["1", "4", "5"] {
        let (ok, text) = run(&["figure", "--fig", fig, "--scale", "smoke"]);
        assert!(ok, "fig {fig}: {text}");
        assert!(text.contains(&format!("Fig {fig}")), "fig {fig}: {text}");
    }
    let (ok, text) = run(&["figure", "--fig", "8", "--scale", "smoke", "--duration", "5"]);
    assert!(ok, "{text}");
    assert!(text.contains("normalized idle"));
}

#[test]
fn trace_gen_writes_loadable_file() {
    let dir = std::env::temp_dir().join("carbon_sim_cli_trace");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.jsonl");
    let (ok, text) = run(&[
        "trace-gen",
        "--rate",
        "20",
        "--duration",
        "5",
        "--out",
        path.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    let trace = carbon_sim::trace::loader::load(&path).expect("loadable trace");
    assert!(!trace.requests.is_empty());
    // And it can be replayed.
    let (ok2, text2) =
        run(&["simulate", "--trace", path.to_str().unwrap(), "--cores", "8",
              "--prompt-machines", "1", "--token-machines", "1"]);
    assert!(ok2, "{text2}");
}

#[test]
fn sweep_help_lists_axes() {
    // --help exits 2 (usage on stderr), like every other subcommand.
    let (ok, text) = run(&["sweep", "--help"]);
    assert!(!ok);
    for flag in ["--rates", "--cores", "--policies", "--workloads", "--threads", "--out",
                 "--format", "--replicas"] {
        assert!(text.contains(flag), "missing {flag} in sweep help:\n{text}");
    }
    assert!(text.contains("diurnal"), "{text}");
}

#[test]
fn sweep_tiny_end_to_end_writes_deterministic_json() {
    let dir = std::env::temp_dir().join("carbon_sim_cli_sweep");
    std::fs::create_dir_all(&dir).unwrap();
    let args_for = |out: &str, threads: &str| {
        vec![
            "sweep".to_string(),
            "--rates".into(), "5".into(),
            "--cores".into(), "8".into(),
            "--policies".into(), "all".into(),
            "--workloads".into(), "mixed,bursty".into(),
            "--duration".into(), "4".into(),
            "--prompt-machines".into(), "1".into(),
            "--token-machines".into(), "2".into(),
            "--threads".into(), threads.into(),
            "--format".into(), "json".into(),
            "--quiet".into(),
            "--out".into(), out.into(),
        ]
    };
    let p1 = dir.join("sweep_t1.json");
    let p8 = dir.join("sweep_t8.json");
    let argv1 = args_for(p1.to_str().unwrap(), "1");
    let argv8 = args_for(p8.to_str().unwrap(), "8");
    let (ok1, t1) = run(&argv1.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    assert!(ok1, "{t1}");
    let (ok8, t8) = run(&argv8.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    assert!(ok8, "{t8}");
    let b1 = std::fs::read(&p1).unwrap();
    let b8 = std::fs::read(&p8).unwrap();
    assert_eq!(b1, b8, "sweep output must be byte-identical at any thread count");
    // And it is valid JSON with the expected cell count: 1 rate × 1
    // core count × 3 policies × 2 workloads = 6 cells.
    let v = carbon_sim::util::json::parse(&String::from_utf8(b1).unwrap()).unwrap();
    assert_eq!(v.usize_or("n_cells", 0), 6);
    assert_eq!(v.get("cells").and_then(|c| c.as_arr()).unwrap().len(), 6);
}

#[test]
fn sweep_csv_format_writes_table() {
    let dir = std::env::temp_dir().join("carbon_sim_cli_sweep_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("sweep.csv");
    let (ok, text) = run(&[
        "sweep", "--rates", "4", "--cores", "8", "--policies", "proposed",
        "--workloads", "diurnal", "--duration", "4", "--prompt-machines", "1",
        "--token-machines", "1", "--quiet", "--format", "csv", "--out",
        p.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    let csv = std::fs::read_to_string(&p).unwrap();
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines.len(), 2, "{csv}");
    assert!(lines[0].starts_with("scenario,workload,cores"), "{csv}");
    assert!(lines[1].contains("diurnal"), "{csv}");
}

#[test]
fn sweep_spec_file_streams_cells_and_assembles_report() {
    let spec = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/specs/smoke.json");
    let dir = std::env::temp_dir().join("carbon_sim_cli_sweep_spec");
    let _ = std::fs::remove_dir_all(&dir);
    let out_dir = dir.join("out");
    let (ok, text) = run(&[
        "sweep",
        "--spec",
        spec,
        "--threads",
        "4",
        "--quiet",
        "--out-dir",
        out_dir.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("streamed 3 cells"), "{text}");
    // Spill: header + one row per cell (1 rate × 1 core count × 3 policies).
    let spill = std::fs::read_to_string(out_dir.join("cells.jsonl")).unwrap();
    assert_eq!(spill.lines().count(), 1 + 3, "{spill}");
    assert!(spill.lines().next().unwrap().contains("sweep-cells"), "{spill}");
    // Report: valid JSON with the documented shape.
    let body = std::fs::read_to_string(out_dir.join("report.json")).unwrap();
    let v = carbon_sim::util::json::parse(&body).unwrap();
    assert_eq!(v.usize_or("n_cells", 0), 3);
    assert_eq!(
        v.usize_or("schema_version", 0),
        carbon_sim::experiments::OUTPUT_SCHEMA_VERSION
    );
    assert_eq!(v.get("cells").and_then(|c| c.as_arr()).unwrap().len(), 3);

    // A --resume re-run finds everything done and reproduces the report.
    let (ok2, text2) = run(&[
        "sweep",
        "--spec",
        spec,
        "--quiet",
        "--resume",
        "--out-dir",
        out_dir.to_str().unwrap(),
    ]);
    assert!(ok2, "{text2}");
    assert!(text2.contains("(3 resumed, 0 run)"), "{text2}");
    assert_eq!(std::fs::read_to_string(out_dir.join("report.json")).unwrap(), body);
}

#[test]
fn sweep_spec_flag_rejects_bad_files() {
    let dir = std::env::temp_dir().join("carbon_sim_cli_sweep_badspec");
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.json");
    std::fs::write(&bad, r#"{"ratez": [40]}"#).unwrap();
    let (ok, text) = run(&["sweep", "--spec", bad.to_str().unwrap()]);
    assert!(!ok);
    assert!(text.contains("ratez"), "{text}");
    let (ok2, _) = run(&["sweep", "--spec", "/nonexistent_spec.json"]);
    assert!(!ok2);
}

#[test]
fn sweep_spec_conflicts_with_axis_flags() {
    let spec = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/specs/smoke.json");
    let (ok, text) = run(&["sweep", "--spec", spec, "--rates", "4"]);
    assert!(!ok);
    assert!(text.contains("--rates"), "{text}");
    let (ok2, text2) = run(&["sweep", "--spec", spec, "--seed", "9"]);
    assert!(!ok2);
    assert!(text2.contains("--seed"), "{text2}");
}

#[test]
fn sweep_search_races_the_pinned_example_spec() {
    let spec = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/specs/search_smoke.json");
    let dir = std::env::temp_dir().join("carbon_sim_cli_sweep_search");
    let _ = std::fs::remove_dir_all(&dir);
    let out_dir = dir.join("out");
    let (ok, text) = run(&[
        "sweep",
        "--spec",
        spec,
        "--search",
        "--threads",
        "4",
        "--quiet",
        "--out-dir",
        out_dir.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("search settled"), "{text}");
    let body = std::fs::read_to_string(out_dir.join("search.json")).unwrap();
    let v = carbon_sim::util::json::parse(&body).unwrap();
    assert_eq!(v.str_or("kind", ""), "sweep-search");
    assert_eq!(v.usize_or("schema_version", 0), carbon_sim::experiments::OUTPUT_SCHEMA_VERSION);
    // The whole point: the settled scenario stops replicating early.
    let (spent, exhaustive) = (v.usize_or("n_cells_run", 0), v.usize_or("n_cells_exhaustive", 0));
    assert!(spent < exhaustive, "search ran {spent}/{exhaustive} cells — nothing settled");
    assert_eq!(v.get("ranking").and_then(|r| r.as_arr()).unwrap().len(), 3);

    // A --resume re-run finds everything done and rewrites the verdict.
    let (ok2, text2) = run(&[
        "sweep",
        "--spec",
        spec,
        "--search",
        "--quiet",
        "--resume",
        "--out-dir",
        out_dir.to_str().unwrap(),
    ]);
    assert!(ok2, "{text2}");
    assert!(text2.contains(", 0 run)"), "{text2}");
    assert_eq!(std::fs::read_to_string(out_dir.join("search.json")).unwrap(), body);
}

#[test]
fn sweep_search_flag_combinations_are_validated() {
    let (ok, text) = run(&["sweep", "--search", "--rates", "5", "--cores", "8"]);
    assert!(!ok);
    assert!(text.contains("--search requires --out-dir"), "{text}");
    let (ok2, text2) = run(&[
        "sweep",
        "--search",
        "--shard",
        "0/2",
        "--out-dir",
        "/tmp/unused_search_dir",
    ]);
    assert!(!ok2);
    assert!(text2.contains("mutually exclusive"), "{text2}");
    let (ok3, text3) = run(&[
        "sweep",
        "--search",
        "--format",
        "csv",
        "--out-dir",
        "/tmp/unused_search_dir",
    ]);
    assert!(!ok3);
    assert!(text3.contains("drop --format"), "{text3}");
}

#[test]
fn sweep_resume_requires_out_dir() {
    let (ok, text) = run(&["sweep", "--resume"]);
    assert!(!ok);
    assert!(text.contains("--out-dir"), "{text}");
}

#[test]
fn sweep_shard_requires_out_dir_and_a_valid_assignment() {
    let (ok, text) = run(&["sweep", "--shard", "0/2"]);
    assert!(!ok);
    assert!(text.contains("--out-dir"), "{text}");
    for bad in ["2/2", "x/2", "1/x", "1/0", "3"] {
        let (ok, text) = run(&["sweep", "--shard", bad, "--out-dir", "/tmp/unused_shard_dir"]);
        assert!(!ok, "--shard {bad} must be rejected:\n{text}");
    }
}

#[test]
fn sharded_sweep_and_merge_reproduce_the_unsharded_report() {
    let spec = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/specs/smoke.json");
    let dir = std::env::temp_dir().join("carbon_sim_cli_sweep_shard");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = |name: &str| dir.join(name).to_str().unwrap().to_string();

    let (ok, text) =
        run(&["sweep", "--spec", spec, "--quiet", "--threads", "2", "--out-dir", &path("full")]);
    assert!(ok, "{text}");
    for k in 0..2 {
        let (ok, text) = run(&[
            "sweep",
            "--spec",
            spec,
            "--quiet",
            "--threads",
            "2",
            "--shard",
            &format!("{k}/2"),
            "--out-dir",
            &path(&format!("s{k}")),
        ]);
        assert!(ok, "shard {k}: {text}");
        assert!(text.contains(&format!("shard {k}/2")), "{text}");
        assert!(text.contains("carbon-sim merge"), "{text}");
        // A shard run must not leave a report behind.
        assert!(!dir.join(format!("s{k}")).join("report.json").exists());
    }
    let (ok, text) =
        run(&["merge", &path("s0"), &path("s1"), "--out-dir", &path("merged")]);
    assert!(ok, "{text}");
    assert!(text.contains("merged 2 shard spill(s)"), "{text}");
    let full = std::fs::read(dir.join("full").join("report.json")).unwrap();
    let merged = std::fs::read(dir.join("merged").join("report.json")).unwrap();
    assert_eq!(full, merged, "merged report must be byte-identical to the unsharded run");

    // An incomplete shard set is refused with the missing cells named.
    let (ok, text) = run(&["merge", &path("s0"), "--out-dir", &path("merged_bad")]);
    assert!(!ok);
    assert!(text.contains("incomplete shard set"), "{text}");
}

#[test]
fn orchestrate_help_lists_fleet_flags() {
    let (ok, text) = run(&["orchestrate", "--help"]);
    assert!(!ok, "--help exits 2 like every other subcommand");
    for flag in ["--spec", "--shards", "--workers", "--retries", "--launcher", "--resume",
                 "--out-dir", "--format"] {
        assert!(text.contains(flag), "missing {flag} in orchestrate help:\n{text}");
    }
    assert!(text.contains("{shard}"), "{text}");
}

#[test]
fn orchestrate_rejects_bad_invocations() {
    let spec = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/specs/smoke.json");
    // Missing --spec / --shards.
    let (ok, text) = run(&["orchestrate", "--shards", "2"]);
    assert!(!ok);
    assert!(text.contains("--spec"), "{text}");
    let (ok2, text2) = run(&["orchestrate", "--spec", spec]);
    assert!(!ok2);
    assert!(text2.contains("--shards"), "{text2}");
    // Malformed and zero shard counts.
    for bad in ["0", "two", "-1"] {
        let (ok, text) = run(&["orchestrate", "--spec", spec, "--shards", bad]);
        assert!(!ok, "--shards {bad} must be rejected:\n{text}");
    }
    // Bad spec file.
    let (ok3, _) = run(&["orchestrate", "--spec", "/nonexistent_spec.json", "--shards", "2"]);
    assert!(!ok3);
}

#[test]
fn orchestrate_three_shards_matches_the_single_machine_sweep() {
    // The acceptance path: `orchestrate --spec smoke.json --shards 3`
    // must produce report.json byte-identical to a plain sweep of the
    // same spec — and refuse to clobber its out-dir without --resume.
    let spec = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/specs/smoke.json");
    let dir = std::env::temp_dir().join("carbon_sim_cli_orchestrate");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = |name: &str| dir.join(name).to_str().unwrap().to_string();

    let (ok, text) =
        run(&["sweep", "--spec", spec, "--quiet", "--threads", "2", "--out-dir", &path("full")]);
    assert!(ok, "{text}");
    let (ok, text) = run(&[
        "orchestrate",
        "--spec",
        spec,
        "--shards",
        "3",
        "--threads",
        "1",
        "--quiet",
        "--out-dir",
        &path("orch"),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("orchestrated 3 shard(s)"), "{text}");
    let full = std::fs::read(dir.join("full").join("report.json")).unwrap();
    let orch = std::fs::read(dir.join("orch").join("report.json")).unwrap();
    assert_eq!(full, orch, "orchestrated report must be byte-identical to the unsharded run");
    assert!(dir.join("orch").join("orchestrate.json").exists());

    // Re-running into the same out-dir without --resume is refused…
    let (ok, text) = run(&[
        "orchestrate", "--spec", spec, "--shards", "3", "--quiet", "--out-dir", &path("orch"),
    ]);
    assert!(!ok);
    assert!(text.contains("--resume"), "{text}");
    // …and with --resume it verifies the done shards and just re-merges.
    let (ok, text) = run(&[
        "orchestrate", "--spec", spec, "--shards", "3", "--quiet", "--resume", "--out-dir",
        &path("orch"),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("(3 already complete, 0 launched)"), "{text}");
    assert_eq!(std::fs::read(dir.join("orch").join("report.json")).unwrap(), full);
}

#[test]
fn merge_rejects_bad_invocations() {
    // No shard dirs.
    let (ok, text) = run(&["merge", "--out-dir", "/tmp/unused_merge_out"]);
    assert!(!ok);
    assert!(text.contains("at least one shard directory"), "{text}");
    // No --out-dir.
    let (ok2, text2) = run(&["merge", "/tmp/nonexistent_shard_dir"]);
    assert!(!ok2);
    assert!(text2.contains("--out-dir"), "{text2}");
    // Nonexistent input dir.
    let (ok3, text3) =
        run(&["merge", "/tmp/nonexistent_shard_dir", "--out-dir", "/tmp/unused_merge_out"]);
    assert!(!ok3);
    assert!(text3.contains("cells.jsonl"), "{text3}");
    // --help shows the positional contract.
    let (ok4, text4) = run(&["merge", "--help"]);
    assert!(!ok4);
    assert!(text4.contains("<shard-dir>..."), "{text4}");
}

#[test]
fn sweep_rejects_bad_flags_with_exit_2() {
    for bad in [
        vec!["sweep", "--no-such-flag"],
        vec!["sweep", "--format", "xml"],
        vec!["sweep", "--workloads", "frobnicate"],
        vec!["sweep", "--policies", "nope"],
        vec!["sweep", "--rates", "abc"],
        vec!["sweep", "--rates", ""],
        vec!["sweep", "--replicas", "0"],
        vec!["sweep", "--replicas", "-1"],
        vec!["sweep", "--duration", "12O"],
        vec!["sweep", "--threads", "two"],
        vec!["sweep", "--seed", "x7"],
        vec!["sweep", "--out", "a.json", "--out-dir", "b"],
    ] {
        let (ok, text) = run(&bad);
        assert!(!ok, "expected failure for {bad:?}:\n{text}");
    }
}

#[test]
fn queue_flag_selects_and_rejects() {
    // A valid --queue runs on every subcommand that takes it.
    let (ok, text) = run(&[
        "simulate", "--queue", "heap", "--rate", "3", "--duration", "3", "--cores", "8",
        "--prompt-machines", "1", "--token-machines", "1",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("requests completed"), "{text}");
    // A bad value exits 2 with the expected kinds named, everywhere.
    for argv in [
        vec!["simulate", "--queue", "fifo"],
        vec!["sweep", "--queue", "fifo", "--rates", "4"],
        vec!["bench", "--queue", "fifo", "--quick"],
    ] {
        let (ok, text) = run(&argv);
        assert!(!ok, "expected failure for {argv:?}:\n{text}");
        assert!(text.contains("calendar"), "{argv:?}: {text}");
        assert!(text.contains("heap"), "{argv:?}: {text}");
    }
    // --queue is an execution detail, not an axis: it composes with --spec.
    let spec = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/specs/smoke.json");
    let (ok, text) = run(&["sweep", "--spec", spec, "--queue", "heap", "--quiet"]);
    assert!(ok, "{text}");
}

#[test]
fn bench_quick_writes_wellformed_json() {
    let dir = std::env::temp_dir().join("carbon_sim_cli_bench");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("bench.json");
    let (ok, text) = run(&["bench", "--quick", "--quiet", "--out", p.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("events/s"), "{text}");
    let body = std::fs::read_to_string(&p).unwrap();
    let v = carbon_sim::util::json::parse(&body).expect("bench output must be valid JSON");
    // The pinned quick matrix: 2 traces × 2 core counts × 3 policies.
    let cells = v.get("cells").and_then(|c| c.as_arr()).expect("cells array");
    assert_eq!(cells.len(), 12, "{body}");
    assert_eq!(v.usize_or("n_cells", 0), 12);
    assert!(v.f64_or("events_per_s", 0.0) > 0.0);
    assert!(v.f64_or("total_wall_s", 0.0) > 0.0);
    // Date stamp has the YYYY-MM-DD shape.
    let date = v.get("date").and_then(|d| d.as_str()).expect("date field");
    assert_eq!(date.len(), 10, "{date}");
    assert_eq!(&date[4..5], "-");
    assert_eq!(&date[7..8], "-");
    for cell in cells {
        assert!(cell.f64_or("events", 0.0) > 0.0);
        assert!(cell.f64_or("events_per_s", 0.0) > 0.0);
        assert!(cell.get("policy").and_then(|p| p.as_str()).is_some());
        let trace = cell.get("trace").and_then(|t| t.as_str()).unwrap();
        assert!(trace == "short" || trace == "long");
        let cores = cell.usize_or("cores", 0);
        assert!(cores == 40 || cores == 80);
    }
}

#[test]
fn bench_rejects_bad_flags() {
    let (ok, _) = run(&["bench", "--no-such-flag"]);
    assert!(!ok);
}

#[test]
fn lint_is_clean_on_the_real_tree() {
    // The CI gate in binary form: the shipped sources must carry zero
    // violations (fixed, not suppressed — see docs/static-analysis.md).
    let src = concat!(env!("CARGO_MANIFEST_DIR"), "/src");
    let (ok, text) = run(&["lint", src]);
    assert!(ok, "{text}");
    assert!(text.contains("simlint: clean"), "{text}");
}

#[test]
fn lint_fails_on_a_seeded_violation_and_names_the_rule() {
    let bad = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/lint_fixtures/bad_wall_clock.rs");
    let (ok, text) = run(&["lint", bad]);
    assert!(!ok, "{text}");
    assert!(text.contains("no-wall-clock"), "{text}");
    assert!(text.contains("bad_wall_clock.rs:"), "findings are file:line addressed: {text}");
}

#[test]
fn lint_json_emits_schema_versioned_report() {
    let src = concat!(env!("CARGO_MANIFEST_DIR"), "/src");
    let (ok, text) = run(&["lint", "--json", src]);
    assert!(ok, "{text}");
    let v = carbon_sim::util::json::parse(&text).expect("lint --json must be valid JSON");
    assert_eq!(v.get("kind").and_then(|k| k.as_str()), Some("lint-report"));
    assert_eq!(v.usize_or("schema_version", 0), carbon_sim::experiments::OUTPUT_SCHEMA_VERSION);
    assert!(v.bool_or("clean", false), "{text}");
    assert_eq!(v.get("findings").and_then(|f| f.as_arr()).map(|f| f.len()), Some(0));
    assert!(v.usize_or("files_scanned", 0) > 40, "the whole tree is scanned: {text}");
}

#[test]
fn lint_rejects_a_missing_path() {
    let (ok, text) = run(&["lint", "no/such/path.rs"]);
    assert!(!ok);
    assert!(text.contains("lint error"), "{text}");
}

#[test]
fn aging_demo_prints_calibration() {
    let (ok, text) = run(&["aging-demo", "--years", "10"]);
    assert!(ok);
    // Year 10 always-on must show the 30% calibration datum.
    let year10 = text.lines().find(|l| l.trim_start().starts_with("10 ")).unwrap();
    assert!(year10.contains("30.00"), "{year10}");
}
