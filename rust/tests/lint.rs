//! simlint fixture contract: every rule has a known-bad file that must
//! trigger it and a near-miss that must not, pragma suppression works
//! exactly as documented, and the real source tree is clean.
//!
//! The fixtures live in `tests/lint_fixtures/` — a subdirectory, so
//! cargo never compiles them; they only have to lex.

use std::path::PathBuf;

use carbon_sim::analysis::{lint_tree, Finding, LintReport, RULE_PRAGMA};

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures").join(name)
}

fn lint_fixture(name: &str) -> LintReport {
    lint_tree(&[fixture(name)]).expect("fixture lint must not error")
}

fn rules_of(report: &LintReport) -> Vec<&'static str> {
    report.findings.iter().map(|f| f.rule).collect()
}

/// The bad fixture must trigger `rule` (and only `rule`) the expected
/// number of times; the near-miss fixture must be completely clean.
fn assert_rule_pair(rule: &str, bad: &str, bad_count: usize, ok: &str) {
    let bad_report = lint_fixture(bad);
    assert_eq!(
        rules_of(&bad_report),
        vec![rule; bad_count],
        "{bad} must trigger {rule} exactly {bad_count}x, got: {:?}",
        bad_report.findings
    );
    for f in &bad_report.findings {
        assert!(f.line > 0, "findings are 1-indexed");
        assert!(f.path.ends_with(bad), "finding path {} should end with {bad}", f.path);
        assert!(!f.message.is_empty());
    }
    let ok_report = lint_fixture(ok);
    assert!(
        ok_report.is_clean(),
        "{ok} is a near-miss and must stay clean, got: {:?}",
        ok_report.findings
    );
}

#[test]
fn no_float_partial_cmp_fixture_pair() {
    assert_rule_pair("no-float-partial-cmp", "bad_partial_cmp.rs", 2, "ok_partial_cmp.rs");
}

#[test]
fn no_map_iteration_fixture_pair() {
    assert_rule_pair("no-map-iteration", "bad_map_iteration.rs", 2, "ok_map_lookup.rs");
}

#[test]
fn no_wall_clock_fixture_pair() {
    assert_rule_pair("no-wall-clock", "bad_wall_clock.rs", 2, "ok_sim_clock.rs");
}

#[test]
fn no_wall_clock_serving_directory_is_allowlisted() {
    let report = lint_fixture("serving/ok_wall_clock.rs");
    assert!(report.is_clean(), "serving/ is allowlisted, got: {:?}", report.findings);
}

#[test]
fn no_stray_threads_fixture_pair() {
    assert_rule_pair("no-stray-threads", "bad_thread_spawn.rs", 2, "ok_spawn_task.rs");
}

#[test]
fn schema_version_sync_fixture_pair() {
    assert_rule_pair("schema-version-sync", "bad_schema_literal.rs", 1, "ok_schema_constant.rs");
}

#[test]
fn wellformed_pragma_suppresses_the_named_rule() {
    let report = lint_fixture("pragma_suppressed.rs");
    assert!(report.is_clean(), "valid pragma must suppress, got: {:?}", report.findings);
}

#[test]
fn pragma_without_reason_is_a_finding_and_suppresses_nothing() {
    let report = lint_fixture("pragma_missing_reason.rs");
    let mut rules = rules_of(&report);
    rules.sort_unstable();
    assert_eq!(rules, ["no-wall-clock", RULE_PRAGMA], "got: {:?}", report.findings);
    let pragma = report.findings.iter().find(|f| f.rule == RULE_PRAGMA).unwrap();
    assert!(pragma.message.contains("reason"), "{}", pragma.message);
}

#[test]
fn pragma_naming_unknown_rule_is_a_finding() {
    let report = lint_fixture("pragma_unknown_rule.rs");
    assert_eq!(rules_of(&report), [RULE_PRAGMA], "got: {:?}", report.findings);
    let f = &report.findings[0];
    assert!(f.message.contains("no-flaky-clocks"), "{}", f.message);
    assert!(f.message.contains("no-wall-clock"), "the known rules are listed: {}", f.message);
}

#[test]
fn fixture_directory_scan_is_deterministic_and_sorted() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures");
    let a = lint_tree(&[root.clone()]).unwrap();
    let b = lint_tree(&[root]).unwrap();
    assert_eq!(a.render_text(), b.render_text(), "two scans must render identically");
    assert_eq!(a.to_json().to_string_pretty(), b.to_json().to_string_pretty());
    assert!(a.files_scanned >= 14, "all fixtures scanned, got {}", a.files_scanned);
    fn key(f: &Finding) -> (&str, usize, &str) {
        (f.path.as_str(), f.line, f.rule)
    }
    let keys: Vec<_> = a.findings.iter().map(key).collect();
    let mut sorted = keys.clone();
    sorted.sort_unstable();
    assert_eq!(keys, sorted, "findings sorted by (path, line, rule)");
}

#[test]
fn real_tree_is_clean_with_zero_suppressions() {
    // The repaired tree carries no violations AND no pragmas: the
    // pre-existing hazards were fixed, not silenced. (A pragma would
    // not show up as a finding, so grep the sources directly.)
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_tree(&[src.clone()]).unwrap();
    assert!(report.is_clean(), "shipped tree must be clean, got:\n{}", report.render_text());
    assert!(report.files_scanned > 40, "whole tree scanned, got {}", report.files_scanned);

    let mut pragmas = Vec::new();
    let mut stack = vec![src];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir).unwrap() {
            let path = entry.unwrap().path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|x| x == "rs") {
                let body = std::fs::read_to_string(&path).unwrap();
                for (i, line) in body.lines().enumerate() {
                    let t = line.trim_start().trim_start_matches('/').trim_start();
                    if t.starts_with("simlint:") {
                        pragmas.push(format!("{}:{}", path.display(), i + 1));
                    }
                }
            }
        }
    }
    assert!(pragmas.is_empty(), "no suppressions in the shipped tree: {pragmas:?}");
}

#[test]
fn json_report_shape_matches_the_schema_doc() {
    let report = lint_fixture("bad_schema_literal.rs");
    let v = report.to_json();
    assert_eq!(v.get("kind").and_then(|k| k.as_str()), Some("lint-report"));
    assert_eq!(v.usize_or("schema_version", 0), carbon_sim::experiments::OUTPUT_SCHEMA_VERSION);
    assert_eq!(v.usize_or("files_scanned", 0), 1);
    assert!(!v.bool_or("clean", true));
    let findings = v.get("findings").and_then(|f| f.as_arr()).expect("findings array");
    assert_eq!(findings.len(), 1);
    let f = &findings[0];
    assert_eq!(f.str_or("rule", ""), "schema-version-sync");
    assert!(f.str_or("path", "").ends_with("bad_schema_literal.rs"));
    assert!(f.usize_or("line", 0) > 0);
    assert!(!f.str_or("message", "").is_empty());
}
