//! Adaptive-search contract tests (`sweep --search`): the search must
//! spend strictly fewer cells than the exhaustive grid when scenarios
//! settle, a search forced to run the whole grid must reproduce the
//! exhaustive pooled ranking exactly, an interrupted search resumed from
//! a truncated `cells.jsonl` must converge to a `search.json`
//! byte-identical to an uninterrupted run, and the resume guards must
//! refuse spills written by a plain sweep or by a different search
//! configuration.

use std::fs;
use std::path::PathBuf;

use carbon_sim::experiments::search::{run_search, SearchConfig, SEARCH_FILE};
use carbon_sim::experiments::sweep::{self, Format, ShardSpec, SweepSpec};
use carbon_sim::experiments::sweep_stream::{self, CELLS_FILE};
use carbon_sim::experiments::OUTPUT_SCHEMA_VERSION;
use carbon_sim::sim::QueueKind;
use carbon_sim::trace::azure::Workload;
use carbon_sim::util::json::{parse, Value};

fn base_spec() -> SweepSpec {
    SweepSpec {
        rates: vec![5.0, 9.0],
        core_counts: vec![16],
        policies: vec!["linux".into(), "proposed".into()],
        workloads: vec![Workload::Mixed],
        replicas: 1,
        duration_s: 3.0,
        n_prompt: 1,
        n_token: 1,
        seed: 77,
        fleet: None,
        lifecycle: None,
    }
}

fn search_cfg() -> SearchConfig {
    SearchConfig {
        confidence: 0.7,
        min_replicas: 2,
        max_replicas: 8,
        metric: "fred_mean_ghz".to_string(),
    }
}

/// Fresh scratch dir under the system temp root.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("carbon_sim_search").join(name);
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn read_search_json(dir: &std::path::Path) -> (String, Value) {
    let raw = fs::read_to_string(dir.join(SEARCH_FILE)).unwrap();
    let doc = parse(&raw).unwrap();
    (raw, doc)
}

#[test]
fn adaptive_search_spends_fewer_cells_and_writes_a_consistent_verdict() {
    let spec = base_spec();
    let cfg = search_cfg();
    let dir = scratch("adaptive");
    let s = run_search(&spec, &cfg, 1, &dir, false, false, QueueKind::Calendar).unwrap();

    let grid = cfg.grid(&spec);
    assert_eq!(s.n_cells_exhaustive, grid.n_cells());
    assert_eq!(s.n_scenarios, spec.rates.len(), "one base scenario per rate here");
    assert_eq!(s.n_resumed, 0);
    assert_eq!(s.n_run, s.n_cells_spent);
    // Every base gets at least the first rung, never more than the budget.
    let floor = s.n_scenarios * cfg.min_replicas * spec.policies.len();
    assert!(s.n_cells_spent >= floor, "{} cells < first rung {floor}", s.n_cells_spent);
    assert!(
        s.n_cells_spent < s.n_cells_exhaustive,
        "search spent the whole exhaustive budget ({} cells) — nothing settled early",
        s.n_cells_spent
    );

    let (_, doc) = read_search_json(&dir);
    assert_eq!(doc.str_or("kind", ""), "sweep-search");
    assert_eq!(doc.usize_or("schema_version", 0), OUTPUT_SCHEMA_VERSION);
    assert_eq!(doc.usize_or("n_cells_run", 0), s.n_cells_spent);
    assert_eq!(doc.usize_or("n_cells_exhaustive", 0), s.n_cells_exhaustive);
    assert_eq!(doc.usize_or("n_scenarios", 0), s.n_scenarios);
    assert_eq!(doc.usize_or("n_settled", 99), s.n_settled);
    assert_eq!(doc.str_or("spec_hash", ""), grid.spec_hash());
    let ranking = doc.get("ranking").unwrap().as_arr().unwrap();
    assert_eq!(ranking.len(), spec.policies.len(), "pooled ranking covers every policy");
    let scenarios = doc.get("scenarios").unwrap().as_arr().unwrap();
    assert_eq!(scenarios.len(), s.n_scenarios);
    for sc in scenarios {
        let run = sc.usize_or("replicas_run", 0);
        assert!(run >= cfg.min_replicas, "scenario ran {run} < first rung");
        assert!(run <= cfg.max_replicas);
        assert_eq!(sc.usize_or("replicas_budget", 0), cfg.max_replicas);
        let pairs = sc.get("pairs").unwrap().as_arr().unwrap();
        assert_eq!(pairs.len(), spec.policies.len() - 1);
        if sc.bool_or("settled", false) {
            assert!(pairs.iter().all(|p| p.bool_or("resolved", false)));
        }
    }
    // The spill stays a valid plain sweep spill: the resume scanner of
    // the exhaustive engine accepts it as a partial grid.
    let done = sweep_stream::scan_done(&dir.join(CELLS_FILE), &grid, &ShardSpec::full()).unwrap();
    assert_eq!(done.iter().filter(|&&d| d).count(), s.n_cells_spent);
}

#[test]
fn forced_full_search_reproduces_the_exhaustive_ranking() {
    let spec = base_spec();
    // min == max: a single rung that runs every cell of the grid, so the
    // pooled ranking must equal the one computed from the exhaustive
    // engine's report.
    let cfg = SearchConfig {
        confidence: 0.7,
        min_replicas: 3,
        max_replicas: 3,
        metric: "fred_mean_ghz".to_string(),
    };
    let dir = scratch("forced-full");
    let s = run_search(&spec, &cfg, 1, &dir, false, false, QueueKind::Calendar).unwrap();
    assert_eq!(s.n_cells_spent, s.n_cells_exhaustive, "min == max must exhaust the grid");

    let grid = cfg.grid(&spec);
    let report = sweep::run_with_queue(&grid, 1, QueueKind::Calendar).unwrap();
    let n_policies = grid.policies.len();
    // Pool exactly like the search: a replica contributes only when the
    // metric is finite for every policy of its scenario.
    let mut sums = vec![0.0f64; n_policies];
    let mut counts = vec![0u64; n_policies];
    for scenario in 0..grid.n_scenarios() {
        let vals: Vec<f64> = (0..n_policies)
            .map(|p| {
                let row = report.cells[scenario * n_policies + p].to_json();
                row.get(&cfg.metric).and_then(Value::as_f64).unwrap_or(f64::NAN)
            })
            .collect();
        if vals.iter().all(|v| v.is_finite()) {
            for (p, v) in vals.iter().enumerate() {
                sums[p] += v;
                counts[p] += 1;
            }
        }
    }
    let mut order: Vec<usize> = (0..n_policies).collect();
    order.sort_by(|&a, &b| {
        let (ma, mb) = (sums[a] / counts[a] as f64, sums[b] / counts[b] as f64);
        ma.total_cmp(&mb).then(a.cmp(&b))
    });
    let expected: Vec<&str> = order.iter().map(|&p| grid.policies[p].as_str()).collect();

    let (_, doc) = read_search_json(&dir);
    let got: Vec<String> = doc
        .get("ranking")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| r.str_or("policy", "").to_string())
        .collect();
    assert_eq!(got, expected, "search ranking diverged from the exhaustive pooled ranking");
    for sc in doc.get("scenarios").unwrap().as_arr().unwrap() {
        assert_eq!(sc.usize_or("replicas_run", 0), 3);
    }
}

#[test]
fn interrupted_search_resumes_to_identical_verdict_bytes() {
    let spec = base_spec();
    let mut cfg = search_cfg();
    cfg.max_replicas = 4; // keep the grid small; the ladder is 2 → 4
    let full_dir = scratch("resume-full");
    run_search(&spec, &cfg, 1, &full_dir, false, false, QueueKind::Calendar).unwrap();
    let (full_doc, _) = read_search_json(&full_dir);
    let full_cells = fs::read(full_dir.join(CELLS_FILE)).unwrap();

    // Interrupt: keep the header and the first three rows, plus a
    // torn fourth row (a crash mid-append).
    let cut_dir = scratch("resume-cut");
    let keep: Vec<&[u8]> = full_cells.split_inclusive(|&b| b == b'\n').take(4).collect();
    let mut torn = keep.concat();
    torn.extend_from_slice(b"{\"index\":9,\"torn\":");
    fs::write(cut_dir.join(CELLS_FILE), &torn).unwrap();

    let s = run_search(&spec, &cfg, 1, &cut_dir, true, false, QueueKind::Calendar).unwrap();
    assert_eq!(s.n_resumed, 3, "three complete rows survive the cut");
    assert!(s.n_run > 0);
    let (cut_doc, _) = read_search_json(&cut_dir);
    assert_eq!(cut_doc, full_doc, "resumed search.json must be byte-identical");

    // Resuming a finished search runs nothing and rewrites the same bytes.
    let s2 = run_search(&spec, &cfg, 1, &cut_dir, true, false, QueueKind::Calendar).unwrap();
    assert_eq!(s2.n_run, 0);
    assert_eq!(s2.n_resumed, s.n_cells_spent);
    let (again, _) = read_search_json(&cut_dir);
    assert_eq!(again, full_doc);

    // A different search configuration must be refused: it would replay
    // a different rung ladder over the same spill.
    let mut other = cfg.clone();
    other.confidence = 0.9;
    let err = run_search(&spec, &other, 1, &cut_dir, true, false, QueueKind::Calendar).unwrap_err();
    assert!(err.contains("use a fresh --out-dir"), "unexpected error: {err}");
}

#[test]
fn plain_sweep_spills_are_refused_on_search_resume() {
    // A spill written by the plain streaming engine has no `search`
    // header object; resuming it as a search must fail loudly instead
    // of replaying a ladder over foreign rows.
    let spec = SweepSpec { rates: vec![5.0], policies: vec!["linux".into()], ..base_spec() };
    let dir = scratch("plain-spill");
    sweep_stream::run_streaming_with(
        &spec,
        1,
        &dir,
        &ShardSpec::full(),
        Format::Json,
        false,
        false,
        QueueKind::Calendar,
    )
    .unwrap();
    let cfg = SearchConfig::defaults_for(&spec);
    let err = run_search(&spec, &cfg, 1, &dir, true, false, QueueKind::Calendar).unwrap_err();
    assert!(err.contains("plain"), "unexpected error: {err}");
}
