//! Fleet lifecycle: heterogeneous SKUs, fault injection, and
//! aging-triggered retirement — the subsystem that turns the fixed
//! `n_prompt + n_token` machine set into a *living* fleet whose embodied
//! carbon is amortized over actual service windows (ROADMAP: "Fleet
//! lifecycle & heterogeneity scenarios").
//!
//! # Configuration
//!
//! Two optional config blocks drive the subsystem:
//!
//! * [`FleetConfig`] — a list of [`MachineGroup`]s (SKUs). Groups fill
//!   machine ids sequentially: a fleet `[{count: 2, ...}, {count: 3, ...}]`
//!   assigns machines 0–1 to group 0 and 2–4 to group 1. Each group
//!   carries its cores-per-package, process-variation generation
//!   ([`crate::cpu::ProcVarParams::for_generation`]), embodied-carbon
//!   charge, planned amortization lifetime, and the service age the
//!   machines carried into the simulation (`commission_age_yr`).
//! * [`LifecycleConfig`] — fleet *events*: scheduled maintenance windows,
//!   explicit per-core failure injections, a stochastic per-core failure
//!   rate, and the two retirement triggers (calendar age limit and the
//!   p99 ΔVth guard band), plus the replacement SKU procured after a
//!   retirement. `lifecycle` requires `fleet`: without the ledger there
//!   is nothing to retire against.
//!
//! # Event ordering and determinism contract
//!
//! Lifecycle events flow through the ordinary [`crate::sim::Scheduler`]
//! queue — never a side channel — so they interleave with simulation
//! events in the deterministic `(time, sequence)` order both queue
//! implementations share. All lifecycle event pushes happen in
//! `Cluster::run` *after* the arrival pushes and tick-train arming, in a
//! fixed order: maintenance windows (config order, start before end),
//! explicit failures (config order), stochastic failures (machine id
//! order, then core id order), and finally the retirement-check train.
//! When no `lifecycle` block is configured **zero** events are pushed and
//! no lifecycle randomness is drawn, so sequence-number streams, queue
//! stats, and every report byte are identical to the pre-lifecycle
//! simulator (`tests/lifecycle_identity.rs` pins this).
//!
//! Stochastic failure times are drawn from a dedicated RNG stream forked
//! off the cluster seed with [`LIFECYCLE_SEED_XOR`] — never wall clock —
//! and that same stream later feeds replacement-silicon sampling, in
//! event order, which is itself deterministic. Results are therefore
//! byte-identical at any `--threads` and for both `--queue` kinds.
//!
//! Within one timestamp the usual push-order tie-break applies; the
//! handlers are written so any interleaving is safe: a failure evicts
//! its task to the front of the FIFO oversubscription queue (arrival
//! order preserved), a retirement migrates every in-flight task onto the
//! replacement package's queue, and scheduled `TaskDone` completions
//! resolve the task wherever it now lives — so no task is ever lost or
//! double-completed across drain/failure/retirement
//! (`tests/lifecycle_prop.rs`).

use crate::carbon::FleetLedger;
use crate::cpu::ProcVarParams;
use crate::util::json::Value;
use crate::util::rng::Rng;

/// Seed domain separator for the lifecycle RNG stream (stochastic
/// failure draws + replacement-silicon sampling), keeping it independent
/// of the task-duration and process-variation streams.
pub const LIFECYCLE_SEED_XOR: u64 = 0x11FE_C1C1_E5EE_D001;

/// One machine SKU in the fleet: `count` identical machines.
#[derive(Clone, Debug, PartialEq)]
pub struct MachineGroup {
    /// Number of machines in this group.
    pub count: usize,
    /// CPU cores per package for this SKU.
    pub cores: usize,
    /// Process-variation generation name
    /// ([`ProcVarParams::for_generation`]): "paper"/"gen1", "gen2", "gen3".
    pub generation: String,
    /// Embodied carbon charged per machine at procurement (kgCO₂eq).
    pub embodied_kg: f64,
    /// Planned amortization lifetime (years).
    pub lifetime_yr: f64,
    /// Service years the group's machines had already accrued at
    /// simulation time 0 (a commission date in the past).
    pub commission_age_yr: f64,
}

impl Default for MachineGroup {
    /// Paper-default SKU with zero machines: parsers fill `count` and
    /// `cores` (both required) and override the rest when present.
    fn default() -> Self {
        MachineGroup {
            count: 0,
            cores: 0,
            generation: "paper".to_string(),
            embodied_kg: 278.3,
            lifetime_yr: 3.0,
            commission_age_yr: 0.0,
        }
    }
}

impl MachineGroup {
    /// The process-variation parameters this group's generation implies.
    pub fn procvar(&self) -> ProcVarParams {
        ProcVarParams::for_generation(&self.generation).expect("generation validated at parse time")
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("commission_age_yr", self.commission_age_yr.into()),
            ("cores", self.cores.into()),
            ("count", self.count.into()),
            ("embodied_kg", self.embodied_kg.into()),
            ("generation", self.generation.as_str().into()),
            ("lifetime_yr", self.lifetime_yr.into()),
        ])
    }
}

/// The heterogeneous fleet: machine groups filling ids sequentially.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetConfig {
    pub groups: Vec<MachineGroup>,
}

impl FleetConfig {
    /// Total machines across all groups.
    pub fn n_machines(&self) -> usize {
        self.groups.iter().map(|g| g.count).sum()
    }

    /// Group index owning machine `id` (ids fill groups sequentially).
    pub fn group_of(&self, id: usize) -> usize {
        let mut first = 0;
        for (gi, g) in self.groups.iter().enumerate() {
            if id < first + g.count {
                return gi;
            }
            first += g.count;
        }
        panic!("machine id {id} beyond fleet of {} machines", first);
    }

    /// Validate against the cluster's machine count; errors name the
    /// offending group field.
    pub fn validate(&self, n_machines: usize) -> Result<(), String> {
        if self.groups.is_empty() {
            return Err("fleet.groups must not be empty".into());
        }
        for (gi, g) in self.groups.iter().enumerate() {
            if g.count == 0 {
                return Err(format!("fleet.groups[{gi}].count must be > 0"));
            }
            if g.cores == 0 {
                return Err(format!("fleet.groups[{gi}].cores must be > 0"));
            }
            if !(g.embodied_kg > 0.0) {
                return Err(format!("fleet.groups[{gi}].embodied_kg must be > 0"));
            }
            if !(g.lifetime_yr > 0.0) {
                return Err(format!("fleet.groups[{gi}].lifetime_yr must be > 0"));
            }
            if !(g.commission_age_yr >= 0.0) {
                return Err(format!("fleet.groups[{gi}].commission_age_yr must be >= 0"));
            }
            ProcVarParams::for_generation(&g.generation)
                .map_err(|e| format!("fleet.groups[{gi}].generation: {e}"))?;
        }
        let total = self.n_machines();
        if total != n_machines {
            return Err(format!(
                "fleet.groups machine count {total} != n_prompt + n_token = {n_machines}"
            ));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![(
            "groups",
            Value::Arr(self.groups.iter().map(|g| g.to_json()).collect()),
        )])
    }
}

/// A scheduled maintenance window: the machine is drained (no new work
/// routed to it, free cores parked) for `[start_s, start_s + duration_s)`.
#[derive(Clone, Debug, PartialEq)]
pub struct MaintenanceWindow {
    pub machine: usize,
    pub start_s: f64,
    pub duration_s: f64,
}

impl MaintenanceWindow {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("duration_s", self.duration_s.into()),
            ("machine", self.machine.into()),
            ("start_s", self.start_s.into()),
        ])
    }
}

/// An explicit (scripted) permanent core failure.
#[derive(Clone, Debug, PartialEq)]
pub struct CoreFailure {
    pub machine: usize,
    pub core: usize,
    pub time_s: f64,
}

impl CoreFailure {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("core", self.core.into()),
            ("machine", self.machine.into()),
            ("time_s", self.time_s.into()),
        ])
    }
}

/// Fleet events: maintenance, failures, and retirement triggers.
#[derive(Clone, Debug, PartialEq)]
pub struct LifecycleConfig {
    /// Scheduled maintenance windows.
    pub maintenance: Vec<MaintenanceWindow>,
    /// Explicit per-core failure injections.
    pub failures: Vec<CoreFailure>,
    /// Stochastic permanent-failure rate per core per year (0 = off).
    /// Failure times are exponential draws from the seeded lifecycle RNG.
    pub failure_rate_per_core_year: f64,
    /// Calendar retirement trigger: retire a machine once its service age
    /// (prior age + in-simulation time) reaches this many years.
    pub age_limit_yr: Option<f64>,
    /// Aging retirement trigger: retire a machine once the p99 of its
    /// per-core ΔVth reaches this guard band (V).
    pub dvth_guard_band_v: Option<f64>,
    /// Period of the retirement-check event train (s).
    pub check_period_s: f64,
    /// Index into `fleet.groups` of the SKU procured as a replacement
    /// after each retirement.
    pub replacement_group: usize,
}

impl Default for LifecycleConfig {
    fn default() -> Self {
        LifecycleConfig {
            maintenance: Vec::new(),
            failures: Vec::new(),
            failure_rate_per_core_year: 0.0,
            age_limit_yr: None,
            dvth_guard_band_v: None,
            check_period_s: 1.0,
            replacement_group: 0,
        }
    }
}

impl LifecycleConfig {
    /// Validate against the fleet this lifecycle runs over; errors name
    /// the offending field.
    pub fn validate(&self, fleet: &FleetConfig) -> Result<(), String> {
        let n_machines = fleet.n_machines();
        for (i, w) in self.maintenance.iter().enumerate() {
            if w.machine >= n_machines {
                return Err(format!(
                    "lifecycle.maintenance[{i}].machine {} out of range (fleet has {n_machines})",
                    w.machine
                ));
            }
            if !(w.start_s >= 0.0) {
                return Err(format!("lifecycle.maintenance[{i}].start_s must be >= 0"));
            }
            if !(w.duration_s > 0.0) {
                return Err(format!("lifecycle.maintenance[{i}].duration_s must be > 0"));
            }
        }
        for (i, f) in self.failures.iter().enumerate() {
            if f.machine >= n_machines {
                return Err(format!(
                    "lifecycle.failures[{i}].machine {} out of range (fleet has {n_machines})",
                    f.machine
                ));
            }
            if !(f.time_s >= 0.0) {
                return Err(format!("lifecycle.failures[{i}].time_s must be >= 0"));
            }
        }
        if !(self.failure_rate_per_core_year >= 0.0) {
            return Err("lifecycle.failure_rate_per_core_year must be >= 0".into());
        }
        if let Some(a) = self.age_limit_yr {
            if !(a > 0.0) {
                return Err("lifecycle.age_limit_yr must be > 0".into());
            }
        }
        if let Some(g) = self.dvth_guard_band_v {
            if !(g > 0.0) {
                return Err("lifecycle.dvth_guard_band_v must be > 0".into());
            }
        }
        if !(self.check_period_s > 0.0) {
            return Err("lifecycle.check_period_s must be > 0".into());
        }
        if self.replacement_group >= fleet.groups.len() {
            return Err(format!(
                "lifecycle.replacement_group {} out of range (fleet has {} groups)",
                self.replacement_group,
                fleet.groups.len()
            ));
        }
        Ok(())
    }

    /// Whether any retirement trigger is configured (arms the
    /// retirement-check event train).
    pub fn retirement_armed(&self) -> bool {
        self.age_limit_yr.is_some() || self.dvth_guard_band_v.is_some()
    }

    pub fn to_json(&self) -> Value {
        let mut entries: Vec<(&str, Value)> = vec![
            ("check_period_s", self.check_period_s.into()),
            ("failure_rate_per_core_year", self.failure_rate_per_core_year.into()),
            (
                "failures",
                Value::Arr(self.failures.iter().map(|f| f.to_json()).collect()),
            ),
            (
                "maintenance",
                Value::Arr(self.maintenance.iter().map(|w| w.to_json()).collect()),
            ),
            ("replacement_group", self.replacement_group.into()),
        ];
        if let Some(a) = self.age_limit_yr {
            entries.push(("age_limit_yr", a.into()));
        }
        if let Some(g) = self.dvth_guard_band_v {
            entries.push(("dvth_guard_band_v", g.into()));
        }
        Value::obj(entries)
    }
}

/// Per-run lifecycle state: the carbon ledger, the seeded event RNG, and
/// the fleet-event counters the summary reports. Exists exactly when the
/// cluster config carries a `fleet` block; the event side is armed only
/// when a `lifecycle` block is present too.
#[derive(Clone, Debug)]
pub struct LifecycleRuntime {
    pub fleet: FleetConfig,
    pub lifecycle: Option<LifecycleConfig>,
    /// Embodied-carbon service-window ledger (commission/retire records).
    pub ledger: FleetLedger,
    /// Dedicated lifecycle RNG stream (module docs: determinism contract).
    pub rng: Rng,
    /// Machines retired (and replaced) during the run.
    pub retirements: u64,
    /// Cores permanently failed during the run.
    pub core_failures: u64,
    /// Requests re-routed out of a draining machine's prompt queue.
    pub rerouted: u64,
}

impl LifecycleRuntime {
    /// Build the runtime and commission every machine's opening service
    /// record at t = 0.
    pub fn new(fleet: FleetConfig, lifecycle: Option<LifecycleConfig>, seed: u64) -> Self {
        let mut ledger = FleetLedger::new();
        let mut id = 0;
        for g in &fleet.groups {
            for _ in 0..g.count {
                ledger.commission(id, g.embodied_kg, g.lifetime_yr, g.commission_age_yr, 0.0);
                id += 1;
            }
        }
        LifecycleRuntime {
            fleet,
            lifecycle,
            ledger,
            rng: Rng::new(seed ^ LIFECYCLE_SEED_XOR),
            retirements: 0,
            core_failures: 0,
            rerouted: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn group(count: usize, cores: usize) -> MachineGroup {
        MachineGroup {
            count,
            cores,
            generation: "paper".into(),
            embodied_kg: 278.3,
            lifetime_yr: 3.0,
            commission_age_yr: 0.0,
        }
    }

    #[test]
    fn groups_fill_ids_sequentially() {
        let fleet = FleetConfig { groups: vec![group(2, 16), group(3, 12)] };
        assert_eq!(fleet.n_machines(), 5);
        assert_eq!(fleet.group_of(0), 0);
        assert_eq!(fleet.group_of(1), 0);
        assert_eq!(fleet.group_of(2), 1);
        assert_eq!(fleet.group_of(4), 1);
    }

    #[test]
    fn fleet_validation_names_offending_fields() {
        let fleet = FleetConfig { groups: vec![group(2, 16)] };
        assert!(fleet.validate(2).is_ok());
        assert!(fleet.validate(3).unwrap_err().contains("n_prompt + n_token"));
        let mut bad = fleet.clone();
        bad.groups[0].generation = "7nm".into();
        assert!(bad.validate(2).unwrap_err().contains("generation"));
        let mut bad = fleet.clone();
        bad.groups[0].embodied_kg = 0.0;
        assert!(bad.validate(2).unwrap_err().contains("embodied_kg"));
    }

    #[test]
    fn lifecycle_validation_checks_ranges() {
        let fleet = FleetConfig { groups: vec![group(2, 16)] };
        let mut lc = LifecycleConfig::default();
        assert!(lc.validate(&fleet).is_ok());
        assert!(!lc.retirement_armed());
        lc.age_limit_yr = Some(3.0);
        assert!(lc.retirement_armed());
        lc.maintenance.push(MaintenanceWindow { machine: 5, start_s: 0.0, duration_s: 1.0 });
        assert!(lc.validate(&fleet).unwrap_err().contains("maintenance[0].machine"));
        lc.maintenance.clear();
        lc.replacement_group = 1;
        assert!(lc.validate(&fleet).unwrap_err().contains("replacement_group"));
    }

    #[test]
    fn runtime_commissions_every_machine() {
        let fleet = FleetConfig { groups: vec![group(1, 16), group(2, 12)] };
        let rt = LifecycleRuntime::new(fleet, None, 42);
        assert_eq!(rt.ledger.records.len(), 3);
        for (m, r) in rt.ledger.records.iter().enumerate() {
            assert_eq!(r.machine, m);
            assert!(r.retired_s.is_none());
        }
        let total = rt.ledger.total_charged_kg();
        assert!((total - 3.0 * 278.3).abs() < 1e-9);
    }

    #[test]
    fn json_shape_round_trips_key_names() {
        let fleet = FleetConfig { groups: vec![group(2, 16)] };
        let s = fleet.to_json().to_string_compact();
        assert!(s.contains("\"groups\"") && s.contains("\"generation\""));
        let mut lc = LifecycleConfig::default();
        let s = lc.to_json().to_string_compact();
        assert!(!s.contains("age_limit_yr"), "unset optional keys stay absent");
        lc.age_limit_yr = Some(3.0);
        lc.dvth_guard_band_v = Some(0.05);
        let s = lc.to_json().to_string_compact();
        assert!(s.contains("\"age_limit_yr\"") && s.contains("\"dvth_guard_band_v\""));
    }
}
