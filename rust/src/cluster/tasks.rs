//! The CPU inference-task taxonomy — Table 2 of the paper.
//!
//! Each entry corresponds to a class/function of the original
//! splitwise-sim whose CPU cost the paper models; every spawn of one of
//! these becomes a `assign_core_to_cpu_task` call into the core manager.
//! Durations are sampled from mildly dispersed log-normals around
//! published-order-of-magnitude means (scheduler bookkeeping is
//! single-digit milliseconds); the simulator stretches them by the
//! executing core's aging slowdown (§5).

use crate::util::rng::Rng;

/// Table 2: tasks modeled as inference tasks.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// `Executor.finish_flow`
    FinishFlow,
    /// `Executor.finish_request`
    FinishRequest,
    /// `Executor.finish_task`
    FinishTask,
    /// `Executor.submit`
    Submit,
    /// `Executor.submit_chain`
    SubmitChain,
    /// `Executor.submit_flow`
    SubmitFlow,
    /// `Executor.submit_task`
    SubmitTask,
    /// `Instance.alloc_memory`
    AllocMemory,
    /// `Instance.free_memory`
    FreeMemory,
    /// `ORCAInstance.start_iteration`
    StartIteration,
    /// `Link.flow_completion`
    FlowCompletion,
}

pub const ALL_TASK_KINDS: [TaskKind; 11] = [
    TaskKind::FinishFlow,
    TaskKind::FinishRequest,
    TaskKind::FinishTask,
    TaskKind::Submit,
    TaskKind::SubmitChain,
    TaskKind::SubmitFlow,
    TaskKind::SubmitTask,
    TaskKind::AllocMemory,
    TaskKind::FreeMemory,
    TaskKind::StartIteration,
    TaskKind::FlowCompletion,
];

impl TaskKind {
    /// Position of this kind in [`ALL_TASK_KINDS`] — the enum declaration
    /// order, so per-kind counters index directly by discriminant instead
    /// of a linear scan per spawn (§Perf).
    #[inline]
    pub const fn index(self) -> usize {
        self as usize
    }

    /// Mean CPU occupancy in seconds.
    pub fn mean_duration_s(self) -> f64 {
        match self {
            TaskKind::Submit => 0.003,
            TaskKind::SubmitChain => 0.002,
            TaskKind::SubmitFlow => 0.002,
            TaskKind::SubmitTask => 0.003,
            TaskKind::AllocMemory => 0.0015,
            TaskKind::FreeMemory => 0.0012,
            TaskKind::StartIteration => 0.006,
            TaskKind::FlowCompletion => 0.0025,
            TaskKind::FinishTask => 0.002,
            TaskKind::FinishRequest => 0.004,
            TaskKind::FinishFlow => 0.0018,
        }
    }

    /// Sample an execution time (log-normal, σ = 0.4, clamped to 20× mean
    /// to keep the event queue sane).
    pub fn sample_duration_s(self, rng: &mut Rng) -> f64 {
        let mean = self.mean_duration_s();
        // For log-normal with median m: mean = m·exp(σ²/2); parameterize by
        // mean so average CPU load matches the table.
        let sigma = 0.4;
        let mu = mean.ln() - sigma * sigma / 2.0;
        rng.lognormal(mu, sigma).min(mean * 20.0)
    }

    pub fn name(self) -> &'static str {
        match self {
            TaskKind::FinishFlow => "finish_flow",
            TaskKind::FinishRequest => "finish_request",
            TaskKind::FinishTask => "finish_task",
            TaskKind::Submit => "submit",
            TaskKind::SubmitChain => "submit_chain",
            TaskKind::SubmitFlow => "submit_flow",
            TaskKind::SubmitTask => "submit_task",
            TaskKind::AllocMemory => "alloc_memory",
            TaskKind::FreeMemory => "free_memory",
            TaskKind::StartIteration => "start_iteration",
            TaskKind::FlowCompletion => "flow_completion",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn sampled_mean_tracks_nominal() {
        let mut rng = Rng::new(1);
        for kind in ALL_TASK_KINDS {
            let xs: Vec<f64> = (0..20_000).map(|_| kind.sample_duration_s(&mut rng)).collect();
            let m = stats::mean(&xs);
            let target = kind.mean_duration_s();
            assert!(
                (m - target).abs() / target < 0.05,
                "{}: mean {m} vs nominal {target}",
                kind.name()
            );
        }
    }

    #[test]
    fn durations_positive_and_bounded() {
        let mut rng = Rng::new(2);
        for kind in ALL_TASK_KINDS {
            for _ in 0..1000 {
                let d = kind.sample_duration_s(&mut rng);
                assert!(d > 0.0 && d <= kind.mean_duration_s() * 20.0);
            }
        }
    }

    #[test]
    fn index_matches_all_task_kinds_order() {
        for (i, kind) in ALL_TASK_KINDS.iter().enumerate() {
            assert_eq!(kind.index(), i, "{} discriminant drifted", kind.name());
        }
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<&str> = ALL_TASK_KINDS.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), ALL_TASK_KINDS.len());
    }
}
