//! One inference server: a GPU instance (prompt or token role under phase
//! splitting) plus the multi-core CPU its inference tasks run on.

use std::collections::VecDeque;

use crate::cpu::CpuPackage;
use crate::model::KvMemory;
use crate::policy::{CoreManager, CorePolicy};
use crate::util::rng::Rng;

/// Phase-splitting role (Splitwise): prompt machines run prefills, token
/// machines run continuous-batched decode iterations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Prompt,
    Token,
}

/// A cluster machine.
pub struct Machine {
    pub id: usize,
    pub role: Role,
    /// The aging-aware (or baseline) CPU core manager. The cluster's
    /// coalesced 250 ms adjust event drives it through
    /// [`CoreManager::adjust_tick`], which skips machines whose package
    /// saw no mutation since the previous tick (dirty-flag skip-ahead).
    pub mgr: CoreManager,
    /// KV-cache memory pool (token machines).
    pub kv: KvMemory,

    // ---- prompt-instance state ----
    /// FIFO of requests waiting for a prefill slot.
    pub prompt_queue: VecDeque<usize>,
    /// Request currently in prefill, if any.
    pub prompt_busy: Option<usize>,

    // ---- token-instance state ----
    /// Requests in the continuous batch.
    pub batch: Vec<usize>,
    /// Requests whose KV arrived but which have not been admitted yet.
    pub pending: VecDeque<usize>,
    /// Whether an iteration is currently in flight.
    pub iterating: bool,

    // ---- interconnect state (ingress link serialization) ----
    pub link_busy_until: f64,

    // ---- lifecycle state ----
    /// False while the machine is drained for a maintenance window: the
    /// cluster scheduler routes new work elsewhere (when it can) and the
    /// periodic adjust tick skips it. Always true without a lifecycle
    /// config, so the flag is behaviour-free when lifecycle is off.
    pub available: bool,
}

impl Machine {
    pub fn new(
        id: usize,
        role: Role,
        cpu: CpuPackage,
        policy: Box<dyn CorePolicy>,
        kv_capacity_tokens: u64,
        rng: Rng,
    ) -> Machine {
        Machine {
            id,
            role,
            mgr: CoreManager::new(cpu, policy, rng),
            kv: KvMemory::new(kv_capacity_tokens),
            prompt_queue: VecDeque::new(),
            prompt_busy: None,
            batch: Vec::new(),
            pending: VecDeque::new(),
            iterating: false,
            link_busy_until: 0.0,
            available: true,
        }
    }

    /// Load proxy used by the cluster scheduler: queued + running work.
    pub fn sched_load(&self) -> usize {
        match self.role {
            Role::Prompt => self.prompt_queue.len() + usize::from(self.prompt_busy.is_some()),
            Role::Token => self.batch.len() + self.pending.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::{AgingParams, TemperatureModel};
    use crate::policy;

    fn machine(role: Role) -> Machine {
        let cpu = CpuPackage::uniform(
            4,
            AgingParams::paper_default(),
            TemperatureModel::paper_default(),
        );
        Machine::new(0, role, cpu, policy::by_name("proposed").unwrap(), 1000, Rng::new(1))
    }

    #[test]
    fn sched_load_prompt() {
        let mut m = machine(Role::Prompt);
        assert_eq!(m.sched_load(), 0);
        m.prompt_queue.push_back(1);
        m.prompt_busy = Some(0);
        assert_eq!(m.sched_load(), 2);
    }

    #[test]
    fn sched_load_token() {
        let mut m = machine(Role::Token);
        m.batch.push(0);
        m.batch.push(1);
        m.pending.push_back(2);
        assert_eq!(m.sched_load(), 3);
    }
}
