//! The LLM inference cluster simulator — our from-scratch splitwise-sim.
//!
//! Models the paper's experimental cluster (§6.1): 22 H100 machines under
//! Splitwise phase splitting (5 prompt + 17 token instances), a
//! JSQ cluster-level scheduler, ORCA-style continuous batching on token
//! machines, KV-cache flows over the interconnect, and — the point of the
//! exercise — the CPU inference tasks of Table 2, each pinned to a core by
//! the configured management policy while the NBTI model ages every C0
//! core.
//!
//! Event flow per request:
//!
//! ```text
//! Arrive ──(submit/submit_chain/submit_task CPU tasks)──▶ prompt queue
//!   └─▶ prefill (alloc_memory) ──▶ PromptDone (finish_task, submit_flow)
//!         └─▶ KV flow over link ──▶ FlowDone (flow_completion + finish_flow
//!               + alloc_memory on token machine; free_memory on prompt)
//!               └─▶ continuous batch ──▶ IterDone* (start_iteration each)
//!                     └─▶ completion (finish_task, finish_request, free_memory)
//! ```

pub mod lifecycle;
pub mod machine;
pub mod tasks;

pub use lifecycle::{
    CoreFailure, FleetConfig, LifecycleConfig, LifecycleRuntime, MachineGroup, MaintenanceWindow,
};
pub use machine::{Machine, Role};
pub use tasks::{TaskKind, ALL_TASK_KINDS};

use crate::cpu::aging::SECONDS_PER_YEAR;
use crate::cpu::{AgingParams, CState, CpuPackage, ProcVarParams, ProcVarSampler, TemperatureModel};
use crate::metrics::{Collector, LifecycleSummary, SimResult};
use crate::model::PerfModel;
use crate::policy;
use crate::sim::{QueueKind, Scheduler, SchedulerImpl};
use crate::trace::Trace;
use crate::util::rng::Rng;

/// Cluster configuration (the paper's §6.1 setup by default).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Prompt (prefill) machines. Paper: 5.
    pub n_prompt: usize,
    /// Token (decode) machines. Paper: 17.
    pub n_token: usize,
    /// CPU cores per machine. Paper evaluates 40 and 80.
    pub cores_per_cpu: usize,
    /// Core-management policy: "proposed" | "linux" | "least-aged".
    pub policy: String,
    /// Metrics sampling period (s).
    pub sample_period_s: f64,
    /// Continuous-batching cap per token machine.
    pub max_batch: usize,
    /// KV capacity per token machine, in tokens.
    pub kv_capacity_tokens: u64,
    /// RNG seed (shared by process variation and task-duration sampling).
    pub seed: u64,
    /// Optional pre-sampled per-machine initial core frequencies. Used to
    /// run *paired* policy comparisons on identical silicon.
    pub f0_override: Option<Vec<Vec<f64>>>,
    /// Event-queue implementation. An execution detail — results are
    /// byte-identical under either — so it lives outside sweep specs.
    pub queue: QueueKind,
    pub aging: AgingParams,
    pub temps: TemperatureModel,
    pub procvar: ProcVarParams,
    pub perf: PerfModel,
    /// Optional heterogeneous fleet (machine groups / SKUs). When set,
    /// per-machine core counts and process-variation generations come
    /// from the groups and `cores_per_cpu`/`procvar` above are nominal
    /// only; when `None` the simulator is byte-identical to the
    /// pre-lifecycle code paths.
    pub fleet: Option<FleetConfig>,
    /// Optional fleet events (maintenance, failures, retirement).
    /// Requires `fleet`.
    pub lifecycle: Option<LifecycleConfig>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            n_prompt: 5,
            n_token: 17,
            cores_per_cpu: 40,
            policy: "proposed".into(),
            sample_period_s: 0.1,
            max_batch: 64,
            kv_capacity_tokens: 400_000,
            seed: 42,
            f0_override: None,
            queue: QueueKind::default(),
            aging: AgingParams::paper_default(),
            temps: TemperatureModel::paper_default(),
            procvar: ProcVarParams::paper_default(),
            perf: PerfModel::h100_70b(),
            fleet: None,
            lifecycle: None,
        }
    }
}

impl ClusterConfig {
    pub fn n_machines(&self) -> usize {
        self.n_prompt + self.n_token
    }

    /// Sample the per-machine initial core frequencies this config implies
    /// (or return the override). Use this to build the shared silicon for
    /// paired experiments.
    pub fn sample_f0(&self) -> Vec<Vec<f64>> {
        if let Some(f0) = &self.f0_override {
            assert_eq!(f0.len(), self.n_machines(), "f0 override machine count");
            return f0.clone();
        }
        let mut rng = Rng::new(self.seed ^ 0x5EED_F0F0);
        if let Some(fleet) = &self.fleet {
            // Heterogeneous fleet: per-group sampler parameters, ONE
            // shared gaussian stream consumed in machine-id order.
            // `sample_chip` draws a fixed n_chip² gaussians per chip
            // regardless of core count, so a single default-generation
            // group consumes the exact stream the no-fleet branch does
            // (the differential test in tests/lifecycle_identity.rs
            // leans on this).
            let mut out = Vec::with_capacity(self.n_machines());
            for g in &fleet.groups {
                let sampler = ProcVarSampler::new(g.procvar());
                for _ in 0..g.count {
                    out.push(sampler.sample_chip(&mut rng, g.cores));
                }
            }
            return out;
        }
        let sampler = ProcVarSampler::new(self.procvar);
        (0..self.n_machines()).map(|_| sampler.sample_chip(&mut rng, self.cores_per_cpu)).collect()
    }
}

/// Per-request simulation state.
#[derive(Clone, Debug)]
struct ReqState {
    arrival_s: f64,
    prompt_tokens: u32,
    output_tokens: u32,
    prompt_machine: usize,
    token_machine: usize,
    /// Output tokens still to generate.
    remaining: u32,
    /// Context tokens currently held (prompt + generated so far).
    ctx_tokens: u64,
    ttft_s: Option<f64>,
    done_s: Option<f64>,
}

/// Simulator events.
#[derive(Clone, Copy, Debug)]
enum Ev {
    /// Request `idx` arrives at the cluster scheduler.
    Arrive(usize),
    /// The prefill running on prompt machine `m` finished.
    PromptDone(usize),
    /// Request `idx`'s KV flow reached its token machine.
    FlowDone(usize),
    /// A decode iteration on token machine `m` finished.
    IterDone(usize),
    /// CPU inference task finished on machine `m`.
    TaskDone { m: usize, task: u64 },
    /// Selective Core Idling tick — one coalesced event ticks every
    /// machine (§Perf: all machines share the policy's period, so one
    /// queue entry replaces `n_machines` per tick). Fixed-period, so it
    /// lives in a rearming tick-train slot ([`Scheduler::arm_periodic`])
    /// and never traverses the queue proper.
    Adjust,
    /// Metrics sampling tick (all machines); the other tick-train slot.
    Sample,
    /// Maintenance window opens on machine `m`: drain and park.
    MaintStart(usize),
    /// Maintenance window closes on machine `m`: back in rotation.
    MaintEnd(usize),
    /// Permanent core failure on machine `m`.
    FailCore { m: usize, core: usize },
    /// Periodic retirement check (age limit / ΔVth guard band); rearms
    /// itself through an ordinary push, so it exists in the queue only
    /// when a retirement trigger is configured.
    RetireCheck,
}

/// Tick-train slot indices (arm order matches the pre-slot push order,
/// keeping sequence-number streams — and thus results — unchanged).
const SLOT_ADJUST: usize = 0;
const SLOT_SAMPLE: usize = 1;

/// The cluster simulator.
pub struct Cluster {
    pub cfg: ClusterConfig,
    pub machines: Vec<Machine>,
    reqs: Vec<ReqState>,
    q: SchedulerImpl<Ev>,
    rng: Rng,
    next_task: u64,
    completed: usize,
    arrivals_pending: usize,
    pub collector: Collector,
    /// Cluster-global spawn counts, indexed by [`TaskKind::index`]
    /// (diagnostics / Table 2 evidence).
    pub task_spawns: Vec<u64>,
    /// Fleet lifecycle state (ledger, event RNG, counters). `Some` iff
    /// the config carries a `fleet` block.
    pub lifecycle: Option<LifecycleRuntime>,
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Cluster {
        // Config-file loads validate before they get here; programmatic
        // construction gets the same checks at a panic level.
        if let Some(fleet) = &cfg.fleet {
            fleet.validate(cfg.n_machines()).expect("valid fleet config");
            if let Some(lc) = &cfg.lifecycle {
                lc.validate(fleet).expect("valid lifecycle config");
            }
        } else {
            assert!(cfg.lifecycle.is_none(), "lifecycle config requires a fleet block");
        }
        let lifecycle = cfg
            .fleet
            .clone()
            .map(|fleet| LifecycleRuntime::new(fleet, cfg.lifecycle.clone(), cfg.seed));
        let f0 = cfg.sample_f0();
        let mut rng = Rng::new(cfg.seed);
        let machines: Vec<Machine> = (0..cfg.n_machines())
            .map(|id| {
                let role = if id < cfg.n_prompt { Role::Prompt } else { Role::Token };
                let cpu = CpuPackage::new(f0[id].clone(), cfg.aging, cfg.temps);
                let pol = policy::by_name(&cfg.policy).expect("valid policy name");
                Machine::new(id, role, cpu, pol, cfg.kv_capacity_tokens, rng.fork(id as u64))
            })
            .collect();
        let n = cfg.n_machines();
        let queue = cfg.queue;
        Cluster {
            cfg,
            machines,
            reqs: Vec::new(),
            q: SchedulerImpl::new(queue),
            rng,
            next_task: 0,
            completed: 0,
            arrivals_pending: 0,
            collector: Collector::new(n),
            task_spawns: vec![0; ALL_TASK_KINDS.len()],
            lifecycle,
        }
    }

    /// Run the trace to completion and report results.
    ///
    /// Wall-clock-free by contract (the simlint `no-wall-clock` gate):
    /// the returned [`SimResult::wall_time_s`] is 0.0 here, and timing
    /// callers (the CLI, the bench harness) stamp it around this call.
    pub fn run(&mut self, trace: &Trace) -> SimResult {
        // Seed request states + arrival events.
        self.reqs = trace
            .requests
            .iter()
            .map(|r| ReqState {
                arrival_s: r.arrival_s,
                prompt_tokens: r.prompt_tokens,
                output_tokens: r.output_tokens,
                prompt_machine: usize::MAX,
                token_machine: usize::MAX,
                remaining: r.output_tokens,
                ctx_tokens: 0,
                ttft_s: None,
                done_s: None,
            })
            .collect();
        self.arrivals_pending = self.reqs.len();
        for (idx, r) in trace.requests.iter().enumerate() {
            self.q.push(r.arrival_s, Ev::Arrive(idx));
        }
        // Periodic hooks, held as rearming tick-train slots merged into
        // the pop order (they fire forever; the loop below breaks on the
        // finishing event, which is never a tick). The adjust period is
        // read off machine 0's already-constructed policy — every machine
        // runs the same policy, and re-boxing via `policy::by_name` just
        // to read the period was a needless allocation.
        let adjust_period = self.machines.first().and_then(|m| m.mgr.policy.adjust_period_s());
        if let Some(p) = adjust_period {
            self.q.arm_periodic(SLOT_ADJUST, p, p, Ev::Adjust);
        }
        let sample = self.cfg.sample_period_s;
        self.q.arm_periodic(SLOT_SAMPLE, sample, sample, Ev::Sample);

        // Fleet lifecycle events (module docs in `lifecycle` spell out
        // the ordering/determinism contract). Zero pushes when no
        // lifecycle block is configured, so sequence-number streams and
        // queue stats are untouched for plain runs.
        self.push_lifecycle_events();

        // Main loop: drain until every request completed.
        while let Some((now, ev)) = self.q.pop() {
            self.handle(now, ev);
            if self.completed == self.reqs.len() && self.arrivals_pending == 0 {
                break;
            }
        }
        let end = self.q.now();

        // Integrate the (last Sample, end] tail: the run usually ends
        // between sampling ticks, and dropping that partial interval
        // under-counts `oversub_integral`/`active_core_seconds` (and thus
        // `oversub_fraction`) on short runs.
        let tail = end - self.collector.last_integral_t;
        if tail > 0.0 {
            for m in 0..self.machines.len() {
                let cpu = &self.machines[m].mgr.cpu;
                self.collector.integrate(
                    m,
                    tail,
                    cpu.running_tasks(),
                    cpu.active_count(),
                    cpu.usable_cores(),
                );
            }
            self.collector.last_integral_t = end;
        }

        // Final aging snapshot.
        let f0: Vec<Vec<f64>> = self
            .machines
            .iter()
            .map(|m| m.mgr.cpu.core_views().map(|c| c.f0_ghz()).collect())
            .collect();
        let freq: Vec<Vec<f64>> =
            self.machines.iter_mut().map(|m| m.mgr.cpu.frequencies(end)).collect();

        // Lifecycle summary: amortize embodied carbon over the service
        // windows the ledger actually recorded (early retirement raises
        // the yearly figure — the paper's amortization argument).
        let lifecycle = self.lifecycle.as_ref().map(|rt| LifecycleSummary {
            yearly_embodied_kg: rt.ledger.yearly_embodied_kg(end),
            retirements: rt.retirements,
            core_failures: rt.core_failures,
            rerouted: rt.rerouted,
        });

        SimResult {
            policy: self.cfg.policy.clone(),
            rate_rps: trace.rate_rps(),
            cores_per_cpu: self.cfg.cores_per_cpu,
            duration_s: end,
            completed_requests: self.completed,
            events_processed: self.q.processed(),
            wall_time_s: 0.0,
            queue: self.q.stats(),
            f0,
            freq,
            collector: std::mem::replace(&mut self.collector, Collector::new(0)),
            lifecycle,
        }
    }

    /// Push every configured lifecycle event through the ordinary
    /// scheduler queue, in a fixed order: maintenance windows (config
    /// order, start before end), explicit failures (config order),
    /// stochastic failures (machine id order, then core id order), then
    /// the first retirement check. Far-future events are pushed
    /// unconditionally — the main loop breaks on trace completion, so
    /// they simply never pop.
    fn push_lifecycle_events(&mut self) {
        let Some(rt) = self.lifecycle.as_mut() else { return };
        let Some(life) = rt.lifecycle.clone() else { return };
        for w in &life.maintenance {
            self.q.push(w.start_s, Ev::MaintStart(w.machine));
            self.q.push(w.start_s + w.duration_s, Ev::MaintEnd(w.machine));
        }
        for f in &life.failures {
            self.q.push(f.time_s, Ev::FailCore { m: f.machine, core: f.core });
        }
        if life.failure_rate_per_core_year > 0.0 {
            let lambda_s = life.failure_rate_per_core_year / SECONDS_PER_YEAR;
            for m in 0..self.machines.len() {
                let n = self.machines[m].mgr.cpu.n_cores();
                let rt = self.lifecycle.as_mut().expect("checked above");
                for core in 0..n {
                    let t = rt.rng.exp(lambda_s);
                    self.q.push(t, Ev::FailCore { m, core });
                }
            }
        }
        if life.retirement_armed() {
            self.q.push(life.check_period_s, Ev::RetireCheck);
        }
    }

    // ------------------------------------------------------------ events

    fn handle(&mut self, now: f64, ev: Ev) {
        match ev {
            Ev::Arrive(idx) => self.on_arrive(now, idx),
            Ev::PromptDone(m) => self.on_prompt_done(now, m),
            Ev::FlowDone(idx) => self.on_flow_done(now, idx),
            Ev::IterDone(m) => self.on_iter_done(now, m),
            Ev::TaskDone { m, task } => self.machines[m].mgr.finish_task(task, now),
            Ev::Adjust => {
                // Machine order matches the per-machine events this
                // replaces (they were pushed, and thus popped, in id
                // order at the shared timestamp). `adjust_tick` skips
                // machines whose package saw no state change since their
                // last tick (dirty-flag skip-ahead; see `cpu::package`).
                // Rearming is the scheduler's job now (tick-train slot).
                // Machines drained for maintenance are skipped — their
                // cores are parked and the policy has nothing to manage
                // until the window closes.
                for m in 0..self.machines.len() {
                    if self.machines[m].available {
                        self.machines[m].mgr.adjust_tick(now);
                    }
                }
            }
            Ev::Sample => self.on_sample(now),
            Ev::MaintStart(m) => self.on_maint_start(now, m),
            Ev::MaintEnd(m) => self.on_maint_end(now, m),
            Ev::FailCore { m, core } => {
                // `fail_core` is a no-op (false) for stale core indices
                // — e.g. a stochastic draw landing after the machine was
                // retired onto a smaller SKU — and for already-failed
                // cores (explicit + stochastic collision).
                if self.machines[m].mgr.fail_core(core, now) {
                    if let Some(rt) = self.lifecycle.as_mut() {
                        rt.core_failures += 1;
                    }
                }
            }
            Ev::RetireCheck => self.on_retire_check(now),
        }
    }

    /// Open a maintenance window: take machine `m` out of the routing
    /// rotation, park its free healthy cores in C6, and re-route any
    /// queued (not yet started) prefills to other prompt machines. Work
    /// already running — the in-flight prefill, the decode batch, pinned
    /// CPU tasks — runs to completion; a drain never cancels anything.
    fn on_maint_start(&mut self, now: f64, m: usize) {
        self.machines[m].available = false;
        let mgr = &mut self.machines[m].mgr;
        let to_park: Vec<usize> = mgr
            .cpu
            .core_views()
            .filter(|c| c.state() == CState::C0 && c.task().is_none() && !c.failed())
            .map(|c| c.id())
            .collect();
        for core in to_park {
            mgr.cpu.set_state(core, CState::C6, now);
        }
        if self.machines[m].role == Role::Prompt {
            let queued: Vec<usize> = self.machines[m].prompt_queue.drain(..).collect();
            for idx in queued {
                // JSQ over the prompt slice again; `m` is unavailable so
                // it is only re-chosen via the all-drained fallback. The
                // request's scheduler CPU tasks already ran on arrival —
                // re-routing moves the queue entry, not the bookkeeping.
                let pm = Self::least_loaded(&self.machines[..self.cfg.n_prompt]);
                self.reqs[idx].prompt_machine = pm;
                self.machines[pm].prompt_queue.push_back(idx);
                if let Some(rt) = self.lifecycle.as_mut() {
                    rt.rerouted += 1;
                }
                self.try_start_prompt(now, pm);
            }
        }
    }

    /// Close a maintenance window: the machine rejoins the rotation and
    /// its healthy parked cores wake (the policy's next adjust tick
    /// re-parks whatever Algorithm 2 deems surplus).
    fn on_maint_end(&mut self, now: f64, m: usize) {
        self.machines[m].available = true;
        let mgr = &mut self.machines[m].mgr;
        let to_wake: Vec<usize> = mgr
            .cpu
            .core_views()
            .filter(|c| c.state() == CState::C6 && !c.failed())
            .map(|c| c.id())
            .collect();
        for core in to_wake {
            mgr.cpu.set_state(core, CState::C0, now);
        }
    }

    /// Periodic retirement check: retire any machine past the calendar
    /// age limit or whose p99 per-core ΔVth crossed the guard band, then
    /// rearm. Machines are checked — and retired — in id order.
    fn on_retire_check(&mut self, now: f64) {
        let Some(rt) = self.lifecycle.as_ref() else { return };
        let Some(life) = rt.lifecycle.as_ref() else { return };
        let (age_limit, guard, period) =
            (life.age_limit_yr, life.dvth_guard_band_v, life.check_period_s);
        let mut to_retire: Vec<usize> = Vec::new();
        for m in 0..self.machines.len() {
            let over_age = match (age_limit, rt.ledger.service_age_yr(m, now)) {
                (Some(limit), Some(age)) => age >= limit,
                _ => false,
            };
            let over_band = match guard {
                Some(band) => {
                    let cpu = &mut self.machines[m].mgr.cpu;
                    cpu.advance_all(now);
                    let dvths: Vec<f64> = cpu.core_views().map(|c| c.dvth()).collect();
                    crate::util::stats::percentile(&dvths, 99.0) >= band
                }
                None => false,
            };
            if over_age || over_band {
                to_retire.push(m);
            }
        }
        for m in to_retire {
            self.retire_machine(now, m);
        }
        self.q.push(now + period, Ev::RetireCheck);
    }

    /// Retire machine `m` and procure its replacement: close the ledger
    /// record, commission the replacement SKU with a fresh embodied
    /// charge at age zero, sample fresh silicon from the lifecycle RNG
    /// stream, and swap the package in.
    /// [`crate::policy::CoreManager::replace_package`] migrates every
    /// in-flight task onto the new package's oversubscription queue in
    /// arrival order, so nothing is lost or double-completed.
    fn retire_machine(&mut self, now: f64, m: usize) {
        let rt = self.lifecycle.as_mut().expect("retirement implies lifecycle runtime");
        let gi = rt.lifecycle.as_ref().expect("retirement implies lifecycle config").replacement_group;
        let group = rt.fleet.groups[gi].clone();
        rt.ledger.retire(m, now);
        let sampler = ProcVarSampler::new(group.procvar());
        let f0 = sampler.sample_chip(&mut rt.rng, group.cores);
        rt.ledger.commission(m, group.embodied_kg, group.lifetime_yr, 0.0, now);
        rt.retirements += 1;
        let cpu = CpuPackage::new(f0, self.cfg.aging, self.cfg.temps);
        let pol = policy::by_name(&self.cfg.policy).expect("valid policy name");
        self.machines[m].mgr.replace_package(cpu, pol, now);
    }

    fn on_arrive(&mut self, now: f64, idx: usize) {
        self.arrivals_pending -= 1;
        // Cluster-level scheduler: JSQ over prompt machines, then the
        // least-loaded token machine (Splitwise's pairing step). Roles
        // occupy contiguous id ranges, so split once and scan each
        // role's slice directly instead of filtering all machines twice.
        let (prompt_machines, token_machines) = self.machines.split_at(self.cfg.n_prompt);
        let pm = Self::least_loaded(prompt_machines);
        let tm = Self::least_loaded(token_machines);
        self.reqs[idx].prompt_machine = pm;
        self.reqs[idx].token_machine = tm;
        // Scheduler bookkeeping burns CPU on the chosen prompt machine.
        self.spawn_task(now, pm, TaskKind::Submit);
        self.spawn_task(now, pm, TaskKind::SubmitChain);
        self.spawn_task(now, pm, TaskKind::SubmitTask);
        self.machines[pm].prompt_queue.push_back(idx);
        self.try_start_prompt(now, pm);
    }

    /// JSQ pick over one role's contiguous machine slice; returns the
    /// machine id. `min_by_key` keeps the filter-scan era tie-break
    /// (first minimum in id order), so schedules are unchanged. Machines
    /// drained for maintenance are skipped; if the whole role is drained
    /// at once we fall back to plain JSQ over everyone — work must land
    /// somewhere, and the drained machine simply serves it late. Without
    /// a lifecycle config every machine is available, so the filter
    /// passes everything and schedules are byte-identical to before.
    fn least_loaded(machines: &[Machine]) -> usize {
        machines
            .iter()
            .filter(|m| m.available)
            .min_by_key(|m| m.sched_load())
            .or_else(|| machines.iter().min_by_key(|m| m.sched_load()))
            .expect("at least one machine per role")
            .id
    }

    fn try_start_prompt(&mut self, now: f64, m: usize) {
        if self.machines[m].prompt_busy.is_some() {
            return;
        }
        let Some(idx) = self.machines[m].prompt_queue.pop_front() else {
            return;
        };
        self.machines[m].prompt_busy = Some(idx);
        self.spawn_task(now, m, TaskKind::AllocMemory);
        let dur = self.cfg.perf.prompt_time_s(self.reqs[idx].prompt_tokens);
        self.q.push(now + dur, Ev::PromptDone(m));
    }

    fn on_prompt_done(&mut self, now: f64, m: usize) {
        let idx = self.machines[m].prompt_busy.take().expect("prompt machine was busy");
        self.reqs[idx].ttft_s = Some(now - self.reqs[idx].arrival_s);
        self.spawn_task(now, m, TaskKind::FinishTask);
        self.spawn_task(now, m, TaskKind::SubmitFlow);
        // KV flow to the token machine: serialize on its ingress link.
        let tm = self.reqs[idx].token_machine;
        let xfer = self.cfg.perf.kv_transfer_s(self.reqs[idx].prompt_tokens);
        let start = self.machines[tm].link_busy_until.max(now);
        let done = start + xfer;
        self.machines[tm].link_busy_until = done;
        self.q.push(done, Ev::FlowDone(idx));
        // Prompt-side KV is freed once the flow leaves.
        self.spawn_task(now, m, TaskKind::FreeMemory);
        // Pull the next queued prefill.
        self.try_start_prompt(now, m);
    }

    fn on_flow_done(&mut self, now: f64, idx: usize) {
        let tm = self.reqs[idx].token_machine;
        self.spawn_task(now, tm, TaskKind::FlowCompletion);
        self.spawn_task(now, tm, TaskKind::FinishFlow);
        self.spawn_task(now, tm, TaskKind::AllocMemory);
        self.reqs[idx].ctx_tokens = self.reqs[idx].prompt_tokens as u64;
        self.machines[tm].pending.push_back(idx);
        if !self.machines[tm].iterating {
            self.start_iteration(now, tm);
        }
    }

    /// Admit pending requests (KV permitting) and run one decode iteration.
    fn start_iteration(&mut self, now: f64, m: usize) {
        // Admission: batch cap + KV capacity.
        while self.machines[m].batch.len() < self.cfg.max_batch {
            let Some(&idx) = self.machines[m].pending.front() else {
                break;
            };
            let need = self.reqs[idx].ctx_tokens + self.reqs[idx].output_tokens as u64;
            if !self.machines[m].kv.fits(need) {
                break;
            }
            self.machines[m].kv.alloc(need);
            self.machines[m].pending.pop_front();
            self.machines[m].batch.push(idx);
        }
        if self.machines[m].batch.is_empty() {
            self.machines[m].iterating = false;
            return;
        }
        self.machines[m].iterating = true;
        self.spawn_task(now, m, TaskKind::StartIteration);
        let batch = self.machines[m].batch.len();
        let ctx: u64 = self.machines[m].batch.iter().map(|&i| self.reqs[i].ctx_tokens).sum();
        let dur = self.cfg.perf.iter_time_s(batch, ctx);
        self.q.push(now + dur, Ev::IterDone(m));
    }

    fn on_iter_done(&mut self, now: f64, m: usize) {
        // Each batched request produced one token.
        let batch = std::mem::take(&mut self.machines[m].batch);
        for idx in batch {
            self.reqs[idx].remaining -= 1;
            self.reqs[idx].ctx_tokens += 1;
            if self.reqs[idx].remaining == 0 {
                // Request complete.
                self.reqs[idx].done_s = Some(now);
                let r = &self.reqs[idx];
                self.collector.record_request(
                    r.ttft_s.unwrap_or(0.0),
                    now - r.arrival_s,
                );
                let reserve = r.prompt_tokens as u64 + r.output_tokens as u64;
                self.machines[m].kv.free(reserve);
                self.completed += 1;
                self.spawn_task(now, m, TaskKind::FinishTask);
                self.spawn_task(now, m, TaskKind::FinishRequest);
                self.spawn_task(now, m, TaskKind::FreeMemory);
            } else {
                self.machines[m].batch.push(idx);
            }
        }
        self.start_iteration(now, m);
    }

    fn on_sample(&mut self, now: f64) {
        let dt = self.cfg.sample_period_s;
        for m in 0..self.machines.len() {
            let cpu = &self.machines[m].mgr.cpu;
            let running = cpu.running_tasks();
            let active = cpu.active_count();
            self.collector.sample_machine(m, running, cpu.normalized_idle());
            self.collector.integrate(m, dt, running, active, cpu.usable_cores());
        }
        self.collector.last_integral_t = now;
    }

    // ------------------------------------------------------------ tasks

    /// Spawn one CPU inference task of `kind` on machine `m`: route it
    /// through the core manager (Algorithm 1 for the proposed policy) and
    /// schedule its completion, stretched by the core's aging slowdown or
    /// the time-sharing penalty when oversubscribed.
    fn spawn_task(&mut self, now: f64, m: usize, kind: TaskKind) {
        let task = self.next_task;
        self.next_task += 1;
        self.task_spawns[kind.index()] += 1;
        let base = kind.sample_duration_s(&mut self.rng);
        let mach = &mut self.machines[m];
        // Event-driven Fig. 8 sample: idle-core availability at the moment
        // this task asks for a core (before any emergency wake).
        self.collector.sample_idle_event(m, mach.mgr.cpu.normalized_idle_for_extra_task());
        let dur = match mach.mgr.start_task(task, now) {
            Some(core) => base * mach.mgr.cpu.slowdown(core),
            None => {
                // Time-shared execution across the working set.
                let cpu = &mach.mgr.cpu;
                let factor =
                    (cpu.running_tasks() as f64 / cpu.active_count().max(1) as f64).max(1.0);
                base * factor
            }
        };
        self.q.push(now + dur, Ev::TaskDone { m, task });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::azure::{AzureTraceGen, TraceParams, Workload};

    fn small_cfg(policy: &str) -> ClusterConfig {
        ClusterConfig {
            n_prompt: 2,
            n_token: 3,
            cores_per_cpu: 16,
            policy: policy.into(),
            seed: 7,
            ..ClusterConfig::default()
        }
    }

    fn small_trace(rate: f64, dur: f64) -> Trace {
        AzureTraceGen::new(TraceParams {
            rate_rps: rate,
            duration_s: dur,
            workload: Workload::Mixed,
            seed: 3,
        })
        .generate()
    }

    #[test]
    fn completes_all_requests() {
        for pol in crate::policy::ALL_POLICIES {
            let mut c = Cluster::new(small_cfg(pol));
            let t = small_trace(5.0, 20.0);
            let r = c.run(&t);
            assert_eq!(r.completed_requests, t.requests.len(), "policy {pol}");
            assert!(r.duration_s >= t.requests.last().unwrap().arrival_s);
            assert!(r.events_processed > 100);
        }
    }

    #[test]
    fn deterministic_runs() {
        let run = || {
            let mut c = Cluster::new(small_cfg("proposed"));
            c.run(&small_trace(5.0, 15.0))
        };
        let (a, b) = (run(), run());
        assert_eq!(a.duration_s, b.duration_s);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.freq, b.freq);
    }

    #[test]
    fn latencies_recorded_and_positive() {
        let mut c = Cluster::new(small_cfg("proposed"));
        let t = small_trace(5.0, 20.0);
        let r = c.run(&t);
        assert_eq!(r.collector.e2e.len(), t.requests.len());
        for (&ttft, &e2e) in r.collector.ttft.iter().zip(r.collector.e2e.iter()) {
            assert!(ttft > 0.0);
            assert!(e2e >= ttft);
        }
    }

    #[test]
    fn proposed_idles_cores_baselines_do_not() {
        let t = small_trace(3.0, 20.0);
        let r_prop = Cluster::new(small_cfg("proposed")).run(&t);
        let r_linux = Cluster::new(small_cfg("linux")).run(&t);
        // Baselines: normalized idle ~ 1 (all cores active, few tasks).
        let linux_idle = crate::util::stats::mean(&r_linux.pooled_idle_samples());
        let prop_idle = crate::util::stats::mean(&r_prop.pooled_idle_samples());
        assert!(linux_idle > 0.7, "linux idle={linux_idle}");
        assert!(prop_idle < linux_idle * 0.5, "proposed idle={prop_idle} linux={linux_idle}");
    }

    #[test]
    fn proposed_ages_less() {
        let t = small_trace(5.0, 30.0);
        let mut cfg_a = small_cfg("proposed");
        let mut cfg_b = small_cfg("linux");
        // Paired silicon.
        let f0 = cfg_a.sample_f0();
        cfg_a.f0_override = Some(f0.clone());
        cfg_b.f0_override = Some(f0);
        let r_prop = Cluster::new(cfg_a).run(&t);
        let r_linux = Cluster::new(cfg_b).run(&t);
        let fred_prop = crate::util::stats::mean(&r_prop.mean_fred_per_machine());
        let fred_linux = crate::util::stats::mean(&r_linux.mean_fred_per_machine());
        assert!(
            fred_prop < fred_linux * 0.9,
            "proposed fred={fred_prop} linux fred={fred_linux}"
        );
    }

    #[test]
    fn integrals_cover_the_tail_after_the_last_sample() {
        // All cores stay C0 under the linux baseline, so the active-core
        // integral must equal n_machines × cores × duration — including
        // the partial (last Sample, end] interval that used to be dropped
        // (the run almost never ends exactly on a sampling tick).
        let mut c = Cluster::new(small_cfg("linux"));
        let t = small_trace(5.0, 10.0);
        let r = c.run(&t);
        let total: f64 = r.collector.active_core_seconds.iter().sum();
        let expect = (5 * 16) as f64 * r.duration_s;
        let rel = (total - expect).abs() / expect;
        assert!(rel < 1e-9, "active core-seconds {total} != {expect} (rel {rel:e})");
        assert!((r.collector.last_integral_t - r.duration_s).abs() < 1e-12);
    }

    #[test]
    fn kv_never_leaks() {
        let mut c = Cluster::new(small_cfg("proposed"));
        let t = small_trace(8.0, 15.0);
        c.run(&t);
        for m in &c.machines {
            assert_eq!(m.kv.used_tokens, 0, "machine {} leaked KV", m.id);
            assert!(m.batch.is_empty() && m.pending.is_empty());
        }
    }

    #[test]
    fn heap_and_calendar_queues_run_identically() {
        // The queue implementation is an execution detail: every
        // observable — event count, clock, silicon aging, and the shared
        // queue stats — must match exactly between the two.
        let t = small_trace(6.0, 15.0);
        for pol in crate::policy::ALL_POLICIES {
            let run = |queue| {
                let cfg = ClusterConfig { queue, ..small_cfg(pol) };
                Cluster::new(cfg).run(&t)
            };
            let (h, c) = (run(QueueKind::Heap), run(QueueKind::Calendar));
            assert_eq!(h.events_processed, c.events_processed, "policy {pol}");
            assert_eq!(h.duration_s, c.duration_s, "policy {pol}");
            assert_eq!(h.completed_requests, c.completed_requests, "policy {pol}");
            assert_eq!(h.freq, c.freq, "policy {pol}");
            assert_eq!(h.queue, c.queue, "policy {pol}");
            assert!(h.queue.pushes > 0 && h.queue.peak_len > 0);
        }
    }

    /// 5 machines over two SKU groups; group 1 (machines 2–4) enters the
    /// run 0.05 yr past the 3.0 yr age limit, so the first retirement
    /// check (t = 2 s) retires all three. The t = 6 s failure targets
    /// machine 2's *replacement* (failure-after-retirement path).
    fn fleet_cfg(policy: &str) -> ClusterConfig {
        let fleet = FleetConfig {
            groups: vec![
                MachineGroup {
                    count: 2,
                    cores: 16,
                    generation: "paper".into(),
                    embodied_kg: 278.3,
                    lifetime_yr: 3.0,
                    commission_age_yr: 0.5,
                },
                MachineGroup {
                    count: 3,
                    cores: 12,
                    generation: "gen2".into(),
                    embodied_kg: 240.0,
                    lifetime_yr: 3.0,
                    commission_age_yr: 3.05,
                },
            ],
        };
        let lc = LifecycleConfig {
            maintenance: vec![MaintenanceWindow { machine: 0, start_s: 4.0, duration_s: 1.5 }],
            failures: vec![
                CoreFailure { machine: 1, core: 3, time_s: 1.0 },
                CoreFailure { machine: 2, core: 5, time_s: 6.0 },
            ],
            age_limit_yr: Some(3.0),
            check_period_s: 2.0,
            ..LifecycleConfig::default()
        };
        ClusterConfig { fleet: Some(fleet), lifecycle: Some(lc), ..small_cfg(policy) }
    }

    #[test]
    fn lifecycle_runs_complete_and_are_queue_deterministic() {
        let t = small_trace(5.0, 15.0);
        for pol in crate::policy::ALL_POLICIES {
            let run = |queue| {
                let cfg = ClusterConfig { queue, ..fleet_cfg(pol) };
                Cluster::new(cfg).run(&t)
            };
            let (h, c) = (run(QueueKind::Heap), run(QueueKind::Calendar));
            assert_eq!(h.completed_requests, t.requests.len(), "policy {pol}");
            assert_eq!(h.events_processed, c.events_processed, "policy {pol}");
            assert_eq!(h.duration_s, c.duration_s, "policy {pol}");
            assert_eq!(h.freq, c.freq, "policy {pol}");
            let lc = h.lifecycle.expect("fleet run reports a lifecycle summary");
            assert_eq!(lc.retirements, 3, "policy {pol}");
            assert_eq!(lc.core_failures, 2, "policy {pol}");
            assert!(lc.yearly_embodied_kg > 0.0, "policy {pol}");
            assert_eq!(h.lifecycle, c.lifecycle, "policy {pol}");
        }
    }

    #[test]
    fn maintenance_drains_without_losing_work() {
        // A drain window that outlives the trace: machine 0 must end the
        // run drained (queue empty, nothing pinned, still out of the
        // rotation) and every request must still complete — queued
        // prefills were re-routed, not dropped.
        let mut cfg = fleet_cfg("linux");
        cfg.lifecycle = Some(LifecycleConfig {
            maintenance: vec![MaintenanceWindow { machine: 0, start_s: 0.5, duration_s: 1e6 }],
            ..LifecycleConfig::default()
        });
        let mut c = Cluster::new(cfg);
        let t = small_trace(5.0, 15.0);
        let r = c.run(&t);
        assert_eq!(r.completed_requests, t.requests.len());
        assert!(!c.machines[0].available, "window outlives the trace");
        assert!(c.machines[0].prompt_queue.is_empty());
        assert!(c.machines[0].prompt_busy.is_none());
        assert_eq!(c.machines[0].mgr.cpu.running_tasks(), 0);
        // Re-routes happened iff prefills were queued at the drain
        // instant; either way the summary counter matches the runtime.
        assert_eq!(
            r.lifecycle.expect("summary").rerouted,
            c.lifecycle.as_ref().unwrap().rerouted
        );
    }

    #[test]
    fn retirement_replaces_silicon_and_restarts_amortization() {
        let t = small_trace(5.0, 15.0);
        let mut c = Cluster::new(fleet_cfg("proposed"));
        let r = c.run(&t);
        let rt = c.lifecycle.as_ref().expect("fleet runtime");
        // 5 opening records + 3 replacements.
        assert_eq!(rt.ledger.records.len(), 8);
        // Replacements use the group-0 SKU: 16 cores on machines 2–4.
        for m in 2..5 {
            assert_eq!(c.machines[m].mgr.cpu.n_cores(), 16, "machine {m} replaced");
            assert!(rt.ledger.service_age_yr(m, r.duration_s).unwrap() < 1e-3);
        }
        // Early retirement amortizes group 1's charge over ~3.05 served
        // years instead of never charging it: yearly embodied exceeds
        // the static planned rate of the surviving fleet alone.
        assert!(r.lifecycle.unwrap().yearly_embodied_kg > 0.0);
    }

    #[test]
    fn all_task_kinds_spawned() {
        let mut c = Cluster::new(small_cfg("proposed"));
        c.run(&small_trace(10.0, 20.0));
        for (i, &count) in c.task_spawns.iter().enumerate() {
            assert!(count > 0, "task kind {} never spawned", ALL_TASK_KINDS[i].name());
        }
    }
}
