//! carbon-sim CLI launcher.
//!
//! Subcommands:
//!   simulate    run the cluster simulator on a (synthetic or file) trace
//!   sweep       run a parallel scenario sweep (rates × cores × policies ×
//!               workloads × replicas) and aggregate JSON/CSV results;
//!               --shard K/N runs one machine's slice of the grid;
//!               --search races the grid adaptively, stopping replication
//!               of scenarios whose policy ranking is statistically settled
//!   orchestrate launch a whole sharded sweep from one spec — N shard runs
//!               (local children or a --launcher template), a retry/resume
//!               manifest, and the final merge, in one command
//!   merge       validate and reassemble sharded sweep spills into one report
//!   bench       run the pinned perf matrix and write BENCH_<date>.json
//!   lint        run the determinism & invariants static-analysis pass
//!               (simlint) over the source tree — the CI gate
//!   figure      regenerate a paper figure (1, 2, 4, 5, 6, 7, 8)
//!   trace-gen   synthesize an Azure-like trace to a JSONL file
//!   serve       run the real PJRT serving stack on sample prompts
//!   aging-demo  print NBTI aging curves for core schedules
//!
//! Run `carbon-sim <subcommand> --help` for options.

use std::path::Path;

use carbon_sim::carbon::{EmbodiedModel, ServerPowerModel};
use carbon_sim::cluster::{Cluster, ClusterConfig};
use carbon_sim::cpu::{AgingParams, TemperatureModel};
use carbon_sim::experiments::search::SearchConfig;
use carbon_sim::experiments::{self, search, sweep, sweep_stream, Scale};
use carbon_sim::sim::QueueKind;
use carbon_sim::trace::azure::{AzureTraceGen, TraceParams, Workload};
use carbon_sim::util::cli::Cli;
use carbon_sim::util::stats::Summary;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, rest)) => (c.as_str(), rest.to_vec()),
        None => {
            eprintln!("{}", top_usage());
            std::process::exit(2);
        }
    };
    let code = match cmd {
        "simulate" => cmd_simulate(&rest),
        "sweep" => cmd_sweep(&rest),
        "orchestrate" => cmd_orchestrate(&rest),
        "merge" => cmd_merge(&rest),
        "bench" => cmd_bench(&rest),
        "lint" => cmd_lint(&rest),
        "figure" => cmd_figure(&rest),
        "trace-gen" => cmd_trace_gen(&rest),
        "serve" => cmd_serve(&rest),
        "aging-demo" => cmd_aging_demo(&rest),
        "--help" | "-h" | "help" => {
            println!("{}", top_usage());
            0
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n\n{}", top_usage());
            2
        }
    };
    std::process::exit(code);
}

fn top_usage() -> String {
    "carbon-sim — aging-aware CPU core management for LLM inference (paper reproduction)\n\n\
     Subcommands:\n\
     \x20 simulate     run the cluster simulator\n\
     \x20 sweep        parallel scenario sweep: rates × cores × policies × workloads ×\n\
     \x20              replicas, sharded over a worker pool (--threads), aggregated to\n\
     \x20              JSON/CSV; bit-identical output at any thread count. Grids come\n\
     \x20              from axis flags or a JSON spec (--spec examples/specs/paper.json);\n\
     \x20              --out-dir streams per-cell JSONL with crash resume (--resume);\n\
     \x20              --shard K/N runs one machine's slice of the grid; --search races\n\
     \x20              the grid adaptively and stops replicating scenarios whose policy\n\
     \x20              ranking is statistically settled (writes search.json)\n\
     \x20 orchestrate  drive a whole sharded sweep from one spec: launch N shard runs\n\
     \x20              (local children, or remote via --launcher template), track them\n\
     \x20              in a retry/resume manifest (orchestrate.json), and merge the\n\
     \x20              finished spills into the final report — one command end to end\n\
     \x20 merge        validate sharded sweep spills against one another and reassemble\n\
     \x20              them into a report byte-identical to a single-machine run\n\
     \x20 bench        run the pinned perf matrix (short/long traces × 40/80 cores ×\n\
     \x20              all policies) and write events/sec to BENCH_<date>.json\n\
     \x20 lint         simlint: the determinism & invariants static-analysis pass\n\
     \x20              (total_cmp, no map iteration, no wall clock, no stray threads,\n\
     \x20              schema-version sync) over rust/src — nonzero exit on findings;\n\
     \x20              --json emits a lint-report document (docs/static-analysis.md)\n\
     \x20 figure       regenerate a paper figure (--fig 1|2|4|5|6|7|8)\n\
     \x20 trace-gen    synthesize an Azure-like trace (JSONL)\n\
     \x20 serve        run the PJRT serving stack (needs `make artifacts`)\n\
     \x20 aging-demo   print NBTI aging curves\n"
        .to_string()
}

fn parse_or_exit(cli: &Cli, rest: &[String]) -> carbon_sim::util::cli::Args {
    match cli.parse(rest) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

// ----------------------------------------------------------------- simulate

fn cmd_simulate(rest: &[String]) -> i32 {
    let cli = Cli::new("carbon-sim simulate", "run the LLM cluster simulator")
        .opt("policy", "", "core policy: proposed | linux | least-aged (default: proposed)")
        .opt("rate", "60", "request rate (rps)")
        .opt("duration", "60", "trace duration (s)")
        .opt("cores", "", "CPU cores per machine (default: 40)")
        .opt("prompt-machines", "", "prompt (prefill) machines (default: 5)")
        .opt("token-machines", "", "token (decode) machines (default: 17)")
        .opt("workload", "mixed", "workload: conv | code | mixed | diurnal | bursty | long-context")
        .opt("trace", "", "replay a JSONL trace file instead of synthesizing")
        .opt("config", "", "JSON cluster config file (see configs/; flags override)")
        .opt("seed", "", "RNG seed (default: 42)")
        .opt("queue", "", "event-queue implementation: calendar | heap (default: calendar)")
        .flag("pjrt-aging", "cross-check final aging through the PJRT aging_step artifact");
    let a = parse_or_exit(&cli, rest);

    let workload = match Workload::parse(&a.str_or("workload", "mixed")) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let trace = if a.str_or("trace", "").is_empty() {
        AzureTraceGen::new(TraceParams {
            rate_rps: a.f64_or("rate", 60.0),
            duration_s: a.f64_or("duration", 60.0),
            workload,
            seed: a.u64_or("seed", 42),
        })
        .generate()
    } else {
        match carbon_sim::trace::loader::load(Path::new(&a.str_or("trace", ""))) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("trace load failed: {e}");
                return 1;
            }
        }
    };

    let base = match a.str_or("config", "").as_str() {
        "" => ClusterConfig::default(),
        path => match carbon_sim::config::cluster_from_file(Path::new(path)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        },
    };
    // Flags override the config file, which overrides paper defaults.
    // (Empty-string CLI defaults fail to parse and fall through to `base`.)
    let policy_flag = a.str_or("policy", "");
    let queue = match a.str_or("queue", "").as_str() {
        "" => base.queue,
        s => match QueueKind::parse(s) {
            Ok(k) => k,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
    };
    let cfg = ClusterConfig {
        n_prompt: a.usize_or("prompt-machines", base.n_prompt),
        n_token: a.usize_or("token-machines", base.n_token),
        cores_per_cpu: a.usize_or("cores", base.cores_per_cpu),
        policy: if policy_flag.is_empty() { base.policy.clone() } else { policy_flag },
        seed: a.u64_or("seed", base.seed),
        queue,
        ..base
    };
    let mut cluster = Cluster::new(cfg);
    // The simulator core is wall-clock-free (the simlint no-wall-clock
    // gate): wall time is a launcher-side measurement stamped here.
    let wall_start = std::time::Instant::now();
    let mut result = cluster.run(&trace);
    result.wall_time_s = wall_start.elapsed().as_secs_f64();

    println!(
        "── simulation ({} @ {:.0} rps, {} cores) ──",
        result.policy, result.rate_rps, result.cores_per_cpu
    );
    println!("requests completed  {:>12}", result.completed_requests);
    println!("sim duration        {:>12.1} s", result.duration_s);
    println!("events processed    {:>12}", result.events_processed);
    println!(
        "wall time           {:>12.2} s  ({:.1}M events/s)",
        result.wall_time_s,
        result.events_processed as f64 / result.wall_time_s / 1e6
    );
    let ttft = result.ttft_summary();
    let e2e = result.e2e_summary();
    println!("TTFT  p50/p99       {:>9.3} / {:.3} s", ttft.p50, ttft.p99);
    println!("E2E   p50/p99       {:>9.3} / {:.3} s", e2e.p50, e2e.p99);
    let cv = Summary::of(&result.freq_cv_per_machine());
    let fred = Summary::of(&result.mean_fred_per_machine());
    println!("freq CV  p50/p99    {:>9.5} / {:.5}", cv.p50, cv.p99);
    println!("mean fred p50/p99   {:>9.3} / {:.3} MHz", fred.p50 * 1e3, fred.p99 * 1e3);
    let idle = Summary::of(&result.pooled_idle_samples());
    println!("norm idle p1/p50/p90 {:>8.3} / {:.3} / {:.3}", idle.p1, idle.p50, idle.p90);
    println!("oversub fraction    {:>12.4}", result.oversub_fraction());

    if a.flag("pjrt-aging") {
        match pjrt_aging_check(&result) {
            Ok(max_err) => println!("pjrt aging_step cross-check: max |Δf| = {max_err:.3e} GHz ✓"),
            Err(e) => {
                eprintln!("pjrt aging check failed: {e:#}");
                return 1;
            }
        }
    }
    0
}

/// Re-run the final frequency computation through the PJRT aging artifact
/// and compare with the simulator's pure-Rust values.
fn pjrt_aging_check(result: &carbon_sim::metrics::SimResult) -> anyhow::Result<f64> {
    use carbon_sim::runtime::{AgingStepPjrt, Runtime};
    let rt = Runtime::cpu(Runtime::default_artifacts_dir())?;
    let step = AgingStepPjrt::load(&rt)?;
    let aging = AgingParams::paper_default();
    let temps = TemperatureModel::paper_default();
    let m = step.machines.min(result.f0.len());
    let c = step.cores.min(result.f0[0].len());
    // tau = 0 keeps the accumulated dvth frozen; the kernel then reports
    // f = f0 (1 - dvth/(vdd - vth)) which must match the simulator.
    let mut dvth = vec![0f32; step.machines * step.cores];
    let mut f0 = vec![2.6f32; step.machines * step.cores];
    let adf = vec![
        aging.adf(temps.steady_k(carbon_sim::cpu::CState::C0, true), 1.0) as f32;
        step.machines * step.cores
    ];
    let tau = vec![0f32; step.machines * step.cores];
    for i in 0..m {
        for j in 0..c {
            let core_f0 = result.f0[i][j];
            let core_f = result.freq[i][j];
            f0[i * step.cores + j] = core_f0 as f32;
            // Invert Eq. (1) to recover dvth from the simulator's result.
            dvth[i * step.cores + j] = ((1.0 - core_f / core_f0) * (aging.vdd - aging.vth)) as f32;
        }
    }
    let (_, freqs) = step.step(&dvth, &adf, &tau, &f0)?;
    let mut max_err = 0f64;
    for i in 0..m {
        for j in 0..c {
            let err = (freqs[i * step.cores + j] as f64 - result.freq[i][j]).abs();
            max_err = max_err.max(err);
        }
    }
    anyhow::ensure!(max_err < 1e-5, "PJRT/Rust aging mismatch: {max_err}");
    Ok(max_err)
}

// ----------------------------------------------------------------- sweep

fn cmd_sweep(rest: &[String]) -> i32 {
    let cli = Cli::new(
        "carbon-sim sweep",
        "parallel scenario sweep over rates × cores × policies × workloads × replicas",
    )
    .opt("spec", "", "JSON sweep spec file (see examples/specs/); cannot be combined with axis flags")
    .opt("rates", "40,60,80,100", "comma-separated request rates (rps)")
    .opt("cores", "40,80", "comma-separated VM core counts")
    .opt("policies", "all", "comma-separated policies, or 'all' (linux,least-aged,proposed)")
    .opt("workloads", "mixed", "comma-separated scenarios: conv|code|mixed|diurnal|bursty|long-context")
    .opt("replicas", "1", "seed replicas per scenario")
    .opt("duration", "120", "trace duration per cell (s)")
    .opt("prompt-machines", "5", "prompt (prefill) machines per cell")
    .opt("token-machines", "17", "token (decode) machines per cell")
    .opt("seed", "42", "root seed; per-cell seeds derive from (seed, scenario index)")
    .opt("threads", "0", "worker threads (0 = one per available core)")
    .opt(
        "queue",
        "calendar",
        "event-queue implementation: calendar | heap (execution detail — reports are \
         byte-identical either way, so it composes with --spec)",
    )
    .opt("out", "", "write the aggregated report to this file (default: stdout table only)")
    .opt(
        "out-dir",
        "",
        "stream one JSONL row per finished cell to <dir>/cells.jsonl (O(workers) memory) \
         and assemble <dir>/report.<format> from it",
    )
    .opt("format", "json", "report format: json | csv")
    .opt(
        "shard",
        "",
        "run only this machine's slice of the grid, as K/N (cells with index % N == K); \
         requires --out-dir; reassemble finished shards with `carbon-sim merge`",
    )
    .flag(
        "search",
        "adaptive search: race the grid in replica rungs and stop replicating scenarios \
         whose policy ranking is statistically settled (requires --out-dir; writes \
         <dir>/search.json; tune via a `search` block in the spec file)",
    )
    .flag(
        "resume",
        "with --out-dir: skip cells already recorded in cells.jsonl (spec hash must match)",
    )
    .flag("quiet", "suppress the stdout summary table");
    let a = parse_or_exit(&cli, rest);

    type Parsed = (sweep::SweepSpec, Option<SearchConfig>, sweep::Format, usize, QueueKind);
    let parsed = (|| -> Result<Parsed, String> {
        let spec_path = a.str_or("spec", "");
        let (spec, search_cfg) = if spec_path.is_empty() {
            let spec = sweep::SweepSpec {
                rates: sweep::parse_f64_list(&a.str_or("rates", ""))?,
                core_counts: sweep::parse_usize_list(&a.str_or("cores", ""))?,
                policies: sweep::parse_policy_list(&a.str_or("policies", "all"))?,
                workloads: sweep::parse_workload_list(&a.str_or("workloads", "mixed"))?,
                // Strict scalar parsing (`Args::parsed`): a malformed
                // value must exit 2, not silently run the wrong grid
                // for hours at paper scale.
                replicas: a.parsed("replicas")?,
                duration_s: a.parsed("duration")?,
                n_prompt: a.parsed("prompt-machines")?,
                n_token: a.parsed("token-machines")?,
                seed: a.parsed("seed")?,
                // Fleet/lifecycle blocks are spec-file-only (too
                // structured for axis flags); see examples/specs.
                fleet: None,
                lifecycle: None,
            };
            // Axis-flag grids carry no `search` block; --search falls back
            // to SearchConfig::defaults_for below.
            (spec, None)
        } else {
            // The spec file defines the whole grid; silently ignoring an
            // explicitly typed axis flag would run the wrong grid for
            // hours, so the combination is an error.
            const AXIS_FLAGS: &[&str] = &[
                "rates",
                "cores",
                "policies",
                "workloads",
                "replicas",
                "duration",
                "prompt-machines",
                "token-machines",
                "seed",
            ];
            if let Some(conflict) = AXIS_FLAGS.iter().find(|k| a.was_given(k)) {
                return Err(format!(
                    "--spec defines the whole grid; drop --{conflict} (edit the spec file instead)"
                ));
            }
            carbon_sim::config::sweep_search_from_file(Path::new(&spec_path))?
        };
        // sweep::run validates the spec; only the format needs checking here.
        let format = sweep::Format::parse(&a.str_or("format", "json"))?;
        let threads = a.parsed("threads")?;
        // Not an axis flag: the queue kind changes nothing in the report,
        // so it composes with --spec (differential CI runs rely on this).
        let queue = QueueKind::parse(&a.str_or("queue", "calendar"))?;
        Ok((spec, search_cfg, format, threads, queue))
    })();
    let (spec, search_cfg, format, threads, queue) = match parsed {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };

    // --out-dir selects the streaming engine: per-cell JSONL spill with
    // O(workers) memory, crash resume, and a report assembled from the
    // spill (byte-identical to the in-memory path).
    let out_dir = a.str_or("out-dir", "");
    if a.flag("resume") && out_dir.is_empty() {
        eprintln!("--resume requires --out-dir (the cells.jsonl spill to resume from)");
        return 2;
    }
    if !out_dir.is_empty() && !a.str_or("out", "").is_empty() {
        eprintln!("--out and --out-dir are mutually exclusive (the streaming report goes to <out-dir>/report.<format>)");
        return 2;
    }
    let shard = match a.str_or("shard", "").as_str() {
        "" => sweep::ShardSpec::full(),
        s => match sweep::ShardSpec::parse(s) {
            Ok(sh) => sh,
            Err(e) => {
                eprintln!("--shard: {e}");
                return 2;
            }
        },
    };
    if !shard.is_full() && out_dir.is_empty() {
        eprintln!("--shard requires --out-dir (shard spills are what `carbon-sim merge` reassembles)");
        return 2;
    }
    if a.flag("search") {
        if out_dir.is_empty() {
            eprintln!(
                "--search requires --out-dir (rung cells spill to <dir>/cells.jsonl and the \
                 verdict to <dir>/search.json)"
            );
            return 2;
        }
        if !shard.is_full() {
            eprintln!(
                "--search and --shard are mutually exclusive (the search schedules the grid \
                 itself; shard the exhaustive sweep instead)"
            );
            return 2;
        }
        // --format shapes the assembled report, which a search does not
        // produce; silently ignoring an explicitly typed flag would hide
        // that, so the combination is an error.
        if a.was_given("format") {
            eprintln!(
                "--search writes search.json, not a report; drop --format (finish the grid \
                 with `sweep --resume` on the same --out-dir to assemble one)"
            );
            return 2;
        }
        let cfg = search_cfg.unwrap_or_else(|| SearchConfig::defaults_for(&spec));
        let summary = match search::run_search(
            &spec,
            &cfg,
            threads,
            Path::new(&out_dir),
            a.flag("resume"),
            !a.flag("quiet"),
            queue,
        ) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        println!(
            "search settled {}/{} scenarios with {}/{} cells ({} resumed, {} run) in {}; \
             verdict: {}",
            summary.n_settled,
            summary.n_scenarios,
            summary.n_cells_spent,
            summary.n_cells_exhaustive,
            summary.n_resumed,
            summary.n_run,
            summary.cells_path.display(),
            summary.search_path.display()
        );
        return 0;
    }
    if !out_dir.is_empty() {
        let summary = match sweep_stream::run_streaming_with(
            &spec,
            threads,
            Path::new(&out_dir),
            &shard,
            format,
            a.flag("resume"),
            !a.flag("quiet"),
            queue,
        ) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        match summary.report_path {
            Some(report) => println!(
                "streamed {} cells ({} resumed, {} run) to {}; report: {}",
                summary.n_cells,
                summary.n_resumed,
                summary.n_run,
                summary.cells_path.display(),
                report.display()
            ),
            None => println!(
                "streamed shard {shard}: {} cells ({} resumed, {} run) to {}; when every \
                 shard is done: carbon-sim merge <dir>... --out-dir <merged>",
                summary.n_cells,
                summary.n_resumed,
                summary.n_run,
                summary.cells_path.display()
            ),
        }
        return 0;
    }

    let report = match sweep::run_with_queue(&spec, threads, queue) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };

    if !a.flag("quiet") {
        println!(
            "── sweep: {} cells ({} scenarios × {} policies) ──",
            report.cells.len(),
            spec.n_scenarios(),
            spec.policies.len()
        );
        report.print_table();
    }
    let out = a.str_or("out", "");
    if !out.is_empty() {
        if let Err(e) = report.write(Path::new(&out), format) {
            eprintln!("writing {out}: {e}");
            return 1;
        }
        println!("wrote {} cells to {out}", report.cells.len());
    } else if a.flag("quiet") {
        // Quiet with no --out: emit the report itself to stdout.
        print!("{}", report.render(format));
    }
    0
}

// ----------------------------------------------------------------- orchestrate

fn cmd_orchestrate(rest: &[String]) -> i32 {
    let cli = Cli::new(
        "carbon-sim orchestrate",
        "drive a sharded sweep end to end: launch N `sweep --shard K/N` runs from one \
         spec (at most --workers in flight), relay their progress, retry failures \
         against their partial spills, track everything in <out-dir>/orchestrate.json, \
         and merge the finished shards into a report byte-identical to a \
         single-machine run",
    )
    .opt("spec", "", "JSON sweep spec file (required; defines the whole grid)")
    .opt("shards", "", "number of shards N to split the grid across (required)")
    .opt("workers", "0", "max shard runs in flight at once (0 = all N)")
    .opt(
        "retries",
        "2",
        "re-launches per shard after a failure; retries resume the shard's partial spill",
    )
    .opt("threads", "0", "worker threads per local shard child (0 = one per core)")
    .opt(
        "out-dir",
        "orchestrate-out",
        "directory for the shard out-dirs (shard-<k>/), the orchestrate.json manifest, \
         and the merged cells.jsonl + report",
    )
    .opt("format", "json", "merged report format: json | csv")
    .opt(
        "launcher",
        "",
        "shell template launching one shard, with {shard}, {out_dir}, and {spec} \
         substituted (e.g. for SSH/SLURM); it must block until the shard finishes and \
         write the spill under {out_dir}. Default: local carbon-sim child processes",
    )
    .flag(
        "resume",
        "continue a previous orchestrate run in this --out-dir: done shards are kept \
         (re-verified on disk), interrupted and failed ones relaunch with --resume",
    )
    .flag("quiet", "suppress relayed shard stdout lines (stderr is always relayed)");
    let a = parse_or_exit(&cli, rest);

    let spec_path = a.str_or("spec", "");
    if spec_path.is_empty() {
        eprintln!("orchestrate requires --spec (the grid definition every shard runs)");
        return 2;
    }
    if a.str_or("shards", "").is_empty() {
        eprintln!("orchestrate requires --shards N (how many slices to split the grid into)");
        return 2;
    }
    let parsed = (|| -> Result<experiments::orchestrate::OrchestrateConfig, String> {
        let spec = carbon_sim::config::sweep_from_file(Path::new(&spec_path))?;
        let shards: usize = a.parsed("shards")?;
        if shards == 0 {
            return Err("--shards must be ≥ 1".to_string());
        }
        let program = std::env::current_exe()
            .map_err(|e| format!("cannot locate the carbon-sim binary for shard children: {e}"))?;
        let launcher = match a.str_or("launcher", "").as_str() {
            "" => None,
            t => Some(t.to_string()),
        };
        Ok(experiments::orchestrate::OrchestrateConfig {
            spec,
            spec_path: spec_path.clone().into(),
            shards,
            workers: a.parsed("workers")?,
            retries: a.parsed("retries")?,
            threads_per_shard: a.parsed("threads")?,
            format: sweep::Format::parse(&a.str_or("format", "json"))?,
            launcher,
            program,
            resume: a.flag("resume"),
            verbose: !a.flag("quiet"),
        })
    })();
    let cfg = match parsed {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let out_dir = a.str_or("out-dir", "orchestrate-out");
    match experiments::orchestrate::run(&cfg, Path::new(&out_dir)) {
        Ok(s) => {
            println!(
                "orchestrated {} shard(s) ({} already complete, {} launched); merged {} \
                 cells -> {}; report: {}",
                s.n_shards,
                s.n_skipped,
                s.n_launched,
                cfg.spec.n_cells(),
                s.cells_path.display(),
                s.report_path.display()
            );
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

// ----------------------------------------------------------------- merge

fn cmd_merge(rest: &[String]) -> i32 {
    let cli = Cli::new(
        "carbon-sim merge",
        "validate sharded sweep spills (same spec hash, disjoint-and-complete cell \
         coverage) and reassemble them into <out-dir>/cells.jsonl plus a report \
         byte-identical to a single-machine run of the full grid",
    )
    .pos(
        "shard-dir",
        "one `sweep --out-dir` directory per shard, each holding a cells.jsonl spill",
    )
    .opt("out-dir", "", "directory for the merged cells.jsonl and report (required)")
    .opt("format", "json", "report format: json | csv");
    let a = parse_or_exit(&cli, rest);

    if a.positional.is_empty() {
        eprintln!("merge needs at least one shard directory\n\n{}", cli.usage());
        return 2;
    }
    let out_dir = a.str_or("out-dir", "");
    if out_dir.is_empty() {
        eprintln!("merge requires --out-dir (where the merged spill and report go)");
        return 2;
    }
    let format = match sweep::Format::parse(&a.str_or("format", "json")) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let dirs: Vec<std::path::PathBuf> =
        a.positional.iter().map(|d| std::path::PathBuf::from(d.as_str())).collect();
    match experiments::merge::merge_spills(&dirs, Path::new(&out_dir), format) {
        Ok(s) => {
            println!(
                "merged {} shard spill(s), {} cells -> {}; report: {}",
                s.n_spills,
                s.n_cells,
                s.cells_path.display(),
                s.report_path.display()
            );
            0
        }
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

// ----------------------------------------------------------------- bench

fn cmd_bench(rest: &[String]) -> i32 {
    let cli = Cli::new(
        "carbon-sim bench",
        "run the pinned perf matrix (short/long traces × 40/80 cores × all policies) \
         and record simulated events/sec",
    )
    .opt("out", "", "output JSON path (default: BENCH_<date>.json)")
    .opt("queue", "calendar", "event-queue implementation under test: calendar | heap")
    .flag("quick", "CI-scale matrix: seconds-long traces, 1+2 machines")
    .flag("quiet", "suppress the stdout table");
    let a = parse_or_exit(&cli, rest);

    let quick = a.flag("quick");
    let queue = match QueueKind::parse(&a.str_or("queue", "calendar")) {
        Ok(k) => k,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let report = experiments::bench::run(quick, queue);
    let date = experiments::bench::utc_date_string(
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0),
    );
    if !a.flag("quiet") {
        println!(
            "── bench: {} cells ({}) ──",
            report.cells.len(),
            if quick { "quick matrix" } else { "full matrix" }
        );
        report.print_table();
    }
    let out = match a.str_or("out", "").as_str() {
        "" => format!("BENCH_{date}.json"),
        path => path.to_string(),
    };
    let mut body = report.to_json(&date).to_string_pretty();
    body.push('\n');
    match std::fs::write(&out, body) {
        Ok(()) => {
            println!(
                "wrote {out}: {:.0} events/s over {} cells",
                report.events_per_s(),
                report.cells.len()
            );
            0
        }
        Err(e) => {
            eprintln!("writing {out}: {e}");
            1
        }
    }
}

// ----------------------------------------------------------------- lint

fn cmd_lint(rest: &[String]) -> i32 {
    let cli = Cli::new(
        "carbon-sim lint",
        "simlint — the determinism & invariants static-analysis pass (rules: \
         no-float-partial-cmp, no-map-iteration, no-wall-clock, no-stray-threads, \
         schema-version-sync; see docs/static-analysis.md)",
    )
    .pos("path", ".rs files or directories to scan (default: the crate's src tree)")
    .flag("json", "emit the schema-versioned lint-report JSON document instead of text");
    let a = parse_or_exit(&cli, rest);

    let roots: Vec<std::path::PathBuf> = if a.positional.is_empty() {
        match carbon_sim::analysis::default_roots() {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    } else {
        a.positional.iter().map(std::path::PathBuf::from).collect()
    };
    match carbon_sim::analysis::lint_tree(&roots) {
        Ok(report) => {
            if a.flag("json") {
                println!("{}", report.to_json().to_string_pretty());
            } else {
                print!("{}", report.render_text());
            }
            if report.is_clean() {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("lint error: {e}");
            2
        }
    }
}

// ----------------------------------------------------------------- figure

fn cmd_figure(rest: &[String]) -> i32 {
    let cli = Cli::new("carbon-sim figure", "regenerate a paper figure")
        .opt("fig", "6", "figure number: 1 | 2 | 4 | 5 | 6 | 7 | 8")
        .opt("scale", "paper", "experiment scale: paper | smoke")
        .opt("duration", "0", "override trace duration (s); 0 = scale default")
        .opt("threads", "0", "worker threads for the run matrix (0 = one per core)");
    let a = parse_or_exit(&cli, rest);
    let mut scale = match a.str_or("scale", "paper").as_str() {
        "paper" => Scale::paper(),
        "smoke" => Scale::smoke(),
        other => {
            eprintln!("unknown scale '{other}'");
            return 2;
        }
    };
    let dur = a.f64_or("duration", 0.0);
    if dur > 0.0 {
        scale.duration_s = dur;
    }
    let threads = a.usize_or("threads", 0);
    match a.str_or("fig", "6").as_str() {
        "1" => experiments::fig1::print(&experiments::fig1::run(&ServerPowerModel::a100x4())),
        "2" => {
            let levels = experiments::fig2::run(&scale, scale.core_counts[0]);
            experiments::fig2::print(&levels);
        }
        "4" => experiments::fig4::print(&experiments::fig4::run(600.0, 120.0, 420.0, 1.0)),
        "5" => experiments::fig5::print(&experiments::fig5::run(40)),
        "6" => {
            let cells = experiments::run_matrix_threads(&scale, threads);
            experiments::fig6::print(&experiments::fig6::rows(&cells, 2.6));
        }
        "7" => {
            let cells = experiments::run_matrix_threads(&scale, threads);
            experiments::fig7::print(&experiments::fig7::rows(
                &cells,
                &EmbodiedModel::paper_default(),
            ));
        }
        "8" => {
            let cells = experiments::run_matrix_threads(&scale, threads);
            experiments::fig8::print(&experiments::fig8::rows(&cells));
        }
        other => {
            eprintln!("unknown figure '{other}'");
            return 2;
        }
    }
    0
}

// ----------------------------------------------------------------- trace-gen

fn cmd_trace_gen(rest: &[String]) -> i32 {
    let cli = Cli::new("carbon-sim trace-gen", "synthesize an Azure-like JSONL trace")
        .opt("rate", "60", "request rate (rps)")
        .opt("duration", "120", "duration (s)")
        .opt("workload", "mixed", "conv | code | mixed | diurnal | bursty | long-context")
        .opt("seed", "42", "RNG seed")
        .opt("out", "trace.jsonl", "output path");
    let a = parse_or_exit(&cli, rest);
    let workload = match Workload::parse(&a.str_or("workload", "mixed")) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let trace = AzureTraceGen::new(TraceParams {
        rate_rps: a.f64_or("rate", 60.0),
        duration_s: a.f64_or("duration", 120.0),
        workload,
        seed: a.u64_or("seed", 42),
    })
    .generate();
    let out = a.str_or("out", "trace.jsonl");
    match carbon_sim::trace::loader::save(&trace, Path::new(&out)) {
        Ok(()) => {
            println!(
                "wrote {} requests ({:.1} rps) to {out}",
                trace.requests.len(),
                trace.rate_rps()
            );
            0
        }
        Err(e) => {
            eprintln!("write failed: {e}");
            1
        }
    }
}

// ----------------------------------------------------------------- serve

fn cmd_serve(rest: &[String]) -> i32 {
    let cli = Cli::new("carbon-sim serve", "run the PJRT serving stack (needs `make artifacts`)")
        .opt("requests", "16", "number of sample requests")
        .opt("max-new", "32", "max new tokens per request")
        .opt("policy", "proposed", "shadow core-management policy")
        .opt("cores", "40", "shadow CPU cores")
        .opt("artifacts", "", "artifacts dir (default: ./artifacts)");
    let a = parse_or_exit(&cli, rest);
    let mut cfg = carbon_sim::serving::ServerConfig {
        policy: a.str_or("policy", "proposed"),
        shadow_cores: a.usize_or("cores", 40),
        ..Default::default()
    };
    let art = a.str_or("artifacts", "");
    if !art.is_empty() {
        cfg.artifacts_dir = art.into();
    }
    let server = match carbon_sim::serving::Server::start(cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("server start failed: {e:#}\nhint: run `make artifacts` first");
            return 1;
        }
    };
    let n = a.usize_or("requests", 16);
    let max_new = a.usize_or("max-new", 32);
    let prompts = [
        "Summarize the maintenance schedule for rack 12.",
        "Write a haiku about silicon aging.",
        "Explain NBTI to a new SRE.",
        "What is the carbon footprint of this cluster?",
    ];
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            server.submit(carbon_sim::serving::ServeRequest {
                id: i as u64,
                prompt: prompts[i % prompts.len()].to_string(),
                max_new_tokens: max_new,
            })
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("response");
        if i < 3 {
            println!(
                "req {:>3}: {} prompt toks -> {} gen toks, ttft {:.1} ms, e2e {:.1} ms",
                resp.id,
                resp.prompt_tokens,
                resp.generated_tokens,
                resp.ttft_s * 1e3,
                resp.e2e_s * 1e3
            );
        }
    }
    server.shutdown().print();
    0
}

// ----------------------------------------------------------------- aging-demo

fn cmd_aging_demo(rest: &[String]) -> i32 {
    let cli =
        Cli::new("carbon-sim aging-demo", "print NBTI aging curves").opt("years", "10", "horizon");
    let a = parse_or_exit(&cli, rest);
    let years = a.f64_or("years", 10.0);
    let aging = AgingParams::paper_default();
    let temps = TemperatureModel::paper_default();
    println!("NBTI frequency degradation vs schedule (f0 = {} GHz)", aging.f_nominal_ghz);
    println!(
        "{:>6} {:>14} {:>14} {:>14}",
        "year", "always-on(%)", "50%-halted(%)", "90%-halted(%)"
    );
    for step in 1..=(years as usize) {
        let t = step as f64 * carbon_sim::cpu::aging::SECONDS_PER_YEAR;
        let adf = aging.adf(temps.steady_k(carbon_sim::cpu::CState::C0, true), 1.0);
        let on = aging.rel_reduction(aging.dvth_step(0.0, adf, t));
        let half = aging.rel_reduction(aging.dvth_step(0.0, adf, t * 0.5));
        let tenth = aging.rel_reduction(aging.dvth_step(0.0, adf, t * 0.1));
        println!("{:>6} {:>14.2} {:>14.2} {:>14.2}", step, on * 100.0, half * 100.0, tenth * 100.0);
    }
    0
}
