//! carbon-sim: reproduction of "Aging-aware CPU Core Management for
//! Embodied Carbon Amortization in Cloud LLM Inference" (Hewage et al.,
//! 2025) as a Rust + JAX + Pallas three-layer system.
//!
//! * [`cpu`] — NBTI aging, process variation, C-states (the §3 system model).
//! * [`policy`] — the proposed technique (Algorithms 1–2) and baselines.
//! * [`cluster`] — the from-scratch splitwise-sim equivalent (§5).
//! * [`trace`] — Azure-like trace synthesis and replay (§6.1.2).
//! * [`carbon`] — embodied/operational carbon accounting (Figs. 1 and 7).
//! * [`experiments`] — one runner per paper figure.
//! * [`runtime`] — PJRT loader for the AOT-compiled JAX/Pallas artifacts.
//! * [`serving`] — the real mini serving stack (end-to-end example).
//! * [`analysis`] — simlint, the determinism & invariants lint pass.
//!
//! See DESIGN.md for the system inventory and per-experiment index.

pub mod analysis;
pub mod carbon;
pub mod cluster;
pub mod config;
pub mod cpu;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod policy;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod trace;
pub mod util;
