//! Metrics collection for simulation runs.
//!
//! Gathers exactly what the paper's evaluation consumes:
//!
//! * per-machine concurrent inference-task samples (Fig. 2 violins),
//! * per-machine normalized idle-core samples (Fig. 8; positive =
//!   underutilization, negative = oversubscription),
//! * the oversubscription integral `T_oversub` (§3.3),
//! * end-of-run per-core frequencies → CV + mean degradation (Fig. 6),
//! * request service-quality stats (TTFT / E2E latency).

use crate::util::json::Value;
use crate::util::stats::{self, Summary};

/// Raw sample streams captured during a run.
#[derive(Clone, Debug)]
pub struct Collector {
    pub n_machines: usize,
    /// Per machine: sampled concurrent running inference tasks.
    pub task_samples: Vec<Vec<f64>>,
    /// Per machine: sampled normalized idle cores.
    pub idle_samples: Vec<Vec<f64>>,
    /// Per machine: ∫ u(T−(N−N_idle))·(T−(N−N_idle)) dt  (task-seconds).
    pub oversub_integral: Vec<f64>,
    /// Per machine: ∫ active_core_count dt (core-seconds in C0).
    pub active_core_seconds: Vec<f64>,
    /// Per machine: ∫ usable_core_count dt (core-seconds of healthy
    /// capacity). With a static fleet this is the constant
    /// `cores × duration` the old reporting divided by; under lifecycle
    /// events (core failures, SKU swaps on retirement) the usable count
    /// varies over time, and this integral is the correct denominator
    /// for capacity-fraction metrics.
    pub capacity_core_seconds: Vec<f64>,
    /// Simulation time the integrals have been advanced to — written at
    /// each sampling tick and consumed by `Cluster::run`, which integrates
    /// the final partial `(last Sample, end]` interval before snapshotting.
    pub last_integral_t: f64,
    /// Time-to-first-token per request (s).
    pub ttft: Vec<f64>,
    /// End-to-end latency per request (s).
    pub e2e: Vec<f64>,
}

impl Collector {
    pub fn new(n_machines: usize) -> Collector {
        Collector {
            n_machines,
            task_samples: vec![Vec::new(); n_machines],
            idle_samples: vec![Vec::new(); n_machines],
            oversub_integral: vec![0.0; n_machines],
            active_core_seconds: vec![0.0; n_machines],
            capacity_core_seconds: vec![0.0; n_machines],
            last_integral_t: 0.0,
            ttft: Vec::new(),
            e2e: Vec::new(),
        }
    }

    /// Record one periodic sampling instant for machine `m`.
    pub fn sample_machine(&mut self, m: usize, running_tasks: usize, norm_idle: f64) {
        self.task_samples[m].push(running_tasks as f64);
        self.idle_samples[m].push(norm_idle);
    }

    /// Record an event-driven idle sample (taken at task-allocation
    /// instants, like the paper's per-task measurement points — this is
    /// what exposes transient oversubscription in Fig. 8).
    pub fn sample_idle_event(&mut self, m: usize, norm_idle: f64) {
        self.idle_samples[m].push(norm_idle);
    }

    /// Advance the time integrals by `dt` given machine `m`'s state.
    /// `usable_cores` is the machine's healthy (non-failed) core count
    /// *during this interval* — integrated, not assumed constant, because
    /// core failures and retirement SKU swaps change it mid-run.
    pub fn integrate(
        &mut self,
        m: usize,
        dt: f64,
        running_tasks: usize,
        active_cores: usize,
        usable_cores: usize,
    ) {
        let over = running_tasks as f64 - active_cores as f64;
        if over > 0.0 {
            self.oversub_integral[m] += over * dt;
        }
        self.active_core_seconds[m] += active_cores as f64 * dt;
        self.capacity_core_seconds[m] += usable_cores as f64 * dt;
    }

    pub fn record_request(&mut self, ttft_s: f64, e2e_s: f64) {
        self.ttft.push(ttft_s);
        self.e2e.push(e2e_s);
    }
}

/// Fleet-lifecycle roll-up reported by runs with a `fleet` config block.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LifecycleSummary {
    /// Embodied carbon amortized over the service windows machines
    /// *actually* delivered (kgCO₂eq / year); early retirement raises it
    /// above the planned `Σ embodied / lifetime` rate.
    pub yearly_embodied_kg: f64,
    /// Machines retired (and replaced) during the run.
    pub retirements: u64,
    /// Cores permanently failed during the run.
    pub core_failures: u64,
    /// Requests re-routed out of draining machines.
    pub rerouted: u64,
}

/// End-of-run results: everything the experiment harness and benches need.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub policy: String,
    pub rate_rps: f64,
    pub cores_per_cpu: usize,
    pub duration_s: f64,
    pub completed_requests: usize,
    pub events_processed: u64,
    /// Host wall-clock seconds, stamped by *timing callers* around
    /// [`crate::cluster::Cluster::run`] (which itself is wall-clock-free
    /// under the simlint `no-wall-clock` gate and leaves this 0.0).
    pub wall_time_s: f64,
    /// Event-queue counters (peak length, pushes, clamps). Identical
    /// for either queue implementation; surfaced in the bench JSON but
    /// kept out of [`SimResult::to_json_summary`] so sweep reports stay
    /// a function of the spec alone.
    pub queue: crate::sim::QueueStats,

    /// Per machine, per core: initial frequency (GHz).
    pub f0: Vec<Vec<f64>>,
    /// Per machine, per core: final frequency (GHz).
    pub freq: Vec<Vec<f64>>,

    pub collector: Collector,
    /// Present iff the run had a `fleet` config block (see
    /// [`LifecycleSummary`]); `None` keeps non-fleet summaries
    /// byte-identical to the pre-lifecycle schema.
    pub lifecycle: Option<LifecycleSummary>,
}

impl SimResult {
    /// Per-machine coefficient of variation of the final core-frequency
    /// distribution (the Fig. 6 aging-unevenness metric).
    pub fn freq_cv_per_machine(&self) -> Vec<f64> {
        self.freq.iter().map(|f| stats::coeff_of_variation(f)).collect()
    }

    /// Per-machine mean frequency degradation in GHz (Fig. 6 / Fig. 7
    /// input): mean over cores of `f0 − f(t_end)`.
    pub fn mean_fred_per_machine(&self) -> Vec<f64> {
        self.f0
            .iter()
            .zip(self.freq.iter())
            .map(|(f0s, fs)| {
                let reds: Vec<f64> = f0s.iter().zip(fs.iter()).map(|(a, b)| a - b).collect();
                stats::mean(&reds)
            })
            .collect()
    }

    /// All normalized-idle samples pooled across machines (Fig. 8).
    pub fn pooled_idle_samples(&self) -> Vec<f64> {
        self.collector.idle_samples.iter().flatten().copied().collect()
    }

    /// All task-count samples pooled (Fig. 2 aggregate view).
    pub fn pooled_task_samples(&self) -> Vec<f64> {
        self.collector.task_samples.iter().flatten().copied().collect()
    }

    pub fn ttft_summary(&self) -> Summary {
        Summary::of(&self.collector.ttft)
    }

    pub fn e2e_summary(&self) -> Summary {
        Summary::of(&self.collector.e2e)
    }

    /// Machine-readable summary of the run as a JSON object.
    ///
    /// Contains only **seed-deterministic** quantities: `wall_time_s` and
    /// anything else depending on host speed or thread scheduling is
    /// deliberately excluded, so two runs of the same seed serialize to
    /// byte-identical JSON — the property the sweep engine's any-thread-
    /// count determinism guarantee is built on.
    pub fn to_json_summary(&self) -> Value {
        let ttft = self.ttft_summary();
        let e2e = self.e2e_summary();
        let mut entries: Vec<(&str, Value)> = vec![
            ("policy", self.policy.as_str().into()),
            ("cores", self.cores_per_cpu.into()),
            ("rate_achieved_rps", self.rate_rps.into()),
            ("sim_duration_s", self.duration_s.into()),
            ("completed", self.completed_requests.into()),
            ("events", (self.events_processed as usize).into()),
            ("ttft_p50_s", ttft.p50.into()),
            ("ttft_p99_s", ttft.p99.into()),
            ("e2e_p50_s", e2e.p50.into()),
            ("e2e_p99_s", e2e.p99.into()),
            ("fred_mean_ghz", stats::mean(&self.mean_fred_per_machine()).into()),
            ("freq_cv_mean", stats::mean(&self.freq_cv_per_machine()).into()),
            ("oversub_fraction", self.oversub_fraction().into()),
            ("idle_p50", stats::percentile(&self.pooled_idle_samples(), 50.0).into()),
        ];
        // Lifecycle keys appear only for fleet-configured runs, keeping
        // plain summaries byte-identical to schema_version 6 output.
        if let Some(lc) = &self.lifecycle {
            entries.push(("active_capacity_fraction", self.active_capacity_fraction().into()));
            entries.push(("lifecycle_core_failures", (lc.core_failures as usize).into()));
            entries.push(("lifecycle_rerouted", (lc.rerouted as usize).into()));
            entries.push(("lifecycle_retirements", (lc.retirements as usize).into()));
            entries.push(("lifecycle_yearly_embodied_kg", lc.yearly_embodied_kg.into()));
        }
        Value::obj(entries)
    }

    /// Fraction of total core-seconds spent oversubscribed, cluster-wide.
    pub fn oversub_fraction(&self) -> f64 {
        let over: f64 = self.collector.oversub_integral.iter().sum();
        let active: f64 = self.collector.active_core_seconds.iter().sum();
        if active == 0.0 {
            0.0
        } else {
            over / active
        }
    }

    /// Fraction of the fleet's healthy core capacity that was active
    /// (C0), cluster-wide: `∫active dt / ∫usable dt`. The denominator is
    /// the time-varying capacity integral, NOT `machines × cores ×
    /// duration` — a constant denominator over-reports capacity (and so
    /// under-reports utilization) the moment a core fails or a
    /// retirement swaps in a different-sized SKU.
    pub fn active_capacity_fraction(&self) -> f64 {
        let active: f64 = self.collector.active_core_seconds.iter().sum();
        let cap: f64 = self.collector.capacity_core_seconds.iter().sum();
        if cap == 0.0 {
            0.0
        } else {
            active / cap
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result_with_freqs(f0: Vec<Vec<f64>>, freq: Vec<Vec<f64>>) -> SimResult {
        SimResult {
            policy: "test".into(),
            rate_rps: 0.0,
            cores_per_cpu: 2,
            duration_s: 1.0,
            completed_requests: 0,
            events_processed: 0,
            wall_time_s: 0.0,
            queue: crate::sim::QueueStats::default(),
            f0,
            freq,
            collector: Collector::new(1),
            lifecycle: None,
        }
    }

    #[test]
    fn cv_and_fred_per_machine() {
        let r = result_with_freqs(
            vec![vec![2.6, 2.6], vec![2.6, 2.6]],
            vec![vec![2.5, 2.5], vec![2.6, 2.4]],
        );
        let cv = r.freq_cv_per_machine();
        assert!(cv[0] < 1e-12); // uniform degradation -> zero CV
        assert!(cv[1] > 0.01); // uneven -> positive CV
        let fred = r.mean_fred_per_machine();
        assert!((fred[0] - 0.1).abs() < 1e-12);
        assert!((fred[1] - 0.1).abs() < 1e-12);
    }

    #[test]
    fn integrate_only_counts_oversubscription() {
        let mut c = Collector::new(1);
        c.integrate(0, 1.0, 5, 8, 8); // underutilized: no oversub
        assert_eq!(c.oversub_integral[0], 0.0);
        c.integrate(0, 2.0, 10, 8, 8); // 2 tasks over for 2 s
        assert!((c.oversub_integral[0] - 4.0).abs() < 1e-12);
        assert!((c.active_core_seconds[0] - 24.0).abs() < 1e-12);
        assert!((c.capacity_core_seconds[0] - 24.0).abs() < 1e-12);
    }

    #[test]
    fn capacity_integral_tracks_failures_not_the_constant_denominator() {
        // An 8-core machine loses a core after 1 s: healthy capacity is
        // 8 + 7 = 15 core-seconds, not the constant-denominator 8 × 2 =
        // 16 a static `cores × duration` would claim. With 4 cores
        // active throughout (4 + 7 = 11 active core-seconds once the
        // survivor count is 7) the fraction must be 11/15.
        let mut c = Collector::new(1);
        c.integrate(0, 1.0, 4, 4, 8);
        c.integrate(0, 1.0, 4, 7, 7);
        assert!((c.capacity_core_seconds[0] - 15.0).abs() < 1e-12);
        assert!(c.capacity_core_seconds[0] < 16.0, "old constant-denominator math");
        let mut r = result_with_freqs(vec![vec![2.6]], vec![vec![2.6]]);
        r.collector = c;
        let frac = r.active_capacity_fraction();
        assert!((frac - 11.0 / 15.0).abs() < 1e-12, "fraction {frac}");
    }

    #[test]
    fn lifecycle_keys_appear_only_for_fleet_runs() {
        let mut r = result_with_freqs(vec![vec![2.6, 2.5]], vec![vec![2.5, 2.4]]);
        let plain = r.to_json_summary().to_string_pretty();
        assert!(!plain.contains("lifecycle_"), "non-fleet summary unchanged");
        assert!(!plain.contains("active_capacity_fraction"));
        r.lifecycle = Some(LifecycleSummary {
            yearly_embodied_kg: 123.4,
            retirements: 2,
            core_failures: 1,
            rerouted: 3,
        });
        let with = r.to_json_summary().to_string_pretty();
        for key in [
            "active_capacity_fraction",
            "lifecycle_core_failures",
            "lifecycle_rerouted",
            "lifecycle_retirements",
            "lifecycle_yearly_embodied_kg",
        ] {
            assert!(with.contains(key), "missing {key}");
        }
        let parsed = crate::util::json::parse(&with).unwrap();
        assert_eq!(parsed.usize_or("lifecycle_retirements", 0), 2);
        assert!((parsed.f64_or("lifecycle_yearly_embodied_kg", 0.0) - 123.4).abs() < 1e-12);
    }

    #[test]
    fn sampling_appends() {
        let mut c = Collector::new(2);
        c.sample_machine(0, 3, 0.5);
        c.sample_machine(1, 7, -0.1);
        assert_eq!(c.task_samples[0], vec![3.0]);
        assert_eq!(c.idle_samples[1], vec![-0.1]);
    }

    #[test]
    fn json_summary_is_deterministic_and_excludes_wall_time() {
        let mut r = result_with_freqs(vec![vec![2.6, 2.5]], vec![vec![2.5, 2.4]]);
        r.policy = "proposed".into();
        r.wall_time_s = 1.23;
        let a = r.to_json_summary().to_string_pretty();
        r.wall_time_s = 9.87; // host-dependent — must not affect the summary
        let b = r.to_json_summary().to_string_pretty();
        assert_eq!(a, b);
        assert!(a.contains("\"fred_mean_ghz\""));
        assert!(!a.contains("wall_time"));
        let parsed = crate::util::json::parse(&a).unwrap();
        assert_eq!(parsed.str_or("policy", ""), "proposed");
        assert_eq!(parsed.usize_or("cores", 0), 2);
    }

    #[test]
    fn pooled_views() {
        let mut r = result_with_freqs(vec![vec![2.6]], vec![vec![2.6]]);
        r.collector = Collector::new(2);
        r.collector.sample_machine(0, 1, 0.2);
        r.collector.sample_machine(1, 2, 0.4);
        assert_eq!(r.pooled_idle_samples(), vec![0.2, 0.4]);
        assert_eq!(r.pooled_task_samples(), vec![1.0, 2.0]);
    }
}
