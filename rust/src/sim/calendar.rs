//! Calendar-queue (two-level timing-wheel) scheduler: O(1) amortized
//! push/pop for the DES hot loop.
//!
//! The [`super::EventQueue`] heap pays O(log n) float-comparison sift
//! operations for every task spawn, decode iteration, and periodic
//! tick. This module replaces it on the hot path with a classic
//! calendar queue (Brown 1988): near-future events land in an array of
//! time buckets sized to the observed event spacing, so push is a
//! bucket-index computation plus (almost always) a `Vec` append, and
//! pop walks a cursor across the wheel.
//!
//! # Structure
//!
//! - **Wheel.** `buckets[i]` covers the half-open interval
//!   `[wheel_start + i·width, wheel_start + (i+1)·width)`. Each bucket
//!   keeps its events sorted ascending by `(time, seq)`; because
//!   sequence numbers increase globally, the common case inserts at the
//!   tail in O(1). A `cursor` sweeps the wheel left to right and only
//!   ever advances past *empty* buckets, so a new event whose computed
//!   index falls behind the cursor (possible only for buckets the sweep
//!   has already verified empty) is clamped forward to the cursor
//!   bucket and sorted into place there.
//! - **Backlog.** Events at or beyond the wheel's end spill to an
//!   overflow `Vec` kept sorted *descending* by `(time, seq)` (earliest
//!   at the back, so draining pops from the tail). When the wheel is
//!   exhausted, it **rotates**: `wheel_start` jumps to the earliest
//!   backlog event, the bucket width is re-derived from the observed
//!   inter-pop gap (see below), and every backlog event inside the new
//!   wheel span is re-homed into buckets.
//! - **Tick train.** The fixed-period `Adjust`/`Sample` recurring
//!   events live in two rearming slots ([`super::Scheduler::arm_periodic`])
//!   merged into the pop order on demand — they never traverse the
//!   wheel at all. Firing a slot rearms it one period ahead under a
//!   fresh sequence number, exactly reproducing the event stream of the
//!   handler-side re-push it replaces.
//!
//! # Bucket sizing
//!
//! The wheel starts at 64 buckets of 1 ms. Every pop feeds the gap to
//! the previous pop into an exponential moving average (`α = 0.1`), and
//! each rotation or resize re-derives `width = max(4·gap_ema, 1e-9)` —
//! a bucket then holds ~4 events, keeping both the per-pop bucket scan
//! and the per-push sort cost O(1) amortized. When the pending count
//! exceeds 2× the bucket count, the wheel doubles and rebuilds (events
//! keep their sequence numbers, so order is unaffected). All geometry
//! inputs (gap EMA, counts) are pure functions of the push/pop stream,
//! so the layout — and therefore every observable — is deterministic.
//!
//! # Determinism argument
//!
//! The contract is *strict global `(time, seq)` order*, bit-identical
//! to the heap's. Within a bucket and within the backlog, order is
//! explicit (sorted inserts). Across buckets it follows from monotone
//! placement: the computed index `⌊(t − wheel_start)/width⌋` is
//! monotone non-decreasing in `t` (subtraction and division by a
//! positive width are correctly-rounded monotone operations; `floor`
//! and the saturating f64→usize cast preserve monotonicity, as do the
//! `min`/`max` clamps applied after). Hence `t < t′` can never place
//! `t′` in an earlier bucket than `t`, and *exactly equal* times
//! compute the *identical* index — same bucket — where the sorted
//! insert restores FIFO by `seq`. Events re-homed by a rotation or
//! rebuild are all re-placed under one geometry, so the same argument
//! applies; events left in the backlog lie entirely beyond the new
//! wheel, preserving order between the two levels. Push clamp/panic
//! semantics ([`PAST_TOLERANCE_S`]) are shared verbatim with the heap.
//! `tests/queue_differential.rs` pins all of this differentially, and
//! `tests/queue_sweep_identity.rs` pins byte-identical sweep reports.

use std::collections::VecDeque;

use super::{QueueStats, Scheduler, TickTrain, PAST_TOLERANCE_S};

/// Initial bucket count; doubles when occupancy exceeds 2× the count.
const INITIAL_BUCKETS: usize = 64;
/// Initial bucket width before any inter-pop gap has been observed.
const INITIAL_WIDTH_S: f64 = 1e-3;
/// Floor on the derived bucket width (degenerate all-same-time loads).
const MIN_WIDTH_S: f64 = 1e-9;
/// Target mean events per bucket: `width = TARGET_GAPS_PER_BUCKET · gap_ema`.
const TARGET_GAPS_PER_BUCKET: f64 = 4.0;
/// EMA smoothing for the observed inter-pop gap.
const GAP_EMA_ALPHA: f64 = 0.1;

/// A pending event: the same `(time, seq, payload)` triple the heap
/// stores, kept in sorted bucket / backlog order instead.
struct Entry<E> {
    time: f64,
    seq: u64,
    payload: E,
}

impl<E> Entry<E> {
    #[inline]
    fn key(&self) -> (f64, u64) {
        (self.time, self.seq)
    }
}

/// The calendar-queue event queue / simulation clock — the production
/// implementation (see the module docs for the full contract).
pub struct CalendarQueue<E> {
    buckets: Vec<VecDeque<Entry<E>>>,
    /// Absolute time of bucket 0's left edge.
    wheel_start: f64,
    /// Width of one bucket in seconds (> 0).
    width: f64,
    /// First bucket that may still hold events; only advances past
    /// empty buckets.
    cursor: usize,
    /// Overflow beyond the wheel, sorted descending by `(time, seq)`.
    backlog: Vec<Entry<E>>,
    /// Pending entries across buckets and backlog (excludes the train).
    items: usize,
    /// EMA of the gap between consecutive pop timestamps.
    gap_ema: f64,
    train: TickTrain<E>,
    seq: u64,
    now: f64,
    processed: u64,
    stats: QueueStats,
}

impl<E> CalendarQueue<E> {
    /// An empty queue with the clock at 0.
    pub fn new() -> CalendarQueue<E> {
        CalendarQueue {
            buckets: (0..INITIAL_BUCKETS).map(|_| VecDeque::new()).collect(),
            wheel_start: 0.0,
            width: INITIAL_WIDTH_S,
            cursor: 0,
            backlog: Vec::new(),
            items: 0,
            gap_ema: INITIAL_WIDTH_S / TARGET_GAPS_PER_BUCKET,
            train: TickTrain::new(),
            seq: 0,
            now: 0.0,
            processed: 0,
            stats: QueueStats::default(),
        }
    }

    /// Current simulation time (time of the last popped event).
    #[inline]
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Total events processed so far (periodic firings included).
    #[inline]
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Pending events plus armed periodic slots.
    #[inline]
    pub fn len(&self) -> usize {
        self.items + self.train.armed()
    }

    /// True when nothing is pending and no slot is armed.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of pushes whose time was clamped forward to `now` (always a
    /// sub-[`PAST_TOLERANCE_S`] float round-off; larger skews panic).
    #[inline]
    pub fn clamped(&self) -> u64 {
        self.stats.clamped
    }

    /// Counters shared by both implementations.
    #[inline]
    pub fn stats(&self) -> QueueStats {
        self.stats
    }

    #[inline]
    fn wheel_end(&self) -> f64 {
        self.wheel_start + self.width * self.buckets.len() as f64
    }

    /// Width the next rotation/rebuild should use, from the gap EMA.
    #[inline]
    fn target_width(&self) -> f64 {
        (self.gap_ema * TARGET_GAPS_PER_BUCKET).max(MIN_WIDTH_S)
    }

    /// Bucket index for `time`, clamped into `[cursor, len)`. Only
    /// called with `cursor < buckets.len()` and `time < wheel_end`.
    /// Monotone in `time` — the module-docs determinism argument.
    #[inline]
    fn bucket_index(&self, time: f64) -> usize {
        // A past-wheel_start time (possible right after a rotation, see
        // `insert`) yields a negative quotient: the f64→usize cast
        // saturates to 0, which `max(cursor)` then fixes up.
        let raw = ((time - self.wheel_start) / self.width) as usize;
        raw.clamp(self.cursor, self.buckets.len() - 1)
    }

    /// Sorted-insert into one bucket. Sequence numbers grow globally,
    /// so the overwhelmingly common case is an O(1) tail append.
    fn bucket_insert(bucket: &mut VecDeque<Entry<E>>, e: Entry<E>) {
        let tail_ok = match bucket.back() {
            None => true,
            Some(last) => last.key() < e.key(),
        };
        if tail_ok {
            bucket.push_back(e);
        } else {
            let at = bucket.partition_point(|x| x.key() < e.key());
            bucket.insert(at, e);
        }
    }

    /// Route one entry to its bucket or to the backlog. Does not touch
    /// `items` or the stats — callers account for those.
    fn insert(&mut self, e: Entry<E>) {
        // `cursor == buckets.len()` means the sweep exhausted the wheel
        // (and any pending events sit in the backlog); park new events
        // there too and let the next pop rotate a fresh wheel.
        if e.time >= self.wheel_end() || self.cursor >= self.buckets.len() {
            let at = self.backlog.partition_point(|x| x.key() > e.key());
            self.backlog.insert(at, e);
        } else {
            let idx = self.bucket_index(e.time);
            Self::bucket_insert(&mut self.buckets[idx], e);
        }
    }

    /// Schedule `payload` at absolute time `at`; contract identical to
    /// [`super::EventQueue::push`] (same clamp, same panics).
    pub fn push(&mut self, at: f64, payload: E) -> f64 {
        assert!(at.is_finite(), "scheduling a non-finite time: {at}");
        assert!(
            at >= self.now - PAST_TOLERANCE_S,
            "scheduling into the past: {at} < {}",
            self.now
        );
        let time = if at < self.now {
            self.stats.clamped += 1;
            self.now
        } else {
            at
        };
        // Same -0.0 canonicalization as the heap queue: buckets compare
        // arithmetically (-0.0 == +0.0) but the differential contract
        // demands both queues agree with `total_cmp` (-0.0 < +0.0).
        let time = if time == 0.0 { 0.0 } else { time };
        let seq = self.seq;
        self.seq += 1;
        self.insert(Entry { time, seq, payload });
        self.items += 1;
        if self.items > 2 * self.buckets.len() {
            self.rebuild(self.buckets.len() * 2);
        }
        self.stats.pushes += 1;
        self.stats.peak_len = self.stats.peak_len.max(self.len());
        time
    }

    /// Schedule `payload` `delay` seconds from now; contract identical
    /// to [`super::EventQueue::push_in`].
    pub fn push_in(&mut self, delay: f64, payload: E) -> f64 {
        assert!(delay.is_finite(), "scheduling a non-finite delay: {delay}");
        assert!(delay >= -PAST_TOLERANCE_S, "scheduling a negative delay: {delay}");
        self.push(self.now + delay, payload)
    }

    /// Arm periodic slot `slot`; see [`Scheduler::arm_periodic`].
    pub fn arm_periodic(&mut self, slot: usize, first: f64, period: f64, payload: E) {
        assert!(first.is_finite(), "scheduling a non-finite time: {first}");
        assert!(
            first >= self.now - PAST_TOLERANCE_S,
            "scheduling into the past: {first} < {}",
            self.now
        );
        let time = if first < self.now {
            self.stats.clamped += 1;
            self.now
        } else {
            first
        };
        self.train.arm(slot, time, period, payload, self.seq);
        self.seq += 1;
        self.stats.peak_len = self.stats.peak_len.max(self.len());
    }

    /// Advance the cursor to the next non-empty bucket (rotating the
    /// backlog into a fresh wheel as needed) and return the head's
    /// `(time, seq)`; `None` when no events are pending anywhere.
    fn wheel_peek(&mut self) -> Option<(f64, u64)> {
        loop {
            while self.cursor < self.buckets.len() {
                if let Some(e) = self.buckets[self.cursor].front() {
                    return Some(e.key());
                }
                self.cursor += 1;
            }
            if self.backlog.is_empty() {
                return None;
            }
            self.rotate();
        }
    }

    /// Re-anchor an exhausted wheel at the earliest backlog event,
    /// re-derive the bucket width from the gap EMA, and re-home every
    /// backlog event that fits the new wheel span. The earliest event
    /// lands in bucket 0, so rotation always makes progress.
    fn rotate(&mut self) {
        self.width = self.target_width();
        self.wheel_start = self.backlog.last().expect("rotate needs a backlog").time;
        self.cursor = 0;
        let wheel_end = self.wheel_end();
        while let Some(e) = self.backlog.last() {
            if e.time >= wheel_end {
                break;
            }
            let e = self.backlog.pop().expect("peeked above");
            let idx = self.bucket_index(e.time);
            Self::bucket_insert(&mut self.buckets[idx], e);
        }
    }

    /// Re-bucket everything under `n_buckets` buckets of the current
    /// target width, anchored at `now`. Entries keep their sequence
    /// numbers, so observable order is unchanged.
    fn rebuild(&mut self, n_buckets: usize) {
        let mut entries: Vec<Entry<E>> = Vec::with_capacity(self.items);
        for b in &mut self.buckets {
            entries.extend(b.drain(..));
        }
        entries.append(&mut self.backlog);
        self.buckets = (0..n_buckets).map(|_| VecDeque::new()).collect();
        self.width = self.target_width();
        self.wheel_start = self.now;
        self.cursor = 0;
        for e in entries {
            self.insert(e);
        }
    }

    /// Fold the gap from the previous pop into the EMA (before `now`
    /// advances to `t`).
    #[inline]
    fn observe_gap(&mut self, t: f64) {
        let gap = (t - self.now).max(0.0);
        self.gap_ema += GAP_EMA_ALPHA * (gap - self.gap_ema);
    }

    /// Peek at the next event time without advancing. The first
    /// non-empty bucket at/after the cursor holds the wheel minimum
    /// (monotone placement); any bucket event precedes every backlog
    /// event.
    pub fn peek_time(&self) -> Option<f64> {
        let mut pending = None;
        for b in self.buckets.iter().skip(self.cursor) {
            if let Some(e) = b.front() {
                pending = Some(e.time);
                break;
            }
        }
        if pending.is_none() {
            pending = self.backlog.last().map(|e| e.time);
        }
        match (self.train.peek_time(), pending) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }
}

impl<E: Clone> CalendarQueue<E> {
    /// Pop the next event — the global `(time, seq)` minimum across the
    /// wheel, the backlog, and the armed periodic slots — advancing the
    /// clock to its timestamp. A firing periodic slot is rearmed one
    /// period ahead under a fresh sequence number, exactly as if its
    /// handler had re-pushed it.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let wheel_key = self.wheel_peek();
        if let Some((t, s, slot)) = self.train.peek() {
            let train_first = match wheel_key {
                None => true,
                Some(wk) => (t, s) < wk,
            };
            if train_first {
                debug_assert!(t >= self.now - PAST_TOLERANCE_S);
                self.observe_gap(t);
                self.now = t;
                self.processed += 1;
                let payload = self.train.fire(slot, self.seq);
                self.seq += 1;
                return Some((t, payload));
            }
        }
        wheel_key?;
        let e = self.buckets[self.cursor].pop_front().expect("wheel_peek found this");
        self.items -= 1;
        debug_assert!(e.time >= self.now - PAST_TOLERANCE_S);
        self.observe_gap(e.time);
        self.now = e.time;
        self.processed += 1;
        Some((e.time, e.payload))
    }
}

impl<E> Default for CalendarQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E: Clone> Scheduler<E> for CalendarQueue<E> {
    fn push(&mut self, at: f64, payload: E) -> f64 {
        CalendarQueue::push(self, at, payload)
    }
    fn push_in(&mut self, delay: f64, payload: E) -> f64 {
        CalendarQueue::push_in(self, delay, payload)
    }
    fn arm_periodic(&mut self, slot: usize, first: f64, period: f64, payload: E) {
        CalendarQueue::arm_periodic(self, slot, first, period, payload);
    }
    fn pop(&mut self) -> Option<(f64, E)> {
        CalendarQueue::pop(self)
    }
    fn peek_time(&self) -> Option<f64> {
        CalendarQueue::peek_time(self)
    }
    fn now(&self) -> f64 {
        CalendarQueue::now(self)
    }
    fn processed(&self) -> u64 {
        CalendarQueue::processed(self)
    }
    fn len(&self) -> usize {
        CalendarQueue::len(self)
    }
    fn stats(&self) -> QueueStats {
        CalendarQueue::stats(self)
    }
}

#[cfg(test)]
mod tests {
    use super::super::EventQueue;
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = CalendarQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn same_time_burst_is_fifo() {
        let mut q = CalendarQueue::new();
        for i in 0..1000 {
            q.push(5.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn clock_and_counters_advance() {
        let mut q = CalendarQueue::new();
        q.push(1.5, ());
        q.push(4.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 1.5);
        q.push_in(1.0, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 2.5);
        q.pop();
        assert_eq!(q.now(), 4.0);
        assert_eq!(q.processed(), 3);
        assert!(q.is_empty());
    }

    #[test]
    fn peek_does_not_advance() {
        let mut q = CalendarQueue::new();
        q.push(2.0, ());
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.now(), 0.0);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn push_returns_scheduled_time_and_counts_clamps() {
        let mut q = CalendarQueue::new();
        assert_eq!(q.push(2.0, ()), 2.0);
        q.pop();
        let t = q.push(2.0 - 1e-12, ());
        assert_eq!(t, 2.0);
        assert_eq!(q.clamped(), 1);
        assert_eq!(q.push_in(1.5, ()), 3.5);
        assert_eq!(q.stats().clamped, 1);
        assert_eq!(q.stats().pushes, 3);
    }

    #[test]
    #[should_panic(expected = "scheduling a negative delay")]
    fn negative_delay_beyond_tolerance_panics() {
        let mut q = CalendarQueue::new();
        q.push(5.0, ());
        q.pop();
        q.push_in(-0.5, ());
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn past_time_panics_in_all_profiles() {
        let mut q = CalendarQueue::new();
        q.push(5.0, ());
        q.pop();
        q.push(4.0, ());
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn non_finite_time_panics() {
        let mut q = CalendarQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    fn far_future_spills_to_backlog_and_rotates_in_order() {
        // The initial wheel spans [0, 64 ms); everything beyond lives in
        // the backlog until rotations pull it in.
        let mut q = CalendarQueue::new();
        let times = [500.0, 0.01, 250.0, 250.0, 1e6, 0.02, 3_000.0];
        for (i, &t) in times.iter().enumerate() {
            q.push(t, i);
        }
        let mut sorted: Vec<(f64, usize)> = times.iter().copied().zip(0..times.len()).collect();
        sorted.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let got: Vec<(f64, usize)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(got, sorted);
    }

    #[test]
    fn resize_preserves_order() {
        // 500 pushes force several wheel doublings (threshold 2×buckets).
        let mut q = CalendarQueue::new();
        let mut expect = Vec::new();
        for i in 0..500u32 {
            // A deterministic scatter with exact duplicate times mixed in.
            let t = f64::from(i * 37 % 101) * 0.25;
            q.push(t, i);
            expect.push((t, i));
        }
        expect.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let got: Vec<(f64, u32)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn interleaved_push_pop_with_backlog_matches_heap() {
        // A scripted interleaving that exercises rotation mid-stream and
        // pushes landing behind the cursor, checked against the heap.
        let mut cal = CalendarQueue::new();
        let mut heap = EventQueue::new();
        let script: &[(f64, u32)] =
            &[(0.001, 0), (10.0, 1), (0.002, 2), (500.0, 3), (10.0, 4), (0.05, 5)];
        for &(t, p) in script {
            cal.push(t, p);
            heap.push(t, p);
        }
        for _ in 0..3 {
            assert_eq!(cal.pop(), heap.pop());
        }
        // Mid-stream pushes: one at exactly `now`, one near-future, one
        // joining the 500.0 event in the backlog.
        for &(dt, p) in &[(0.0, 6), (0.01, 7), (400.0, 8)] {
            cal.push_in(dt, p);
            heap.push_in(dt, p);
        }
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            assert_eq!(a, b);
            assert_eq!(cal.now(), heap.now());
            if a.is_none() {
                break;
            }
        }
        assert_eq!(cal.stats(), heap.stats());
    }

    #[test]
    fn tick_train_merges_with_wheel_events() {
        let mut q = CalendarQueue::new();
        q.arm_periodic(0, 1.0, 1.0, "tick");
        q.push(1.0, "push@1");
        q.push(2.5, "push@2.5");
        let mut got = Vec::new();
        for _ in 0..5 {
            got.push(q.pop().unwrap());
        }
        assert_eq!(
            got,
            vec![
                (1.0, "tick"),
                (1.0, "push@1"),
                (2.0, "tick"),
                (2.5, "push@2.5"),
                (3.0, "tick"),
            ]
        );
        assert_eq!(q.len(), 1); // the slot stays armed
    }

    #[test]
    fn property_matches_heap_on_random_schedules() {
        crate::util::proptest::forall(150, 4242, |g| {
            let n = g.size(1, 300);
            let mut cal = CalendarQueue::new();
            let mut heap = EventQueue::new();
            for i in 0..n {
                // Mix short-range, far-future, and quantized (collision-
                // prone) times; interleave pops to move the clock.
                let t = if g.bool() {
                    (g.f64(0.0, 20.0) * 8.0).floor() / 8.0
                } else {
                    g.f64(0.0, 5_000.0)
                };
                let at = t.max(cal.now());
                cal.push(at, i);
                heap.push(at, i);
                if g.bool() && cal.pop() != heap.pop() {
                    return crate::util::proptest::check(false, format!("diverged at {i}"));
                }
            }
            loop {
                let (a, b) = (cal.pop(), heap.pop());
                if a != b {
                    let msg = format!("drain diverged: {a:?} vs {b:?}");
                    return crate::util::proptest::check(false, msg);
                }
                if a.is_none() {
                    break;
                }
            }
            crate::util::proptest::check(cal.stats() == heap.stats(), "stats diverged")
        });
    }
}
