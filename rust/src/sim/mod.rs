//! Deterministic discrete-event simulation engine.
//!
//! Two interchangeable schedulers implement the same contract behind the
//! [`Scheduler`] trait:
//!
//! - [`EventQueue`] — a `BinaryHeap` of `(time, seq)`-ordered events,
//!   O(log n) per operation. Our from-scratch equivalent of
//!   splitwise-sim's event core, retained as the differential-testing
//!   reference: small, obviously correct, and pinned bit-for-bit
//!   interchangeable with the calendar queue by
//!   `tests/queue_differential.rs` and `tests/queue_sweep_identity.rs`.
//! - [`CalendarQueue`] ([`calendar`]) — a two-level calendar /
//!   timing-wheel queue with O(1) amortized push/pop, the production
//!   default for the simulation hot loop.
//!
//! Both order events strictly by `(time, seq)`: the `seq` tiebreaker
//! guarantees FIFO order among same-timestamp events, which makes every
//! run exactly reproducible from a seed — a property every experiment in
//! EXPERIMENTS.md relies on. Both also carry a two-slot periodic "tick
//! train" ([`Scheduler::arm_periodic`]) for fixed-period recurring
//! events (`Adjust` / `Sample`): a recurring event occupies one rearming
//! slot merged into the pop order on demand instead of being re-pushed
//! through the queue every 100/250 ms. Firing a slot rearms it one
//! period ahead and consumes a sequence number exactly like the
//! handler-side re-push it replaces, so event streams are unchanged.
//!
//! [`SchedulerImpl`] is the enum-dispatch wrapper [`crate::cluster::Cluster`]
//! embeds; [`QueueKind`] selects the implementation
//! (`--queue {heap,calendar}`, calendar default).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

pub mod calendar;

pub use calendar::CalendarQueue;

/// An event scheduled at a simulation time.
struct Scheduled<E> {
    time: f64,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        // `total_cmp`, not `partial_cmp(..).unwrap()`: `push` rejects
        // non-finite times, but the heap's ordering must stay total even
        // for values that slip past that gate — a NaN must mis-sort (to
        // the far future), never panic mid-pop and strand the queue.
        other.time.total_cmp(&self.time).then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Scheduling a past time beyond this tolerance is a hard error; within
/// it, the time is clamped to `now` (float round-off from accumulated
/// `now + dt` arithmetic) and counted in [`QueueStats::clamped`].
pub const PAST_TOLERANCE_S: f64 = 1e-9;

/// Number of periodic tick-train slots every scheduler carries.
pub const PERIODIC_SLOTS: usize = 2;

/// Counters shared by both scheduler implementations, exported into the
/// bench JSON (`peak_queue_len` / `queue_pushes` / `queue_clamped`).
///
/// The counts are a pure function of the logical operation stream, so a
/// heap and a calendar run of the same simulation report identical
/// stats (pinned by `tests/queue_differential.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueueStats {
    /// High-water mark of [`Scheduler::len`] (pending events plus armed
    /// periodic slots), sampled after every push and arm.
    pub peak_len: usize,
    /// Total [`Scheduler::push`] / [`Scheduler::push_in`] calls.
    /// Periodic rearms are not pushes: arming a slot counts nothing
    /// here.
    pub pushes: u64,
    /// Pushes whose time was clamped forward to `now` (always a
    /// sub-[`PAST_TOLERANCE_S`] float round-off; larger skews panic).
    pub clamped: u64,
}

/// One armed periodic slot of a [`TickTrain`].
struct TickSlot<E> {
    time: f64,
    seq: u64,
    period: f64,
    payload: E,
}

/// The two-slot periodic tick train shared by both scheduler
/// implementations. A slot holds the next firing `(time, seq)` of a
/// fixed-period recurring event; firing clones the payload, advances
/// `time` by exactly one period (the same `now + period` float the old
/// handler-side re-push computed), and takes a fresh sequence number
/// from the owning queue's counter.
struct TickTrain<E> {
    slots: [Option<TickSlot<E>>; PERIODIC_SLOTS],
}

impl<E> TickTrain<E> {
    fn new() -> TickTrain<E> {
        TickTrain { slots: [None, None] }
    }

    /// Number of armed slots (counted into [`Scheduler::len`]).
    fn armed(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn arm(&mut self, slot: usize, first: f64, period: f64, payload: E, seq: u64) {
        assert!(slot < PERIODIC_SLOTS, "periodic slot {slot} out of range");
        assert!(
            period.is_finite() && period > 0.0,
            "periodic slot needs a positive finite period, got {period}"
        );
        self.slots[slot] = Some(TickSlot { time: first, seq, period, payload });
    }

    /// The earliest armed `(time, seq)` and its slot index, if any.
    fn peek(&self) -> Option<(f64, u64, usize)> {
        let mut best: Option<(f64, u64, usize)> = None;
        for (i, slot) in self.slots.iter().enumerate() {
            if let Some(s) = slot {
                let better = match best {
                    None => true,
                    Some((t, q, _)) => (s.time, s.seq) < (t, q),
                };
                if better {
                    best = Some((s.time, s.seq, i));
                }
            }
        }
        best
    }

    fn peek_time(&self) -> Option<f64> {
        self.peek().map(|(t, _, _)| t)
    }
}

impl<E: Clone> TickTrain<E> {
    /// Fire `slot`: return its payload and rearm it one period ahead
    /// under `new_seq`.
    fn fire(&mut self, slot: usize, new_seq: u64) -> E {
        let s = self.slots[slot].as_mut().expect("firing an unarmed periodic slot");
        let payload = s.payload.clone();
        s.time += s.period;
        s.seq = new_seq;
        payload
    }
}

/// The common contract of both event-queue implementations. Everything
/// downstream of [`crate::cluster::Cluster`] is generic over this, and
/// `tests/queue_differential.rs` pins that both implementations produce
/// identical `(time, seq, payload)` pop streams for identical operation
/// streams.
pub trait Scheduler<E: Clone> {
    /// Schedule `payload` at absolute time `at` (must be ≥ now within
    /// [`PAST_TOLERANCE_S`]); returns the time actually used.
    fn push(&mut self, at: f64, payload: E) -> f64;
    /// Schedule `payload` `delay` seconds from now; returns the absolute
    /// time used.
    fn push_in(&mut self, delay: f64, payload: E) -> f64;
    /// Arm periodic slot `slot` (< [`PERIODIC_SLOTS`]) to fire first at
    /// `first` and every `period` seconds after. Consumes one sequence
    /// number, like the push it replaces; rearming an armed slot
    /// replaces it.
    fn arm_periodic(&mut self, slot: usize, first: f64, period: f64, payload: E);
    /// Pop the globally earliest `(time, seq)` event — pending or armed
    /// periodic — advancing the clock to its timestamp.
    fn pop(&mut self) -> Option<(f64, E)>;
    /// The next event time without advancing the clock.
    fn peek_time(&self) -> Option<f64>;
    /// Current simulation time (time of the last popped event).
    fn now(&self) -> f64;
    /// Total events processed so far (periodic firings included).
    fn processed(&self) -> u64;
    /// Pending events plus armed periodic slots.
    fn len(&self) -> usize;
    /// True when nothing is pending and no slot is armed.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Counters shared by both implementations.
    fn stats(&self) -> QueueStats;
}

/// Selects the event-queue implementation (`--queue {heap,calendar}`).
///
/// An execution detail, deliberately excluded from sweep specs, spec
/// hashes, and report JSON: reports are byte-identical under either
/// implementation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QueueKind {
    /// [`EventQueue`]: `BinaryHeap`, O(log n), differential reference.
    Heap,
    /// [`CalendarQueue`]: timing wheel, O(1) amortized, the default.
    #[default]
    Calendar,
}

impl QueueKind {
    /// Parse a `--queue` / config-file value.
    pub fn parse(s: &str) -> Result<QueueKind, String> {
        match s {
            "heap" => Ok(QueueKind::Heap),
            "calendar" => Ok(QueueKind::Calendar),
            other => Err(format!(
                "unknown queue implementation '{other}' (expected 'calendar' or 'heap')"
            )),
        }
    }

    /// The canonical flag spelling.
    pub fn name(&self) -> &'static str {
        match self {
            QueueKind::Heap => "heap",
            QueueKind::Calendar => "calendar",
        }
    }
}

/// Enum-dispatch wrapper over the two implementations, so the hot loop
/// stays statically dispatched (one match, no vtable) while callers pick
/// the implementation at runtime via [`QueueKind`].
pub enum SchedulerImpl<E> {
    /// The binary-heap reference implementation.
    Heap(EventQueue<E>),
    /// The calendar-queue production implementation.
    Calendar(CalendarQueue<E>),
}

impl<E: Clone> SchedulerImpl<E> {
    /// An empty scheduler of the requested implementation.
    pub fn new(kind: QueueKind) -> SchedulerImpl<E> {
        match kind {
            QueueKind::Heap => SchedulerImpl::Heap(EventQueue::new()),
            QueueKind::Calendar => SchedulerImpl::Calendar(CalendarQueue::new()),
        }
    }

    /// Which implementation this is.
    pub fn kind(&self) -> QueueKind {
        match self {
            SchedulerImpl::Heap(_) => QueueKind::Heap,
            SchedulerImpl::Calendar(_) => QueueKind::Calendar,
        }
    }
}

impl<E: Clone> Scheduler<E> for SchedulerImpl<E> {
    fn push(&mut self, at: f64, payload: E) -> f64 {
        match self {
            SchedulerImpl::Heap(q) => q.push(at, payload),
            SchedulerImpl::Calendar(q) => q.push(at, payload),
        }
    }

    fn push_in(&mut self, delay: f64, payload: E) -> f64 {
        match self {
            SchedulerImpl::Heap(q) => q.push_in(delay, payload),
            SchedulerImpl::Calendar(q) => q.push_in(delay, payload),
        }
    }

    fn arm_periodic(&mut self, slot: usize, first: f64, period: f64, payload: E) {
        match self {
            SchedulerImpl::Heap(q) => q.arm_periodic(slot, first, period, payload),
            SchedulerImpl::Calendar(q) => q.arm_periodic(slot, first, period, payload),
        }
    }

    fn pop(&mut self) -> Option<(f64, E)> {
        match self {
            SchedulerImpl::Heap(q) => q.pop(),
            SchedulerImpl::Calendar(q) => q.pop(),
        }
    }

    fn peek_time(&self) -> Option<f64> {
        match self {
            SchedulerImpl::Heap(q) => q.peek_time(),
            SchedulerImpl::Calendar(q) => q.peek_time(),
        }
    }

    fn now(&self) -> f64 {
        match self {
            SchedulerImpl::Heap(q) => q.now(),
            SchedulerImpl::Calendar(q) => q.now(),
        }
    }

    fn processed(&self) -> u64 {
        match self {
            SchedulerImpl::Heap(q) => q.processed(),
            SchedulerImpl::Calendar(q) => q.processed(),
        }
    }

    fn len(&self) -> usize {
        match self {
            SchedulerImpl::Heap(q) => q.len(),
            SchedulerImpl::Calendar(q) => q.len(),
        }
    }

    fn stats(&self) -> QueueStats {
        match self {
            SchedulerImpl::Heap(q) => q.stats(),
            SchedulerImpl::Calendar(q) => q.stats(),
        }
    }
}

/// The binary-heap event queue / simulation clock — the differential
/// reference implementation (see the module docs).
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    train: TickTrain<E>,
    seq: u64,
    now: f64,
    processed: u64,
    stats: QueueStats,
}

impl<E> EventQueue<E> {
    /// An empty queue with the clock at 0.
    pub fn new() -> EventQueue<E> {
        EventQueue {
            heap: BinaryHeap::new(),
            train: TickTrain::new(),
            seq: 0,
            now: 0.0,
            processed: 0,
            stats: QueueStats::default(),
        }
    }

    /// Current simulation time (time of the last popped event).
    #[inline]
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Total events processed so far (periodic firings included).
    #[inline]
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Pending events plus armed periodic slots.
    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len() + self.train.armed()
    }

    /// True when nothing is pending and no slot is armed.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of pushes whose time was clamped forward to `now` (always a
    /// sub-[`PAST_TOLERANCE_S`] float round-off; larger skews panic).
    #[inline]
    pub fn clamped(&self) -> u64 {
        self.stats.clamped
    }

    /// Counters shared by both implementations.
    #[inline]
    pub fn stats(&self) -> QueueStats {
        self.stats
    }

    /// Schedule `payload` at absolute time `at` (must be ≥ now) and return
    /// the time actually used.
    ///
    /// Scheduling into the past is a real error in every build profile —
    /// previously a `debug_assert!`, which let release-mode sweep workers
    /// silently clamp buggy past-times to `now` and mask scheduling bugs.
    /// Only float round-off within [`PAST_TOLERANCE_S`] is forgiven: the
    /// time is clamped to `now`, the clamp is counted, and the clamped
    /// time is returned so callers see the effective schedule.
    pub fn push(&mut self, at: f64, payload: E) -> f64 {
        assert!(at.is_finite(), "scheduling a non-finite time: {at}");
        assert!(
            at >= self.now - PAST_TOLERANCE_S,
            "scheduling into the past: {at} < {}",
            self.now
        );
        let time = if at < self.now {
            self.stats.clamped += 1;
            self.now
        } else {
            at
        };
        // Canonicalize -0.0 to +0.0: the heap orders by `total_cmp`
        // (where -0.0 < +0.0) while the calendar queue buckets by
        // arithmetic (where -0.0 == +0.0). One canonical zero keeps the
        // two implementations byte-identical (tests/queue_differential).
        let time = if time == 0.0 { 0.0 } else { time };
        self.heap.push(Scheduled { time, seq: self.seq, payload });
        self.seq += 1;
        self.stats.pushes += 1;
        self.stats.peak_len = self.stats.peak_len.max(self.len());
        time
    }

    /// Schedule `payload` `delay` seconds from now; returns the absolute
    /// time used.
    ///
    /// Negative delays follow the same contract as [`EventQueue::push`]:
    /// a delay below `-`[`PAST_TOLERANCE_S`] panics (it used to be clamped
    /// silently to zero, masking negative-duration bugs in callers), while
    /// sub-tolerance round-off is forgiven — clamped to `now` and counted
    /// in [`QueueStats::clamped`].
    pub fn push_in(&mut self, delay: f64, payload: E) -> f64 {
        assert!(delay.is_finite(), "scheduling a non-finite delay: {delay}");
        assert!(delay >= -PAST_TOLERANCE_S, "scheduling a negative delay: {delay}");
        self.push(self.now + delay, payload)
    }

    /// Arm periodic slot `slot`; see [`Scheduler::arm_periodic`].
    pub fn arm_periodic(&mut self, slot: usize, first: f64, period: f64, payload: E) {
        assert!(first.is_finite(), "scheduling a non-finite time: {first}");
        assert!(
            first >= self.now - PAST_TOLERANCE_S,
            "scheduling into the past: {first} < {}",
            self.now
        );
        let time = if first < self.now {
            self.stats.clamped += 1;
            self.now
        } else {
            first
        };
        self.train.arm(slot, time, period, payload, self.seq);
        self.seq += 1;
        self.stats.peak_len = self.stats.peak_len.max(self.len());
    }

    /// Peek at the next event time without advancing.
    pub fn peek_time(&self) -> Option<f64> {
        let heap = self.heap.peek().map(|e| e.time);
        match (self.train.peek_time(), heap) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }
}

impl<E: Clone> EventQueue<E> {
    /// Pop the next event — the global `(time, seq)` minimum across the
    /// heap and the armed periodic slots — advancing the clock to its
    /// timestamp. A firing periodic slot is rearmed one period ahead
    /// under a fresh sequence number, exactly as if its handler had
    /// re-pushed it.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let heap_key = self.heap.peek().map(|e| (e.time, e.seq));
        if let Some((t, s, slot)) = self.train.peek() {
            let train_first = match heap_key {
                None => true,
                Some(hk) => (t, s) < hk,
            };
            if train_first {
                debug_assert!(t >= self.now - PAST_TOLERANCE_S);
                self.now = t;
                self.processed += 1;
                let payload = self.train.fire(slot, self.seq);
                self.seq += 1;
                return Some((t, payload));
            }
        }
        let ev = self.heap.pop()?;
        debug_assert!(ev.time >= self.now - PAST_TOLERANCE_S);
        self.now = ev.time;
        self.processed += 1;
        Some((ev.time, ev.payload))
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E: Clone> Scheduler<E> for EventQueue<E> {
    fn push(&mut self, at: f64, payload: E) -> f64 {
        EventQueue::push(self, at, payload)
    }
    fn push_in(&mut self, delay: f64, payload: E) -> f64 {
        EventQueue::push_in(self, delay, payload)
    }
    fn arm_periodic(&mut self, slot: usize, first: f64, period: f64, payload: E) {
        EventQueue::arm_periodic(self, slot, first, period, payload);
    }
    fn pop(&mut self) -> Option<(f64, E)> {
        EventQueue::pop(self)
    }
    fn peek_time(&self) -> Option<f64> {
        EventQueue::peek_time(self)
    }
    fn now(&self) -> f64 {
        EventQueue::now(self)
    }
    fn processed(&self) -> u64 {
        EventQueue::processed(self)
    }
    fn len(&self) -> usize {
        EventQueue::len(self)
    }
    fn stats(&self) -> QueueStats {
        EventQueue::stats(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn same_time_is_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(5.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn negative_zero_is_canonicalized_on_push() {
        // -0.0 and +0.0 must be one timestamp: the heap orders by
        // `total_cmp` (-0.0 < +0.0) while the calendar queue buckets
        // arithmetically (-0.0 == +0.0), so without canonicalization the
        // two queue kinds would disagree on FIFO order at time zero.
        let mut heap = EventQueue::new();
        heap.push(-0.0, 0);
        heap.push(0.0, 1);
        heap.push(-0.0, 2);
        let got: Vec<(f64, i32)> = std::iter::from_fn(|| heap.pop()).collect();
        assert_eq!(got, vec![(0.0, 0), (0.0, 1), (0.0, 2)]);
        assert!(got.iter().all(|(t, _)| t.is_sign_positive()));

        let mut cal = calendar::CalendarQueue::new();
        cal.push(-0.0, 0);
        cal.push(0.0, 1);
        cal.push(-0.0, 2);
        let got: Vec<(f64, i32)> = std::iter::from_fn(|| cal.pop()).collect();
        assert_eq!(got, vec![(0.0, 0), (0.0, 1), (0.0, 2)]);
        assert!(got.iter().all(|(t, _)| t.is_sign_positive()));
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.push(1.5, ());
        q.push(4.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 1.5);
        q.push_in(1.0, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 2.5);
        q.pop();
        assert_eq!(q.now(), 4.0);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn peek_does_not_advance() {
        let mut q = EventQueue::new();
        q.push(2.0, ());
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.now(), 0.0);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut q = EventQueue::new();
        q.push(10.0, 10);
        q.push(1.0, 1);
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (1.0, 1));
        q.push(5.0, 5);
        q.push(2.0, 2);
        let mut times = Vec::new();
        while let Some((t, _)) = q.pop() {
            times.push(t);
        }
        assert_eq!(times, vec![2.0, 5.0, 10.0]);
    }

    #[test]
    fn push_returns_scheduled_time() {
        let mut q = EventQueue::new();
        assert_eq!(q.push(2.0, ()), 2.0);
        q.pop();
        // Sub-tolerance round-off clamps forward, is counted, returned.
        let t = q.push(2.0 - 1e-12, ());
        assert_eq!(t, 2.0);
        assert_eq!(q.clamped(), 1);
        assert_eq!(q.push_in(1.5, ()), 3.5);
        assert_eq!(q.clamped(), 1);
    }

    #[test]
    fn sub_tolerance_negative_delay_is_forgiven_and_counted() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.pop();
        // Round-off-scale negative delay: clamped to `now`, not dropped.
        let t = q.push_in(-1e-12, ());
        assert_eq!(t, 5.0);
        assert_eq!(q.clamped(), 1);
    }

    #[test]
    #[should_panic(expected = "scheduling a negative delay")]
    fn negative_delay_beyond_tolerance_panics() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.pop();
        // Used to be silently clamped to zero by `delay.max(0.0)`.
        q.push_in(-0.5, ());
    }

    #[test]
    #[should_panic(expected = "non-finite delay")]
    fn non_finite_delay_panics() {
        let mut q = EventQueue::new();
        q.push_in(f64::NEG_INFINITY, ());
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn past_time_panics_in_all_profiles() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.pop();
        q.push(4.0, ());
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn non_finite_time_panics() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    fn property_random_schedule_is_sorted() {
        crate::util::proptest::forall(200, 99, |g| {
            let n = g.size(1, 200);
            let mut q = EventQueue::new();
            for i in 0..n {
                q.push(g.f64(0.0, 1000.0), i);
            }
            let mut prev = -1.0;
            while let Some((t, _)) = q.pop() {
                if t < prev {
                    return crate::util::proptest::check(false, format!("{t} < {prev}"));
                }
                prev = t;
            }
            crate::util::proptest::check(true, "")
        });
    }

    #[test]
    fn stats_track_pushes_peak_and_clamps() {
        let mut q = EventQueue::new();
        q.push(1.0, ());
        q.push(2.0, ());
        q.arm_periodic(0, 0.5, 0.5, ());
        assert_eq!(q.len(), 3);
        q.pop(); // slot fires at 0.5, rearms to 1.0 — len stays 3
        q.pop(); // 1.0: the push wins (its seq predates the rearm's)
        let s = q.stats();
        // Arms are not pushes; peak saw pushes + the armed slot.
        assert_eq!(s.pushes, 2);
        assert_eq!(s.peak_len, 3);
        assert_eq!(s.clamped, 0);
    }

    #[test]
    fn tick_train_fires_in_time_and_seq_order() {
        // Slot armed BEFORE a push at the same timestamp holds the lower
        // seq and must fire first; rearming consumes a seq so a later
        // same-time push still loses to the rearmed slot.
        let mut q = EventQueue::new();
        q.arm_periodic(0, 1.0, 1.0, "tick"); // seq 0
        q.push(1.0, "push@1"); // seq 1
        q.push(2.5, "push@2.5"); // seq 2
        let mut got = Vec::new();
        for _ in 0..5 {
            let (t, e) = q.pop().unwrap();
            got.push((t, e));
        }
        assert_eq!(
            got,
            vec![
                (1.0, "tick"),
                (1.0, "push@1"),
                (2.0, "tick"),
                (2.5, "push@2.5"),
                (3.0, "tick"),
            ]
        );
        assert_eq!(q.processed(), 5);
        // The slot stays armed: the queue never runs dry on its own.
        assert_eq!(q.len(), 1);
        assert!(!q.is_empty());
    }

    #[test]
    fn two_slots_merge_by_time() {
        let mut q: EventQueue<&str> = EventQueue::new();
        q.arm_periodic(0, 0.25, 0.25, "adjust");
        q.arm_periodic(1, 0.1, 0.1, "sample");
        let mut got = Vec::new();
        for _ in 0..6 {
            let (t, e) = q.pop().unwrap();
            got.push((t, e));
        }
        assert_eq!(
            got,
            vec![
                (0.1, "sample"),
                (0.2, "sample"),
                (0.25, "adjust"),
                (0.30000000000000004, "sample"),
                (0.4, "sample"),
                (0.5, "adjust"),
            ]
        );
    }

    #[test]
    fn queue_kind_parses_and_round_trips() {
        assert_eq!(QueueKind::parse("heap"), Ok(QueueKind::Heap));
        assert_eq!(QueueKind::parse("calendar"), Ok(QueueKind::Calendar));
        assert!(QueueKind::parse("frobnicate").is_err());
        assert_eq!(QueueKind::default(), QueueKind::Calendar);
        for k in [QueueKind::Heap, QueueKind::Calendar] {
            assert_eq!(QueueKind::parse(k.name()), Ok(k));
        }
    }

    #[test]
    fn scheduler_impl_dispatches_to_the_selected_kind() {
        for kind in [QueueKind::Heap, QueueKind::Calendar] {
            let mut q: SchedulerImpl<u32> = SchedulerImpl::new(kind);
            assert_eq!(q.kind(), kind);
            assert!(q.is_empty());
            q.push(1.0, 7);
            q.arm_periodic(1, 0.5, 0.5, 99);
            assert_eq!(q.len(), 2);
            assert_eq!(q.peek_time(), Some(0.5));
            assert_eq!(q.pop(), Some((0.5, 99)));
            assert_eq!(q.pop(), Some((1.0, 7)));
            assert_eq!(q.now(), 1.0);
            assert_eq!(q.processed(), 2);
            assert_eq!(q.stats().pushes, 1);
        }
    }
}
