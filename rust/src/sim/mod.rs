//! Deterministic discrete-event simulation engine.
//!
//! Our from-scratch equivalent of splitwise-sim's event core: a binary
//! heap of `(time, seq)`-ordered events. The `seq` tiebreaker guarantees
//! FIFO order among same-timestamp events, which makes every run exactly
//! reproducible from a seed — a property every experiment in
//! EXPERIMENTS.md relies on.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at a simulation time.
struct Scheduled<E> {
    time: f64,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .expect("event times must not be NaN")
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Scheduling a past time beyond this tolerance is a hard error; within
/// it, the time is clamped to `now` (float round-off from accumulated
/// `now + dt` arithmetic) and counted in [`EventQueue::clamped`].
pub const PAST_TOLERANCE_S: f64 = 1e-9;

/// The event queue / simulation clock.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: f64,
    processed: u64,
    clamped: u64,
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0, processed: 0, clamped: 0 }
    }

    /// Current simulation time (time of the last popped event).
    #[inline]
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Total events processed so far.
    #[inline]
    pub fn processed(&self) -> u64 {
        self.processed
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Number of pushes whose time was clamped forward to `now` (always a
    /// sub-[`PAST_TOLERANCE_S`] float round-off; larger skews panic).
    #[inline]
    pub fn clamped(&self) -> u64 {
        self.clamped
    }

    /// Schedule `payload` at absolute time `at` (must be ≥ now) and return
    /// the time actually used.
    ///
    /// Scheduling into the past is a real error in every build profile —
    /// previously a `debug_assert!`, which let release-mode sweep workers
    /// silently clamp buggy past-times to `now` and mask scheduling bugs.
    /// Only float round-off within [`PAST_TOLERANCE_S`] is forgiven: the
    /// time is clamped to `now`, the clamp is counted, and the clamped
    /// time is returned so callers see the effective schedule.
    pub fn push(&mut self, at: f64, payload: E) -> f64 {
        assert!(at.is_finite(), "scheduling a non-finite time: {at}");
        assert!(
            at >= self.now - PAST_TOLERANCE_S,
            "scheduling into the past: {at} < {}",
            self.now
        );
        let time = if at < self.now {
            self.clamped += 1;
            self.now
        } else {
            at
        };
        self.heap.push(Scheduled { time, seq: self.seq, payload });
        self.seq += 1;
        time
    }

    /// Schedule `payload` `delay` seconds from now; returns the absolute
    /// time used.
    ///
    /// Negative delays follow the same contract as [`EventQueue::push`]:
    /// a delay below `-`[`PAST_TOLERANCE_S`] panics (it used to be clamped
    /// silently to zero, masking negative-duration bugs in callers), while
    /// sub-tolerance round-off is forgiven — clamped to `now` and counted
    /// in [`EventQueue::clamped`].
    pub fn push_in(&mut self, delay: f64, payload: E) -> f64 {
        assert!(delay.is_finite(), "scheduling a non-finite delay: {delay}");
        assert!(delay >= -PAST_TOLERANCE_S, "scheduling a negative delay: {delay}");
        self.push(self.now + delay, payload)
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.time >= self.now - 1e-9);
        self.now = ev.time;
        self.processed += 1;
        Some((ev.time, ev.payload))
    }

    /// Peek at the next event time without advancing.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn same_time_is_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(5.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.push(1.5, ());
        q.push(4.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 1.5);
        q.push_in(1.0, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 2.5);
        q.pop();
        assert_eq!(q.now(), 4.0);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn peek_does_not_advance() {
        let mut q = EventQueue::new();
        q.push(2.0, ());
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.now(), 0.0);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut q = EventQueue::new();
        q.push(10.0, 10);
        q.push(1.0, 1);
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (1.0, 1));
        q.push(5.0, 5);
        q.push(2.0, 2);
        let mut times = Vec::new();
        while let Some((t, _)) = q.pop() {
            times.push(t);
        }
        assert_eq!(times, vec![2.0, 5.0, 10.0]);
    }

    #[test]
    fn push_returns_scheduled_time() {
        let mut q = EventQueue::new();
        assert_eq!(q.push(2.0, ()), 2.0);
        q.pop();
        // Sub-tolerance round-off clamps forward, is counted, returned.
        let t = q.push(2.0 - 1e-12, ());
        assert_eq!(t, 2.0);
        assert_eq!(q.clamped(), 1);
        assert_eq!(q.push_in(1.5, ()), 3.5);
        assert_eq!(q.clamped(), 1);
    }

    #[test]
    fn sub_tolerance_negative_delay_is_forgiven_and_counted() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.pop();
        // Round-off-scale negative delay: clamped to `now`, not dropped.
        let t = q.push_in(-1e-12, ());
        assert_eq!(t, 5.0);
        assert_eq!(q.clamped(), 1);
    }

    #[test]
    #[should_panic(expected = "scheduling a negative delay")]
    fn negative_delay_beyond_tolerance_panics() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.pop();
        // Used to be silently clamped to zero by `delay.max(0.0)`.
        q.push_in(-0.5, ());
    }

    #[test]
    #[should_panic(expected = "non-finite delay")]
    fn non_finite_delay_panics() {
        let mut q = EventQueue::new();
        q.push_in(f64::NEG_INFINITY, ());
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn past_time_panics_in_all_profiles() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.pop();
        q.push(4.0, ());
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn non_finite_time_panics() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    fn property_random_schedule_is_sorted() {
        crate::util::proptest::forall(200, 99, |g| {
            let n = g.size(1, 200);
            let mut q = EventQueue::new();
            for i in 0..n {
                q.push(g.f64(0.0, 1000.0), i);
            }
            let mut prev = -1.0;
            while let Some((t, _)) = q.pop() {
                if t < prev {
                    return crate::util::proptest::check(false, format!("{t} < {prev}"));
                }
                prev = t;
            }
            crate::util::proptest::check(true, "")
        });
    }
}
