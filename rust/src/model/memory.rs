//! KV-cache memory accounting for a token (decode) instance.
//!
//! Continuous batching admits a request only when its context fits in the
//! GPU-memory KV budget; completed requests free their tokens. Token
//! counts, not bytes, are the unit (bytes = tokens × `kv_bytes_per_token`).

/// Token-granular KV memory pool.
#[derive(Clone, Copy, Debug)]
pub struct KvMemory {
    pub capacity_tokens: u64,
    pub used_tokens: u64,
    /// High-water mark for reporting.
    pub peak_tokens: u64,
}

impl KvMemory {
    pub fn new(capacity_tokens: u64) -> KvMemory {
        KvMemory { capacity_tokens, used_tokens: 0, peak_tokens: 0 }
    }

    /// Would an allocation of `tokens` fit right now?
    #[inline]
    pub fn fits(&self, tokens: u64) -> bool {
        self.used_tokens + tokens <= self.capacity_tokens
    }

    /// Reserve `tokens`. Returns false (and does nothing) if it won't fit.
    pub fn alloc(&mut self, tokens: u64) -> bool {
        if !self.fits(tokens) {
            return false;
        }
        self.used_tokens += tokens;
        self.peak_tokens = self.peak_tokens.max(self.used_tokens);
        true
    }

    /// Release `tokens`.
    pub fn free(&mut self, tokens: u64) {
        debug_assert!(tokens <= self.used_tokens, "KV underflow: free {tokens} of {}", self.used_tokens);
        self.used_tokens = self.used_tokens.saturating_sub(tokens);
    }

    /// Utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        if self.capacity_tokens == 0 {
            0.0
        } else {
            self.used_tokens as f64 / self.capacity_tokens as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut kv = KvMemory::new(1000);
        assert!(kv.alloc(400));
        assert!(kv.alloc(600));
        assert!(!kv.alloc(1));
        assert_eq!(kv.used_tokens, 1000);
        assert_eq!(kv.peak_tokens, 1000);
        kv.free(500);
        assert!(kv.alloc(300));
        assert_eq!(kv.used_tokens, 800);
        assert_eq!(kv.peak_tokens, 1000);
    }

    #[test]
    fn utilization_fraction() {
        let mut kv = KvMemory::new(200);
        kv.alloc(50);
        assert!((kv.utilization() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn failed_alloc_changes_nothing() {
        let mut kv = KvMemory::new(10);
        kv.alloc(8);
        assert!(!kv.alloc(5));
        assert_eq!(kv.used_tokens, 8);
    }
}
