//! GPU inference performance + memory model for the simulated cluster.
//!
//! Calibrated against the H100 DGX numbers reported in the Splitwise paper
//! (the same machines the evaluated cluster uses): prompt phases are
//! compute-bound and scale ~linearly in prompt tokens; decode iterations
//! are memory-bound, with a base cost plus small per-sequence and
//! per-context terms; KV-cache state is ~200 KB per token for a 70B-class
//! model, and transfers ride the InfiniBand fabric at ~200 Gb/s.

pub mod memory;

pub use memory::KvMemory;

/// Latency/size model of the GPU side of one inference server.
#[derive(Clone, Copy, Debug)]
pub struct PerfModel {
    /// Prompt phase: fixed overhead (s).
    pub prompt_base_s: f64,
    /// Prompt phase: per-input-token cost (s).
    pub prompt_per_token_s: f64,
    /// Decode iteration: fixed overhead (s).
    pub iter_base_s: f64,
    /// Decode iteration: per-batched-sequence cost (s).
    pub iter_per_seq_s: f64,
    /// Decode iteration: per-context-token cost (s), attention term.
    pub iter_per_ctx_token_s: f64,
    /// KV-cache bytes per token of context.
    pub kv_bytes_per_token: f64,
    /// Interconnect bandwidth for KV transfers (bytes/s).
    pub link_bytes_per_s: f64,
    /// Per-flow fixed latency (s): rendezvous + RDMA setup.
    pub link_latency_s: f64,
}

impl PerfModel {
    /// H100 + 70B-class model defaults (Splitwise-calibrated, chunked
    /// prefill). Sized so the paper's iso-throughput cluster design holds:
    /// 5 prompt machines sustain 100 rps (mean prefill ≈ 40 ms) and 17
    /// token machines sustain the corresponding decode load.
    pub fn h100_70b() -> PerfModel {
        PerfModel {
            prompt_base_s: 0.010,
            prompt_per_token_s: 2.0e-5,
            iter_base_s: 0.015,
            iter_per_seq_s: 0.0004,
            iter_per_ctx_token_s: 2.0e-7,
            kv_bytes_per_token: 200_000.0,
            link_bytes_per_s: 25.0e9, // 200 Gb/s
            link_latency_s: 0.001,
        }
    }

    /// Duration of a prompt (prefill) phase for `n_in` input tokens.
    #[inline]
    pub fn prompt_time_s(&self, n_in: u32) -> f64 {
        self.prompt_base_s + self.prompt_per_token_s * n_in as f64
    }

    /// Duration of one decode iteration over `batch` sequences with a
    /// total of `ctx_tokens` context tokens across the batch.
    #[inline]
    pub fn iter_time_s(&self, batch: usize, ctx_tokens: u64) -> f64 {
        self.iter_base_s
            + self.iter_per_seq_s * batch as f64
            + self.iter_per_ctx_token_s * ctx_tokens as f64
    }

    /// KV-cache size for `tokens` tokens of context.
    #[inline]
    pub fn kv_bytes(&self, tokens: u32) -> f64 {
        self.kv_bytes_per_token * tokens as f64
    }

    /// KV transfer time over the interconnect.
    #[inline]
    pub fn kv_transfer_s(&self, tokens: u32) -> f64 {
        self.link_latency_s + self.kv_bytes(tokens) / self.link_bytes_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_scales_linearly() {
        let m = PerfModel::h100_70b();
        let t1 = m.prompt_time_s(1024);
        let t2 = m.prompt_time_s(2048);
        assert!(t1 > 0.02 && t1 < 0.08, "prefill(1024)={t1}");
        assert!((t2 - t1 - 1024.0 * m.prompt_per_token_s).abs() < 1e-12);
    }

    #[test]
    fn iteration_in_tens_of_ms() {
        let m = PerfModel::h100_70b();
        let t = m.iter_time_s(32, 32 * 1200);
        assert!(t > 0.02 && t < 0.08, "iter={t}");
        // Bigger batches take longer but sublinearly per sequence.
        assert!(m.iter_time_s(64, 64 * 1200) < 2.0 * m.iter_time_s(32, 32 * 1200));
    }

    #[test]
    fn iso_throughput_cluster_capacity() {
        // The paper's cluster (5 prompt + 17 token) must sustain 100 rps:
        // prompt side: 5 / mean_prefill >= 100 rps at ~1500-token prompts;
        // token side: 17 machines * batch-64 decode >= ~14k tok/s.
        let m = PerfModel::h100_70b();
        let prompt_capacity = 5.0 / m.prompt_time_s(1500);
        assert!(prompt_capacity > 100.0, "prompt capacity {prompt_capacity} rps");
        let iter = m.iter_time_s(64, 64 * 1200);
        let token_capacity = 17.0 * 64.0 / iter;
        assert!(token_capacity > 14_000.0, "token capacity {token_capacity} tok/s");
    }

    #[test]
    fn kv_transfer_sane() {
        let m = PerfModel::h100_70b();
        // 1024 tokens * 200 KB = ~205 MB over 25 GB/s ≈ 8 ms + 1 ms latency.
        let t = m.kv_transfer_s(1024);
        assert!(t > 0.005 && t < 0.02, "transfer={t}");
    }

    #[test]
    fn monotonicity() {
        let m = PerfModel::h100_70b();
        assert!(m.prompt_time_s(100) < m.prompt_time_s(200));
        assert!(m.iter_time_s(1, 100) < m.iter_time_s(2, 100));
        assert!(m.iter_time_s(2, 100) < m.iter_time_s(2, 50_000));
        assert!(m.kv_transfer_s(10) < m.kv_transfer_s(1000));
    }
}
