//! Fig. 2 — "Distributions of running inference tasks in an LLM inference
//! cluster of 22 H100 machines".
//!
//! The motivating observation (§2.2): with each CPU task on a dedicated
//! core (stock allocation), per-machine concurrent task counts have **low
//! means** (O1: underutilization) with **occasional bursts** (O2: the
//! reason for high core counts). One subplot per throughput level; here,
//! one row per machine with a text violin.

use super::Scale;
use crate::cluster::Cluster;
use crate::util::stats::{Histogram, Summary};

#[derive(Clone, Debug)]
pub struct Fig2Machine {
    pub machine: usize,
    pub role: &'static str,
    pub tasks: Summary,
    pub sparkline: String,
}

#[derive(Clone, Debug)]
pub struct Fig2Level {
    pub rate: f64,
    pub machines: Vec<Fig2Machine>,
}

/// Run the Fig. 2 observation study: stock (`linux`) placement, every
/// task on a dedicated core, at each throughput level.
pub fn run(scale: &Scale, cores: usize) -> Vec<Fig2Level> {
    let mut levels = Vec::new();
    for &rate in &scale.rates {
        let trace = scale.trace(rate);
        let cfg = scale.config(cores, "linux");
        let mut cluster = Cluster::new(cfg);
        let result = cluster.run(&trace);
        let machines = (0..result.collector.n_machines)
            .map(|m| {
                let samples = &result.collector.task_samples[m];
                let mut h = Histogram::new(0.0, 40.0, 40);
                for &s in samples {
                    h.add(s);
                }
                Fig2Machine {
                    machine: m,
                    role: if m < scale.n_prompt { "prompt" } else { "token" },
                    tasks: Summary::of(samples),
                    sparkline: h.sparkline(),
                }
            })
            .collect();
        levels.push(Fig2Level { rate, machines });
    }
    levels
}

pub fn print(levels: &[Fig2Level]) {
    for level in levels {
        println!("\nFig 2 — concurrent inference tasks per machine @ {} rps", level.rate);
        println!(
            "{:<10} {:<8} {:>8} {:>8} {:>8} {:>8}  {}",
            "machine", "role", "mean", "p50", "p99", "max", "distribution [0..40 tasks]"
        );
        for m in &level.machines {
            println!(
                "{:<10} {:<8} {:>8.2} {:>8.1} {:>8.1} {:>8.0}  |{}|",
                m.machine, m.role, m.tasks.mean, m.tasks.p50, m.tasks.p99, m.tasks.max, m.sparkline
            );
        }
    }
}

/// The two key observations as checks: O1 low means, O2 bursts.
pub fn check_shape(levels: &[Fig2Level], cores: usize) -> Vec<String> {
    let mut violations = Vec::new();
    for level in levels {
        for m in &level.machines {
            // O1: cores are mostly underutilized — mean ≪ core count.
            if m.tasks.mean > cores as f64 * 0.5 {
                violations.push(format!(
                    "rate={} machine={}: mean {} not ≪ {} cores",
                    level.rate, m.machine, m.tasks.mean, cores
                ));
            }
        }
        // O2: bursts exist — some machine's max well above its mean.
        let burst = level.machines.iter().any(|m| m.tasks.max >= (3.0 * m.tasks.mean).max(4.0));
        if !burst {
            violations.push(format!("rate={}: no burst observed", level.rate));
        }
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observations_hold_on_smoke_scale() {
        let mut scale = Scale::smoke();
        scale.duration_s = 30.0;
        scale.rates = vec![10.0];
        let levels = run(&scale, 16);
        assert_eq!(levels.len(), 1);
        assert_eq!(levels[0].machines.len(), 4);
        let violations = check_shape(&levels, 16);
        assert!(violations.is_empty(), "{violations:?}");
    }
}
