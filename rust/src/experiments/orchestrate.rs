//! Shard-fleet orchestration (`carbon-sim orchestrate`): drive the whole
//! distributed sweep pipeline from one spec — launch N `sweep --shard
//! K/N` runs, relay their progress, retry failures against their partial
//! spills, and merge the finished shard spills into a report
//! byte-identical to a single-machine run.
//!
//! PR 4's building blocks (`--shard K/N` spills, `carbon-sim merge`)
//! made distributed sweeps *possible*; this module makes them *one
//! command*. [`run`] owns the fleet: shard children are launched either
//! as local `carbon-sim sweep` processes (the default) or through a
//! `--launcher` shell template with `{shard}`/`{out_dir}`/`{spec}`
//! placeholders (SSH, SLURM `srun`, …), at most `workers` in flight at
//! once, with every child's stdout/stderr relayed line-by-line under a
//! `[shard K/N]` prefix.
//!
//! # Retry/resume state machine
//!
//! Each shard moves through `pending → running → done | failed`, tracked
//! in the `<out-dir>/orchestrate.json` manifest (field reference in
//! `docs/output-schemas.md` §3.2), which is atomically rewritten
//! (temp-file + rename) on **every** transition so a killed orchestrator
//! always leaves a consistent manifest behind:
//!
//! * **Launch.** A `pending` shard starts when a worker slot frees up.
//!   The first attempt of a fresh (non-`--resume`) run starts a fresh
//!   spill; every later attempt — a retry, or any attempt under
//!   `--resume` — passes `--resume` to the child so cells already in the
//!   shard's spill are **reused, not re-simulated**.
//! * **Verification.** Exit code 0 is not trusted blindly: the shard's
//!   spill is re-scanned ([`sweep_stream::scan_done`], the same rules as
//!   resume compaction) and the shard is `done` only when every cell it
//!   owns is on disk. A launcher that queues asynchronously and returns
//!   early (e.g. `sbatch` without `--wait`) therefore fails verification
//!   instead of corrupting the merge.
//! * **Failure.** A non-zero exit, spawn error, or incomplete spill
//!   re-launches the shard up to `retries` more times, then parks it as
//!   `failed`, recording the exit code and the last stderr lines. Other
//!   shards keep running; the orchestrator then errors out, surfacing
//!   each failed shard's stderr tail, and a later `orchestrate --resume`
//!   re-runs only the non-`done` shards.
//! * **Resume.** `--resume` re-reads the manifest (refusing a different
//!   spec hash, cell count, or shard count — the split cannot change
//!   mid-flight), requeues `running` (interrupted) and `failed` shards,
//!   and re-verifies `done` shards' spills on disk rather than trusting
//!   the status — a deleted or truncated shard dir heals itself.
//! * **Merge.** Once every shard is `done`, the existing
//!   [`merge::merge_spills`] validation + reassembly path produces
//!   `<out-dir>/cells.jsonl` and `report.json`/`.csv` — byte-identical
//!   to a single-machine run (pinned by `tests/orchestrate.rs`).

use std::fs;
use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::Mutex;

use super::merge;
use super::sweep::{Format, ShardSpec, SweepSpec};
use super::sweep_stream::{self, header_usize, CELLS_FILE};
use super::OUTPUT_SCHEMA_VERSION;
use crate::util::json::{parse, Value};
use crate::util::pool;
use crate::util::proc;

/// Manifest file name inside the orchestrate `--out-dir`.
pub const MANIFEST_FILE: &str = "orchestrate.json";

/// The sub-directory one shard's spill lands in (`<out-dir>/shard-<k>`).
pub fn shard_dir_name(k: usize) -> String {
    format!("shard-{k}")
}

/// One shard's position in the retry/resume state machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardStatus {
    /// Not launched yet (or requeued by `--resume`).
    Pending,
    /// An attempt is in flight — after a crash, "was in flight".
    Running,
    /// Exited 0 and the spill verifiably covers every owned cell.
    Done,
    /// Out of retries; `exit_code`/`stderr_tail` say why.
    Failed,
}

impl ShardStatus {
    fn name(self) -> &'static str {
        match self {
            ShardStatus::Pending => "pending",
            ShardStatus::Running => "running",
            ShardStatus::Done => "done",
            ShardStatus::Failed => "failed",
        }
    }

    fn parse(s: &str) -> Result<ShardStatus, String> {
        match s {
            "pending" => Ok(ShardStatus::Pending),
            "running" => Ok(ShardStatus::Running),
            "done" => Ok(ShardStatus::Done),
            "failed" => Ok(ShardStatus::Failed),
            other => Err(format!("unknown shard status '{other}'")),
        }
    }
}

/// One shard's manifest record.
#[derive(Clone, Debug)]
pub struct ShardState {
    pub status: ShardStatus,
    /// Cumulative launch attempts, across orchestrate invocations.
    pub attempts: usize,
    /// Exit code of the most recent finished attempt (`None` before the
    /// first exit, or when the child was signal-killed or failed to
    /// spawn).
    pub exit_code: Option<i32>,
    /// Last stderr lines of the most recent failed attempt (cleared once
    /// the shard succeeds).
    pub stderr_tail: Vec<String>,
}

impl Default for ShardState {
    fn default() -> ShardState {
        ShardState {
            status: ShardStatus::Pending,
            attempts: 0,
            exit_code: None,
            stderr_tail: Vec::new(),
        }
    }
}

/// The in-memory manifest; serialized to [`MANIFEST_FILE`] on every
/// state transition.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub spec_hash: String,
    pub n_cells: usize,
    pub shard_count: usize,
    /// The canonical spec, embedded like the `cells.jsonl` header embeds
    /// it — the manifest is self-describing.
    pub spec: Value,
    pub shards: Vec<ShardState>,
}

impl Manifest {
    fn fresh(spec: &SweepSpec, shards: usize) -> Manifest {
        Manifest {
            spec_hash: spec.spec_hash(),
            n_cells: spec.n_cells(),
            shard_count: shards,
            spec: spec.to_json(),
            shards: vec![ShardState::default(); shards],
        }
    }

    fn to_json(&self) -> Value {
        let shards = self
            .shards
            .iter()
            .enumerate()
            .map(|(k, s)| {
                let mut pairs = vec![
                    ("index", k.into()),
                    ("out_dir", shard_dir_name(k).into()),
                    ("status", s.status.name().into()),
                    ("attempts", s.attempts.into()),
                ];
                if let Some(code) = s.exit_code {
                    pairs.push(("exit_code", f64::from(code).into()));
                }
                if !s.stderr_tail.is_empty() {
                    pairs.push((
                        "stderr_tail",
                        Value::Arr(s.stderr_tail.iter().map(|l| l.as_str().into()).collect()),
                    ));
                }
                Value::obj(pairs)
            })
            .collect();
        Value::obj(vec![
            ("kind", "orchestrate".into()),
            ("schema_version", OUTPUT_SCHEMA_VERSION.into()),
            ("spec_hash", self.spec_hash.as_str().into()),
            ("n_cells", self.n_cells.into()),
            ("shard_count", self.shard_count.into()),
            ("spec", self.spec.clone()),
            ("shards", Value::Arr(shards)),
        ])
    }

    /// Atomic rewrite: a kill between transitions leaves either the old
    /// or the new manifest, never a torn one.
    fn write(&self, path: &Path) -> Result<(), String> {
        let tmp = path.with_extension("json.tmp");
        let mut body = self.to_json().to_string_pretty();
        body.push('\n');
        fs::write(&tmp, body).map_err(|e| format!("writing {tmp:?}: {e}"))?;
        fs::rename(&tmp, path).map_err(|e| format!("renaming {tmp:?} over {path:?}: {e}"))
    }

    /// Load and identity-check an existing manifest against the current
    /// invocation. Every refusal names what diverged — a resume must
    /// never mix shards of a different grid or a different split.
    fn load(path: &Path, spec: &SweepSpec, shards: usize) -> Result<Manifest, String> {
        let text = fs::read_to_string(path).map_err(|e| format!("reading {path:?}: {e}"))?;
        let v = parse(&text).map_err(|e| format!("{path:?}: {e}"))?;
        if v.str_or("kind", "") != "orchestrate" {
            return Err(format!("{path:?}: not an orchestrate manifest (missing kind)"));
        }
        let ver = header_usize(&v, "schema_version", 0, path)?;
        if ver != OUTPUT_SCHEMA_VERSION {
            return Err(format!(
                "{path:?}: manifest schema_version {ver} != supported {OUTPUT_SCHEMA_VERSION}"
            ));
        }
        let hash = spec.spec_hash();
        if v.str_or("spec_hash", "") != hash {
            return Err(format!(
                "{path:?}: manifest spec hash {} does not match the current spec ({hash}) — \
                 this out-dir belongs to a different grid; use a fresh --out-dir",
                v.str_or("spec_hash", "")
            ));
        }
        let n_cells = header_usize(&v, "n_cells", 0, path)?;
        if n_cells != spec.n_cells() {
            return Err(format!(
                "{path:?}: manifest expects {n_cells} cells, current spec expands to {}",
                spec.n_cells()
            ));
        }
        let shard_count = header_usize(&v, "shard_count", 0, path)?;
        if shard_count != shards {
            return Err(format!(
                "{path:?}: manifest records {shard_count} shards, this run asked for {shards} — \
                 a grid's split cannot change mid-flight; finish with --shards {shard_count} \
                 or start a fresh --out-dir"
            ));
        }
        let raw = v
            .get("shards")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| format!("{path:?}: manifest has no shards array"))?;
        if raw.len() != shards {
            return Err(format!(
                "{path:?}: manifest lists {} shard entries for shard_count {shards}",
                raw.len()
            ));
        }
        let mut states = Vec::with_capacity(shards);
        for (k, entry) in raw.iter().enumerate() {
            if header_usize(entry, "index", usize::MAX, path)? != k {
                return Err(format!("{path:?}: shard entry {k} has a mismatched index field"));
            }
            let status = ShardStatus::parse(entry.str_or("status", ""))
                .map_err(|e| format!("{path:?}: shard entry {k}: {e}"))?;
            let exit_code = match entry.get("exit_code") {
                None => None,
                Some(Value::Num(x)) if x.fract() == 0.0 && x.abs() < 2_147_483_648.0 => {
                    Some(*x as i32)
                }
                Some(other) => {
                    return Err(format!(
                        "{path:?}: shard entry {k}: exit_code must be an integer, got {other}"
                    ))
                }
            };
            let stderr_tail = match entry.get("stderr_tail") {
                None => Vec::new(),
                Some(v) => v
                    .as_arr()
                    .ok_or_else(|| {
                        format!("{path:?}: shard entry {k}: stderr_tail must be an array")
                    })?
                    .iter()
                    .map(|l| l.as_str().unwrap_or_default().to_string())
                    .collect(),
            };
            states.push(ShardState {
                status,
                attempts: header_usize(entry, "attempts", 0, path)?,
                exit_code,
                stderr_tail,
            });
        }
        Ok(Manifest {
            spec_hash: hash,
            n_cells,
            shard_count,
            spec: v.get("spec").cloned().unwrap_or_else(|| spec.to_json()),
            shards: states,
        })
    }
}

/// Everything [`run`] needs; the CLI builds one from flags, tests build
/// one directly (pointing `program` at the `carbon-sim` binary under
/// test).
#[derive(Clone, Debug)]
pub struct OrchestrateConfig {
    /// The parsed grid — hashed for the manifest and used for
    /// verification; children re-read it from `spec_path`.
    pub spec: SweepSpec,
    /// Spec file handed to every shard child (`{spec}` in templates).
    pub spec_path: PathBuf,
    /// How many shards to split the grid across (the `N` of `K/N`).
    pub shards: usize,
    /// Max shard runs in flight at once (0 = all shards).
    pub workers: usize,
    /// Re-launches per shard after a failure, per invocation.
    pub retries: usize,
    /// `--threads` forwarded to local shard children (0 = one per core).
    pub threads_per_shard: usize,
    /// Format of the merged report.
    pub format: Format,
    /// Shell template with `{shard}`/`{out_dir}`/`{spec}` placeholders,
    /// run as `sh -c`; `None` launches local children via `program`.
    /// Templates must block until the shard finishes and must land the
    /// spill under `{out_dir}` on this machine's filesystem.
    pub launcher: Option<String>,
    /// The `carbon-sim` binary for the default local launcher.
    pub program: PathBuf,
    /// Continue a previous run in the same out-dir.
    pub resume: bool,
    /// Relay child stdout progress lines (stderr is always relayed).
    pub verbose: bool,
}

/// What an orchestrate run did (the CLI's summary line).
#[derive(Clone, Debug)]
pub struct OrchestrateSummary {
    pub n_shards: usize,
    /// Shards whose spills were already complete and were not relaunched.
    pub n_skipped: usize,
    /// Shards launched (at least once) by this invocation.
    pub n_launched: usize,
    pub cells_path: PathBuf,
    pub report_path: PathBuf,
}

/// Is every cell this shard owns recorded in `done`?
fn shard_complete(done: &[bool], shard: &ShardSpec) -> bool {
    (0..done.len()).filter(|&i| shard.owns(i)).all(|i| done[i])
}

/// Drive the fleet to completion: launch/retry every non-done shard,
/// then merge. See the module docs for the state machine.
pub fn run(cfg: &OrchestrateConfig, out_dir: &Path) -> Result<OrchestrateSummary, String> {
    cfg.spec.validate()?;
    if cfg.shards == 0 {
        return Err("orchestrate: --shards must be ≥ 1".to_string());
    }
    fs::create_dir_all(out_dir).map_err(|e| format!("creating {out_dir:?}: {e}"))?;
    let manifest_path = out_dir.join(MANIFEST_FILE);

    let mut manifest = if manifest_path.exists() {
        if !cfg.resume {
            return Err(format!(
                "{manifest_path:?} already exists — pass --resume to continue that run \
                 (done shards are kept, interrupted/failed ones relaunched against their \
                 partial spills), or use a fresh --out-dir"
            ));
        }
        Manifest::load(&manifest_path, &cfg.spec, cfg.shards)?
    } else {
        Manifest::fresh(&cfg.spec, cfg.shards)
    };

    // Requeue interrupted and failed shards, and re-verify "done" ones
    // against the spill actually on disk — the manifest records intent,
    // the spill is the ground truth.
    for k in 0..cfg.shards {
        let requeue = match manifest.shards[k].status {
            ShardStatus::Pending => false,
            ShardStatus::Running | ShardStatus::Failed => true,
            ShardStatus::Done => {
                let cells = out_dir.join(shard_dir_name(k)).join(CELLS_FILE);
                let shard = ShardSpec::new(k, cfg.shards).expect("k < shards");
                if !cells.exists() {
                    true
                } else {
                    !shard_complete(&sweep_stream::scan_done(&cells, &cfg.spec, &shard)?, &shard)
                }
            }
        };
        if requeue {
            manifest.shards[k].status = ShardStatus::Pending;
        }
    }
    manifest.write(&manifest_path)?;

    let to_run: Vec<usize> = (0..cfg.shards)
        .filter(|&k| manifest.shards[k].status != ShardStatus::Done)
        .collect();
    let n_skipped = cfg.shards - to_run.len();
    if cfg.verbose && n_skipped > 0 {
        println!("orchestrate: {n_skipped} shard(s) already complete, launching {}", to_run.len());
    }

    let shared = Mutex::new(manifest);
    let workers = if cfg.workers == 0 { cfg.shards } else { cfg.workers };
    let mut failures: Vec<(usize, String)> = Vec::new();
    pool::run_streamed(
        &to_run,
        workers,
        |k| run_shard(cfg, out_dir, &manifest_path, &shared, k),
        |k, outcome| {
            if let Err(msg) = outcome {
                failures.push((k, msg));
            }
            true // keep the rest of the fleet running
        },
    );
    if !failures.is_empty() {
        failures.sort_unstable_by_key(|&(k, _)| k);
        let mut msg = format!(
            "orchestrate: {} of {} shard(s) failed:\n",
            failures.len(),
            cfg.shards
        );
        for (_, detail) in &failures {
            msg.push_str(detail);
            msg.push('\n');
        }
        msg.push_str(&format!(
            "finished shards and partial spills are kept under {out_dir:?}; fix the cause \
             and re-run with --resume"
        ));
        return Err(msg);
    }

    // Every shard verified complete: validate + reassemble through the
    // same merge path a by-hand `carbon-sim merge` would use.
    let dirs: Vec<PathBuf> = (0..cfg.shards).map(|k| out_dir.join(shard_dir_name(k))).collect();
    let m = merge::merge_spills(&dirs, out_dir, cfg.format)?;
    Ok(OrchestrateSummary {
        n_shards: cfg.shards,
        n_skipped,
        n_launched: to_run.len(),
        cells_path: m.cells_path,
        report_path: m.report_path,
    })
}

/// Update shard `k`'s manifest record under the lock and persist it.
fn update_shard(
    shared: &Mutex<Manifest>,
    manifest_path: &Path,
    k: usize,
    f: impl FnOnce(&mut ShardState),
) -> Result<(), String> {
    let mut m = shared.lock().expect("manifest lock");
    f(&mut m.shards[k]);
    m.write(manifest_path)
}

/// Build shard `k`'s launch command for this attempt.
fn shard_command(cfg: &OrchestrateConfig, shard_dir: &Path, k: usize, resume: bool) -> Command {
    let shard = format!("{k}/{}", cfg.shards);
    match &cfg.launcher {
        Some(template) => {
            let line = proc::substitute(
                template,
                &[
                    ("shard", shard.as_str()),
                    ("out_dir", &shard_dir.display().to_string()),
                    ("spec", &cfg.spec_path.display().to_string()),
                ],
            );
            proc::shell_command(&line)
        }
        None => {
            let mut cmd = Command::new(&cfg.program);
            cmd.arg("sweep")
                .arg("--spec")
                .arg(&cfg.spec_path)
                .arg("--shard")
                .arg(&shard)
                .arg("--out-dir")
                .arg(shard_dir)
                .arg("--threads")
                .arg(cfg.threads_per_shard.to_string());
            if resume {
                cmd.arg("--resume");
            }
            if !cfg.verbose {
                cmd.arg("--quiet");
            }
            cmd
        }
    }
}

/// Run one shard to `done` or `failed`: up to `1 + retries` attempts,
/// each verified against the on-disk spill. Returns `Err` with the
/// preformatted failure description (exit code + stderr tail) once the
/// shard is parked as failed.
fn run_shard(
    cfg: &OrchestrateConfig,
    out_dir: &Path,
    manifest_path: &Path,
    shared: &Mutex<Manifest>,
    k: usize,
) -> Result<(), String> {
    let shard = ShardSpec::new(k, cfg.shards).expect("k < shards");
    let shard_dir = out_dir.join(shard_dir_name(k));
    fs::create_dir_all(&shard_dir).map_err(|e| format!("creating {shard_dir:?}: {e}"))?;
    let label = format!("[shard {shard}]");

    let mut last_failure = String::new();
    let mut last_code: Option<i32> = None;
    let mut last_tail: Vec<String> = Vec::new();
    for attempt in 1..=cfg.retries + 1 {
        update_shard(shared, manifest_path, k, |s| {
            s.status = ShardStatus::Running;
            s.attempts += 1;
        })?;
        // Only the very first attempt of a fresh run starts a fresh
        // spill; retries and resumed runs reuse what is already on disk.
        let child_resume = cfg.resume || attempt > 1;
        if cfg.verbose {
            println!(
                "{label} launching (attempt {attempt}/{}{})",
                cfg.retries + 1,
                if child_resume { ", resuming spill" } else { "" }
            );
        }
        let mut cmd = shard_command(cfg, &shard_dir, k, child_resume);
        let spawned = proc::run_streaming_lines(&mut cmd, &mut |line, is_err| {
            if is_err {
                eprintln!("{label} {line}");
            } else if cfg.verbose {
                println!("{label} {line}");
            }
        });
        let (outcome, code, tail) = match spawned {
            Err(e) => (Err(e), None, Vec::new()),
            Ok((status, tail)) => {
                let code = status.code();
                if status.success() {
                    // Exit 0 must be backed by a complete spill.
                    let cells = shard_dir.join(CELLS_FILE);
                    match sweep_stream::scan_done(&cells, &cfg.spec, &shard) {
                        Err(e) => {
                            (Err(format!("exit 0 but the spill is unreadable: {e}")), code, tail)
                        }
                        Ok(done) if shard_complete(&done, &shard) => (Ok(()), code, tail),
                        Ok(done) => {
                            let owned = shard.owned_count(done.len());
                            let have =
                                (0..done.len()).filter(|&i| shard.owns(i) && done[i]).count();
                            (
                                Err(format!(
                                    "exit 0 but {cells:?} records only {have} of {owned} owned \
                                     cells — did the launcher return before the shard finished?"
                                )),
                                code,
                                tail,
                            )
                        }
                    }
                } else {
                    let why = match code {
                        Some(c) => format!("exit code {c}"),
                        None => "killed by signal".to_string(),
                    };
                    (Err(why), code, tail)
                }
            }
        };
        match outcome {
            Ok(()) => {
                update_shard(shared, manifest_path, k, |s| {
                    s.status = ShardStatus::Done;
                    s.exit_code = code;
                    s.stderr_tail.clear();
                })?;
                if cfg.verbose {
                    println!("{label} done (attempt {attempt})");
                }
                return Ok(());
            }
            Err(why) => {
                eprintln!("{label} attempt {attempt}/{} failed: {why}", cfg.retries + 1);
                last_failure = why;
                last_code = code;
                last_tail = tail;
                update_shard(shared, manifest_path, k, |s| {
                    s.exit_code = last_code;
                    s.stderr_tail = last_tail.clone();
                })?;
            }
        }
    }
    update_shard(shared, manifest_path, k, |s| {
        s.status = ShardStatus::Failed;
    })?;
    let mut detail = format!(
        "  shard {shard}: {last_failure} after {} attempt(s)",
        cfg.retries + 1
    );
    if last_tail.is_empty() {
        detail.push_str(" (no stderr output)");
    } else {
        detail.push_str("; stderr tail:");
        for line in &last_tail {
            detail.push_str("\n    ");
            detail.push_str(line);
        }
    }
    Err(detail)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::azure::Workload;

    fn tiny_spec() -> SweepSpec {
        SweepSpec {
            rates: vec![5.0],
            core_counts: vec![8],
            policies: vec!["linux".into(), "proposed".into()],
            workloads: vec![Workload::Mixed],
            replicas: 1,
            duration_s: 2.0,
            n_prompt: 1,
            n_token: 1,
            seed: 31,
            fleet: None,
            lifecycle: None,
        }
    }

    fn scratch(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("carbon_sim_orchestrate_unit").join(name);
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn manifest_roundtrips_through_disk() {
        let spec = tiny_spec();
        let dir = scratch("roundtrip");
        let path = dir.join(MANIFEST_FILE);
        let mut m = Manifest::fresh(&spec, 3);
        m.shards[0].status = ShardStatus::Done;
        m.shards[0].attempts = 1;
        m.shards[0].exit_code = Some(0);
        m.shards[1].status = ShardStatus::Failed;
        m.shards[1].attempts = 3;
        m.shards[1].exit_code = Some(7);
        m.shards[1].stderr_tail = vec!["boom".into(), "again".into()];
        m.write(&path).unwrap();

        let back = Manifest::load(&path, &spec, 3).unwrap();
        assert_eq!(back.spec_hash, spec.spec_hash());
        assert_eq!(back.n_cells, spec.n_cells());
        assert_eq!(back.shard_count, 3);
        assert_eq!(back.shards[0].status, ShardStatus::Done);
        assert_eq!(back.shards[0].exit_code, Some(0));
        assert_eq!(back.shards[1].status, ShardStatus::Failed);
        assert_eq!(back.shards[1].attempts, 3);
        assert_eq!(back.shards[1].exit_code, Some(7));
        assert_eq!(back.shards[1].stderr_tail, vec!["boom", "again"]);
        assert_eq!(back.shards[2].status, ShardStatus::Pending);
        // The manifest is self-describing: the embedded spec round-trips
        // through the config parser to the same hash.
        let rebuilt = crate::config::sweep_from_value(&back.spec).unwrap();
        assert_eq!(rebuilt.spec_hash(), spec.spec_hash());
    }

    #[test]
    fn manifest_load_refuses_identity_mismatches() {
        let spec = tiny_spec();
        let dir = scratch("mismatch");
        let path = dir.join(MANIFEST_FILE);
        Manifest::fresh(&spec, 2).write(&path).unwrap();

        let mut other = tiny_spec();
        other.seed = 32;
        let err = Manifest::load(&path, &other, 2).unwrap_err();
        assert!(err.contains("spec hash"), "{err}");

        let err2 = Manifest::load(&path, &spec, 3).unwrap_err();
        assert!(err2.contains("2 shards"), "{err2}");
        assert!(err2.contains("--shards 2"), "{err2}");

        fs::write(&path, "{\"kind\": \"something-else\"}\n").unwrap();
        let err3 = Manifest::load(&path, &spec, 2).unwrap_err();
        assert!(err3.contains("not an orchestrate manifest"), "{err3}");
    }

    #[test]
    fn manifest_load_rejects_corrupt_fields() {
        let spec = tiny_spec();
        let dir = scratch("corrupt");
        let path = dir.join(MANIFEST_FILE);
        Manifest::fresh(&spec, 2).write(&path).unwrap();
        let body = fs::read_to_string(&path).unwrap();
        let poisoned = body.replace("\"pending\"", "\"exploded\"");
        assert_ne!(poisoned, body);
        fs::write(&path, poisoned).unwrap();
        let err = Manifest::load(&path, &spec, 2).unwrap_err();
        assert!(err.contains("exploded"), "{err}");
    }

    #[test]
    fn shard_complete_checks_only_owned_cells() {
        let shard = ShardSpec::new(1, 2).unwrap();
        // 4-cell grid: shard 1/2 owns cells 1 and 3.
        assert!(shard_complete(&[false, true, false, true], &shard));
        assert!(!shard_complete(&[true, true, true, false], &shard));
        assert!(shard_complete(&[true; 4], &shard));
    }

    #[test]
    fn fresh_run_refuses_an_existing_manifest_without_resume() {
        let spec = tiny_spec();
        let dir = scratch("no_resume");
        Manifest::fresh(&spec, 2).write(&dir.join(MANIFEST_FILE)).unwrap();
        let cfg = OrchestrateConfig {
            spec: spec.clone(),
            spec_path: dir.join("spec.json"),
            shards: 2,
            workers: 0,
            retries: 0,
            threads_per_shard: 1,
            format: Format::Json,
            launcher: None,
            program: PathBuf::from("/nonexistent"),
            resume: false,
            verbose: false,
        };
        let err = run(&cfg, &dir).unwrap_err();
        assert!(err.contains("--resume"), "{err}");
    }
}
