//! `carbon-sim bench` — the pinned perf matrix that tracks simulator
//! throughput (simulated events per wall-clock second) from PR 2 onward.
//!
//! The matrix is deliberately small and *pinned*: short/long traces ×
//! 40/80-core machines × every policy, fixed seeds, fixed machine counts —
//! so `BENCH_<date>.json` files are comparable across commits. Cells run
//! **sequentially** on one thread: the number under test is the hot-path
//! cost per event, not pool scheduling.
//!
//! `--quick` shrinks durations and machine counts (keeping the matrix
//! shape) for the CI smoke job, which uploads the JSON as an artifact so
//! every PR leaves a perf record.

use crate::cluster::{Cluster, ClusterConfig};
use crate::policy::ALL_POLICIES;
use crate::sim::{QueueKind, QueueStats};
use crate::trace::azure::{AzureTraceGen, TraceParams, Workload};
use crate::trace::Trace;
use crate::util::json::Value;

/// Root seed of every bench cell — pinned so the matrix is identical
/// across commits.
pub const BENCH_SEED: u64 = 0xBE7C;

/// One pinned cell of the bench matrix.
#[derive(Clone, Debug)]
pub struct BenchScenario {
    /// Trace label: "short" | "long".
    pub trace: &'static str,
    pub rate_rps: f64,
    pub duration_s: f64,
    pub cores: usize,
    pub policy: &'static str,
}

/// The per-trace axes of the matrix: (label, rate rps, duration s).
fn trace_axes(quick: bool) -> Vec<(&'static str, f64, f64)> {
    if quick {
        vec![("short", 20.0, 3.0), ("long", 20.0, 6.0)]
    } else {
        vec![("short", 60.0, 30.0), ("long", 60.0, 120.0)]
    }
}

/// Expand the pinned matrix: traces × 40/80 cores × all policies.
pub fn matrix(quick: bool) -> Vec<BenchScenario> {
    let mut out = Vec::new();
    for &(label, rate, dur) in &trace_axes(quick) {
        for &cores in &[40usize, 80] {
            for &policy in ALL_POLICIES.iter() {
                out.push(BenchScenario {
                    trace: label,
                    rate_rps: rate,
                    duration_s: dur,
                    cores,
                    policy,
                });
            }
        }
    }
    out
}

/// A finished bench cell.
#[derive(Clone, Debug)]
pub struct BenchCellResult {
    pub scenario: BenchScenario,
    pub events: u64,
    pub wall_s: f64,
    pub completed: usize,
    pub sim_duration_s: f64,
    /// Event-queue counters for the cell (identical under either queue
    /// implementation; recorded so CI artifacts track scheduler
    /// behavior across commits).
    pub queue: QueueStats,
}

impl BenchCellResult {
    pub fn events_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.events as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn to_json(&self) -> Value {
        let s = &self.scenario;
        Value::obj(vec![
            ("trace", s.trace.into()),
            ("rate_rps", s.rate_rps.into()),
            ("duration_s", s.duration_s.into()),
            ("cores", s.cores.into()),
            ("policy", s.policy.into()),
            ("events", (self.events as f64).into()),
            ("wall_s", self.wall_s.into()),
            ("events_per_s", self.events_per_s().into()),
            ("completed", self.completed.into()),
            ("sim_duration_s", self.sim_duration_s.into()),
            ("peak_queue_len", self.queue.peak_len.into()),
            ("queue_pushes", (self.queue.pushes as f64).into()),
            ("queue_clamped", (self.queue.clamped as f64).into()),
        ])
    }
}

/// The aggregated bench report.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub quick: bool,
    /// Queue implementation the matrix ran under (recorded in the JSON;
    /// throughput numbers are only comparable within one kind).
    pub queue: QueueKind,
    pub cells: Vec<BenchCellResult>,
}

impl BenchReport {
    pub fn total_events(&self) -> u64 {
        self.cells.iter().map(|c| c.events).sum()
    }

    pub fn total_wall_s(&self) -> f64 {
        self.cells.iter().map(|c| c.wall_s).sum()
    }

    /// Matrix-level throughput: total events / total wall — the headline
    /// number the perf trajectory tracks.
    pub fn events_per_s(&self) -> f64 {
        let wall = self.total_wall_s();
        if wall > 0.0 {
            self.total_events() as f64 / wall
        } else {
            0.0
        }
    }

    /// The report as one JSON document (schema documented in
    /// `docs/output-schemas.md`, versioned by
    /// [`super::OUTPUT_SCHEMA_VERSION`]).
    pub fn to_json(&self, date: &str) -> Value {
        Value::obj(vec![
            ("date", date.into()),
            ("quick", self.quick.into()),
            ("queue", self.queue.name().into()),
            ("schema_version", super::OUTPUT_SCHEMA_VERSION.into()),
            ("seed", format!("{BENCH_SEED}").into()),
            ("n_cells", self.cells.len().into()),
            ("total_events", (self.total_events() as f64).into()),
            ("total_wall_s", self.total_wall_s().into()),
            ("events_per_s", self.events_per_s().into()),
            ("cells", Value::Arr(self.cells.iter().map(|c| c.to_json()).collect())),
        ])
    }

    pub fn print_table(&self) {
        println!(
            "{:<6} {:>6} {:>5} {:<12} {:>11} {:>8} {:>13}",
            "trace", "dur(s)", "cores", "policy", "events", "wall(s)", "events/s"
        );
        for c in &self.cells {
            let s = &c.scenario;
            println!(
                "{:<6} {:>6.0} {:>5} {:<12} {:>11} {:>8.3} {:>13.0}",
                s.trace,
                s.duration_s,
                s.cores,
                s.policy,
                c.events,
                c.wall_s,
                c.events_per_s()
            );
        }
        println!(
            "total: {} events in {:.2} s wall -> {:.0} events/s",
            self.total_events(),
            self.total_wall_s(),
            self.events_per_s()
        );
    }
}

/// Run one cell against a pre-generated trace.
fn run_cell(sc: &BenchScenario, trace: &Trace, quick: bool, queue: QueueKind) -> BenchCellResult {
    let (n_prompt, n_token) = if quick { (1, 2) } else { (5, 17) };
    let cfg = ClusterConfig {
        n_prompt,
        n_token,
        cores_per_cpu: sc.cores,
        policy: sc.policy.into(),
        seed: BENCH_SEED,
        queue,
        ..ClusterConfig::default()
    };
    // `Cluster::run` is wall-clock-free; the bench harness is the timing
    // caller, so the cell's wall time is stamped here.
    let mut cluster = Cluster::new(cfg);
    let wall_start = std::time::Instant::now();
    let result = cluster.run(trace);
    let wall_s = wall_start.elapsed().as_secs_f64();
    BenchCellResult {
        scenario: sc.clone(),
        events: result.events_processed,
        wall_s,
        completed: result.completed_requests,
        sim_duration_s: result.duration_s,
        queue: result.queue,
    }
}

/// Run the full pinned matrix sequentially under `queue`.
pub fn run(quick: bool, queue: QueueKind) -> BenchReport {
    // One trace per label, shared by every (cores, policy) cell of that
    // row — pinned workload, and trace synthesis stays out of the timings.
    // The xor decorrelates the trace RNG stream from the cluster's, like
    // the sweep engine's TRACE_SEED_XOR.
    let mut cells = Vec::new();
    for &(label, rate, dur) in &trace_axes(quick) {
        let trace = AzureTraceGen::new(TraceParams {
            rate_rps: rate,
            duration_s: dur,
            workload: Workload::Mixed,
            seed: BENCH_SEED ^ 0x7AC3_5EED,
        })
        .generate();
        for sc in matrix(quick).into_iter().filter(|s| s.trace == label) {
            cells.push(run_cell(&sc, &trace, quick, queue));
        }
    }
    BenchReport { quick, queue, cells }
}

/// `YYYY-MM-DD` (UTC) from a Unix timestamp — no chrono offline, so this
/// is the standard days-to-civil conversion (Howard Hinnant's algorithm).
pub fn utc_date_string(unix_secs: u64) -> String {
    let days = (unix_secs / 86_400) as i64;
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let mut y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    if m <= 2 {
        y += 1;
    }
    format!("{y:04}-{m:02}-{d:02}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_shape_is_pinned() {
        for quick in [false, true] {
            let m = matrix(quick);
            // 2 traces × 2 core counts × |policies|.
            assert_eq!(m.len(), 2 * 2 * ALL_POLICIES.len());
            assert!(m.iter().any(|s| s.trace == "short" && s.cores == 40));
            assert!(m.iter().any(|s| s.trace == "long" && s.cores == 80));
        }
    }

    #[test]
    fn quick_run_produces_wellformed_report() {
        let report = run(true, QueueKind::default());
        assert_eq!(report.cells.len(), matrix(true).len());
        for c in &report.cells {
            assert!(c.events > 0, "{:?} processed no events", c.scenario);
            assert!(c.completed > 0);
            assert!(c.sim_duration_s > 0.0);
            assert!(c.queue.pushes > 0 && c.queue.peak_len > 0);
        }
        assert!(report.events_per_s() > 0.0);
        let json = report.to_json("2026-01-01");
        let parsed =
            crate::util::json::parse(&json.to_string_pretty()).expect("bench JSON parses");
        assert_eq!(parsed.usize_or("n_cells", 0), report.cells.len());
        assert_eq!(parsed.usize_or("schema_version", 0), crate::experiments::OUTPUT_SCHEMA_VERSION);
        assert!(parsed.f64_or("events_per_s", 0.0) > 0.0);
        assert_eq!(parsed.get("queue").and_then(Value::as_str), Some("calendar"));
        let cells = match parsed.get("cells") {
            Some(Value::Arr(cells)) => cells,
            other => panic!("cells should be an array, got {other:?}"),
        };
        for c in cells {
            assert!(c.usize_or("peak_queue_len", 0) > 0);
            assert!(c.f64_or("queue_pushes", 0.0) > 0.0);
            assert!(c.f64_or("queue_clamped", -1.0) >= 0.0);
        }
    }

    #[test]
    fn queue_kinds_agree_on_event_counts_and_stats() {
        // Wall times differ (that's the point of the bench); every
        // seed-deterministic field must not.
        let h = run(true, QueueKind::Heap);
        let c = run(true, QueueKind::Calendar);
        assert_eq!(h.cells.len(), c.cells.len());
        for (a, b) in h.cells.iter().zip(c.cells.iter()) {
            assert_eq!(a.events, b.events, "{:?}", a.scenario);
            assert_eq!(a.completed, b.completed, "{:?}", a.scenario);
            assert_eq!(a.sim_duration_s, b.sim_duration_s, "{:?}", a.scenario);
            assert_eq!(a.queue, b.queue, "{:?}", a.scenario);
        }
    }

    #[test]
    fn date_conversion_known_values() {
        assert_eq!(utc_date_string(0), "1970-01-01");
        assert_eq!(utc_date_string(86_400), "1970-01-02");
        // 2000-03-01 00:00:00 UTC = 951868800 (leap-century boundary).
        assert_eq!(utc_date_string(951_868_800), "2000-03-01");
        // 2026-07-26 00:00:00 UTC = 20660 days × 86400.
        assert_eq!(utc_date_string(20_660 * 86_400), "2026-07-26");
    }
}
