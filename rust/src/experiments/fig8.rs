//! Fig. 8 — "Comparison of utilization of available cores for running
//! tasks": distributions of **normalized idle CPU cores**
//! `(active − running_tasks)/N` sampled across the cluster.
//!
//! Positive = underutilization (active cores with nothing pinned),
//! negative = oversubscription. Expected shape: baselines pile up near
//! +1.0 (p1–p90 close to 1); the proposed technique sits near 0 — at
//! least a 77 % smaller p90 — with bounded oversubscription (p1 ≥ −0.1).

use super::PairedCell;
use crate::policy::ALL_POLICIES;
use crate::util::stats::{Histogram, Summary};

#[derive(Clone, Debug)]
pub struct Fig8Row {
    pub cores: usize,
    pub rate: f64,
    pub policy: String,
    /// Distribution of pooled normalized-idle samples.
    pub idle: Summary,
    /// Text-mode violin over [−0.2, 1.0].
    pub sparkline: String,
}

pub fn rows(cells: &[PairedCell]) -> Vec<Fig8Row> {
    let mut out = Vec::new();
    for cell in cells {
        for &pol in &ALL_POLICIES {
            let samples = cell.result(pol).pooled_idle_samples();
            let mut h = Histogram::new(-0.2, 1.0, 48);
            for &s in &samples {
                h.add(s);
            }
            out.push(Fig8Row {
                cores: cell.cores,
                rate: cell.rate,
                policy: pol.to_string(),
                idle: Summary::of(&samples),
                sparkline: h.sparkline(),
            });
        }
    }
    out
}

pub fn print(rows: &[Fig8Row]) {
    println!("\nFig 8 — normalized idle cores (negative = oversubscription)");
    println!(
        "{:<8} {:<8} {:<12} {:>8} {:>8} {:>8} {:>8} {:>8}  {}",
        "cores", "rate", "policy", "p1", "p50", "p90", "p99", "mean", "distribution [-0.2 .. 1.0]"
    );
    for r in rows {
        println!(
            "{:<8} {:<8} {:<12} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>8.3}  |{}|",
            r.cores, r.rate, r.policy, r.idle.p1, r.idle.p50, r.idle.p90, r.idle.p99, r.idle.mean,
            r.sparkline
        );
    }
}

/// Shape checks for the paper's claims.
pub fn check_shape(rows: &[Fig8Row]) -> Vec<String> {
    let mut violations = Vec::new();
    for r in rows {
        match r.policy.as_str() {
            "linux" | "least-aged" => {
                // No oversubscription; heavy underutilization.
                if r.idle.p1 < 0.0 {
                    violations.push(format!("{} oversubscribed (p1={})", r.policy, r.idle.p1));
                }
                if r.idle.p90 < 0.5 {
                    violations.push(format!(
                        "{} p90={:.3} not near 1.0 at cores={} rate={}",
                        r.policy, r.idle.p90, r.cores, r.rate
                    ));
                }
            }
            "proposed" => {
                let linux = rows
                    .iter()
                    .find(|x| x.cores == r.cores && x.rate == r.rate && x.policy == "linux")
                    .unwrap();
                // ≥77% underutilization reduction at p90 (paper: ≥77.8%).
                if r.idle.p90 > linux.idle.p90 * 0.35 {
                    violations.push(format!(
                        "proposed p90={:.3} not ≪ linux p90={:.3} (cores={} rate={})",
                        r.idle.p90, linux.idle.p90, r.cores, r.rate
                    ));
                }
                // Oversubscription bounded: p1 ≥ −0.1 ("below 10%").
                if r.idle.p1 < -0.101 {
                    violations.push(format!(
                        "proposed oversubscription p1={:.3} exceeds 10% (cores={} rate={})",
                        r.idle.p1, r.cores, r.rate
                    ));
                }
            }
            _ => {}
        }
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::{run_matrix, Scale};

    #[test]
    fn smoke_scale_idle_distributions() {
        let mut scale = Scale::smoke();
        scale.duration_s = 30.0;
        scale.rates = vec![8.0];
        scale.core_counts = vec![16];
        let cells = run_matrix(&scale);
        let rows = rows(&cells);
        assert_eq!(rows.len(), 3);
        let violations = check_shape(&rows);
        assert!(violations.is_empty(), "{violations:?}");
    }
}
