//! Fig. 7 — "Comparison of estimated yearly CPU embodied carbon reduction
//! in the cluster through management of CPU aging effects".
//!
//! Takes the mean-frequency-degradation percentiles from the Fig. 6 runs,
//! maps them to a lifetime extension vs the linux baseline with the
//! linear model (3-year refresh, 278.3 kgCO₂eq per server CPU complex),
//! and reports yearly cluster emissions. Paper headline: the proposed
//! technique cuts yearly CPU embodied emissions **37.67 % at p99**
//! (49.01 % at p50).

use super::PairedCell;
use crate::carbon::EmbodiedModel;
use crate::policy::ALL_POLICIES;
use crate::util::stats;

#[derive(Clone, Debug)]
pub struct Fig7Row {
    pub cores: usize,
    pub rate: f64,
    pub policy: String,
    /// Yearly cluster emissions (kgCO₂eq/yr) estimated at p99 / p50 of
    /// per-machine mean frequency degradation.
    pub yearly_kg_p99: f64,
    pub yearly_kg_p50: f64,
    /// Percent reduction vs the linux baseline at each percentile.
    pub reduction_pct_p99: f64,
    pub reduction_pct_p50: f64,
    /// Implied refresh-cycle length (years) at p99.
    pub lifetime_yr_p99: f64,
}

pub fn rows(cells: &[PairedCell], model: &EmbodiedModel) -> Vec<Fig7Row> {
    let mut out = Vec::new();
    for cell in cells {
        let n_machines = cell.results[0].f0.len();
        let linux_fred = cell.result("linux").mean_fred_per_machine();
        for &pol in &ALL_POLICIES {
            let fred = cell.result(pol).mean_fred_per_machine();
            let mut row = Fig7Row {
                cores: cell.cores,
                rate: cell.rate,
                policy: pol.to_string(),
                yearly_kg_p99: 0.0,
                yearly_kg_p50: 0.0,
                reduction_pct_p99: 0.0,
                reduction_pct_p50: 0.0,
                lifetime_yr_p99: 0.0,
            };
            for &(pct, is99) in &[(99.0, true), (50.0, false)] {
                let base_p = stats::percentile(&linux_fred, pct);
                let tech_p = stats::percentile(&fred, pct);
                let yearly = model.yearly_kg_for(base_p, tech_p) * n_machines as f64;
                let reduction = model.reduction_pct(base_p, tech_p);
                if is99 {
                    row.yearly_kg_p99 = yearly;
                    row.reduction_pct_p99 = reduction;
                    row.lifetime_yr_p99 = model.extended_lifetime_yr(base_p, tech_p);
                } else {
                    row.yearly_kg_p50 = yearly;
                    row.reduction_pct_p50 = reduction;
                }
            }
            out.push(row);
        }
    }
    out
}

pub fn print(rows: &[Fig7Row]) {
    println!("\nFig 7 — yearly cluster CPU embodied emissions (kgCO2eq/yr)");
    println!(
        "{:<8} {:<8} {:<12} {:>14} {:>14} {:>12} {:>12} {:>12}",
        "cores", "rate", "policy", "yearly@p99", "yearly@p50", "red%@p99", "red%@p50", "life_yr@p99"
    );
    for r in rows {
        println!(
            "{:<8} {:<8} {:<12} {:>14.2} {:>14.2} {:>12.2} {:>12.2} {:>12.2}",
            r.cores,
            r.rate,
            r.policy,
            r.yearly_kg_p99,
            r.yearly_kg_p50,
            r.reduction_pct_p99,
            r.reduction_pct_p50,
            r.lifetime_yr_p99
        );
    }
}

/// Shape checks: proposed saves substantially; least-aged saves little.
pub fn check_shape(rows: &[Fig7Row]) -> Vec<String> {
    let mut violations = Vec::new();
    for r in rows {
        match r.policy.as_str() {
            "linux" => {
                if r.reduction_pct_p99.abs() > 1e-6 {
                    violations.push(format!("linux must be the 0% reference, got {r:?}"));
                }
            }
            "proposed" => {
                // p50 is stable across cluster sizes; p99 needs the full
                // 22-machine cluster to be meaningful (checked at paper
                // scale by the fig7 bench / integration test).
                if r.reduction_pct_p50 < 10.0 {
                    violations.push(format!(
                        "cores={} rate={}: proposed reduction {:.2}%@p50 too small",
                        r.cores, r.rate, r.reduction_pct_p50
                    ));
                }
            }
            "least-aged" => {
                // "minimal when compared to linux" — well below proposed.
                let prop = rows
                    .iter()
                    .find(|x| x.cores == r.cores && x.rate == r.rate && x.policy == "proposed")
                    .unwrap();
                if r.reduction_pct_p99 > prop.reduction_pct_p99 * 0.8 {
                    violations.push(format!(
                        "cores={} rate={}: least-aged {:.2}% not minimal vs proposed {:.2}%",
                        r.cores, r.rate, r.reduction_pct_p99, prop.reduction_pct_p99
                    ));
                }
            }
            _ => {}
        }
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::{run_matrix, Scale};

    #[test]
    fn smoke_scale_reductions() {
        let mut scale = Scale::smoke();
        scale.duration_s = 20.0;
        scale.rates = vec![8.0];
        let cells = run_matrix(&scale);
        let rows = rows(&cells, &EmbodiedModel::paper_default());
        assert_eq!(rows.len(), 3);
        let violations = check_shape(&rows);
        assert!(violations.is_empty(), "{violations:?}");
        // Proposed's implied lifetime must exceed the 3-year baseline.
        let prop = rows.iter().find(|r| r.policy == "proposed").unwrap();
        assert!(prop.lifetime_yr_p99 > 3.0);
    }
}
