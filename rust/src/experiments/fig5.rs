//! Fig. 5 — "Behavior of the piecewise Reaction Function (F) for
//! utilization of the CPU": the F(e) curve over e ∈ [−1, 1].

use crate::policy::ReactionFunction;

#[derive(Clone, Copy, Debug)]
pub struct Fig5Point {
    pub e: f64,
    pub f: f64,
}

pub fn run(steps: usize) -> Vec<Fig5Point> {
    let rf = ReactionFunction::default();
    (0..=steps)
        .map(|i| {
            let e = -1.0 + 2.0 * i as f64 / steps as f64;
            Fig5Point { e, f: rf.eval(e) }
        })
        .collect()
}

pub fn print(points: &[Fig5Point]) {
    println!("\nFig 5 — reaction function F(e)");
    println!("{:>8} {:>10}  curve", "e", "F(e)");
    for p in points {
        let col = ((p.f + 1.0) / 2.0 * 60.0) as usize;
        let mut line = vec![' '; 61];
        line[30] = '|';
        line[col.min(60)] = '*';
        println!("{:>8.3} {:>10.4}  {}", p.e, p.f, line.iter().collect::<String>());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_spans_domain_and_range() {
        let pts = run(40);
        assert_eq!(pts.len(), 41);
        assert!((pts[0].e + 1.0).abs() < 1e-12);
        assert!((pts.last().unwrap().e - 1.0).abs() < 1e-12);
        assert!(pts[0].f < -0.99);
        assert!(pts.last().unwrap().f > 0.99);
        // Midpoint is zero.
        let mid = &pts[20];
        assert!(mid.e.abs() < 1e-12 && mid.f.abs() < 1e-12);
    }

    #[test]
    fn asymmetry_visible_in_curve() {
        let pts = run(200);
        // At |e| = 0.2, the oversubscription side reacts harder.
        let pos = pts.iter().find(|p| (p.e - 0.2).abs() < 1e-9).unwrap();
        let neg = pts.iter().find(|p| (p.e + 0.2).abs() < 1e-9).unwrap();
        assert!(neg.f.abs() > pos.f.abs());
    }
}
