//! Fig. 1 — "Carbon footprint of A100x4 GPU server running per second
//! inference application when powered by energy sources with different
//! carbon intensity": yearly operational vs embodied carbon per energy
//! source, showing CPU embodied dominating under renewables.

use crate::carbon::{grid_intensities, ServerPowerModel};

#[derive(Clone, Debug)]
pub struct Fig1Row {
    pub source: &'static str,
    pub ci_g_per_kwh: f64,
    pub operational_kg_yr: f64,
    pub cpu_embodied_kg_yr: f64,
    pub gpu_embodied_kg_yr: f64,
    pub other_embodied_kg_yr: f64,
    pub cpu_share: f64,
}

pub fn run(model: &ServerPowerModel) -> Vec<Fig1Row> {
    grid_intensities()
        .into_iter()
        .map(|(source, ci)| {
            let (cpu, gpu, other) = model.yearly_embodied_kg();
            Fig1Row {
                source,
                ci_g_per_kwh: ci,
                operational_kg_yr: model.yearly_operational_kg(ci),
                cpu_embodied_kg_yr: cpu,
                gpu_embodied_kg_yr: gpu,
                other_embodied_kg_yr: other,
                cpu_share: model.cpu_embodied_share(ci),
            }
        })
        .collect()
}

pub fn print(rows: &[Fig1Row]) {
    println!("\nFig 1 — A100x4 server yearly carbon by energy source (kgCO2eq/yr)");
    println!(
        "{:<10} {:>10} {:>14} {:>14} {:>14} {:>14} {:>10}",
        "source", "gCO2/kWh", "operational", "cpu_embodied", "gpu_embodied", "other_embodied",
        "cpu_share"
    );
    for r in rows {
        println!(
            "{:<10} {:>10.0} {:>14.1} {:>14.1} {:>14.1} {:>14.1} {:>9.1}%",
            r.source,
            r.ci_g_per_kwh,
            r.operational_kg_yr,
            r.cpu_embodied_kg_yr,
            r.gpu_embodied_kg_yr,
            r.other_embodied_kg_yr,
            r.cpu_share * 100.0
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embodied_flat_operational_scales() {
        let rows = run(&ServerPowerModel::a100x4());
        assert_eq!(rows.len(), 6);
        for w in rows.windows(2) {
            assert!(w[1].operational_kg_yr > w[0].operational_kg_yr);
            assert_eq!(w[0].cpu_embodied_kg_yr, w[1].cpu_embodied_kg_yr);
        }
        // Under wind, CPU embodied share is substantial; under coal, tiny.
        assert!(rows[0].cpu_share > 0.25);
        assert!(rows.last().unwrap().cpu_share < 0.05);
    }
}
