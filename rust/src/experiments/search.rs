//! Adaptive sweep search (`carbon-sim sweep --search`): successive
//! halving over the scenario grid instead of exhausting it.
//!
//! An exhaustive sweep spends `replicas` seed replicas on every
//! (workload, cores, rate) scenario, even though most scenarios separate
//! the policies after two or three. The search runs the grid in
//! **rungs**: every unresolved scenario gets its replica target doubled
//! (min → 2·min → … → max), the missing cells of the rung are simulated
//! on the shared [`pool::run_streamed`] worker pool, and after each rung
//! a scenario is retired as soon as its policy ranking is statistically
//! settled — so the replica budget concentrates on the scenarios where
//! policies are genuinely close.
//!
//! **Why paired statistics work here:** every policy of a scenario runs
//! on the same derived seed ([`super::sweep::cell_seed`] excludes the
//! policy axis), i.e. the same trace and the same silicon sample. The
//! per-replica metric difference between two policies is therefore a
//! paired sample, and the common trace/silicon noise cancels — a
//! [`PairedDiff`] per adjacent pair of the ranking (Student-t CI on the
//! mean difference, exact sign test as the small-n fallback, exact ties
//! short-circuited) decides settlement at the configured confidence.
//!
//! **Spill compatibility:** searched cells stream to the same
//! `cells.jsonl` a plain streaming sweep writes — identical rows,
//! identical header plus one extra `search` object recording the search
//! configuration (ignored by every other reader). `--resume` picks an
//! interrupted search up losslessly, and because the rung ladder is a
//! pure function of the search config, a resumed search converges to a
//! `search.json` byte-identical to an uninterrupted run. A finished or
//! abandoned search directory can even be completed into a full
//! exhaustive grid later by a plain `sweep --resume --out-dir` on the
//! same spec.
//!
//! **Determinism:** metric values are keyed by cell index (never by
//! completion order) and per-cell seeds derive from indices, so rung
//! evaluations — and therefore `search.json` — are identical at any
//! `--threads` value.

use std::fs::{self, File, OpenOptions};
use std::io::{BufReader, Write};
use std::path::{Path, PathBuf};

use super::sweep::{run_cell_with_queue, ShardSpec, SweepSpec};
use super::sweep_stream::{self, CELLS_FILE};
use super::OUTPUT_SCHEMA_VERSION;
use crate::sim::QueueKind;
use crate::trace::azure::Workload;
use crate::util::json::{parse, Value};
use crate::util::pool;
use crate::util::stats::PairedDiff;

/// Search summary file name inside `--out-dir`.
pub const SEARCH_FILE: &str = "search.json";

/// Cell metrics the search can race on — every key of
/// [`crate::metrics::SimResult::to_json_summary`] that is a scalar
/// measurement (identity fields like `policy` or `seed` make no sense
/// as a ranking objective).
pub const METRIC_KEYS: &[&str] = &[
    "rate_achieved_rps",
    "ttft_p50_s",
    "ttft_p99_s",
    "e2e_p50_s",
    "e2e_p99_s",
    "fred_mean_ghz",
    "freq_cv_mean",
    "oversub_fraction",
    "idle_p50",
];

/// How the search races the grid (`search` block of a sweep spec, or
/// [`SearchConfig::defaults_for`] when the block is absent).
#[derive(Clone, Debug, PartialEq)]
pub struct SearchConfig {
    /// Confidence level for settlement decisions, in (0, 1).
    pub confidence: f64,
    /// Replicas of the first rung — every scenario gets at least these.
    pub min_replicas: usize,
    /// Replica budget per scenario; the exhaustive grid this search is
    /// racing against is the spec expanded at this replica count.
    pub max_replicas: usize,
    /// The cell metric whose per-scenario policy ranking is raced
    /// (one of [`METRIC_KEYS`]).
    pub metric: String,
}

impl SearchConfig {
    /// Defaults: 95% confidence, first rung of 3 replicas, budget =
    /// the spec's own `replicas` (floored to the minimum rung so the
    /// ladder is well-formed even for a `replicas: 1` spec).
    pub fn defaults_for(spec: &SweepSpec) -> SearchConfig {
        SearchConfig {
            confidence: 0.95,
            min_replicas: 3,
            max_replicas: spec.replicas.max(3),
            metric: "fred_mean_ghz".to_string(),
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(self.confidence > 0.0 && self.confidence < 1.0) {
            return Err(format!(
                "search: confidence must be in (0, 1), got {}",
                self.confidence
            ));
        }
        if self.min_replicas < 2 {
            return Err(format!(
                "search: min_replicas must be ≥ 2 (paired tests need two samples), got {}",
                self.min_replicas
            ));
        }
        if self.max_replicas < self.min_replicas {
            return Err(format!(
                "search: max_replicas ({}) must be ≥ min_replicas ({})",
                self.max_replicas, self.min_replicas
            ));
        }
        if !METRIC_KEYS.contains(&self.metric.as_str()) {
            return Err(format!(
                "search: unknown metric '{}' (one of: {})",
                self.metric,
                METRIC_KEYS.join(", ")
            ));
        }
        Ok(())
    }

    /// Canonical JSON — the `search` object of the spill header and of
    /// `search.json`. Also the identity a `--resume` verifies: resuming
    /// a search spill under a different search configuration would
    /// replay a different rung ladder and must be refused.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("confidence", self.confidence.into()),
            ("max_replicas", self.max_replicas.into()),
            ("metric", self.metric.as_str().into()),
            ("min_replicas", self.min_replicas.into()),
        ])
    }

    /// The grid the search races over — the base spec expanded at the
    /// full per-scenario replica budget. Its hash is the spill identity,
    /// so a plain `sweep --resume` on the same directory completes this
    /// exact grid.
    pub fn grid(&self, base: &SweepSpec) -> SweepSpec {
        SweepSpec { replicas: self.max_replicas, ..base.clone() }
    }
}

/// What a search run did (the CLI's summary line comes from this; the
/// durable record is `search.json`).
#[derive(Clone, Debug)]
pub struct SearchSummary {
    /// Base scenarios raced (grid scenarios / max_replicas).
    pub n_scenarios: usize,
    /// Scenarios whose ranking settled before the budget ran out.
    pub n_settled: usize,
    /// Cells simulated by this invocation.
    pub n_run: usize,
    /// Cells recovered from an existing spill (`--resume`).
    pub n_resumed: usize,
    /// Total cells on disk = the budget actually spent.
    pub n_cells_spent: usize,
    /// The exhaustive grid's cell count the spend compares against.
    pub n_cells_exhaustive: usize,
    pub cells_path: PathBuf,
    pub search_path: PathBuf,
}

/// One policy's pooled standing in a ranking.
struct RankEntry {
    policy: usize,
    mean: f64,
    n: u64,
}

/// Paired comparison of two ranking-adjacent policies.
struct PairEval {
    /// Policy with the lower metric mean (ties broken by spec order).
    lo: usize,
    /// Policy with the higher metric mean.
    hi: usize,
    diff: PairedDiff,
    resolved: bool,
}

/// A scenario's (or the pooled grid's) ranking evaluation.
struct Eval {
    ranking: Vec<RankEntry>,
    pairs: Vec<PairEval>,
    /// Every adjacent pair resolved (decisively separated or an exact
    /// tie) — replication of this scenario can stop.
    settled: bool,
    /// Replicas with every policy's cell recorded (resumed or run).
    replicas_done: usize,
}

/// Evaluate one scenario's ranking from its metric slice `m`, laid out
/// `m[k * n_policies + p]` for replicas `k = 0..m.len()/n_policies`.
/// `None` is a cell not yet simulated; non-finite metric values exclude
/// the whole replica from the statistics (pairing must stay balanced)
/// but still count as done.
fn evaluate(m: &[Option<f64>], n_policies: usize, confidence: f64) -> Eval {
    let n_reps = m.len() / n_policies;
    let done = |k: usize| (0..n_policies).all(|p| m[k * n_policies + p].is_some());
    let finite =
        |k: usize| (0..n_policies).all(|p| m[k * n_policies + p].is_some_and(f64::is_finite));
    let replicas_done = (0..n_reps).filter(|&k| done(k)).count();
    let usable: Vec<usize> = (0..n_reps).filter(|&k| finite(k)).collect();

    let mut ranking: Vec<RankEntry> = (0..n_policies)
        .map(|p| {
            let mut w = crate::util::stats::Welford::default();
            for &k in &usable {
                w.add(m[k * n_policies + p].unwrap());
            }
            let mean = if w.count() > 0 { w.mean() } else { f64::NAN };
            RankEntry { policy: p, mean, n: w.count() }
        })
        .collect();
    // total_cmp gives NaN a fixed sort position, and the spec-order
    // tie-break keeps the ranking deterministic under exact ties.
    ranking.sort_by(|a, b| a.mean.total_cmp(&b.mean).then(a.policy.cmp(&b.policy)));

    let mut settled = true;
    let mut pairs = Vec::with_capacity(n_policies.saturating_sub(1));
    for w in ranking.windows(2) {
        let (lo, hi) = (w[0].policy, w[1].policy);
        let mut diff = PairedDiff::default();
        for &k in &usable {
            diff.add(m[k * n_policies + hi].unwrap() - m[k * n_policies + lo].unwrap());
        }
        let resolved = diff.decisive(confidence) || diff.all_ties();
        if !resolved {
            settled = false;
        }
        pairs.push(PairEval { lo, hi, diff, resolved });
    }
    Eval { ranking, pairs, settled, replicas_done }
}

/// Decompose a base-scenario index (grid scenario / max_replicas) into
/// its axis coordinates — the same nesting as [`SweepSpec::cell`] with
/// the replica digit stripped.
fn base_coords(spec: &SweepSpec, b: usize) -> (Workload, usize, f64) {
    let mut s = b;
    let rate = spec.rates[s % spec.rates.len()];
    s /= spec.rates.len();
    let cores = spec.core_counts[s % spec.core_counts.len()];
    s /= spec.core_counts.len();
    (spec.workloads[s], cores, rate)
}

/// The extended spill header (compact, no trailing newline): the plain
/// unsharded sweep header plus a `search` object. Every non-search
/// reader ignores the extra key.
fn search_header_line(spec: &SweepSpec, cfg: &SearchConfig) -> String {
    let mut v = sweep_stream::header_value(spec, &ShardSpec::full());
    match &mut v {
        Value::Obj(o) => {
            o.insert("search".to_string(), cfg.to_json());
        }
        _ => unreachable!("header_value returns an object"),
    }
    v.to_string_compact()
}

/// Read the spill's first line; `Ok(None)` when the file is empty or the
/// header never landed (treat as a fresh spill, exactly like
/// [`sweep_stream::scan_and_compact`] would).
fn read_header_line(path: &Path) -> Result<Option<Vec<u8>>, String> {
    let file = File::open(path).map_err(|e| format!("opening {path:?}: {e}"))?;
    let mut r = BufReader::new(file);
    let mut buf = Vec::new();
    let (len, complete) = sweep_stream::read_line(&mut r, &mut buf)?;
    if len == 0 || !complete {
        return Ok(None);
    }
    Ok(Some(buf))
}

/// Verify a resumed spill was written by a search with this exact
/// configuration. Grid identity (spec hash, cell count, shard) is
/// checked separately by the compaction scan; this guards the rung
/// ladder itself.
fn check_search_header(line: &[u8], cfg: &SearchConfig, path: &Path) -> Result<(), String> {
    let text = std::str::from_utf8(line).map_err(|_| format!("{path:?}: header is not UTF-8"))?;
    let v = parse(text.trim_end())
        .map_err(|e| format!("{path:?}: header is not a JSON object: {e}"))?;
    match v.get("search") {
        None => Err(format!(
            "{path:?}: spill has no search configuration — it was written by a plain \
             sweep; resume it with `sweep --resume` (no --search) or use a fresh --out-dir"
        )),
        Some(rec) if *rec == cfg.to_json() => Ok(()),
        Some(rec) => Err(format!(
            "{path:?}: spill records search configuration {}, this run expects {} — \
             a different configuration replays a different rung ladder; use a fresh --out-dir",
            rec.to_string_compact(),
            cfg.to_json().to_string_compact()
        )),
    }
}

/// Load the per-cell metric values a compacted spill already records.
/// Every row counts as done; a missing or non-numeric metric field
/// becomes NaN (done, but excluded from the statistics).
fn load_metrics(path: &Path, n: usize, metric: &str) -> Result<Vec<Option<f64>>, String> {
    let mut metrics: Vec<Option<f64>> = vec![None; n];
    let file = File::open(path).map_err(|e| format!("opening {path:?}: {e}"))?;
    let mut r = BufReader::new(file);
    let mut buf = Vec::new();
    let (len, complete) = sweep_stream::read_line(&mut r, &mut buf)?;
    if len == 0 || !complete {
        return Ok(metrics);
    }
    loop {
        let (len, complete) = sweep_stream::read_line(&mut r, &mut buf)?;
        if len == 0 || !complete {
            break;
        }
        let Some(idx) = sweep_stream::row_index(&buf, n) else {
            break; // corrupt tail: resume compaction would drop it too
        };
        if metrics[idx].is_some() {
            continue; // first copy wins, like the compaction scan
        }
        let text = std::str::from_utf8(&buf)
            .map_err(|_| format!("{path:?}: spill row is not UTF-8"))?;
        let row = parse(text.trim_end()).map_err(|e| format!("{path:?}: spill row: {e}"))?;
        metrics[idx] = Some(row.get(metric).and_then(Value::as_f64).unwrap_or(f64::NAN));
    }
    Ok(metrics)
}

fn rank_json(spec: &SweepSpec, e: &Eval) -> Value {
    Value::Arr(
        e.ranking
            .iter()
            .map(|r| {
                Value::obj(vec![
                    ("mean", r.mean.into()),
                    ("n", (r.n as usize).into()),
                    ("policy", spec.policies[r.policy].as_str().into()),
                ])
            })
            .collect(),
    )
}

fn pairs_json(spec: &SweepSpec, e: &Eval, confidence: f64) -> Value {
    Value::Arr(
        e.pairs
            .iter()
            .map(|p| {
                let d = &p.diff;
                let mean_diff = if d.n() > 0 { d.mean() } else { f64::NAN };
                Value::obj(vec![
                    ("ci_half_width", d.ci_half_width(confidence).unwrap_or(f64::NAN).into()),
                    ("mean_diff", mean_diff.into()),
                    ("n", (d.n() as usize).into()),
                    ("policy_hi", spec.policies[p.hi].as_str().into()),
                    ("policy_lo", spec.policies[p.lo].as_str().into()),
                    ("resolved", p.resolved.into()),
                    ("sign_test_p", d.sign_test_p().into()),
                ])
            })
            .collect(),
    )
}

/// Race the grid. `base` is the spec as configured (its `replicas` value
/// seeds [`SearchConfig::defaults_for`] but the grid actually raced is
/// [`SearchConfig::grid`]); cells stream to `<out_dir>/cells.jsonl` and
/// the verdicts to `<out_dir>/search.json`.
#[allow(clippy::too_many_arguments)] // mirrors run_streaming_with
pub fn run_search(
    base: &SweepSpec,
    cfg: &SearchConfig,
    threads: usize,
    out_dir: &Path,
    resume: bool,
    verbose: bool,
    queue: QueueKind,
) -> Result<SearchSummary, String> {
    cfg.validate()?;
    let spec = cfg.grid(base);
    spec.validate()?;
    fs::create_dir_all(out_dir).map_err(|e| format!("creating {out_dir:?}: {e}"))?;
    let cells_path = out_dir.join(CELLS_FILE);
    let search_path = out_dir.join(SEARCH_FILE);

    let n = spec.n_cells();
    let n_policies = spec.policies.len();
    let n_bases = spec.n_scenarios() / cfg.max_replicas;
    let scen_stride = cfg.max_replicas * n_policies;

    // Fresh spill, or lossless resume of an interrupted search. The
    // compaction scan copies the original header line verbatim, so the
    // `search` extension survives it.
    let fresh_header = || -> Result<(), String> {
        let mut line = search_header_line(&spec, cfg);
        line.push('\n');
        fs::write(&cells_path, line).map_err(|e| format!("writing {cells_path:?}: {e}"))
    };
    let mut metrics: Vec<Option<f64>> = if resume && cells_path.exists() {
        match read_header_line(&cells_path)? {
            None => {
                // Killed before the header landed: no rows can follow.
                fresh_header()?;
                vec![None; n]
            }
            Some(line) => {
                check_search_header(&line, cfg, &cells_path)?;
                sweep_stream::scan_and_compact(&cells_path, &spec, &ShardSpec::full())?;
                load_metrics(&cells_path, n, &cfg.metric)?
            }
        }
    } else {
        fresh_header()?;
        vec![None; n]
    };
    let n_resumed = metrics.iter().filter(|m| m.is_some()).count();

    let mut spill = OpenOptions::new()
        .append(true)
        .open(&cells_path)
        .map_err(|e| format!("opening {cells_path:?}: {e}"))?;

    // The rung ladder: each unresolved scenario's replica target doubles
    // per round, capped at the budget. The ladder is a pure function of
    // the config and each rung's verdict a pure function of the metric
    // matrix, so an interrupted search replays to the same verdicts.
    let mut target = vec![cfg.min_replicas; n_bases];
    let mut resolved = vec![false; n_bases];
    let mut settled = vec![false; n_bases];
    let mut n_run = 0usize;
    let mut io_err: Option<String> = None;
    while !resolved.iter().all(|&r| r) {
        let pending: Vec<usize> = (0..n_bases)
            .filter(|&b| !resolved[b])
            .flat_map(|b| {
                let lo = b * scen_stride;
                (lo..lo + target[b] * n_policies).filter(|&i| metrics[i].is_none())
            })
            .collect();
        if !pending.is_empty() {
            pool::run_streamed(
                &pending,
                threads,
                |i| run_cell_with_queue(&spec, &spec.cell(i), queue),
                |i, res| {
                    let record = res.to_json();
                    let mut line = record.to_string_compact();
                    line.push('\n');
                    if let Err(e) = spill.write_all(line.as_bytes()) {
                        io_err = Some(format!("appending to {cells_path:?}: {e}"));
                        return false;
                    }
                    metrics[i] =
                        Some(record.get(&cfg.metric).and_then(Value::as_f64).unwrap_or(f64::NAN));
                    n_run += 1;
                    if verbose {
                        let c = &res.cell;
                        println!(
                            "[{} run] scenario {:>3} {:<12} {:>4}c {:>6.1} rps rep {} {:<12}",
                            n_run,
                            c.scenario,
                            c.workload.name(),
                            c.cores,
                            c.rate,
                            c.replica,
                            c.policy
                        );
                    }
                    true
                },
            );
            if let Some(e) = io_err.take() {
                return Err(e);
            }
        }
        for b in 0..n_bases {
            if resolved[b] {
                continue;
            }
            let lo = b * scen_stride;
            let e = evaluate(&metrics[lo..lo + target[b] * n_policies], n_policies, cfg.confidence);
            if e.settled {
                resolved[b] = true;
                settled[b] = true;
            } else if target[b] >= cfg.max_replicas {
                resolved[b] = true; // budget exhausted, still contested
            } else {
                target[b] = (target[b] * 2).min(cfg.max_replicas);
            }
        }
    }
    drop(spill);

    // Verdicts. Per-scenario evaluations re-run over each scenario's
    // final replica window; the grid-level ranking pools every usable
    // replica of every scenario (the full matrix has exactly the
    // required `[k][p]` layout when read scenario-by-scenario).
    let n_cells_spent = metrics.iter().filter(|m| m.is_some()).count();
    let mut scenarios = Vec::with_capacity(n_bases);
    for b in 0..n_bases {
        let lo = b * scen_stride;
        let e = evaluate(&metrics[lo..lo + target[b] * n_policies], n_policies, cfg.confidence);
        let (workload, cores, rate) = base_coords(&spec, b);
        scenarios.push(Value::obj(vec![
            ("cores", cores.into()),
            ("pairs", pairs_json(&spec, &e, cfg.confidence)),
            ("ranking", rank_json(&spec, &e)),
            ("rate_rps", rate.into()),
            ("replicas_budget", cfg.max_replicas.into()),
            ("replicas_run", e.replicas_done.into()),
            ("scenario", b.into()),
            ("settled", settled[b].into()),
            ("workload", workload.name().into()),
        ]));
    }
    let pooled = evaluate(&metrics, n_policies, cfg.confidence);
    let n_settled = settled.iter().filter(|&&s| s).count();

    let doc = Value::obj(vec![
        ("confidence", cfg.confidence.into()),
        ("kind", "sweep-search".into()),
        ("max_replicas", cfg.max_replicas.into()),
        ("metric", cfg.metric.as_str().into()),
        ("min_replicas", cfg.min_replicas.into()),
        ("n_cells_exhaustive", n.into()),
        ("n_cells_run", n_cells_spent.into()),
        ("n_scenarios", n_bases.into()),
        ("n_settled", n_settled.into()),
        ("pairs", pairs_json(&spec, &pooled, cfg.confidence)),
        ("ranking", rank_json(&spec, &pooled)),
        ("scenarios", Value::Arr(scenarios)),
        ("schema_version", OUTPUT_SCHEMA_VERSION.into()),
        ("spec", spec.to_json()),
        ("spec_hash", spec.spec_hash().as_str().into()),
    ]);
    let mut rendered = doc.to_string_pretty();
    rendered.push('\n');
    fs::write(&search_path, rendered).map_err(|e| format!("writing {search_path:?}: {e}"))?;

    Ok(SearchSummary {
        n_scenarios: n_bases,
        n_settled,
        n_run,
        n_resumed,
        n_cells_spent,
        n_cells_exhaustive: n,
        cells_path,
        search_path,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec2() -> SweepSpec {
        SweepSpec {
            rates: vec![4.0, 8.0],
            core_counts: vec![8, 16],
            policies: vec!["linux".into(), "proposed".into()],
            workloads: vec![Workload::Mixed],
            replicas: 1,
            duration_s: 3.0,
            n_prompt: 1,
            n_token: 1,
            seed: 11,
            fleet: None,
            lifecycle: None,
        }
    }

    #[test]
    fn defaults_are_valid_and_floor_the_budget() {
        let spec = spec2();
        let cfg = SearchConfig::defaults_for(&spec);
        assert!(cfg.validate().is_ok());
        assert_eq!(cfg.max_replicas, 3, "replicas: 1 spec floors the budget to min");
        let mut spec8 = spec2();
        spec8.replicas = 8;
        assert_eq!(SearchConfig::defaults_for(&spec8).max_replicas, 8);
    }

    #[test]
    fn validate_rejects_bad_configs() {
        let base = SearchConfig::defaults_for(&spec2());
        let mut c = base.clone();
        c.confidence = 1.0;
        assert!(c.validate().is_err());
        c.confidence = 0.0;
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.min_replicas = 1;
        assert!(c.validate().unwrap_err().contains("min_replicas"));
        let mut c = base.clone();
        c.max_replicas = 2;
        assert!(c.validate().unwrap_err().contains("max_replicas"));
        let mut c = base.clone();
        c.metric = "policy".into();
        assert!(c.validate().unwrap_err().contains("unknown metric"));
        c.metric = "ttft_p99_s".into();
        assert!(c.validate().is_ok());
    }

    #[test]
    fn grid_swaps_only_the_replica_count() {
        let base = spec2();
        let mut cfg = SearchConfig::defaults_for(&base);
        cfg.max_replicas = 5;
        let grid = cfg.grid(&base);
        assert_eq!(grid.replicas, 5);
        assert_eq!(grid.rates, base.rates);
        assert_eq!(grid.seed, base.seed);
        assert_ne!(grid.spec_hash(), base.spec_hash());
    }

    #[test]
    fn base_coords_match_cell_decomposition() {
        let mut spec = spec2();
        spec.replicas = 3;
        let n_bases = spec.n_scenarios() / spec.replicas;
        assert_eq!(n_bases, 4);
        for b in 0..n_bases {
            let (workload, cores, rate) = base_coords(&spec, b);
            for k in 0..spec.replicas {
                // First policy cell of (base, replica k).
                let cell = spec.cell((b * spec.replicas + k) * spec.policies.len());
                assert_eq!(cell.workload, workload);
                assert_eq!(cell.cores, cores);
                assert_eq!(cell.rate, rate);
                assert_eq!(cell.replica, k);
            }
        }
    }

    #[test]
    fn extended_header_is_a_valid_spill_header() {
        let spec = spec2();
        let cfg = SearchConfig::defaults_for(&spec);
        let line = search_header_line(&spec, &cfg);
        // Plain-sweep readers must parse it, ignoring the extension.
        let h = sweep_stream::parse_header(line.as_bytes(), Path::new("test")).unwrap();
        assert_eq!(h.spec_hash, spec.spec_hash());
        assert_eq!(h.n_cells, spec.n_cells());
        assert!(h.shard.is_full());
        // And the extension round-trips to exactly the config's JSON.
        let v = parse(&line).unwrap();
        assert_eq!(v.get("search"), Some(&cfg.to_json()));
        assert!(check_search_header(line.as_bytes(), &cfg, Path::new("test")).is_ok());
        let mut other = cfg.clone();
        other.confidence = 0.5;
        assert!(check_search_header(line.as_bytes(), &other, Path::new("test")).is_err());
    }

    // evaluate() on fabricated metric matrices: m[k * P + p].
    fn m(vals: &[f64]) -> Vec<Option<f64>> {
        vals.iter().map(|&v| Some(v)).collect()
    }

    #[test]
    fn evaluate_settles_clear_separation() {
        // Two policies, four replicas, policy 1 consistently ~1 lower.
        let mm = m(&[2.0, 1.0, 2.1, 1.05, 1.9, 0.95, 2.05, 1.0]);
        let e = evaluate(&mm, 2, 0.95);
        assert!(e.settled);
        assert_eq!(e.replicas_done, 4);
        assert_eq!(e.ranking.len(), 2);
        assert_eq!(e.ranking[0].policy, 1, "lower metric ranks first");
        assert_eq!(e.ranking[1].policy, 0);
        assert_eq!(e.pairs.len(), 1);
        assert!(e.pairs[0].resolved);
        assert!(e.pairs[0].diff.mean() > 0.0, "hi − lo must be positive");
    }

    #[test]
    fn evaluate_keeps_contested_scenarios_open() {
        // Sign flips around zero: nothing to settle.
        let mm = m(&[2.0, 1.0, 1.0, 2.0, 2.0, 1.0, 1.0, 2.0]);
        let e = evaluate(&mm, 2, 0.95);
        assert!(!e.settled);
        assert!(!e.pairs[0].resolved);
    }

    #[test]
    fn evaluate_settles_exact_ties() {
        let mm = m(&[1.5, 1.5, 2.5, 2.5, 0.5, 0.5]);
        let e = evaluate(&mm, 2, 0.95);
        assert!(e.settled, "identical policies must not burn the budget");
        assert!(e.pairs[0].diff.all_ties());
        // Tie-break: spec order.
        assert_eq!(e.ranking[0].policy, 0);
    }

    #[test]
    fn evaluate_excludes_nan_replicas_and_missing_cells() {
        let mut mm = m(&[2.0, 1.0, 2.1, 1.1, 2.2, 1.2, 2.05, 1.05]);
        mm[2] = Some(f64::NAN); // replica 1 poisoned
        mm[7] = None; // replica 3 not simulated yet
        let e = evaluate(&mm, 2, 0.95);
        assert_eq!(e.replicas_done, 3, "NaN is done, missing is not");
        assert_eq!(e.ranking[0].n, 2, "only finite complete replicas count");
        assert_eq!(e.pairs[0].diff.n(), 2);
    }

    #[test]
    fn evaluate_single_policy_is_trivially_settled() {
        let e = evaluate(&m(&[1.0, 2.0, 3.0]), 1, 0.95);
        assert!(e.settled);
        assert!(e.pairs.is_empty());
        assert_eq!(e.ranking.len(), 1);
    }

    #[test]
    fn evaluate_underpowered_scenario_stays_open() {
        // One replica: no test has power, decisive() needs n ≥ 2.
        let e = evaluate(&m(&[2.0, 1.0]), 2, 0.95);
        assert!(!e.settled);
    }
}
