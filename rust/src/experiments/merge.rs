//! Merge sharded sweep spills into one report (`carbon-sim merge`).
//!
//! A grid split with `sweep --shard K/N` leaves N `cells.jsonl` spills,
//! typically on N machines. [`merge_spills`] reassembles them — invoked
//! by hand, or automatically by [`super::orchestrate`] once its fleet
//! reports every shard `done`.
//!
//! # Validation contract
//!
//! A merge succeeds only when **all** of the following hold; every
//! refusal is a hard error naming the offending spill path or the cell
//! indexes involved (the full error→cause→fix table is in
//! `docs/distributed-sweeps.md`):
//!
//! 1. Every `<dir>/cells.jsonl` exists, starts with a complete
//!    `sweep-cells` header of the supported `schema_version`, and the
//!    first spill's header embeds a canonical `spec` that parses and
//!    hashes to its recorded `spec_hash` (spills are self-contained —
//!    the merging machine needs no `--spec` file).
//! 2. Every spill carries the same `spec_hash` and `n_cells` as the
//!    first: shards of different grids never mix.
//! 3. Together the spills cover the grid **disjointly and completely**:
//!    duplicate cell indexes (overlapping shard sets, or one shard
//!    passed twice) and missing indexes (a forgotten or unfinished
//!    shard) are each reported by index, capped at 16 shown.
//!
//! Within one spill, repeated rows for a cell keep the **first** copy
//! and a truncated or corrupt tail is dropped — exactly the rules
//! [`sweep_stream::scan_and_compact`] applies on resume, so a spill
//! reads the same whether it is resumed, merged, or verified by the
//! orchestrator ([`sweep_stream::scan_done`]).
//!
//! # Assembly
//!
//! The merged `<out-dir>/cells.jsonl` is written as an unsharded spill —
//! header from the spec embedded in the shard headers, rows copied
//! verbatim in cell-index order — and the report is assembled from it by
//! [`sweep_stream::assemble_report`]. Because cell seeds derive from
//! cell indexes (never execution order or machine), the resulting
//! `report.json`/`report.csv` is **byte-identical** to a single-machine
//! run of the full grid (pinned by `tests/sweep_shard.rs` and
//! `tests/orchestrate.rs`).

use std::fs::{self, File};
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::sweep::{Format, SweepSpec};
use super::sweep_stream::{
    self, parse_header, read_line, row_index, SpillHeader, CELLS_FILE,
};

/// What a merge did (the CLI's summary line).
#[derive(Clone, Debug)]
pub struct MergeSummary {
    /// Input spills merged.
    pub n_spills: usize,
    /// Cells in the reassembled grid.
    pub n_cells: usize,
    pub cells_path: PathBuf,
    pub report_path: PathBuf,
}

/// Cap index lists in error messages — a million-cell grid missing one
/// whole shard should not print a million numbers.
fn fmt_indexes(idx: &[usize]) -> String {
    const SHOWN: usize = 16;
    let shown: Vec<String> = idx.iter().take(SHOWN).map(|i| i.to_string()).collect();
    if idx.len() > SHOWN {
        format!("[{}, … +{} more]", shown.join(", "), idx.len() - SHOWN)
    } else {
        format!("[{}]", shown.join(", "))
    }
}

/// One input spill opened for merging.
struct Spill {
    cells_path: PathBuf,
    header: SpillHeader,
}

/// Read and identity-check the header of `<dir>/cells.jsonl`.
fn open_spill(dir: &Path) -> Result<Spill, String> {
    let cells_path = dir.join(CELLS_FILE);
    let file = File::open(&cells_path)
        .map_err(|e| format!("opening {cells_path:?}: {e} (is {dir:?} a sweep --out-dir?)"))?;
    let mut r = BufReader::new(file);
    let mut buf = Vec::new();
    let (len, complete) = read_line(&mut r, &mut buf)?;
    if len == 0 || !complete {
        return Err(format!("{cells_path:?}: missing spill header"));
    }
    let header = parse_header(&buf, &cells_path)?;
    Ok(Spill { cells_path, header })
}

/// Validate the shard spills under `dirs` against one another and
/// reassemble them into `<out_dir>/cells.jsonl` plus the final report —
/// byte-identical to an unsharded single-machine run of the same spec.
pub fn merge_spills(
    dirs: &[PathBuf],
    out_dir: &Path,
    format: Format,
) -> Result<MergeSummary, String> {
    if dirs.is_empty() {
        return Err("merge: need at least one shard directory".to_string());
    }
    let spills: Vec<Spill> = dirs.iter().map(|d| open_spill(d)).collect::<Result<_, _>>()?;

    // The first spill fixes the grid identity; every other spill must
    // match it, and its embedded spec must hash to the recorded value.
    let first = &spills[0];
    let spec_v = first.header.spec.as_ref().ok_or_else(|| {
        format!(
            "{:?}: spill header has no embedded spec — cannot reconstruct the grid",
            first.cells_path
        )
    })?;
    let spec: SweepSpec = crate::config::sweep_from_value(spec_v)
        .map_err(|e| format!("{:?}: embedded spec: {e}", first.cells_path))?;
    if spec.spec_hash() != first.header.spec_hash {
        return Err(format!(
            "{:?}: embedded spec hashes to {}, header records {} — corrupt spill header",
            first.cells_path,
            spec.spec_hash(),
            first.header.spec_hash
        ));
    }
    for s in &spills[1..] {
        if s.header.spec_hash != first.header.spec_hash {
            return Err(format!(
                "{:?}: spec hash mismatch ({} here, {} in {:?}) — shards of different \
                 grids cannot merge",
                s.cells_path, s.header.spec_hash, first.header.spec_hash, first.cells_path
            ));
        }
        if s.header.n_cells != first.header.n_cells {
            return Err(format!(
                "{:?}: spill expects {} cells, {:?} expects {}",
                s.cells_path, s.header.n_cells, first.cells_path, first.header.n_cells
            ));
        }
    }
    let n = spec.n_cells();

    // Scan every spill's rows: byte range per cell index. Within a
    // spill the first copy wins — the same dedup rule the resume
    // compaction applies — while a duplicate *across* spills is a
    // coverage-overlap error.
    let mut ranges: Vec<Option<(usize, u64, usize)>> = vec![None; n];
    let mut overlap: Vec<usize> = Vec::new();
    for (spill_id, s) in spills.iter().enumerate() {
        let file = File::open(&s.cells_path)
            .map_err(|e| format!("opening {:?}: {e}", s.cells_path))?;
        let mut r = BufReader::new(file);
        let mut buf = Vec::new();
        let (header_len, _) = read_line(&mut r, &mut buf)?;
        let mut offset = header_len as u64;
        loop {
            let (len, complete) = read_line(&mut r, &mut buf)?;
            if len == 0 || !complete {
                break; // EOF, or an interrupt's truncated tail: drop
            }
            let Some(idx) = row_index(&buf, n) else {
                break; // corrupt row: drop it and everything after
            };
            match ranges[idx] {
                Some((owner, _, _)) if owner != spill_id => overlap.push(idx),
                Some(_) => {} // repeat within the spill: first copy wins
                None => ranges[idx] = Some((spill_id, offset, len - 1)),
            }
            offset += len as u64;
        }
    }
    if !overlap.is_empty() {
        overlap.sort_unstable();
        overlap.dedup();
        let example = overlap[0];
        let owner = ranges[example].map(|(o, _, _)| o).unwrap_or(0);
        return Err(format!(
            "merge: overlapping shard coverage — {} cell index(es) appear in more than one \
             spill (e.g. cell {example} is in {:?} and at least one later spill): {} — \
             shards must partition the grid disjointly; pass each shard exactly once",
            overlap.len(),
            spills[owner].cells_path,
            fmt_indexes(&overlap)
        ));
    }
    let missing: Vec<usize> = (0..n).filter(|&i| ranges[i].is_none()).collect();
    if !missing.is_empty() {
        return Err(format!(
            "merge: incomplete shard set — {} of {n} cells missing: {} — pass every shard \
             directory; an interrupted shard can be finished with \
             `carbon-sim sweep --resume --shard K/N` first",
            missing.len(),
            fmt_indexes(&missing)
        ));
    }

    // Reassemble: an unsharded spill, rows verbatim in cell-index order.
    // Written to a temp file and renamed, so an out-dir that doubles as
    // an input dir never clobbers a spill while rows are still read.
    fs::create_dir_all(out_dir).map_err(|e| format!("creating {out_dir:?}: {e}"))?;
    let cells_path = out_dir.join(CELLS_FILE);
    let tmp = cells_path.with_extension("jsonl.tmp");
    {
        let mut srcs: Vec<File> = spills
            .iter()
            .map(|s| {
                File::open(&s.cells_path).map_err(|e| format!("opening {:?}: {e}", s.cells_path))
            })
            .collect::<Result<_, _>>()?;
        let mut w = BufWriter::new(
            File::create(&tmp).map_err(|e| format!("creating {tmp:?}: {e}"))?,
        );
        let werr = |e: std::io::Error| format!("writing {tmp:?}: {e}");
        let mut header = sweep_stream::full_header_line(&spec);
        header.push('\n');
        w.write_all(header.as_bytes()).map_err(werr)?;
        let mut buf = Vec::new();
        for &range in &ranges {
            let (spill_id, offset, len) = range.expect("coverage verified complete");
            let src = &mut srcs[spill_id];
            src.seek(SeekFrom::Start(offset))
                .map_err(|e| format!("seeking {:?}: {e}", spills[spill_id].cells_path))?;
            buf.resize(len, 0);
            src.read_exact(&mut buf)
                .map_err(|e| format!("reading {:?}: {e}", spills[spill_id].cells_path))?;
            w.write_all(&buf).map_err(werr)?;
            w.write_all(b"\n").map_err(werr)?;
        }
        w.flush().map_err(werr)?;
    }
    fs::rename(&tmp, &cells_path)
        .map_err(|e| format!("renaming {tmp:?} over {cells_path:?}: {e}"))?;

    let report_path = out_dir.join(sweep_stream::report_file_name(format));
    sweep_stream::assemble_report(&cells_path, &spec, format, &report_path)?;
    Ok(MergeSummary { n_spills: spills.len(), n_cells: n, cells_path, report_path })
}
