//! Disk-backed sweep runs: per-cell JSONL spill, crash resume, and
//! report assembly from the spill file (`carbon-sim sweep --out-dir`).
//!
//! [`sweep::run`](super::sweep::run) holds every cell result in memory
//! until the end — O(grid) memory, and a crash loses everything. This
//! module runs the same grid holding only O(workers) cell *results* at
//! any moment (cells are derived per index on demand, never expanded up
//! front; the done/pending bookkeeping and the spill's byte-range index
//! cost a few machine words per cell) and loses at most the in-flight
//! row on a kill:
//!
//! * **Spill.** Workers hand each finished [`SweepCellResult`] to a
//!   single writer (via [`pool::run_streamed`]'s completion callback)
//!   that appends one compact JSON row to `<out-dir>/cells.jsonl` in
//!   **completion order** and retains nothing. The file starts with a
//!   header row recording [`SweepSpec::spec_hash`] and the expected cell
//!   count.
//! * **Resume.** [`scan_and_compact`] re-reads an existing spill, drops
//!   a truncated in-flight tail line, verifies the header's spec hash
//!   against the current spec (refusing to mix grids), and returns which
//!   cell indices are already done; [`run_streaming`] then simulates
//!   only the remainder.
//! * **Assembly.** [`assemble_report`] indexes the spill (byte ranges
//!   per cell), then streams the rows back **in cell-index order** into
//!   the final JSON/CSV report. Because rows are keyed by cell index and
//!   per-cell seeds never depend on execution order, the assembled
//!   report is byte-identical to [`SweepReport::render`] on an in-memory
//!   run — at any `--threads` value, interrupted or not (covered by
//!   `tests/sweep_stream.rs`).
//! * **Sharding.** `--shard K/N` ([`ShardSpec`]) runs only the cells
//!   with `index % N == K`, spilled exactly as above; the header records
//!   the shard assignment, `--resume` composes with it (a partial shard
//!   resumes like a partial grid), and no report is assembled — the N
//!   shard spills, possibly from N machines, are validated and
//!   reassembled by [`super::merge`] (`carbon-sim merge`) into a report
//!   byte-identical to a single-machine run of the whole grid.

use std::fs::{self, File, OpenOptions};
use std::io::{BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::sweep::{csv_columns, run_cell_with_queue, Format, ShardSpec, SweepSpec};
#[allow(unused_imports)] // rustdoc links
use super::sweep::{SweepCellResult, SweepReport};
use super::OUTPUT_SCHEMA_VERSION;
use crate::sim::QueueKind;
use crate::util::json::{parse, Value};
use crate::util::pool;

/// Spill file name inside `--out-dir`.
pub const CELLS_FILE: &str = "cells.jsonl";

/// What a streaming run did (the CLI's summary line).
#[derive(Clone, Debug)]
pub struct StreamSummary {
    /// Cells this invocation is responsible for: the whole grid when
    /// unsharded, the shard's owned subset under `--shard K/N`.
    pub n_cells: usize,
    /// Cells already present in `cells.jsonl` and skipped (`--resume`).
    pub n_resumed: usize,
    /// Cells actually simulated by this invocation.
    pub n_run: usize,
    pub cells_path: PathBuf,
    /// `None` for a shard run: a shard spill covers only part of the
    /// grid, so the report comes from `carbon-sim merge`.
    pub report_path: Option<PathBuf>,
}

/// The spill header row (line 1 of `cells.jsonl`). Embeds the full
/// canonical spec (not just its hash) so a spill is self-contained:
/// `carbon-sim merge` reconstructs the grid from the header alone,
/// without needing the original `--spec` file on the merging machine.
/// The shard fields are written only for sharded runs; their absence
/// means full coverage (`0/1`), so an unsharded spill carries no shard
/// noise. (Spills from schema version 1 are refused outright by the
/// version check, sharded or not.) [`super::search`] extends this
/// header with a `search` object recording the search configuration —
/// [`parse_header`] ignores unknown keys, so every tool here reads a
/// search spill unchanged.
pub(crate) fn header_value(spec: &SweepSpec, shard: &ShardSpec) -> Value {
    let mut pairs = vec![
        ("kind", "sweep-cells".into()),
        ("schema_version", OUTPUT_SCHEMA_VERSION.into()),
        ("spec_hash", spec.spec_hash().as_str().into()),
        ("n_cells", spec.n_cells().into()),
        ("spec", spec.to_json()),
    ];
    if !shard.is_full() {
        pairs.push(("shard_index", shard.index.into()));
        pairs.push(("shard_count", shard.count.into()));
    }
    Value::obj(pairs)
}

/// The compact header line (no trailing newline) of an **unsharded**
/// spill for `spec` — what a fresh full-grid run writes, and what
/// [`super::merge`] stamps onto a reassembled spill.
pub(crate) fn full_header_line(spec: &SweepSpec) -> String {
    header_value(spec, &ShardSpec::full()).to_string_compact()
}

/// A parsed and version-checked spill header.
pub(crate) struct SpillHeader {
    pub spec_hash: String,
    pub n_cells: usize,
    /// Recorded shard assignment; `0/1` when the header has no shard
    /// fields (an unsharded spill).
    pub shard: ShardSpec,
    /// The embedded canonical spec, when present.
    pub spec: Option<Value>,
}

/// Strict non-negative-integer header field, defaulting when absent.
/// The lenient `as_usize` cast would saturate/truncate a corrupt value
/// (`-1`, `1.7`) into a plausible one — same reasoning as [`row_index`].
/// Shared with [`super::orchestrate`]'s manifest parser, which applies
/// the same strictness to `orchestrate.json`.
pub(crate) fn header_usize(
    v: &Value,
    key: &str,
    default: usize,
    path: &Path,
) -> Result<usize, String> {
    match v.get(key) {
        None => Ok(default),
        Some(Value::Num(x)) if *x >= 0.0 && x.fract() == 0.0 && *x < 9_007_199_254_740_992.0 => {
            Ok(*x as usize)
        }
        Some(other) => Err(format!(
            "{path:?}: header field '{key}' must be a non-negative integer, got {other}"
        )),
    }
}

/// Parse a complete header line, checking only spill identity (kind,
/// schema version, well-formed shard fields) — comparisons against a
/// concrete spec belong to [`check_header`].
pub(crate) fn parse_header(line: &[u8], path: &Path) -> Result<SpillHeader, String> {
    let text = std::str::from_utf8(line).map_err(|_| format!("{path:?}: header is not UTF-8"))?;
    let v = parse(text.trim_end())
        .map_err(|e| format!("{path:?}: header is not a JSON object: {e}"))?;
    if v.str_or("kind", "") != "sweep-cells" {
        return Err(format!("{path:?}: not a sweep cells.jsonl spill (missing kind)"));
    }
    let ver = header_usize(&v, "schema_version", 0, path)?;
    if !(super::MIN_SUPPORTED_SPILL_SCHEMA_VERSION..=OUTPUT_SCHEMA_VERSION).contains(&ver) {
        return Err(format!(
            "{path:?}: spill schema_version {ver} outside supported {}..={OUTPUT_SCHEMA_VERSION}",
            super::MIN_SUPPORTED_SPILL_SCHEMA_VERSION
        ));
    }
    let shard = ShardSpec::new(
        header_usize(&v, "shard_index", 0, path)?,
        header_usize(&v, "shard_count", 1, path)?,
    )
    .map_err(|e| format!("{path:?}: bad shard fields in spill header: {e}"))?;
    Ok(SpillHeader {
        spec_hash: v.str_or("spec_hash", "").to_string(),
        n_cells: header_usize(&v, "n_cells", 0, path)?,
        shard,
        spec: v.get("spec").cloned(),
    })
}

/// Validate a complete header line against the current spec and shard
/// assignment. Every failure names what diverged — a resume must never
/// silently mix cells from a different grid or another machine's shard.
fn check_header(
    line: &[u8],
    spec: &SweepSpec,
    shard: &ShardSpec,
    path: &Path,
) -> Result<(), String> {
    let h = parse_header(line, path)?;
    let hash = spec.spec_hash();
    if h.spec_hash != hash {
        return Err(format!(
            "{path:?}: spec hash mismatch (file {}, current spec {hash}) — \
             the spill belongs to a different grid; use a fresh --out-dir",
            h.spec_hash
        ));
    }
    if h.n_cells != spec.n_cells() {
        return Err(format!(
            "{path:?}: spill expects {} cells, current spec expands to {}",
            h.n_cells,
            spec.n_cells()
        ));
    }
    if h.shard != *shard {
        return Err(format!(
            "{path:?}: spill records shard {}, this run expects {} — a spill holds exactly \
             one shard's cells; use a fresh --out-dir per shard and reassemble completed \
             shards with `carbon-sim merge`",
            h.shard, shard
        ));
    }
    Ok(())
}

/// Read one line (including any trailing newline) into `buf`; returns
/// `(bytes_read, newline_terminated)`. `bytes_read == 0` is EOF.
pub(crate) fn read_line(r: &mut impl BufRead, buf: &mut Vec<u8>) -> Result<(usize, bool), String> {
    buf.clear();
    let len = r.read_until(b'\n', buf).map_err(|e| format!("reading spill: {e}"))?;
    Ok((len, buf.last() == Some(&b'\n')))
}

/// Parse a spill row's cell index, if the line is a valid row for an
/// `n`-cell grid. Strict on purpose: a negative or fractional `"index"`
/// must be rejected, not saturated/truncated into some other cell's slot
/// (the lenient `as_usize` cast would silently misattribute the row).
pub(crate) fn row_index(line: &[u8], n: usize) -> Option<usize> {
    let text = std::str::from_utf8(line).ok()?;
    let v = parse(text.trim_end()).ok()?;
    match v.get("index")? {
        Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 && ((*x) as usize) < n => {
            Some(*x as usize)
        }
        _ => None,
    }
}

/// Scan an existing spill for completed cells and compact it in place:
/// keep the header and every valid, newline-terminated row (first copy
/// wins on duplicates), drop the truncated tail an interrupt leaves
/// behind. Returns `done[i] == true` for every cell already on disk.
///
/// An empty or header-truncated file (killed before the header landed)
/// is reset to a fresh spill; a readable header from a *different* spec
/// or shard assignment is a hard error.
pub fn scan_and_compact(
    path: &Path,
    spec: &SweepSpec,
    shard: &ShardSpec,
) -> Result<Vec<bool>, String> {
    let n = spec.n_cells();
    let mut done = vec![false; n];
    let tmp = path.with_extension("jsonl.tmp");
    {
        let file = File::open(path).map_err(|e| format!("opening {path:?}: {e}"))?;
        let mut r = BufReader::new(file);
        let mut w = BufWriter::new(
            File::create(&tmp).map_err(|e| format!("creating {tmp:?}: {e}"))?,
        );
        let mut buf = Vec::new();
        let (len, complete) = read_line(&mut r, &mut buf)?;
        if len == 0 || !complete {
            // Killed before the header landed: no rows can follow it.
            let mut line = header_value(spec, shard).to_string_compact();
            line.push('\n');
            w.write_all(line.as_bytes()).map_err(|e| format!("writing {tmp:?}: {e}"))?;
        } else {
            check_header(&buf, spec, shard, path)?;
            w.write_all(&buf).map_err(|e| format!("writing {tmp:?}: {e}"))?;
            loop {
                let (len, complete) = read_line(&mut r, &mut buf)?;
                if len == 0 {
                    break;
                }
                if !complete {
                    break; // in-flight row truncated by the interrupt: drop
                }
                let Some(idx) = row_index(&buf, n) else {
                    break; // corrupt row: drop it and everything after
                };
                if !done[idx] {
                    done[idx] = true;
                    w.write_all(&buf).map_err(|e| format!("writing {tmp:?}: {e}"))?;
                }
            }
        }
        w.flush().map_err(|e| format!("writing {tmp:?}: {e}"))?;
    }
    fs::rename(&tmp, path).map_err(|e| format!("renaming {tmp:?} over {path:?}: {e}"))?;
    Ok(done)
}

/// Read-only variant of [`scan_and_compact`]: report which of the grid's
/// cells a spill already records, by the same rules resume compaction
/// applies (header identity check, first copy wins, a truncated or
/// corrupt tail is ignored) — without rewriting the file. The
/// orchestrator ([`super::orchestrate`]) uses this as its validation
/// hook: a shard child's exit code 0 is only trusted once every cell the
/// shard owns is on disk, and a `--resume` only skips a shard whose
/// spill is verifiably complete. An empty or header-truncated file is
/// simply "nothing recorded"; a header from a different spec or shard
/// assignment is a hard error, exactly as on resume.
pub fn scan_done(path: &Path, spec: &SweepSpec, shard: &ShardSpec) -> Result<Vec<bool>, String> {
    let n = spec.n_cells();
    let mut done = vec![false; n];
    let file = File::open(path).map_err(|e| format!("opening {path:?}: {e}"))?;
    let mut r = BufReader::new(file);
    let mut buf = Vec::new();
    let (len, complete) = read_line(&mut r, &mut buf)?;
    if len == 0 || !complete {
        return Ok(done); // killed before the header landed: no rows follow
    }
    check_header(&buf, spec, shard, path)?;
    loop {
        let (len, complete) = read_line(&mut r, &mut buf)?;
        if len == 0 || !complete {
            break;
        }
        let Some(idx) = row_index(&buf, n) else {
            break; // corrupt row: it and everything after would be dropped
        };
        done[idx] = true;
    }
    Ok(done)
}

/// Run the sweep with per-cell streaming to `<out_dir>/cells.jsonl`,
/// then assemble `<out_dir>/report.json` (or `.csv`) from the spill.
/// With `resume`, cells already recorded by a previous (possibly
/// interrupted) run of the **same spec** are skipped.
///
/// Under a non-full `shard`, only the cells that shard owns are run and
/// spilled, the header records the assignment, and **no report is
/// assembled** (`report_path` is `None`): completed shard spills are
/// reassembled by [`super::merge::merge_spills`].
pub fn run_streaming(
    spec: &SweepSpec,
    threads: usize,
    out_dir: &Path,
    shard: &ShardSpec,
    format: Format,
    resume: bool,
    verbose: bool,
) -> Result<StreamSummary, String> {
    run_streaming_with(spec, threads, out_dir, shard, format, resume, verbose, QueueKind::default())
}

/// [`run_streaming`] under an explicit queue implementation
/// (`--queue`). The choice touches nothing recorded on disk — not the
/// spill header, not the rows, not the assembled report — so spills
/// from different queue implementations mix freely under `--resume`
/// and `merge`.
#[allow(clippy::too_many_arguments)] // mirrors run_streaming + the kind
pub fn run_streaming_with(
    spec: &SweepSpec,
    threads: usize,
    out_dir: &Path,
    shard: &ShardSpec,
    format: Format,
    resume: bool,
    verbose: bool,
    queue: QueueKind,
) -> Result<StreamSummary, String> {
    spec.validate()?;
    fs::create_dir_all(out_dir).map_err(|e| format!("creating {out_dir:?}: {e}"))?;
    let cells_path = out_dir.join(CELLS_FILE);
    // Cells are derived per index on demand — the grid is never
    // materialized, so worker memory stays O(1) per in-flight cell.
    let n = spec.n_cells();
    let n_owned = shard.owned_count(n);

    let done = if resume && cells_path.exists() {
        scan_and_compact(&cells_path, spec, shard)?
    } else {
        let mut line = header_value(spec, shard).to_string_compact();
        line.push('\n');
        fs::write(&cells_path, line).map_err(|e| format!("writing {cells_path:?}: {e}"))?;
        vec![false; n]
    };
    let pending: Vec<usize> = (0..n).filter(|&i| shard.owns(i) && !done[i]).collect();
    let n_resumed = n_owned - pending.len();

    let mut spill = OpenOptions::new()
        .append(true)
        .open(&cells_path)
        .map_err(|e| format!("opening {cells_path:?}: {e}"))?;
    let mut io_err: Option<String> = None;
    let mut n_done = n_resumed;
    pool::run_streamed(
        &pending,
        threads,
        |i| run_cell_with_queue(spec, &spec.cell(i), queue),
        |_i, res| {
            // One write per row: an interrupt loses at most the
            // in-flight line, which the resume scan drops.
            let mut line = res.to_json().to_string_compact();
            line.push('\n');
            if let Err(e) = spill.write_all(line.as_bytes()) {
                // Returning false stops the pool: no point simulating
                // the rest of the grid when rows can't be recorded.
                io_err = Some(format!("appending to {cells_path:?}: {e}"));
                return false;
            }
            n_done += 1;
            if verbose {
                let c = &res.cell;
                println!(
                    "[{n_done}/{n_owned}] scenario {:>3} {:<12} {:>4}c {:>6.1} rps rep {} {:<12}",
                    c.scenario,
                    c.workload.name(),
                    c.cores,
                    c.rate,
                    c.replica,
                    c.policy
                );
            }
            true
        },
    );
    drop(spill);
    if let Some(e) = io_err {
        return Err(e);
    }

    // A shard spill covers only its owned cells, so there is nothing to
    // assemble here — that is `carbon-sim merge`'s job once every shard
    // has finished.
    let report_path = if shard.is_full() {
        let path = out_dir.join(report_file_name(format));
        assemble_report(&cells_path, spec, format, &path)?;
        Some(path)
    } else {
        None
    };
    Ok(StreamSummary {
        n_cells: n_owned,
        n_resumed,
        n_run: pending.len(),
        cells_path,
        report_path,
    })
}

/// The report file name inside an `--out-dir` for a given format.
pub fn report_file_name(format: Format) -> &'static str {
    match format {
        Format::Json => "report.json",
        Format::Csv => "report.csv",
    }
}

/// Assemble the final report from a complete spill, streaming rows from
/// disk in cell-index order — byte-identical to what
/// [`SweepReport::render`] produces for the same spec.
pub fn assemble_report(
    cells_path: &Path,
    spec: &SweepSpec,
    format: Format,
    report_path: &Path,
) -> Result<(), String> {
    let n = spec.n_cells();
    // Pass 1: index the spill — the byte range of each cell's row.
    let mut ranges: Vec<Option<(u64, usize)>> = vec![None; n];
    {
        let file = File::open(cells_path).map_err(|e| format!("opening {cells_path:?}: {e}"))?;
        let mut r = BufReader::new(file);
        let mut buf = Vec::new();
        let (len, complete) = read_line(&mut r, &mut buf)?;
        if len == 0 || !complete {
            return Err(format!("{cells_path:?}: missing spill header"));
        }
        // A report always covers the whole grid, so only a full (0/1)
        // spill assembles; shard spills go through `carbon-sim merge`.
        check_header(&buf, spec, &ShardSpec::full(), cells_path)?;
        let mut offset = len as u64;
        loop {
            let (len, complete) = read_line(&mut r, &mut buf)?;
            if len == 0 || !complete {
                break;
            }
            let Some(idx) = row_index(&buf, n) else {
                break;
            };
            if ranges[idx].is_none() {
                // Row length without the trailing newline.
                ranges[idx] = Some((offset, len - 1));
            }
            offset += len as u64;
        }
    }
    let missing = ranges.iter().filter(|r| r.is_none()).count();
    if missing > 0 {
        return Err(format!(
            "{cells_path:?}: {missing} of {n} cells missing — interrupted sweep? rerun with --resume"
        ));
    }
    let ranges: Vec<(u64, usize)> = ranges.into_iter().map(|r| r.unwrap()).collect();

    // Pass 2: emit rows in cell-index order.
    let mut src = File::open(cells_path).map_err(|e| format!("opening {cells_path:?}: {e}"))?;
    let out = File::create(report_path).map_err(|e| format!("creating {report_path:?}: {e}"))?;
    let mut w = BufWriter::new(out);
    let write_err = |e: std::io::Error| format!("writing {report_path:?}: {e}");
    match format {
        Format::Json => write_report_json(&mut w, spec, &mut src, &ranges).map_err(write_err)?,
        Format::Csv => {
            write_report_csv(&mut w, &mut src, &ranges, cells_path, &csv_columns(spec))?
        }
    }
    w.flush().map_err(write_err)
}

/// Seek-and-parse one spill row.
fn read_row(src: &mut File, (offset, len): (u64, usize)) -> Result<Value, String> {
    src.seek(SeekFrom::Start(offset)).map_err(|e| format!("seeking spill: {e}"))?;
    let mut buf = vec![0u8; len];
    src.read_exact(&mut buf).map_err(|e| format!("reading spill row: {e}"))?;
    let text = std::str::from_utf8(&buf).map_err(|_| "spill row is not UTF-8".to_string())?;
    parse(text).map_err(|e| format!("spill row: {e}"))
}

/// Stream the JSON report. The glue between rows mirrors exactly what
/// `Value::write` emits for the equivalent in-memory report object
/// (top-level keys in BTreeMap order: cells, n_cells, schema_version,
/// spec) — pinned byte-for-byte against [`SweepReport::render`] by
/// `tests/sweep_stream.rs`.
fn write_report_json<W: Write>(
    w: &mut W,
    spec: &SweepSpec,
    src: &mut File,
    ranges: &[(u64, usize)],
) -> std::io::Result<()> {
    let io_invalid =
        |e: String| std::io::Error::new(std::io::ErrorKind::InvalidData, e);
    w.write_all(b"{\n  \"cells\": [")?;
    let mut buf = String::new();
    for (k, &range) in ranges.iter().enumerate() {
        if k > 0 {
            w.write_all(b",")?;
        }
        w.write_all(b"\n    ")?;
        let row = read_row(src, range).map_err(io_invalid)?;
        buf.clear();
        row.write_pretty_at(&mut buf, 2);
        w.write_all(buf.as_bytes())?;
    }
    if !ranges.is_empty() {
        w.write_all(b"\n  ")?;
    }
    w.write_all(b"],\n  \"n_cells\": ")?;
    w.write_all(Value::from(ranges.len()).to_string_compact().as_bytes())?;
    w.write_all(b",\n  \"schema_version\": ")?;
    w.write_all(Value::from(OUTPUT_SCHEMA_VERSION).to_string_compact().as_bytes())?;
    w.write_all(b",\n  \"spec\": ")?;
    buf.clear();
    spec.to_json().write_pretty_at(&mut buf, 1);
    w.write_all(buf.as_bytes())?;
    w.write_all(b"\n}\n")
}

/// Stream the CSV report: the same column extraction as
/// [`SweepReport::to_csv`], row by row from the spill. `columns` comes
/// from [`csv_columns`] so fleet-configured specs get the lifecycle
/// columns and plain specs keep their historic header.
fn write_report_csv<W: Write>(
    w: &mut W,
    src: &mut File,
    ranges: &[(u64, usize)],
    cells_path: &Path,
    columns: &[&'static str],
) -> Result<(), String> {
    let werr = |e: std::io::Error| format!("writing report: {e}");
    w.write_all(columns.join(",").as_bytes()).map_err(werr)?;
    w.write_all(b"\n").map_err(werr)?;
    for &range in ranges {
        let record = read_row(src, range)?;
        let mut row = Vec::with_capacity(columns.len());
        for col in columns {
            match record.get(col) {
                // Strings (workload, policy, seed) are quoted only when
                // RFC 4180 requires it — same rule as SweepReport::to_csv.
                Some(Value::Str(s)) => row.push(super::sweep::csv_field(s)),
                Some(v) => row.push(v.to_string_compact()),
                None => {
                    return Err(format!(
                        "{cells_path:?}: spill row is missing CSV column '{col}'"
                    ))
                }
            }
        }
        w.write_all(row.join(",").as_bytes()).map_err(werr)?;
        w.write_all(b"\n").map_err(werr)?;
    }
    Ok(())
}
