//! Experiment runners — one per paper figure (see DESIGN.md's
//! per-experiment index). Bench binaries (`cargo bench`) and the CLI
//! (`carbon-sim figure ...`) both call into these. The [`bench`] module
//! is the pinned perf matrix behind `carbon-sim bench` (§Perf).
//!
//! The [`sweep`] module generalizes the per-figure matrix into a
//! parallel scenario-sweep engine: arbitrary rate × core count × policy
//! × workload × replica grids, sharded across a worker pool with
//! deterministic per-cell seeds and JSON/CSV aggregation
//! (`carbon-sim sweep`). [`sweep_stream`] is its disk-backed variant:
//! per-cell JSONL spill, crash resume, and report assembly from the
//! spill file (`--out-dir` / `--resume`); `--shard K/N` restricts a run
//! to one interleaved slice of the grid so N machines can split it, and
//! [`merge`] (`carbon-sim merge`) validates and reassembles the shard
//! spills into a report byte-identical to a single-machine run.
//! [`orchestrate`] (`carbon-sim orchestrate`) drives that whole
//! distributed pipeline from one spec: it launches the N shard runs
//! (local children or a `--launcher` template), tracks them in a
//! retry/resume manifest, and invokes the merge on completion.
//! [`search`] (`carbon-sim sweep --search`) is the adaptive alternative
//! to exhausting a grid: successive-halving over the scenario axes that
//! stops replicating scenarios whose policy ranking is statistically
//! settled, spilling cells through the same `cells.jsonl` machinery.
//! [`run_matrix`] itself runs its paired cells on the same pool, so
//! `carbon-sim figure --fig 6|7|8` parallelizes too.

pub mod bench;
pub mod fig1;
pub mod fig2;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod merge;
pub mod orchestrate;
pub mod search;
pub mod sweep;
pub mod sweep_stream;

/// Version stamp written into every machine-readable output this crate
/// produces (sweep report JSON, `cells.jsonl` header, bench JSON, the
/// `orchestrate.json` manifest), so `docs/output-schemas.md` can be
/// versioned against the files. Bump it whenever a field is added,
/// removed, or changes meaning.
///
/// Version history: **1** — initial schemas; **2** — spill headers embed
/// the canonical `spec` plus optional `shard_index`/`shard_count`,
/// non-finite numbers serialize as `NaN`/`Infinity`/`-Infinity` instead
/// of `null`, and CSV string fields use RFC-4180 quoting when needed;
/// **3** — adds the `orchestrate.json` shard-fleet manifest
/// (`carbon-sim orchestrate`); the sweep report, spill, and bench
/// schemas are unchanged from version 2; **4** — bench JSON records the
/// event-queue implementation (top-level `queue`) and per-cell queue
/// counters (`peak_queue_len`, `queue_pushes`, `queue_clamped`); the
/// sweep report, spill, and orchestrate schemas are unchanged from
/// version 2/3 (the queue kind is an execution detail that never
/// reaches them); **5** — adds the `search.json` summary
/// (`carbon-sim sweep --search`) and an optional `search` object in the
/// `cells.jsonl` header recording the search configuration; the sweep
/// report, plain spill, bench, and orchestrate schemas are unchanged
/// from version 4; **6** — adds the `lint-report` JSON emitted by
/// `carbon-sim lint --json`; every previously-existing schema is
/// unchanged from version 5; **7** — sweep specs may carry optional
/// `fleet`/`lifecycle` blocks (heterogeneous SKUs, maintenance windows,
/// core failures, aging-triggered retirement); fleet-configured cell
/// records append the lifecycle summary keys
/// (`lifecycle_yearly_embodied_kg`, `lifecycle_retirements`,
/// `lifecycle_core_failures`, `lifecycle_rerouted`,
/// `active_capacity_fraction`) and the CSV gains the matching columns;
/// reports without a fleet block are byte-identical to version 6 apart
/// from the stamped number.
pub const OUTPUT_SCHEMA_VERSION: usize = 7;

/// Oldest `cells.jsonl` spill version `--resume` and `merge` still
/// accept. The spill format is unchanged since version 2 (version 3
/// only added the orchestrate manifest; version 4 only extended the
/// bench JSON; version 5 only added an *optional* header field, which
/// older rows simply lack; version 6 only added the lint report;
/// version 7 only added optional spec blocks and per-cell keys that
/// non-fleet spills simply lack), so refusing v2–v6 spills would orphan
/// days of shard work over a label; version-1 spills really do differ
/// (no embedded spec) and stay refused.
pub const MIN_SUPPORTED_SPILL_SCHEMA_VERSION: usize = 2;

use crate::cluster::{Cluster, ClusterConfig};
use crate::metrics::SimResult;
use crate::policy::ALL_POLICIES;
use crate::trace::azure::{AzureTraceGen, TraceParams, Workload};
use crate::trace::Trace;

/// Experiment scale: the sweep axes shared by Figs. 2/6/7/8.
#[derive(Clone, Debug)]
pub struct Scale {
    /// Inference throughput levels (requests/s) — the figures' x-axes.
    pub rates: Vec<f64>,
    /// VM core counts (paper: 40 and 80, matching Azure H100 SKUs).
    pub core_counts: Vec<usize>,
    /// Trace duration per run (s).
    pub duration_s: f64,
    pub n_prompt: usize,
    pub n_token: usize,
    pub workload: Workload,
    pub seed: u64,
}

impl Scale {
    /// The paper's full experimental design (§6.1): 22 machines
    /// (5 prompt + 17 token), throughputs 40–100 rps, 40/80-core VMs.
    pub fn paper() -> Scale {
        Scale {
            rates: vec![40.0, 60.0, 80.0, 100.0],
            core_counts: vec![40, 80],
            duration_s: 120.0,
            n_prompt: 5,
            n_token: 17,
            workload: Workload::Mixed,
            seed: 42,
        }
    }

    /// A seconds-scale configuration for tests and smoke runs. 16-core
    /// CPUs at a light rate keep the idle-core headroom that the
    /// technique's aging gap depends on (like the paper's 40/80-core VMs).
    pub fn smoke() -> Scale {
        Scale {
            rates: vec![6.0],
            core_counts: vec![16],
            duration_s: 10.0,
            n_prompt: 2,
            n_token: 2,
            workload: Workload::Mixed,
            seed: 7,
        }
    }

    pub fn trace(&self, rate: f64) -> Trace {
        AzureTraceGen::new(TraceParams {
            rate_rps: rate,
            duration_s: self.duration_s,
            workload: self.workload,
            seed: self.seed ^ (rate as u64).rotate_left(17),
        })
        .generate()
    }

    pub fn config(&self, cores: usize, policy: &str) -> ClusterConfig {
        ClusterConfig {
            n_prompt: self.n_prompt,
            n_token: self.n_token,
            cores_per_cpu: cores,
            policy: policy.into(),
            seed: self.seed,
            ..ClusterConfig::default()
        }
    }
}

/// One cell of the experiment matrix: every policy run on *identical
/// silicon* (shared process-variation sample) against the same trace.
pub struct PairedCell {
    pub cores: usize,
    pub rate: f64,
    /// Results indexed like [`ALL_POLICIES`].
    pub results: Vec<SimResult>,
}

impl PairedCell {
    pub fn result(&self, policy: &str) -> &SimResult {
        let i = ALL_POLICIES.iter().position(|&p| p == policy).expect("known policy");
        &self.results[i]
    }
}

/// Run one (cores, rate) cell paired across all policies.
pub fn run_paired(scale: &Scale, cores: usize, rate: f64) -> PairedCell {
    let trace = scale.trace(rate);
    let f0 = scale.config(cores, "linux").sample_f0();
    let results = ALL_POLICIES
        .iter()
        .map(|&p| {
            let mut cfg = scale.config(cores, p);
            cfg.f0_override = Some(f0.clone());
            Cluster::new(cfg).run(&trace)
        })
        .collect();
    PairedCell { cores, rate, results }
}

/// The full matrix over (core count × rate), run on `threads` pool
/// workers (0 = one per available core). Cells are independent and
/// seeded from `scale`, so the result is identical at any thread count;
/// output order matches the sequential nested loop.
pub fn run_matrix_threads(scale: &Scale, threads: usize) -> Vec<PairedCell> {
    let mut axes = Vec::new();
    for &cores in &scale.core_counts {
        for &rate in &scale.rates {
            axes.push((cores, rate));
        }
    }
    crate::util::pool::run_indexed(axes.len(), threads, |i| {
        run_paired(scale, axes[i].0, axes[i].1)
    })
}

/// The full matrix over (core count × rate), parallelized across all
/// available cores.
pub fn run_matrix(scale: &Scale) -> Vec<PairedCell> {
    run_matrix_threads(scale, 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paired_cell_shares_silicon() {
        let cell = run_paired(&Scale::smoke(), 8, 10.0);
        assert_eq!(cell.results.len(), ALL_POLICIES.len());
        // Identical f0 across policies.
        let f0_a = &cell.results[0].f0;
        for r in &cell.results[1..] {
            assert_eq!(&r.f0, f0_a);
        }
        // Accessor maps names correctly.
        assert_eq!(cell.result("proposed").policy, "proposed");
        assert_eq!(cell.result("linux").policy, "linux");
    }

    #[test]
    fn matrix_covers_axes() {
        let mut s = Scale::smoke();
        s.rates = vec![5.0, 10.0];
        s.core_counts = vec![4, 8];
        let m = run_matrix(&s);
        assert_eq!(m.len(), 4);
    }

    #[test]
    fn matrix_parallel_matches_sequential() {
        let mut s = Scale::smoke();
        s.duration_s = 5.0;
        s.rates = vec![4.0, 8.0];
        s.core_counts = vec![8];
        let seq = run_matrix_threads(&s, 1);
        let par = run_matrix_threads(&s, 4);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(par.iter()) {
            assert_eq!((a.cores, a.rate), (b.cores, b.rate));
            for (ra, rb) in a.results.iter().zip(b.results.iter()) {
                assert_eq!(ra.events_processed, rb.events_processed);
                assert_eq!(ra.freq, rb.freq);
            }
        }
    }
}
