//! Fig. 6 — "Comparison of managing aging effects in CPU".
//!
//! Two metrics per (VM core count, throughput, policy), each reported as
//! cluster percentiles over the 22 machines:
//!
//! * **Frequency-CV performance** `1 − CV(f)`: decreases when the
//!   coefficient of variation of the per-machine core-frequency
//!   distribution increases (aging unevenness).
//! * **Frequency performance** `1 − mean(f_red)/f_nom`: decreases when
//!   mean frequency degradation increases (overall aging).
//!
//! Expected shape (paper §6.2): proposed ≫ least-aged > linux on CV
//! performance; proposed > (least-aged ≈ linux) on frequency performance.

use super::PairedCell;
use crate::policy::ALL_POLICIES;
use crate::util::stats::Summary;

/// One row of the Fig. 6 table.
#[derive(Clone, Debug)]
pub struct Fig6Row {
    pub cores: usize,
    pub rate: f64,
    pub policy: String,
    /// Summary across machines of the per-machine frequency CV.
    pub cv: Summary,
    /// Summary across machines of per-machine mean degradation (GHz).
    pub fred: Summary,
    /// CV performance at p50/p99 (higher is better).
    pub cv_perf_p50: f64,
    pub cv_perf_p99: f64,
    /// Frequency performance at p50/p99 (higher is better).
    pub freq_perf_p50: f64,
    pub freq_perf_p99: f64,
}

/// Compute Fig. 6 rows from a run matrix.
pub fn rows(cells: &[PairedCell], f_nominal_ghz: f64) -> Vec<Fig6Row> {
    let mut out = Vec::new();
    for cell in cells {
        for &pol in &ALL_POLICIES {
            let r = cell.result(pol);
            let cvs = r.freq_cv_per_machine();
            let freds = r.mean_fred_per_machine();
            let cv = Summary::of(&cvs);
            let fred = Summary::of(&freds);
            out.push(Fig6Row {
                cores: cell.cores,
                rate: cell.rate,
                policy: pol.to_string(),
                cv_perf_p50: 1.0 - cv.p50,
                cv_perf_p99: 1.0 - cv.p99,
                freq_perf_p50: 1.0 - fred.p50 / f_nominal_ghz,
                freq_perf_p99: 1.0 - fred.p99 / f_nominal_ghz,
                cv,
                fred,
            });
        }
    }
    out
}

/// Render the figure as text tables (one per core count), mirroring the
/// paper's 6a (40 cores) and 6b (80 cores) subplots.
pub fn print(rows: &[Fig6Row]) {
    let mut cores_seen: Vec<usize> = rows.iter().map(|r| r.cores).collect();
    cores_seen.sort_unstable();
    cores_seen.dedup();
    for cores in cores_seen {
        println!("\nFig 6 — VM cores = {cores}  (higher = better)");
        println!(
            "{:<8} {:<12} {:>14} {:>14} {:>16} {:>16} {:>12} {:>14}",
            "rate", "policy", "cv_perf_p50", "cv_perf_p99", "freq_perf_p50", "freq_perf_p99",
            "cv_p50", "fred_p50_mhz"
        );
        for r in rows.iter().filter(|r| r.cores == cores) {
            println!(
                "{:<8} {:<12} {:>14.6} {:>14.6} {:>16.9} {:>16.9} {:>12.6} {:>14.6}",
                r.rate,
                r.policy,
                r.cv_perf_p50,
                r.cv_perf_p99,
                r.freq_perf_p50,
                r.freq_perf_p99,
                r.cv.p50,
                r.fred.p50 * 1000.0
            );
        }
    }
}

/// Sanity assertions on the paper's expected ordering; returns a list of
/// violations (empty = shape reproduced).
pub fn check_shape(rows: &[Fig6Row]) -> Vec<String> {
    let mut violations = Vec::new();
    // Group rows by (cores, rate).
    let mut keys: Vec<(usize, u64)> = rows.iter().map(|r| (r.cores, r.rate as u64)).collect();
    keys.sort_unstable();
    keys.dedup();
    for (cores, rate) in keys {
        let find = |pol: &str| {
            rows.iter()
                .find(|r| r.cores == cores && r.rate as u64 == rate && r.policy == pol)
                .unwrap()
        };
        let (linux, least, prop) = (find("linux"), find("least-aged"), find("proposed"));
        if prop.freq_perf_p50 <= linux.freq_perf_p50 {
            violations.push(format!(
                "cores={cores} rate={rate}: proposed freq perf {:.9} !> linux {:.9}",
                prop.freq_perf_p50, linux.freq_perf_p50
            ));
        }
        if prop.freq_perf_p50 <= least.freq_perf_p50 {
            violations.push(format!(
                "cores={cores} rate={rate}: proposed freq perf {:.9} !> least-aged {:.9}",
                prop.freq_perf_p50, least.freq_perf_p50
            ));
        }
        // least-aged evens out aging better than linux (CV performance).
        if least.cv_perf_p50 < linux.cv_perf_p50 * 0.999 {
            violations.push(format!(
                "cores={cores} rate={rate}: least-aged cv perf {:.6} < linux {:.6}",
                least.cv_perf_p50, linux.cv_perf_p50
            ));
        }
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::{run_matrix, Scale};

    #[test]
    fn rows_and_shape_on_smoke_scale() {
        let mut scale = Scale::smoke();
        scale.duration_s = 20.0;
        scale.rates = vec![8.0];
        let cells = run_matrix(&scale);
        let rows = rows(&cells, 2.6);
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(r.cv.p50 >= 0.0);
            assert!(r.fred.p50 > 0.0, "{}: no aging measured", r.policy);
            assert!(r.freq_perf_p50 < 1.0);
        }
        // Core ordering claim: proposed degrades least.
        let violations = check_shape(&rows);
        assert!(violations.is_empty(), "shape violations: {violations:?}");
    }
}
