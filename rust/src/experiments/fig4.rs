//! Fig. 4 — "Changes in operating temperature when 6 out of 12 cores set
//! to deep idle in an Intel Xeon CPU" (Table 1's measurement experiment).
//!
//! Our substitute for the authors' hardware campaign: a first-order
//! thermal model per core, driven through the same schedule — all 12
//! cores 100 % utilized, then 6 cores parked in C6 mid-experiment, then
//! woken again. The steady plateaus must land on Table 1's values.

use crate::cpu::{CState, TemperatureModel, TransientThermal};

#[derive(Clone, Debug)]
pub struct Fig4Point {
    pub t_s: f64,
    /// Mean temperature of the always-active (allocated) group.
    pub active_group_c: f64,
    /// Mean temperature of the toggled group.
    pub toggled_group_c: f64,
}

#[derive(Clone, Debug)]
pub struct Fig4Result {
    pub points: Vec<Fig4Point>,
    pub idle_start_s: f64,
    pub idle_end_s: f64,
}

/// Simulate the 12-core experiment: toggle 6 cores to C6 during
/// [idle_start, idle_end).
pub fn run(duration_s: f64, idle_start_s: f64, idle_end_s: f64, dt_s: f64) -> Fig4Result {
    let temps = TemperatureModel::paper_default();
    let tau = 30.0;
    let mut active: Vec<TransientThermal> =
        (0..6).map(|_| TransientThermal::new(temps.active_allocated_c, tau)).collect();
    let mut toggled: Vec<TransientThermal> =
        (0..6).map(|_| TransientThermal::new(temps.active_allocated_c, tau)).collect();
    let mut points = Vec::new();
    let mut t = 0.0;
    while t <= duration_s {
        let toggled_state =
            if t >= idle_start_s && t < idle_end_s { CState::C6 } else { CState::C0 };
        // Allocated cores hold the Table-1 allocated target; toggled cores
        // chase their state's target.
        let target_toggled = temps.steady_c(toggled_state, toggled_state == CState::C0);
        for c in &mut active {
            c.step(temps.active_allocated_c, dt_s);
        }
        for c in &mut toggled {
            c.step(target_toggled, dt_s);
        }
        points.push(Fig4Point {
            t_s: t,
            active_group_c: active.iter().map(|c| c.temp_c).sum::<f64>() / 6.0,
            toggled_group_c: toggled.iter().map(|c| c.temp_c).sum::<f64>() / 6.0,
        });
        t += dt_s;
    }
    Fig4Result { points, idle_start_s, idle_end_s }
}

pub fn print(r: &Fig4Result) {
    println!("\nFig 4 — core temperatures, 6/12 cores toggled to C6 during [{}, {}) s", r.idle_start_s, r.idle_end_s);
    println!("{:<10} {:>16} {:>16}", "t_s", "active_group_C", "toggled_group_C");
    for p in r.points.iter().step_by((r.points.len() / 30).max(1)) {
        println!("{:<10.0} {:>16.2} {:>16.2}", p.t_s, p.active_group_c, p.toggled_group_c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plateaus_match_table1() {
        let r = run(600.0, 120.0, 420.0, 1.0);
        // Just before idling: both groups at 54.
        let before = r.points.iter().find(|p| p.t_s == 119.0).unwrap();
        assert!((before.toggled_group_c - 54.0).abs() < 0.1);
        // Deep in the idle window: toggled at 48, active still 54.
        let during = r.points.iter().find(|p| p.t_s == 400.0).unwrap();
        assert!((during.toggled_group_c - 48.0).abs() < 0.1, "{}", during.toggled_group_c);
        assert!((during.active_group_c - 54.0).abs() < 0.1);
        // After waking: back to 54 (allocated).
        let after = r.points.last().unwrap();
        assert!((after.toggled_group_c - 54.0).abs() < 0.2);
    }

    #[test]
    fn transition_is_smooth_not_step() {
        let r = run(600.0, 120.0, 420.0, 1.0);
        let p = r.points.iter().find(|p| p.t_s == 135.0).unwrap();
        // 15 s after idling with tau=30: partway between 54 and 48.
        assert!(p.toggled_group_c < 53.0 && p.toggled_group_c > 48.5, "{}", p.toggled_group_c);
    }
}
