//! Parallel scenario-sweep engine.
//!
//! Expands a [`SweepSpec`] — the cross product of throughputs × VM core
//! counts × policies × workload scenarios × seed replicas — into
//! independent cells, shards them across a [`crate::util::pool`] worker
//! pool, and aggregates the per-cell [`SimResult`]s into one JSON or CSV
//! report.
//!
//! **Determinism contract:** every cell's seed is derived from
//! `(spec.seed, scenario index)` by [`cell_seed`], never from execution
//! order, and the pool returns results in cell-index order. The
//! aggregated report is therefore byte-identical at any `--threads`
//! value (covered by `tests/sweep_determinism.rs`).
//!
//! **Pairing:** the scenario index deliberately excludes the policy axis,
//! so every policy in a scenario shares one seed — identical trace and
//! identical silicon (process-variation sample) — exactly like
//! [`super::run_paired`] does for the paper's figures.
//!
//! **Spec sources:** a spec is built from CLI axis flags, from the
//! hard-coded [`SweepSpec::paper`]/[`SweepSpec::smoke`] presets, or
//! declaratively from a JSON file via `config::sweep_from_file`
//! (`carbon-sim sweep --spec examples/specs/paper.json`). A spec's
//! identity is its canonical JSON ([`SweepSpec::to_json`]) hashed by
//! [`SweepSpec::spec_hash`] — the streaming engine records that hash so
//! a resume can refuse to mix cells from different grids.
//!
//! **Streaming:** [`run`] holds every [`SweepCellResult`] in memory —
//! fine for paper-sized grids, the wrong shape for production sweeps.
//! [`super::sweep_stream`] runs the same cells with O(workers) memory by
//! spilling each finished cell to a `cells.jsonl` file and assembling
//! the final report (byte-identical to [`SweepReport::render`]) from the
//! spill, with crash resume.

use std::fmt;
use std::path::Path;

use crate::cluster::{Cluster, ClusterConfig, FleetConfig, LifecycleConfig};
use crate::metrics::SimResult;
use crate::policy::ALL_POLICIES;
use crate::sim::QueueKind;
use crate::trace::azure::{AzureTraceGen, TraceParams, Workload};
use crate::util::json::Value;
use crate::util::pool;
use crate::util::rng::Rng;

/// The sweep axes. The expansion order is workloads (outer) → core
/// counts → rates → replicas → policies (inner), so policies of one
/// scenario are adjacent in the report.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub rates: Vec<f64>,
    pub core_counts: Vec<usize>,
    pub policies: Vec<String>,
    pub workloads: Vec<Workload>,
    /// Independent seed replicas per (workload, cores, rate) scenario.
    pub replicas: usize,
    /// Trace duration per cell (s).
    pub duration_s: f64,
    pub n_prompt: usize,
    pub n_token: usize,
    /// Root seed; per-cell seeds derive from it via [`cell_seed`].
    pub seed: u64,
    /// Optional heterogeneous fleet (machine SKU groups). When set,
    /// per-machine core counts come from the groups and the `core_counts`
    /// axis is nominal labeling only. Absent from canonical JSON when
    /// `None`, so pre-fleet specs keep their bytes and [`spec_hash`].
    ///
    /// [`spec_hash`]: SweepSpec::spec_hash
    pub fleet: Option<FleetConfig>,
    /// Optional fleet events (maintenance / failures / retirement);
    /// requires `fleet`.
    pub lifecycle: Option<LifecycleConfig>,
}

impl SweepSpec {
    /// The paper's full grid (§6.1) under the default mixed workload.
    pub fn paper() -> SweepSpec {
        SweepSpec {
            rates: vec![40.0, 60.0, 80.0, 100.0],
            core_counts: vec![40, 80],
            policies: ALL_POLICIES.iter().map(|p| p.to_string()).collect(),
            workloads: vec![Workload::Mixed],
            replicas: 1,
            duration_s: 120.0,
            n_prompt: 5,
            n_token: 17,
            seed: 42,
            fleet: None,
            lifecycle: None,
        }
    }

    /// A seconds-scale spec for tests and CI smoke runs.
    pub fn smoke() -> SweepSpec {
        SweepSpec {
            rates: vec![6.0],
            core_counts: vec![16],
            policies: ALL_POLICIES.iter().map(|p| p.to_string()).collect(),
            workloads: vec![Workload::Mixed],
            replicas: 1,
            duration_s: 8.0,
            n_prompt: 1,
            n_token: 2,
            seed: 7,
            fleet: None,
            lifecycle: None,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.rates.is_empty()
            || self.core_counts.is_empty()
            || self.policies.is_empty()
            || self.workloads.is_empty()
        {
            return Err("sweep: every axis (rates, cores, policies, workloads) needs ≥ 1 value"
                .to_string());
        }
        if self.replicas == 0 {
            return Err("sweep: replicas must be ≥ 1".to_string());
        }
        if !(self.duration_s > 0.0) {
            return Err("sweep: duration_s must be positive".to_string());
        }
        if self.rates.iter().any(|&r| !(r > 0.0)) {
            return Err("sweep: rates must be positive".to_string());
        }
        if self.core_counts.iter().any(|&c| c == 0) {
            return Err("sweep: core counts must be positive".to_string());
        }
        if self.n_prompt == 0 || self.n_token == 0 {
            return Err("sweep: need ≥ 1 prompt and ≥ 1 token machine".to_string());
        }
        for p in &self.policies {
            crate::policy::by_name(p)?;
        }
        if self.lifecycle.is_some() && self.fleet.is_none() {
            return Err("sweep: a lifecycle block requires a fleet block".to_string());
        }
        if let Some(fleet) = &self.fleet {
            fleet.validate(self.n_prompt + self.n_token)?;
            if let Some(lc) = &self.lifecycle {
                lc.validate(fleet)?;
            }
        }
        Ok(())
    }

    /// Scenarios = cells / policies.
    pub fn n_scenarios(&self) -> usize {
        self.workloads.len() * self.core_counts.len() * self.rates.len() * self.replicas
    }

    pub fn n_cells(&self) -> usize {
        self.n_scenarios() * self.policies.len()
    }

    /// The spec as canonical JSON — the `"spec"` block of the report and
    /// the byte string [`SweepSpec::spec_hash`] is computed over.
    pub fn to_json(&self) -> Value {
        let mut entries: Vec<(&str, Value)> = vec![
            ("rates", Value::from_f64_slice(&self.rates)),
            (
                "core_counts",
                Value::Arr(self.core_counts.iter().map(|&c| c.into()).collect()),
            ),
            (
                "policies",
                Value::Arr(self.policies.iter().map(|p| p.as_str().into()).collect()),
            ),
            (
                "workloads",
                Value::Arr(self.workloads.iter().map(|w| w.name().into()).collect()),
            ),
            ("replicas", self.replicas.into()),
            ("duration_s", self.duration_s.into()),
            ("n_prompt", self.n_prompt.into()),
            ("n_token", self.n_token.into()),
            // u64 seeds exceed f64's 2^53 integer range; keep full fidelity.
            ("seed", format!("{}", self.seed).into()),
        ];
        // Optional blocks appear only when set, so pre-fleet specs keep
        // their canonical bytes (and spec hashes) exactly.
        if let Some(fleet) = &self.fleet {
            entries.push(("fleet", fleet.to_json()));
        }
        if let Some(lc) = &self.lifecycle {
            entries.push(("lifecycle", lc.to_json()));
        }
        Value::obj(entries)
    }

    /// FNV-1a 64 over the canonical spec JSON, as 16 hex digits. Recorded
    /// in the `cells.jsonl` header so `--resume` can verify the on-disk
    /// cells belong to this exact grid.
    pub fn spec_hash(&self) -> String {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in self.to_json().to_string_compact().bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        format!("{h:016x}")
    }

    /// Derive the cell at `index` directly, without materializing the
    /// grid — the streaming engine's workers stay O(1) memory per cell
    /// even on grids too big to expand up front. The decomposition
    /// inverts the [`SweepSpec::cells`] nesting: policies vary fastest,
    /// then replicas, rates, core counts, workloads.
    pub fn cell(&self, index: usize) -> SweepCell {
        assert!(index < self.n_cells(), "cell index {index} out of range");
        let scenario = index / self.policies.len();
        let policy = self.policies[index % self.policies.len()].clone();
        let mut s = scenario;
        let replica = s % self.replicas;
        s /= self.replicas;
        let rate = self.rates[s % self.rates.len()];
        s /= self.rates.len();
        let cores = self.core_counts[s % self.core_counts.len()];
        s /= self.core_counts.len();
        let workload = self.workloads[s];
        SweepCell {
            index,
            scenario,
            workload,
            cores,
            rate,
            replica,
            policy,
            seed: cell_seed(self.seed, scenario as u64),
        }
    }

    /// Expand the axes into the full ordered cell list (the in-memory
    /// engine's shape; equal to `(0..n_cells()).map(|i| cell(i))`).
    pub fn cells(&self) -> Vec<SweepCell> {
        (0..self.n_cells()).map(|i| self.cell(i)).collect()
    }
}

/// A `K/N` shard assignment for distributing one grid across machines:
/// the invocation owns exactly the cells whose
/// `cell_index % count == index` (see [`ShardSpec::owns`]), so the N
/// shards partition the grid disjointly and completely. `0/1` (the
/// default, [`ShardSpec::full`]) is the whole grid. Interleaved
/// ownership keeps shards balanced across slow and fast cells regardless
/// of how the axes are ordered.
///
/// Because cell seeds derive from the cell index ([`cell_seed`]) and
/// never from execution order, a cell simulates identically whichever
/// shard runs it — which is what lets `carbon-sim merge` reassemble
/// shard spills into a report byte-identical to a single-machine run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// This shard's position, in `0..count`.
    pub index: usize,
    /// Total number of shards the grid is split across.
    pub count: usize,
}

impl ShardSpec {
    /// The whole grid as one shard (`0/1`) — an unsharded run.
    pub fn full() -> ShardSpec {
        ShardSpec { index: 0, count: 1 }
    }

    pub fn is_full(&self) -> bool {
        self.count == 1
    }

    pub fn new(index: usize, count: usize) -> Result<ShardSpec, String> {
        if count == 0 {
            return Err("shard count must be ≥ 1".to_string());
        }
        if index >= count {
            return Err(format!("shard index {index} out of range for {count} shards (0..{count})"));
        }
        Ok(ShardSpec { index, count })
    }

    /// Parse the CLI form `K/N` (e.g. `--shard 0/3`).
    pub fn parse(s: &str) -> Result<ShardSpec, String> {
        let (k, n) = s
            .split_once('/')
            .ok_or_else(|| format!("bad shard '{s}': expected K/N, e.g. 0/3"))?;
        let index =
            k.trim().parse::<usize>().map_err(|e| format!("bad shard index '{k}': {e}"))?;
        let count =
            n.trim().parse::<usize>().map_err(|e| format!("bad shard count '{n}': {e}"))?;
        ShardSpec::new(index, count)
    }

    /// Does this shard own the cell at `cell_index`?
    pub fn owns(&self, cell_index: usize) -> bool {
        cell_index % self.count == self.index
    }

    /// How many cells of an `n`-cell grid this shard owns.
    pub fn owned_count(&self, n: usize) -> usize {
        n / self.count + usize::from(n % self.count > self.index)
    }
}

impl fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

/// Derive a cell's seed from the spec seed and its **scenario** index.
/// A pure function of its arguments — independent of thread count and
/// execution order — so sweeps are reproducible by construction.
pub fn cell_seed(base: u64, scenario: u64) -> u64 {
    // Golden-ratio stride into the SplitMix64-seeded generator keeps
    // neighbouring scenarios' streams decorrelated.
    Rng::new(base.wrapping_add((scenario + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))).next_u64()
}

/// One expanded grid cell.
#[derive(Clone, Debug)]
pub struct SweepCell {
    /// Position in the expanded cell list (report order).
    pub index: usize,
    /// Scenario = (workload, cores, rate, replica); shared by all
    /// policies run on it.
    pub scenario: usize,
    pub workload: Workload,
    pub cores: usize,
    pub rate: f64,
    pub replica: usize,
    pub policy: String,
    /// Derived seed (same for every policy of the scenario → paired
    /// trace + silicon).
    pub seed: u64,
}

/// A finished cell: the cell coordinates plus the simulation result.
#[derive(Clone, Debug)]
pub struct SweepCellResult {
    pub cell: SweepCell,
    pub result: SimResult,
}

impl SweepCellResult {
    /// Deterministic JSON record: cell coordinates + the result's
    /// seed-deterministic summary (wall-clock time is deliberately
    /// excluded — see [`SimResult::to_json_summary`]).
    pub fn to_json(&self) -> Value {
        let c = &self.cell;
        let mut obj = match self.result.to_json_summary() {
            Value::Obj(o) => o,
            _ => unreachable!("to_json_summary returns an object"),
        };
        obj.insert("index".into(), c.index.into());
        obj.insert("scenario".into(), c.scenario.into());
        obj.insert("workload".into(), c.workload.name().into());
        obj.insert("rate_rps".into(), c.rate.into());
        obj.insert("replica".into(), c.replica.into());
        // u64 seeds exceed f64's 2^53 integer range; keep full fidelity.
        obj.insert("seed".into(), format!("{}", c.seed).into());
        Value::Obj(obj)
    }
}

/// Decorrelates the trace generator's RNG stream from the cluster's:
/// both are seeded per cell, and `Rng::new` is deterministic, so giving
/// them the same raw seed would replay identical draw sequences —
/// arrivals correlated with service times and silicon sampling (the
/// figure runners avoid this the same way, see [`super::Scale::trace`]).
const TRACE_SEED_XOR: u64 = 0x7AC3_5EED_0000_0001;

/// Run one cell: synthesize its trace, build the cluster, simulate.
/// Uses the default queue implementation; the queue kind is an
/// execution detail and never part of the spec identity.
pub fn run_cell(spec: &SweepSpec, cell: &SweepCell) -> SweepCellResult {
    run_cell_with_queue(spec, cell, QueueKind::default())
}

/// [`run_cell`] under an explicit queue implementation (`--queue`).
/// Reports are byte-identical for any choice — pinned by
/// `tests/queue_sweep_identity.rs` and the CI heap-vs-calendar diff.
pub fn run_cell_with_queue(
    spec: &SweepSpec,
    cell: &SweepCell,
    queue: QueueKind,
) -> SweepCellResult {
    let trace = AzureTraceGen::new(TraceParams {
        rate_rps: cell.rate,
        duration_s: spec.duration_s,
        workload: cell.workload,
        seed: cell.seed ^ TRACE_SEED_XOR,
    })
    .generate();
    let cfg = ClusterConfig {
        n_prompt: spec.n_prompt,
        n_token: spec.n_token,
        cores_per_cpu: cell.cores,
        policy: cell.policy.clone(),
        seed: cell.seed,
        queue,
        fleet: spec.fleet.clone(),
        lifecycle: spec.lifecycle.clone(),
        ..ClusterConfig::default()
    };
    let result = Cluster::new(cfg).run(&trace);
    SweepCellResult { cell: cell.clone(), result }
}

/// The aggregated sweep output.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub spec: SweepSpec,
    /// In cell-index order (stable across thread counts).
    pub cells: Vec<SweepCellResult>,
}

/// Run the full sweep on `threads` workers (0 = one per core) under the
/// default queue implementation.
pub fn run(spec: &SweepSpec, threads: usize) -> Result<SweepReport, String> {
    run_with_queue(spec, threads, QueueKind::default())
}

/// [`run`] under an explicit queue implementation (`--queue`).
pub fn run_with_queue(
    spec: &SweepSpec,
    threads: usize,
    queue: QueueKind,
) -> Result<SweepReport, String> {
    spec.validate()?;
    let cells = spec.cells();
    let results =
        pool::run_indexed(cells.len(), threads, |i| run_cell_with_queue(spec, &cells[i], queue));
    Ok(SweepReport { spec: spec.clone(), cells: results })
}

/// Report serialization format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    Json,
    Csv,
}

impl Format {
    pub fn parse(s: &str) -> Result<Format, String> {
        match s {
            "json" => Ok(Format::Json),
            "csv" => Ok(Format::Csv),
            other => Err(format!("unknown format '{other}' (json|csv)")),
        }
    }
}

/// CSV column order. Every name is a key of [`SweepCellResult::to_json`]'s
/// object — [`SweepReport::to_csv`] extracts values from that same record,
/// so the two serializations cannot drift apart.
pub const CSV_COLUMNS: &[&str] = &[
    "scenario",
    "workload",
    "cores",
    "rate_rps",
    "replica",
    "policy",
    "seed",
    "completed",
    "events",
    "sim_duration_s",
    "rate_achieved_rps",
    "ttft_p50_s",
    "ttft_p99_s",
    "e2e_p50_s",
    "e2e_p99_s",
    "fred_mean_ghz",
    "freq_cv_mean",
    "oversub_fraction",
    "idle_p50",
];

/// Columns appended after [`CSV_COLUMNS`] for fleet-configured sweeps —
/// each is a key the cell record gains when the spec carries a `fleet`
/// block (see [`SimResult::to_json_summary`]).
pub const LIFECYCLE_CSV_COLUMNS: &[&str] = &[
    "active_capacity_fraction",
    "lifecycle_core_failures",
    "lifecycle_rerouted",
    "lifecycle_retirements",
    "lifecycle_yearly_embodied_kg",
];

/// The CSV column list for `spec`: the historic columns, plus the
/// lifecycle columns iff the spec carries a `fleet` block. Keeping the
/// extension conditional preserves non-fleet reports byte-for-byte.
pub fn csv_columns(spec: &SweepSpec) -> Vec<&'static str> {
    let mut cols: Vec<&'static str> = CSV_COLUMNS.to_vec();
    if spec.fleet.is_some() {
        cols.extend_from_slice(LIFECYCLE_CSV_COLUMNS);
    }
    cols
}

/// RFC-4180 CSV field quoting: wrap the field in double quotes (doubling
/// any inner quote) when it contains a comma, quote, or line break;
/// everything else passes through bare, so reports whose fields never
/// need quoting keep their historic bytes. Without this, one
/// spec-provided name containing a comma silently shifts every column
/// after it.
pub fn csv_field(s: &str) -> String {
    if !s.contains(|c| matches!(c, ',' | '"' | '\n' | '\r')) {
        return s.to_string();
    }
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        if c == '"' {
            out.push('"');
        }
        out.push(c);
    }
    out.push('"');
    out
}

impl SweepReport {
    /// The whole report as one deterministic JSON document (schema
    /// documented in `docs/output-schemas.md`, versioned by
    /// [`super::OUTPUT_SCHEMA_VERSION`]).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("spec", self.spec.to_json()),
            ("schema_version", super::OUTPUT_SCHEMA_VERSION.into()),
            ("n_cells", self.cells.len().into()),
            ("cells", Value::Arr(self.cells.iter().map(|c| c.to_json()).collect())),
        ])
    }

    /// The per-cell table as deterministic CSV, extracted column-by-column
    /// from the same JSON record [`SweepCellResult::to_json`] emits.
    pub fn to_csv(&self) -> String {
        let cols = csv_columns(&self.spec);
        let mut out = String::new();
        out.push_str(&cols.join(","));
        out.push('\n');
        for cr in &self.cells {
            let record = cr.to_json();
            let row: Vec<String> = cols
                .iter()
                .map(|col| match record.get(col) {
                    // Strings (workload, policy, seed) are quoted only
                    // when RFC 4180 requires it.
                    Some(Value::Str(s)) => csv_field(s),
                    Some(v) => v.to_string_compact(),
                    None => unreachable!("CSV column '{col}' missing from cell record"),
                })
                .collect();
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Serialize in the given format.
    pub fn render(&self, format: Format) -> String {
        match format {
            Format::Json => {
                let mut s = self.to_json().to_string_pretty();
                s.push('\n');
                s
            }
            Format::Csv => self.to_csv(),
        }
    }

    /// Write the rendered report to a file.
    pub fn write(&self, path: &Path, format: Format) -> std::io::Result<()> {
        std::fs::write(path, self.render(format))
    }

    /// Human-readable per-cell summary table (the CLI's stdout view).
    pub fn print_table(&self) {
        println!(
            "{:>4} {:<12} {:>5} {:>7} {:>3} {:<12} {:>7} {:>9} {:>9} {:>10} {:>9}",
            "#", "workload", "cores", "rate", "rep", "policy", "reqs", "e2e_p50", "e2e_p99",
            "fred(MHz)", "oversub"
        );
        for cr in &self.cells {
            let c = &cr.cell;
            let r = &cr.result;
            let e2e = r.e2e_summary();
            let fred = crate::util::stats::mean(&r.mean_fred_per_machine());
            println!(
                "{:>4} {:<12} {:>5} {:>7.1} {:>3} {:<12} {:>7} {:>9.3} {:>9.3} {:>10.3} {:>9.4}",
                c.scenario,
                c.workload.name(),
                c.cores,
                c.rate,
                c.replica,
                c.policy,
                r.completed_requests,
                e2e.p50,
                e2e.p99,
                fred * 1e3,
                r.oversub_fraction(),
            );
        }
    }
}

// ------------------------------------------------------------ CLI parsing

/// Parse a comma-separated f64 list ("40,60,80").
pub fn parse_f64_list(s: &str) -> Result<Vec<f64>, String> {
    s.split(',')
        .map(|t| t.trim())
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<f64>().map_err(|e| format!("bad number '{t}': {e}")))
        .collect()
}

/// Parse a comma-separated usize list ("40,80").
pub fn parse_usize_list(s: &str) -> Result<Vec<usize>, String> {
    s.split(',')
        .map(|t| t.trim())
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<usize>().map_err(|e| format!("bad count '{t}': {e}")))
        .collect()
}

/// Parse a comma-separated policy list; "all" expands to
/// [`ALL_POLICIES`].
pub fn parse_policy_list(s: &str) -> Result<Vec<String>, String> {
    if s.trim() == "all" {
        return Ok(ALL_POLICIES.iter().map(|p| p.to_string()).collect());
    }
    let list: Vec<String> = s
        .split(',')
        .map(|t| t.trim())
        .filter(|t| !t.is_empty())
        .map(|t| t.to_string())
        .collect();
    for p in &list {
        crate::policy::by_name(p)?;
    }
    Ok(list)
}

/// Parse a comma-separated workload list ("mixed,diurnal,bursty").
pub fn parse_workload_list(s: &str) -> Result<Vec<Workload>, String> {
    s.split(',')
        .map(|t| t.trim())
        .filter(|t| !t.is_empty())
        .map(Workload::parse)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SweepSpec {
        SweepSpec {
            rates: vec![4.0, 8.0],
            core_counts: vec![8],
            policies: vec!["linux".into(), "proposed".into()],
            workloads: vec![Workload::Mixed, Workload::Bursty],
            replicas: 2,
            duration_s: 3.0,
            n_prompt: 1,
            n_token: 1,
            seed: 11,
            fleet: None,
            lifecycle: None,
        }
    }

    fn tiny_fleet() -> SweepSpec {
        use crate::cluster::MachineGroup;
        let mut spec = tiny();
        spec.fleet = Some(FleetConfig {
            groups: vec![MachineGroup {
                count: 2,
                cores: 8,
                generation: "paper".into(),
                embodied_kg: 278.3,
                lifetime_yr: 3.0,
                commission_age_yr: 0.0,
            }],
        });
        spec
    }

    #[test]
    fn expansion_counts_and_order() {
        let spec = tiny();
        assert_eq!(spec.n_scenarios(), 2 * 1 * 2 * 2);
        assert_eq!(spec.n_cells(), spec.n_scenarios() * 2);
        let cells = spec.cells();
        assert_eq!(cells.len(), spec.n_cells());
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
        // Policies of one scenario are adjacent and share the seed.
        for pair in cells.chunks(2) {
            assert_eq!(pair[0].scenario, pair[1].scenario);
            assert_eq!(pair[0].seed, pair[1].seed);
            assert_ne!(pair[0].policy, pair[1].policy);
        }
        // Different scenarios get different seeds.
        assert_ne!(cells[0].seed, cells[2].seed);
    }

    #[test]
    fn cell_by_index_matches_the_nested_loop_expansion() {
        // Pin cell(i)'s index decomposition to the documented nesting:
        // workloads (outer) → cores → rates → replicas → policies (inner).
        let spec = tiny();
        let mut expect = Vec::new();
        let mut scenario = 0usize;
        for &workload in &spec.workloads {
            for &cores in &spec.core_counts {
                for &rate in &spec.rates {
                    for replica in 0..spec.replicas {
                        for policy in &spec.policies {
                            expect.push((
                                expect.len(),
                                scenario,
                                workload,
                                cores,
                                rate,
                                replica,
                                policy.clone(),
                                cell_seed(spec.seed, scenario as u64),
                            ));
                        }
                        scenario += 1;
                    }
                }
            }
        }
        assert_eq!(expect.len(), spec.n_cells());
        for (i, e) in expect.iter().enumerate() {
            let c = spec.cell(i);
            let got =
                (c.index, c.scenario, c.workload, c.cores, c.rate, c.replica, c.policy, c.seed);
            assert_eq!(&got, e, "cell {i}");
        }
    }

    #[test]
    fn spec_hash_tracks_spec_identity() {
        let a = tiny();
        assert_eq!(a.spec_hash(), tiny().spec_hash());
        assert_eq!(a.spec_hash().len(), 16);
        let mut b = tiny();
        b.seed = 12;
        assert_ne!(a.spec_hash(), b.spec_hash());
        let mut c = tiny();
        c.rates.push(16.0);
        assert_ne!(a.spec_hash(), c.spec_hash());
        let mut d = tiny();
        d.policies.reverse();
        assert_ne!(a.spec_hash(), d.spec_hash(), "axis order is part of the identity");
    }

    #[test]
    fn report_json_carries_schema_version() {
        let mut spec = SweepSpec::smoke();
        spec.duration_s = 2.0;
        let report = run(&spec, 1).unwrap();
        let v = crate::util::json::parse(&report.to_json().to_string_pretty()).unwrap();
        assert_eq!(v.usize_or("schema_version", 0), crate::experiments::OUTPUT_SCHEMA_VERSION);
    }

    #[test]
    fn cell_seed_is_pure_and_spreads() {
        assert_eq!(cell_seed(42, 0), cell_seed(42, 0));
        assert_ne!(cell_seed(42, 0), cell_seed(42, 1));
        assert_ne!(cell_seed(42, 0), cell_seed(43, 0));
    }

    #[test]
    fn validate_rejects_bad_specs() {
        let mut s = tiny();
        s.rates.clear();
        assert!(s.validate().is_err());
        let mut s = tiny();
        s.replicas = 0;
        assert!(s.validate().is_err());
        let mut s = tiny();
        s.policies = vec!["nope".into()];
        assert!(s.validate().is_err());
        let mut s = tiny();
        s.duration_s = 0.0;
        assert!(s.validate().is_err());
        assert!(tiny().validate().is_ok());
    }

    #[test]
    fn fleet_specs_validate_hash_and_extend_the_csv() {
        // Lifecycle without fleet is rejected.
        let mut s = tiny();
        s.lifecycle = Some(LifecycleConfig::default());
        assert!(s.validate().unwrap_err().contains("requires a fleet"));
        // Fleet machine count must match n_prompt + n_token (tiny: 1+1).
        let ok = tiny_fleet();
        assert!(ok.validate().is_ok());
        let mut bad = tiny_fleet();
        bad.fleet.as_mut().unwrap().groups[0].count = 3;
        assert!(bad.validate().is_err());
        // The optional block changes the canonical JSON and the hash;
        // its absence keeps the pre-fleet key set.
        assert_ne!(tiny().spec_hash(), ok.spec_hash());
        let plain = tiny().to_json().to_string_compact();
        assert!(!plain.contains("fleet"), "non-fleet specs keep their bytes");
        assert!(ok.to_json().to_string_compact().contains("\"fleet\""));
        // CSV columns extend only for fleet specs.
        assert_eq!(csv_columns(&tiny()), CSV_COLUMNS.to_vec());
        let cols = csv_columns(&ok);
        assert_eq!(cols.len(), CSV_COLUMNS.len() + LIFECYCLE_CSV_COLUMNS.len());
        assert!(cols.contains(&"lifecycle_yearly_embodied_kg"));
    }

    #[test]
    fn fleet_sweep_cells_report_lifecycle_columns() {
        let mut spec = tiny_fleet();
        spec.rates = vec![5.0];
        spec.workloads = vec![Workload::Mixed];
        spec.replicas = 1;
        spec.lifecycle = Some(LifecycleConfig {
            failures: vec![crate::cluster::CoreFailure { machine: 1, core: 0, time_s: 0.5 }],
            ..LifecycleConfig::default()
        });
        let report = run(&spec, 1).unwrap();
        let csv = report.to_csv();
        let header = csv.lines().next().unwrap();
        assert_eq!(header, csv_columns(&spec).join(","));
        for cr in &report.cells {
            let record = cr.to_json();
            for col in LIFECYCLE_CSV_COLUMNS {
                assert!(record.get(col).is_some(), "missing {col}");
            }
            assert_eq!(record.usize_or("lifecycle_core_failures", 99), 1);
        }
    }

    #[test]
    fn paired_silicon_and_trace_across_policies() {
        let mut spec = tiny();
        spec.rates = vec![5.0];
        spec.workloads = vec![Workload::Mixed];
        spec.replicas = 1;
        let report = run(&spec, 1).unwrap();
        assert_eq!(report.cells.len(), 2);
        let (a, b) = (&report.cells[0], &report.cells[1]);
        assert_eq!(a.result.f0, b.result.f0, "policies must share silicon");
        assert_eq!(a.result.rate_rps, b.result.rate_rps, "policies must share the trace");
        assert_ne!(a.cell.policy, b.cell.policy);
    }

    #[test]
    fn axis_parsers() {
        assert_eq!(parse_f64_list("40, 60,80").unwrap(), vec![40.0, 60.0, 80.0]);
        assert!(parse_f64_list("40,x").is_err());
        assert_eq!(parse_usize_list("40,80").unwrap(), vec![40, 80]);
        assert_eq!(parse_policy_list("all").unwrap().len(), ALL_POLICIES.len());
        assert!(parse_policy_list("linux,nope").is_err());
        assert_eq!(
            parse_workload_list("mixed,diurnal,bursty").unwrap(),
            vec![Workload::Mixed, Workload::Diurnal, Workload::Bursty]
        );
        assert!(parse_workload_list("mixed,frob").is_err());
        assert_eq!(Format::parse("json").unwrap(), Format::Json);
        assert!(Format::parse("xml").is_err());
    }

    #[test]
    fn shard_spec_parses_and_partitions() {
        let s = ShardSpec::parse("1/3").unwrap();
        assert_eq!((s.index, s.count), (1, 3));
        assert_eq!(s.to_string(), "1/3");
        assert!(!s.is_full());
        assert!(ShardSpec::parse("0/1").unwrap().is_full());
        for bad in ["", "3", "x/2", "1/x", "1/0", "2/2", "5/3", "1/2/3"] {
            assert!(ShardSpec::parse(bad).is_err(), "'{bad}' should not parse");
        }
        // N shards partition any grid disjointly and completely.
        for n in [0usize, 1, 7, 12] {
            for count in [1usize, 2, 3, 5] {
                let shards: Vec<ShardSpec> =
                    (0..count).map(|k| ShardSpec::new(k, count).unwrap()).collect();
                let mut owners = vec![0usize; n];
                for sh in &shards {
                    let owned: Vec<usize> = (0..n).filter(|&i| sh.owns(i)).collect();
                    assert_eq!(owned.len(), sh.owned_count(n), "{sh} of {n}");
                    for i in owned {
                        owners[i] += 1;
                    }
                }
                assert!(owners.iter().all(|&c| c == 1), "n={n} count={count}: {owners:?}");
            }
        }
    }

    #[test]
    fn csv_field_applies_rfc4180_quoting() {
        assert_eq!(csv_field("plain"), "plain");
        assert_eq!(csv_field(""), "");
        assert_eq!(csv_field("a,b"), "\"a,b\"");
        assert_eq!(csv_field("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(csv_field("two\nlines"), "\"two\nlines\"");
        assert_eq!(csv_field("cr\rhere"), "\"cr\rhere\"");
    }

    #[test]
    fn csv_quotes_fields_that_need_it_and_roundtrips() {
        // A cell whose policy name carries a comma and quotes must not
        // shift the columns after it. to_csv never validates names, so
        // mutate a real cell's record post-run.
        let mut spec = SweepSpec::smoke();
        spec.duration_s = 2.0;
        spec.policies = vec!["linux".into()];
        let mut report = run(&spec, 1).unwrap();
        let evil = "pro,posed \"v2\"";
        report.cells[0].cell.policy = evil.to_string();
        let csv = report.to_csv();
        let line = csv.lines().nth(1).unwrap();
        // Minimal RFC-4180 reader: split on commas outside quotes,
        // un-double inner quotes.
        let mut fields: Vec<String> = Vec::new();
        let mut cur = String::new();
        let mut in_quotes = false;
        let mut chars = line.chars().peekable();
        while let Some(c) = chars.next() {
            match c {
                '"' if in_quotes && chars.peek() == Some(&'"') => {
                    cur.push('"');
                    chars.next();
                }
                '"' => in_quotes = !in_quotes,
                ',' if !in_quotes => fields.push(std::mem::take(&mut cur)),
                c => cur.push(c),
            }
        }
        fields.push(cur);
        assert_eq!(fields.len(), CSV_COLUMNS.len(), "{line}");
        let policy_col = CSV_COLUMNS.iter().position(|&c| c == "policy").unwrap();
        assert_eq!(fields[policy_col], evil);
        // The column after policy is still the seed, undisturbed.
        assert_eq!(fields[policy_col + 1], format!("{}", report.cells[0].cell.seed));
    }

    #[test]
    fn csv_has_header_and_one_row_per_cell() {
        let mut spec = SweepSpec::smoke();
        spec.duration_s = 2.0;
        let report = run(&spec, 2).unwrap();
        let csv = report.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 1 + report.cells.len());
        assert_eq!(lines[0], CSV_COLUMNS.join(","));
        for line in &lines[1..] {
            assert_eq!(line.split(',').count(), CSV_COLUMNS.len());
            assert!(line.split(',').all(|field| !field.is_empty()), "{line}");
        }
        // Every CSV column is a key of the JSON cell record (to_csv
        // extracts from it, so a drift would panic there too).
        let record = report.cells[0].to_json();
        for col in CSV_COLUMNS {
            assert!(record.get(col).is_some(), "CSV column '{col}' missing from JSON record");
        }
    }
}
