//! The served transformer: prefill + decode executables with
//! device-resident weights.
//!
//! Weight tensors are uploaded to the PJRT device once at load time
//! (`buffer_from_host_buffer`) and passed by reference on every call
//! (`execute_b`), so the per-request path moves only tokens, lengths and
//! the KV cache — the same discipline a production server applies.

use anyhow::{Context, Result};

use super::weights::{Manifest, ModelDims};
use super::Runtime;

/// Prefill output: next-token logits + the populated KV cache.
pub struct PrefillOut {
    pub logits: Vec<f32>,
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
}

/// Decode output: next-token logits + the updated KV cache.
pub struct DecodeOut {
    pub logits: Vec<f32>,
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
}

/// Chunked-decode output (§Perf: one dispatch per `chunk` tokens).
pub struct DecodeChunkOut {
    /// [B, chunk] generated tokens; −1 marks frozen (budget-exhausted) slots.
    pub tokens: Vec<i32>,
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
    pub lengths: Vec<i32>,
    pub remaining: Vec<i32>,
}

/// The loaded model.
pub struct ServedModel {
    rt: Runtime,
    pub dims: ModelDims,
    /// Decode steps fused per decode_chunk dispatch (0 = unavailable).
    pub decode_chunk_steps: usize,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
    decode_chunk_exe: Option<xla::PjRtLoadedExecutable>,
    /// Device-resident parameter buffers, in param_spec order.
    weights: Vec<xla::PjRtBuffer>,
}

impl ServedModel {
    /// Load artifacts (manifest + weights + both executables).
    pub fn load(rt: Runtime) -> Result<ServedModel> {
        let manifest = Manifest::load(&rt.artifacts_dir)?;
        let host_weights = manifest.load_weights(&rt.artifacts_dir)?;
        let weights = manifest
            .params
            .iter()
            .zip(host_weights.iter())
            .map(|(p, w)| rt.upload_f32(w, &p.shape))
            .collect::<Result<Vec<_>>>()
            .context("uploading weights")?;
        let prefill_exe = rt.load_hlo("prefill.hlo.txt")?;
        let decode_exe = rt.load_hlo("decode.hlo.txt")?;
        let decode_chunk_exe = if manifest.decode_chunk > 0 {
            Some(rt.load_hlo("decode_chunk.hlo.txt")?)
        } else {
            None
        };
        Ok(ServedModel {
            rt,
            dims: manifest.model,
            decode_chunk_steps: manifest.decode_chunk,
            prefill_exe,
            decode_exe,
            decode_chunk_exe,
            weights,
        })
    }

    /// Run a prefill over `tokens` ([B, S] row-major, padded) with the
    /// given per-sequence lengths.
    pub fn prefill(&self, tokens: &[i32], lengths: &[i32]) -> Result<PrefillOut> {
        let d = &self.dims;
        anyhow::ensure!(tokens.len() == d.batch * d.max_seq, "tokens must be B*S");
        anyhow::ensure!(lengths.len() == d.batch);
        let tok_buf = self.rt.upload_i32(tokens, &[d.batch, d.max_seq])?;
        let len_buf = self.rt.upload_i32(lengths, &[d.batch])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        let out = self.prefill_exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0]
            .to_literal_sync()?;
        let (logits, k, v) = out.to_tuple3().context("prefill returns 3-tuple")?;
        Ok(PrefillOut {
            logits: logits.to_vec::<f32>()?,
            k_cache: k.to_vec::<f32>()?,
            v_cache: v.to_vec::<f32>()?,
        })
    }

    /// Run one decode step. `k_cache`/`v_cache` are the flattened
    /// [L, B, S, H, Dh] buffers from the previous step/prefill; `tokens`
    /// the per-sequence token to feed; `lengths` each sequence's current
    /// context length.
    pub fn decode(
        &self,
        k_cache: &[f32],
        v_cache: &[f32],
        tokens: &[i32],
        lengths: &[i32],
    ) -> Result<DecodeOut> {
        let d = &self.dims;
        anyhow::ensure!(k_cache.len() == d.kv_elems() && v_cache.len() == d.kv_elems());
        anyhow::ensure!(tokens.len() == d.batch && lengths.len() == d.batch);
        let kv_dims = d.kv_dims();
        let k_buf = self.rt.upload_f32(k_cache, &kv_dims)?;
        let v_buf = self.rt.upload_f32(v_cache, &kv_dims)?;
        let tok_buf = self.rt.upload_i32(tokens, &[d.batch])?;
        let len_buf = self.rt.upload_i32(lengths, &[d.batch])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&k_buf);
        args.push(&v_buf);
        args.push(&tok_buf);
        args.push(&len_buf);
        let out = self.decode_exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0]
            .to_literal_sync()?;
        let (logits, k, v) = out.to_tuple3().context("decode returns 3-tuple")?;
        Ok(DecodeOut {
            logits: logits.to_vec::<f32>()?,
            k_cache: k.to_vec::<f32>()?,
            v_cache: v.to_vec::<f32>()?,
        })
    }

    /// Run one fused chunk of greedy decode steps (§Perf): a single PJRT
    /// dispatch advances every active slot by up to `decode_chunk_steps`
    /// tokens, freezing slots whose `remaining` budget hits zero.
    pub fn decode_chunk(
        &self,
        k_cache: &[f32],
        v_cache: &[f32],
        tokens: &[i32],
        lengths: &[i32],
        remaining: &[i32],
    ) -> Result<DecodeChunkOut> {
        let d = &self.dims;
        let exe = self
            .decode_chunk_exe
            .as_ref()
            .context("decode_chunk artifact not built (re-run `make artifacts`)")?;
        anyhow::ensure!(k_cache.len() == d.kv_elems() && v_cache.len() == d.kv_elems());
        anyhow::ensure!(
            tokens.len() == d.batch && lengths.len() == d.batch && remaining.len() == d.batch
        );
        let kv_dims = d.kv_dims();
        let k_buf = self.rt.upload_f32(k_cache, &kv_dims)?;
        let v_buf = self.rt.upload_f32(v_cache, &kv_dims)?;
        let tok_buf = self.rt.upload_i32(tokens, &[d.batch])?;
        let len_buf = self.rt.upload_i32(lengths, &[d.batch])?;
        let rem_buf = self.rt.upload_i32(remaining, &[d.batch])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&k_buf);
        args.push(&v_buf);
        args.push(&tok_buf);
        args.push(&len_buf);
        args.push(&rem_buf);
        let mut out =
            exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0].to_literal_sync()?;
        let parts = out.decompose_tuple().context("decode_chunk returns 5-tuple")?;
        anyhow::ensure!(parts.len() == 5, "expected 5 outputs, got {}", parts.len());
        let mut it = parts.into_iter();
        Ok(DecodeChunkOut {
            tokens: it.next().unwrap().to_vec::<i32>()?,
            k_cache: it.next().unwrap().to_vec::<f32>()?,
            v_cache: it.next().unwrap().to_vec::<f32>()?,
            lengths: it.next().unwrap().to_vec::<i32>()?,
            remaining: it.next().unwrap().to_vec::<i32>()?,
        })
    }

    /// Greedy next tokens from a logits buffer ([B, vocab] row-major).
    pub fn argmax_tokens(&self, logits: &[f32]) -> Vec<i32> {
        let v = self.dims.vocab;
        logits
            .chunks_exact(v)
            .map(|row| {
                row.iter()
                    .enumerate()
                    // total_cmp: a NaN logit must not panic (or, under
                    // max_by's partial ordering, silently win) — NaN
                    // sorts above +inf, so the argmax stays total.
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect()
    }
}
