//! Parse `manifest.json` + `weights.bin` — the parameter contract between
//! `python/compile/aot.py` and the Rust runtime.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{parse, Value};

/// One parameter tensor's layout in `weights.bin`.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// Offset in f32 elements (not bytes).
    pub offset: usize,
}

impl ParamEntry {
    pub fn n_elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Served-model hyperparameters (mirrors python's ModelConfig).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub batch: usize,
}

impl ModelDims {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }
    /// KV-cache dims: [L, B, S, H, Dh].
    pub fn kv_dims(&self) -> Vec<usize> {
        vec![self.n_layers, self.batch, self.max_seq, self.n_heads, self.head_dim()]
    }
    pub fn kv_elems(&self) -> usize {
        self.kv_dims().iter().product()
    }
}

/// Aging-artifact grid dims.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AgingDims {
    pub machines: usize,
    pub cores: usize,
    pub n: f64,
    pub vdd: f64,
    pub vth: f64,
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: ModelDims,
    pub params: Vec<ParamEntry>,
    pub total_floats: usize,
    pub aging: AgingDims,
    /// Decode steps fused per dispatch by decode_chunk.hlo.txt (0 when the
    /// artifact set predates chunked decode).
    pub decode_chunk: usize,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
        let v = parse(&text).map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        let cfg = v.get("config").context("manifest missing config")?;
        let model = ModelDims {
            vocab: cfg.usize_or("vocab", 0),
            d_model: cfg.usize_or("d_model", 0),
            n_heads: cfg.usize_or("n_heads", 0),
            n_layers: cfg.usize_or("n_layers", 0),
            d_ff: cfg.usize_or("d_ff", 0),
            max_seq: cfg.usize_or("max_seq", 0),
            batch: cfg.usize_or("batch", 0),
        };
        if model.vocab == 0 || model.d_model == 0 || model.batch == 0 {
            bail!("manifest config incomplete: {model:?}");
        }
        let params = v
            .get("params")
            .and_then(Value::as_arr)
            .context("manifest missing params")?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p.get("name").and_then(Value::as_str).context("param name")?.to_string(),
                    shape: p
                        .get("shape")
                        .and_then(Value::as_arr)
                        .context("param shape")?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    offset: p.get("offset").and_then(Value::as_usize).context("param offset")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let ag = v.get("aging").context("manifest missing aging")?;
        let aging = AgingDims {
            machines: ag.usize_or("machines", 0),
            cores: ag.usize_or("cores", 0),
            n: ag.f64_or("n", 1.0 / 6.0),
            vdd: ag.f64_or("vdd", 1.0),
            vth: ag.f64_or("vth", 0.3),
        };
        Ok(Manifest {
            model,
            params,
            total_floats: v.usize_or("total_floats", 0),
            aging,
            decode_chunk: v.usize_or("decode_chunk", 0),
        })
    }

    /// Load weights.bin and slice it per the param table.
    pub fn load_weights(&self, dir: impl AsRef<Path>) -> Result<Vec<Vec<f32>>> {
        let path = dir.as_ref().join("weights.bin");
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != self.total_floats * 4 {
            bail!("weights.bin size {} != manifest total {}", bytes.len(), self.total_floats * 4);
        }
        let all: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut out = Vec::with_capacity(self.params.len());
        for p in &self.params {
            let end = p.offset + p.n_elems();
            if end > all.len() {
                bail!("param {} overruns weights.bin", p.name);
            }
            out.push(all[p.offset..end].to_vec());
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        let manifest = r#"{
          "config": {"vocab": 4, "d_model": 2, "n_heads": 1, "n_layers": 1,
                      "d_ff": 4, "max_seq": 8, "batch": 2},
          "params": [
            {"name": "embed", "shape": [4, 2], "offset": 0},
            {"name": "lnf", "shape": [2], "offset": 8}
          ],
          "total_floats": 10,
          "aging": {"machines": 3, "cores": 5, "n": 0.1666, "vdd": 1.0, "vth": 0.3}
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let floats: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let bytes: Vec<u8> = floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("weights.bin"), bytes).unwrap();
    }

    #[test]
    fn parses_manifest_and_weights() {
        let dir = std::env::temp_dir().join("carbon_sim_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.vocab, 4);
        assert_eq!(m.model.head_dim(), 2);
        assert_eq!(m.model.kv_dims(), vec![1, 2, 8, 1, 2]);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.aging.machines, 3);
        let w = m.load_weights(&dir).unwrap();
        assert_eq!(w[0], (0..8).map(|i| i as f32).collect::<Vec<_>>());
        assert_eq!(w[1], vec![8.0, 9.0]);
    }

    #[test]
    fn rejects_truncated_weights() {
        let dir = std::env::temp_dir().join("carbon_sim_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        write_fixture(&dir);
        std::fs::write(dir.join("weights.bin"), [0u8; 8]).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.load_weights(&dir).is_err());
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("carbon_sim_no_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        let _ = std::fs::remove_file(dir.join("manifest.json"));
        assert!(Manifest::load(&dir).is_err());
    }
}
