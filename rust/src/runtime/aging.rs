//! PJRT-backed cluster-wide NBTI aging update.
//!
//! Loads `aging_step.hlo.txt` (the lowered Pallas kernel) and runs the
//! batched `[machines × cores]` ΔVth/frequency refresh through XLA. The
//! simulator uses the pure-Rust path on its hot loop by default; this
//! executable is (a) the cross-validation target proving the L1 kernel
//! and the Rust model agree, and (b) an optional batch path
//! (`carbon-sim simulate --pjrt-aging`) exercising the full
//! three-layer stack.

use anyhow::{Context, Result};

use super::Runtime;

/// Compiled aging-step executable.
pub struct AgingStepPjrt {
    exe: xla::PjRtLoadedExecutable,
    pub machines: usize,
    pub cores: usize,
}

impl AgingStepPjrt {
    pub fn load(rt: &Runtime) -> Result<AgingStepPjrt> {
        let manifest = super::Manifest::load(&rt.artifacts_dir)?;
        let exe = rt.load_hlo("aging_step.hlo.txt")?;
        Ok(AgingStepPjrt { exe, machines: manifest.aging.machines, cores: manifest.aging.cores })
    }

    /// Run one batched update. All slices are `machines*cores` long,
    /// row-major. Returns `(new_dvth, freq_ghz)`.
    pub fn step(
        &self,
        dvth: &[f32],
        adf: &[f32],
        tau: &[f32],
        f0: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let n = self.machines * self.cores;
        anyhow::ensure!(dvth.len() == n && adf.len() == n && tau.len() == n && f0.len() == n);
        let dims = [self.machines, self.cores];
        let lit = |data: &[f32]| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(data)
                .reshape(&[self.machines as i64, self.cores as i64])
                .context("reshape literal")?)
        };
        let args = [lit(dvth)?, lit(adf)?, lit(tau)?, lit(f0)?];
        let _ = dims;
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (d, f) = result.to_tuple2().context("aging_step returns a 2-tuple")?;
        Ok((d.to_vec::<f32>()?, f.to_vec::<f32>()?))
    }
}
