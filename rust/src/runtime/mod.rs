//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py`) and executes them from Rust. Python is never
//! on this path — the artifacts are HLO text + a raw weights file.
//!
//! * [`weights`] — manifest.json / weights.bin parsing.
//! * [`served`] — the transformer executables (prefill + decode) with
//!   device-resident weights.
//! * [`aging`] — the PJRT-backed cluster-wide NBTI update, cross-validated
//!   against [`crate::cpu::aging`].

pub mod aging;
pub mod served;
pub mod weights;

pub use aging::AgingStepPjrt;
pub use served::ServedModel;
pub use weights::{Manifest, ParamEntry};

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A PJRT CPU client plus artifact-directory context.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT runtime rooted at an artifacts directory.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, artifacts_dir: artifacts_dir.as_ref().to_path_buf() })
    }

    /// Default artifacts directory: `$CARBON_SIM_ARTIFACTS` or `artifacts/`.
    pub fn default_artifacts_dir() -> PathBuf {
        std::env::var("CARBON_SIM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Do the artifacts exist? (Tests skip gracefully when they don't.)
    pub fn artifacts_available(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join("manifest.json").exists()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load_hlo(&self, name: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.artifacts_dir.join(name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).with_context(|| format!("compiling {name}"))
    }

    /// Upload an f32 tensor to the device.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an i32 tensor to the device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
}
