//! Operational-vs-embodied carbon of an inference server — the Fig. 1
//! motivation: as grid carbon intensity falls (renewables), operational
//! carbon diminishes and the **CPU-complex embodied** share dominates.
//!
//! Model follows Li'24's A100x4 inference-server breakdown: GPU dominates
//! power (operational), while CPU die + mainboard dominate embodied.

/// Power + embodied model of one GPU inference server.
#[derive(Clone, Copy, Debug)]
pub struct ServerPowerModel {
    pub n_gpus: usize,
    /// Per-GPU average draw while serving (W).
    pub gpu_avg_w: f64,
    /// CPU + platform (board, NICs, fans) average draw (W).
    pub platform_avg_w: f64,
    /// Embodied carbon of the CPU complex: die + mainboard (kgCO₂eq).
    pub cpu_embodied_kg: f64,
    /// Embodied carbon of the GPUs (kgCO₂eq, total).
    pub gpu_embodied_kg: f64,
    /// Other embodied (DRAM, SSD, chassis) (kgCO₂eq).
    pub other_embodied_kg: f64,
    /// Amortization lifetime (years).
    pub lifetime_yr: f64,
}

impl ServerPowerModel {
    /// A100x4 server per Li'24 (Fig. 1's configuration).
    pub fn a100x4() -> ServerPowerModel {
        ServerPowerModel {
            n_gpus: 4,
            gpu_avg_w: 300.0,
            platform_avg_w: 350.0,
            cpu_embodied_kg: 278.3,
            gpu_embodied_kg: 4.0 * 40.0,
            other_embodied_kg: 80.0,
            lifetime_yr: 3.0,
        }
    }

    /// Average server power (kW) while running a per-second inference load.
    pub fn avg_power_kw(&self) -> f64 {
        (self.n_gpus as f64 * self.gpu_avg_w + self.platform_avg_w) / 1000.0
    }

    /// Yearly operational carbon (kgCO₂eq/yr) at a grid carbon intensity
    /// `ci_g_per_kwh` (gCO₂eq per kWh).
    pub fn yearly_operational_kg(&self, ci_g_per_kwh: f64) -> f64 {
        self.avg_power_kw() * 24.0 * 365.0 * ci_g_per_kwh / 1000.0
    }

    /// Yearly embodied carbon split: (cpu, gpu, other) in kg/yr.
    pub fn yearly_embodied_kg(&self) -> (f64, f64, f64) {
        (
            self.cpu_embodied_kg / self.lifetime_yr,
            self.gpu_embodied_kg / self.lifetime_yr,
            self.other_embodied_kg / self.lifetime_yr,
        )
    }

    /// Fraction of total yearly carbon that is CPU-embodied, at `ci`.
    pub fn cpu_embodied_share(&self, ci_g_per_kwh: f64) -> f64 {
        let op = self.yearly_operational_kg(ci_g_per_kwh);
        let (cpu, gpu, other) = self.yearly_embodied_kg();
        cpu / (op + cpu + gpu + other)
    }
}

/// Named grid carbon intensities (gCO₂eq/kWh, IPCC lifecycle medians) —
/// the Fig. 1 x-axis.
pub fn grid_intensities() -> Vec<(&'static str, f64)> {
    vec![
        ("wind", 11.0),
        ("nuclear", 12.0),
        ("hydro", 24.0),
        ("solar", 41.0),
        ("gas", 490.0),
        ("coal", 820.0),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_math() {
        let s = ServerPowerModel::a100x4();
        assert!((s.avg_power_kw() - 1.55).abs() < 1e-12);
    }

    #[test]
    fn operational_scales_with_intensity() {
        let s = ServerPowerModel::a100x4();
        let lo = s.yearly_operational_kg(11.0);
        let hi = s.yearly_operational_kg(820.0);
        assert!((hi / lo - 820.0 / 11.0).abs() < 1e-9);
    }

    #[test]
    fn fig1_shape_cpu_embodied_dominates_under_renewables() {
        // The paper's Fig. 1 claim: with low-carbon energy, CPU embodied
        // becomes the dominant aspect; with coal it is negligible.
        let s = ServerPowerModel::a100x4();
        let share_wind = s.cpu_embodied_share(11.0);
        let share_coal = s.cpu_embodied_share(820.0);
        assert!(share_wind > 0.25, "wind share={share_wind}");
        assert!(share_coal < 0.05, "coal share={share_coal}");
        // And CPU embodied > GPU embodied (Li'24).
        let (cpu, gpu, _) = s.yearly_embodied_kg();
        assert!(cpu > gpu);
    }

    #[test]
    fn intensities_sorted_ascending() {
        let g = grid_intensities();
        for w in g.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }
}
