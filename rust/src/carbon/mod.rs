//! Carbon accounting: embodied amortization (Fig. 7) and the
//! operational-vs-embodied breakdown of an inference server (Fig. 1).

pub mod embodied;
pub mod operational;

pub use embodied::{EmbodiedModel, FleetLedger, ServiceRecord};
pub use operational::{grid_intensities, ServerPowerModel};
