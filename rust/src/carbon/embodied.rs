//! Embodied-carbon amortization — the Fig. 7 estimate.
//!
//! The paper takes a stock Linux inference server's hardware refresh
//! cycle as **3 years** and its CPU-complex embodied carbon as
//! **278.3 kgCO₂eq** over that lifespan (Li'24). Delaying aging effects
//! lets the operator extend the refresh cycle; the paper maps aging
//! performance to lifetime with a **linear model**: a technique whose
//! mean frequency degradation (at a chosen cluster percentile) is k×
//! smaller than the linux baseline's supports a k× longer refresh cycle.
//! Yearly embodied emissions then shrink from `E/3` to `E/(3k)`.
//!
//! The [`FleetLedger`] below extends this static picture to a *living*
//! fleet: machines are commissioned, serve, and retire, and each one's
//! embodied carbon is amortized over its **actual** service window
//! rather than the planned refresh cycle. Early retirement therefore
//! raises a machine's amortization rate (the same kilograms spread over
//! fewer years) — which is precisely the carbon penalty the paper's
//! lifetime-extension argument avoids.

use crate::cpu::aging::SECONDS_PER_YEAR;
use crate::util::stats;

/// Embodied model parameters (paper defaults from Li'24).
#[derive(Clone, Copy, Debug)]
pub struct EmbodiedModel {
    /// CPU-complex embodied carbon per server (kgCO₂eq).
    pub cpu_embodied_kg: f64,
    /// Baseline hardware refresh cycle (years).
    pub base_lifetime_yr: f64,
}

impl EmbodiedModel {
    pub fn paper_default() -> EmbodiedModel {
        EmbodiedModel { cpu_embodied_kg: 278.3, base_lifetime_yr: 3.0 }
    }

    /// Yearly embodied emissions for one server at a given lifetime.
    #[inline]
    pub fn yearly_kg(&self, lifetime_yr: f64) -> f64 {
        assert!(lifetime_yr > 0.0);
        self.cpu_embodied_kg / lifetime_yr
    }

    /// Lifetime extension factor implied by the linear model:
    /// `k = fred_baseline / fred_technique` (≥ 1 when the technique ages
    /// the CPU slower). Degradations must be positive.
    #[inline]
    pub fn extension_factor(&self, fred_baseline: f64, fred_technique: f64) -> f64 {
        if fred_technique <= 0.0 {
            // No measurable aging: cap at a generous bound instead of ∞.
            return 10.0;
        }
        (fred_baseline / fred_technique).max(1e-3)
    }

    /// Extended lifetime (years) for a technique vs the baseline.
    #[inline]
    pub fn extended_lifetime_yr(&self, fred_baseline: f64, fred_technique: f64) -> f64 {
        self.base_lifetime_yr * self.extension_factor(fred_baseline, fred_technique)
    }

    /// Yearly embodied emissions (kg/server/yr) for a technique whose
    /// mean-frequency-degradation percentile is `fred_technique`, against
    /// the linux baseline's `fred_baseline`.
    pub fn yearly_kg_for(&self, fred_baseline: f64, fred_technique: f64) -> f64 {
        self.yearly_kg(self.extended_lifetime_yr(fred_baseline, fred_technique))
    }

    /// Percent reduction in yearly embodied emissions vs the baseline.
    pub fn reduction_pct(&self, fred_baseline: f64, fred_technique: f64) -> f64 {
        let base = self.yearly_kg(self.base_lifetime_yr);
        let tech = self.yearly_kg_for(fred_baseline, fred_technique);
        (1.0 - tech / base) * 100.0
    }
}

/// Fig. 7 helper: yearly cluster emissions from per-machine mean
/// frequency degradations, estimated at percentile `pct`.
pub fn cluster_yearly_kg(
    model: &EmbodiedModel,
    fred_baseline_per_machine: &[f64],
    fred_technique_per_machine: &[f64],
    pct: f64,
    n_machines: usize,
) -> f64 {
    let base_p = stats::percentile(fred_baseline_per_machine, pct);
    let tech_p = stats::percentile(fred_technique_per_machine, pct);
    model.yearly_kg_for(base_p, tech_p) * n_machines as f64
}

/// One machine's service window in the fleet ledger: the embodied carbon
/// charged at commissioning, the lifetime it was *planned* to amortize
/// over, any service age it carried into the simulation, and — once
/// retired — the instant its window closed.
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceRecord {
    /// Machine slot this record belongs to (machine ids are stable across
    /// retirement: the replacement SKU takes over the same slot).
    pub machine: usize,
    /// Embodied carbon charged when the machine was procured (kgCO₂eq).
    pub embodied_kg: f64,
    /// The refresh cycle the charge was planned to amortize over (years).
    pub planned_lifetime_yr: f64,
    /// Service years already accrued before simulation time 0 (the
    /// fleet config's `commission_age_yr`). Zero for replacements
    /// procured mid-run.
    pub prior_age_yr: f64,
    /// Simulation time the record opened (s).
    pub commissioned_s: f64,
    /// Simulation time the record closed (s), once the machine retired.
    pub retired_s: Option<f64>,
}

impl ServiceRecord {
    /// Total service years covered by this record as of `now_s`: the
    /// prior age plus the in-simulation service time (up to retirement).
    pub fn service_yr(&self, now_s: f64) -> f64 {
        let end = self.retired_s.unwrap_or(now_s);
        self.prior_age_yr + (end - self.commissioned_s).max(0.0) / SECONDS_PER_YEAR
    }

    /// Amortization rate (kg/yr). Closed windows spread the charge over
    /// the *actual* service years — an early retirement concentrates the
    /// same kilograms into fewer years. Open windows amortize at the
    /// planned rate, since their actual lifespan is not yet known.
    pub fn yearly_kg(&self, now_s: f64) -> f64 {
        match self.retired_s {
            Some(_) => self.embodied_kg / self.service_yr(now_s).max(1e-9),
            None => self.embodied_kg / self.planned_lifetime_yr,
        }
    }
}

/// Append-only ledger of every machine service window the simulation has
/// seen. Commissioned at fleet construction (and again at each
/// replacement procurement), closed at retirement. All queries are pure
/// functions of the records, so the ledger is trivially deterministic.
#[derive(Clone, Debug, Default)]
pub struct FleetLedger {
    pub records: Vec<ServiceRecord>,
}

impl FleetLedger {
    pub fn new() -> FleetLedger {
        FleetLedger { records: Vec::new() }
    }

    /// Open a service window: a machine is procured and its embodied
    /// carbon charged.
    pub fn commission(
        &mut self,
        machine: usize,
        embodied_kg: f64,
        planned_lifetime_yr: f64,
        prior_age_yr: f64,
        now_s: f64,
    ) {
        assert!(embodied_kg > 0.0 && planned_lifetime_yr > 0.0 && prior_age_yr >= 0.0);
        debug_assert!(
            self.open_record(machine).is_none(),
            "machine {machine} already has an open service window"
        );
        self.records.push(ServiceRecord {
            machine,
            embodied_kg,
            planned_lifetime_yr,
            prior_age_yr,
            commissioned_s: now_s,
            retired_s: None,
        });
    }

    /// Close machine `machine`'s open service window at `now_s`. Returns
    /// false when the machine has no open window.
    pub fn retire(&mut self, machine: usize, now_s: f64) -> bool {
        match self.open_record(machine) {
            Some(i) => {
                self.records[i].retired_s = Some(now_s);
                true
            }
            None => false,
        }
    }

    /// Index of machine `machine`'s open record, if any.
    pub fn open_record(&self, machine: usize) -> Option<usize> {
        self.records.iter().position(|r| r.machine == machine && r.retired_s.is_none())
    }

    /// Machine `machine`'s current service age in years (prior age plus
    /// in-simulation time) — the calendar-age retirement trigger's input.
    pub fn service_age_yr(&self, machine: usize, now_s: f64) -> Option<f64> {
        self.open_record(machine).map(|i| self.records[i].service_yr(now_s))
    }

    /// Total embodied carbon charged across every procurement (kg).
    pub fn total_charged_kg(&self) -> f64 {
        self.records.iter().map(|r| r.embodied_kg).sum()
    }

    /// Embodied carbon amortized over each record's *entire* service
    /// window (prior age included): Σ rate × service-years. For a closed
    /// record the product collapses back to its full charge, so once
    /// every window is closed this equals [`FleetLedger::total_charged_kg`]
    /// exactly — the conservation law `tests/lifecycle_prop.rs` pins.
    pub fn amortized_total_kg(&self, now_s: f64) -> f64 {
        self.records.iter().map(|r| r.yearly_kg(now_s) * r.service_yr(now_s)).sum()
    }

    /// Embodied carbon attributed to the simulated window `[0, now_s]`:
    /// each record's amortization rate times its in-window service time.
    pub fn amortized_in_window_kg(&self, now_s: f64) -> f64 {
        self.records
            .iter()
            .map(|r| {
                let end = r.retired_s.unwrap_or(now_s).min(now_s);
                let in_window_yr = (end - r.commissioned_s).max(0.0) / SECONDS_PER_YEAR;
                r.yearly_kg(now_s) * in_window_yr
            })
            .sum()
    }

    /// The fleet-level yearly-embodied metric reported per sweep cell:
    /// the time-averaged amortization rate over the simulated window
    /// (kg/yr). Early retirements raise it — their charge amortizes over
    /// a shorter total life, so every in-window second carries a higher
    /// rate; lifetime extension lowers it.
    pub fn yearly_embodied_kg(&self, now_s: f64) -> f64 {
        if now_s <= 0.0 {
            return self.records.iter().map(|r| r.yearly_kg(0.0)).sum();
        }
        self.amortized_in_window_kg(now_s) / (now_s / SECONDS_PER_YEAR)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_yearly_is_third_of_total() {
        let m = EmbodiedModel::paper_default();
        assert!((m.yearly_kg(3.0) - 278.3 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn halved_degradation_doubles_lifetime() {
        let m = EmbodiedModel::paper_default();
        assert!((m.extended_lifetime_yr(0.2, 0.1) - 6.0).abs() < 1e-12);
        assert!((m.yearly_kg_for(0.2, 0.1) - 278.3 / 6.0).abs() < 1e-9);
        assert!((m.reduction_pct(0.2, 0.1) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn equal_degradation_no_reduction() {
        let m = EmbodiedModel::paper_default();
        assert!(m.reduction_pct(0.1, 0.1).abs() < 1e-9);
    }

    #[test]
    fn worse_technique_increases_emissions() {
        let m = EmbodiedModel::paper_default();
        assert!(m.reduction_pct(0.1, 0.2) < 0.0);
    }

    #[test]
    fn zero_degradation_capped() {
        let m = EmbodiedModel::paper_default();
        assert!((m.extension_factor(0.1, 0.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn paper_headline_numbers_are_reachable() {
        // A 37.67% reduction corresponds to a 1/(1-0.3767) ≈ 1.604×
        // degradation gap — verify the model arithmetic reproduces it.
        let m = EmbodiedModel::paper_default();
        let k = 1.0 / (1.0 - 0.3767);
        let red = m.reduction_pct(k, 1.0);
        assert!((red - 37.67).abs() < 0.01, "red={red}");
    }

    #[test]
    fn cluster_scaling() {
        let m = EmbodiedModel::paper_default();
        let base = vec![0.2; 22];
        let tech = vec![0.1; 22];
        let total = cluster_yearly_kg(&m, &base, &tech, 99.0, 22);
        assert!((total - 22.0 * 278.3 / 6.0).abs() < 1e-6);
    }

    #[test]
    fn static_fleet_ledger_matches_classic_amortization() {
        // With no retirements the ledger's yearly metric is exactly the
        // paper's Σ embodied / lifetime, at any query instant.
        let mut l = FleetLedger::new();
        l.commission(0, 278.3, 3.0, 0.0, 0.0);
        l.commission(1, 278.3, 3.0, 1.5, 0.0);
        let expect = 2.0 * 278.3 / 3.0;
        assert!((l.yearly_embodied_kg(0.0) - expect).abs() < 1e-9);
        assert!((l.yearly_embodied_kg(120.0) - expect).abs() < 1e-9);
    }

    #[test]
    fn early_retirement_raises_the_yearly_metric() {
        let mut l = FleetLedger::new();
        // Commissioned 2.5 years ago against a 3-year plan, retired after
        // one more in-sim year: actual life 3.5 yr ≥ plan → cheaper rate.
        l.commission(0, 300.0, 3.0, 2.5, 0.0);
        l.retire(0, SECONDS_PER_YEAR);
        let healthy = l.records[0].yearly_kg(0.0);
        assert!((healthy - 300.0 / 3.5).abs() < 1e-9);
        // Same machine scrapped after half a year of total service: the
        // identical charge amortizes over 7× fewer years.
        let mut l2 = FleetLedger::new();
        l2.commission(0, 300.0, 3.0, 0.0, 0.0);
        l2.retire(0, 0.5 * SECONDS_PER_YEAR);
        assert!(l2.records[0].yearly_kg(0.0) > 6.9 * healthy);
    }

    #[test]
    fn retirement_closes_and_recommission_reopens() {
        let mut l = FleetLedger::new();
        l.commission(3, 100.0, 3.0, 0.0, 0.0);
        assert_eq!(l.service_age_yr(3, SECONDS_PER_YEAR), Some(1.0));
        assert!(l.retire(3, 10.0));
        assert!(!l.retire(3, 11.0), "no open window left to close");
        l.commission(3, 120.0, 4.0, 0.0, 10.0);
        assert_eq!(l.records.len(), 2);
        assert!((l.total_charged_kg() - 220.0).abs() < 1e-12);
        let age = l.service_age_yr(3, 10.0 + SECONDS_PER_YEAR).unwrap();
        assert!((age - 1.0).abs() < 1e-12, "replacement age restarts at 0");
    }

    #[test]
    fn fully_closed_ledger_conserves_charge() {
        let mut l = FleetLedger::new();
        l.commission(0, 278.3, 3.0, 2.0, 0.0);
        l.commission(1, 240.0, 3.0, 0.1, 0.0);
        l.retire(0, 100.0);
        l.commission(0, 278.3, 3.0, 0.0, 100.0);
        l.retire(0, 5000.0);
        l.retire(1, 5000.0);
        let charged = l.total_charged_kg();
        let amortized = l.amortized_total_kg(5000.0);
        assert!(((charged - amortized) / charged).abs() < 1e-9);
    }
}
