//! Embodied-carbon amortization — the Fig. 7 estimate.
//!
//! The paper takes a stock Linux inference server's hardware refresh
//! cycle as **3 years** and its CPU-complex embodied carbon as
//! **278.3 kgCO₂eq** over that lifespan (Li'24). Delaying aging effects
//! lets the operator extend the refresh cycle; the paper maps aging
//! performance to lifetime with a **linear model**: a technique whose
//! mean frequency degradation (at a chosen cluster percentile) is k×
//! smaller than the linux baseline's supports a k× longer refresh cycle.
//! Yearly embodied emissions then shrink from `E/3` to `E/(3k)`.

use crate::util::stats;

/// Embodied model parameters (paper defaults from Li'24).
#[derive(Clone, Copy, Debug)]
pub struct EmbodiedModel {
    /// CPU-complex embodied carbon per server (kgCO₂eq).
    pub cpu_embodied_kg: f64,
    /// Baseline hardware refresh cycle (years).
    pub base_lifetime_yr: f64,
}

impl EmbodiedModel {
    pub fn paper_default() -> EmbodiedModel {
        EmbodiedModel { cpu_embodied_kg: 278.3, base_lifetime_yr: 3.0 }
    }

    /// Yearly embodied emissions for one server at a given lifetime.
    #[inline]
    pub fn yearly_kg(&self, lifetime_yr: f64) -> f64 {
        assert!(lifetime_yr > 0.0);
        self.cpu_embodied_kg / lifetime_yr
    }

    /// Lifetime extension factor implied by the linear model:
    /// `k = fred_baseline / fred_technique` (≥ 1 when the technique ages
    /// the CPU slower). Degradations must be positive.
    #[inline]
    pub fn extension_factor(&self, fred_baseline: f64, fred_technique: f64) -> f64 {
        if fred_technique <= 0.0 {
            // No measurable aging: cap at a generous bound instead of ∞.
            return 10.0;
        }
        (fred_baseline / fred_technique).max(1e-3)
    }

    /// Extended lifetime (years) for a technique vs the baseline.
    #[inline]
    pub fn extended_lifetime_yr(&self, fred_baseline: f64, fred_technique: f64) -> f64 {
        self.base_lifetime_yr * self.extension_factor(fred_baseline, fred_technique)
    }

    /// Yearly embodied emissions (kg/server/yr) for a technique whose
    /// mean-frequency-degradation percentile is `fred_technique`, against
    /// the linux baseline's `fred_baseline`.
    pub fn yearly_kg_for(&self, fred_baseline: f64, fred_technique: f64) -> f64 {
        self.yearly_kg(self.extended_lifetime_yr(fred_baseline, fred_technique))
    }

    /// Percent reduction in yearly embodied emissions vs the baseline.
    pub fn reduction_pct(&self, fred_baseline: f64, fred_technique: f64) -> f64 {
        let base = self.yearly_kg(self.base_lifetime_yr);
        let tech = self.yearly_kg_for(fred_baseline, fred_technique);
        (1.0 - tech / base) * 100.0
    }
}

/// Fig. 7 helper: yearly cluster emissions from per-machine mean
/// frequency degradations, estimated at percentile `pct`.
pub fn cluster_yearly_kg(
    model: &EmbodiedModel,
    fred_baseline_per_machine: &[f64],
    fred_technique_per_machine: &[f64],
    pct: f64,
    n_machines: usize,
) -> f64 {
    let base_p = stats::percentile(fred_baseline_per_machine, pct);
    let tech_p = stats::percentile(fred_technique_per_machine, pct);
    model.yearly_kg_for(base_p, tech_p) * n_machines as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_yearly_is_third_of_total() {
        let m = EmbodiedModel::paper_default();
        assert!((m.yearly_kg(3.0) - 278.3 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn halved_degradation_doubles_lifetime() {
        let m = EmbodiedModel::paper_default();
        assert!((m.extended_lifetime_yr(0.2, 0.1) - 6.0).abs() < 1e-12);
        assert!((m.yearly_kg_for(0.2, 0.1) - 278.3 / 6.0).abs() < 1e-9);
        assert!((m.reduction_pct(0.2, 0.1) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn equal_degradation_no_reduction() {
        let m = EmbodiedModel::paper_default();
        assert!(m.reduction_pct(0.1, 0.1).abs() < 1e-9);
    }

    #[test]
    fn worse_technique_increases_emissions() {
        let m = EmbodiedModel::paper_default();
        assert!(m.reduction_pct(0.1, 0.2) < 0.0);
    }

    #[test]
    fn zero_degradation_capped() {
        let m = EmbodiedModel::paper_default();
        assert!((m.extension_factor(0.1, 0.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn paper_headline_numbers_are_reachable() {
        // A 37.67% reduction corresponds to a 1/(1-0.3767) ≈ 1.604×
        // degradation gap — verify the model arithmetic reproduces it.
        let m = EmbodiedModel::paper_default();
        let k = 1.0 / (1.0 - 0.3767);
        let red = m.reduction_pct(k, 1.0);
        assert!((red - 37.67).abs() < 0.01, "red={red}");
    }

    #[test]
    fn cluster_scaling() {
        let m = EmbodiedModel::paper_default();
        let base = vec![0.2; 22];
        let tech = vec![0.1; 22];
        let total = cluster_yearly_kg(&m, &base, &tech, 99.0, 22);
        assert!((total - 22.0 * 278.3 / 6.0).abs() < 1e-6);
    }
}
