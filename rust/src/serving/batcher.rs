//! Dynamic batcher: groups queued requests into model-batch-sized groups
//! under a latency window — the serving-side analogue of the simulator's
//! continuous batching (the AOT model has a fixed batch dimension, so
//! batches are formed up-front; slots that finish early simply stop
//! decoding).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A request waiting to be batched.
#[derive(Debug)]
pub struct Pending<T> {
    pub item: T,
    pub enqueued: Instant,
}

/// Batch-formation policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Maximum requests per batch (= the model's batch dimension).
    pub max_batch: usize,
    /// How long the head request may wait for companions.
    pub window: Duration,
}

/// The batcher state machine. Thread-agnostic: the server loop feeds
/// [`Batcher::push`] and polls [`Batcher::pop_batch`].
#[derive(Debug)]
pub struct Batcher<T> {
    cfg: BatcherConfig,
    queue: VecDeque<Pending<T>>,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Batcher<T> {
        assert!(cfg.max_batch >= 1);
        Batcher { cfg, queue: VecDeque::new() }
    }

    pub fn push(&mut self, item: T, now: Instant) {
        self.queue.push_back(Pending { item, enqueued: now });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should a batch be released now? Either it is full, or the head
    /// request has waited out the batching window.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.cfg.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(head) => now.duration_since(head.enqueued) >= self.cfg.window,
            None => false,
        }
    }

    /// Pop up to `max_batch` requests if [`Batcher::ready`].
    pub fn pop_batch(&mut self, now: Instant) -> Option<Vec<T>> {
        if !self.ready(now) {
            return None;
        }
        let n = self.queue.len().min(self.cfg.max_batch);
        Some(self.queue.drain(..n).map(|p| p.item).collect())
    }

    /// Deadline at which the current head request must be released, if any.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queue.front().map(|h| h.enqueued + self.cfg.window)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, ms: u64) -> BatcherConfig {
        BatcherConfig { max_batch, window: Duration::from_millis(ms) }
    }

    #[test]
    fn releases_full_batch_immediately() {
        let mut b = Batcher::new(cfg(2, 1000));
        let t0 = Instant::now();
        b.push(1, t0);
        assert!(b.pop_batch(t0).is_none());
        b.push(2, t0);
        assert_eq!(b.pop_batch(t0), Some(vec![1, 2]));
        assert!(b.is_empty());
    }

    #[test]
    fn releases_partial_batch_after_window() {
        let mut b = Batcher::new(cfg(4, 10));
        let t0 = Instant::now();
        b.push(7, t0);
        assert!(!b.ready(t0));
        let later = t0 + Duration::from_millis(11);
        assert!(b.ready(later));
        assert_eq!(b.pop_batch(later), Some(vec![7]));
    }

    #[test]
    fn batches_preserve_fifo_order() {
        let mut b = Batcher::new(cfg(3, 0));
        let t0 = Instant::now();
        for i in 0..5 {
            b.push(i, t0);
        }
        assert_eq!(b.pop_batch(t0), Some(vec![0, 1, 2]));
        assert_eq!(b.pop_batch(t0), Some(vec![3, 4]));
    }

    #[test]
    fn deadline_tracks_head() {
        let mut b = Batcher::<u32>::new(cfg(4, 50));
        assert!(b.next_deadline().is_none());
        let t0 = Instant::now();
        b.push(1, t0);
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(50)));
    }
}
