//! Byte-level tokenizer for the served model (vocab = 256 ⇒ every UTF-8
//! byte is a token; no external vocabulary files needed offline).

/// Byte tokenizer.
#[derive(Clone, Copy, Debug)]
pub struct ByteTokenizer {
    pub vocab: usize,
}

impl ByteTokenizer {
    pub fn new(vocab: usize) -> ByteTokenizer {
        assert!(vocab >= 2);
        ByteTokenizer { vocab }
    }

    /// Encode a string: one token per byte, clamped into the vocab.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| (b as usize % self.vocab) as i32).collect()
    }

    /// Decode tokens back to text (lossy for non-UTF-8 sequences).
    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t.clamp(0, 255)) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_roundtrip() {
        let t = ByteTokenizer::new(256);
        let s = "Hello, inference cluster 42!";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn utf8_roundtrip() {
        let t = ByteTokenizer::new(256);
        let s = "θ-shift: ΔVth ✓";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn encode_stays_in_vocab() {
        let t = ByteTokenizer::new(256);
        for tok in t.encode("ÿ\u{7f}\u{0}") {
            assert!((0..256).contains(&tok));
        }
    }

    #[test]
    fn decode_clamps_out_of_range() {
        let t = ByteTokenizer::new(256);
        let s = t.decode(&[72, 105, 999, -5]);
        assert!(s.starts_with("Hi"));
    }
}
