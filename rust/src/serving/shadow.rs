//! Shadow CPU core manager for the real serving stack.
//!
//! On real hardware the technique would drive `cpuidle` states and
//! `sched_setaffinity`; in this repo the serving stack runs on whatever
//! host executes it, so the core manager runs in *shadow mode*: every
//! serving-side CPU task (batch scheduling, memory bookkeeping, each
//! decode iteration) is reported to a [`CoreManager`] against wall-clock
//! time, which runs the exact Algorithm 1/2 implementations the simulator
//! uses and records what the working set, aging, and oversubscription
//! *would have been*. The end-to-end example prints this next to the real
//! latency/throughput numbers.

use std::time::Instant;

use crate::cluster::TaskKind;
use crate::cpu::{AgingParams, CpuPackage, TemperatureModel};
use crate::policy::{self, CoreManager};
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// The shadow manager.
pub struct ShadowCpuManager {
    mgr: CoreManager,
    start: Instant,
    adjust_period_s: Option<f64>,
    last_adjust_s: f64,
    next_task: u64,
    /// Normalized idle-core availability sampled at each task begin.
    pub idle_samples: Vec<f64>,
    pub tasks_started: u64,
}

/// End-of-run shadow statistics.
#[derive(Clone, Debug)]
pub struct ShadowReport {
    pub policy: String,
    pub n_cores: usize,
    pub tasks_started: u64,
    pub oversub_events: u64,
    /// Fraction of wall-clock core-seconds spent in C6 (age-halted).
    pub c6_fraction: f64,
    /// Mean accumulated ΔVth across cores (V) — wall-clock scale.
    pub mean_dvth: f64,
    /// CV of the (hypothetical) core frequency distribution.
    pub freq_cv: f64,
    pub idle: Summary,
}

impl ShadowCpuManager {
    pub fn new(n_cores: usize, policy_name: &str, seed: u64) -> Result<ShadowCpuManager, String> {
        let cpu = CpuPackage::uniform(
            n_cores,
            AgingParams::paper_default(),
            TemperatureModel::paper_default(),
        );
        let policy = policy::by_name(policy_name)?;
        let adjust_period_s = policy.adjust_period_s();
        Ok(ShadowCpuManager {
            mgr: CoreManager::new(cpu, policy, Rng::new(seed)),
            start: Instant::now(),
            adjust_period_s,
            last_adjust_s: 0.0,
            next_task: 0,
            idle_samples: Vec::new(),
            tasks_started: 0,
        })
    }

    /// Wall-clock simulation time (seconds since server start).
    pub fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn maybe_adjust(&mut self, now: f64) {
        if let Some(p) = self.adjust_period_s {
            if now - self.last_adjust_s >= p {
                self.mgr.adjust(now);
                self.last_adjust_s = now;
            }
        }
    }

    /// Report a CPU task starting; returns its shadow id.
    pub fn task_begin(&mut self, _kind: TaskKind) -> u64 {
        let now = self.now();
        self.maybe_adjust(now);
        let id = self.next_task;
        self.next_task += 1;
        self.tasks_started += 1;
        self.idle_samples.push(self.mgr.cpu.normalized_idle_for_extra_task());
        self.mgr.start_task(id, now);
        id
    }

    /// Report a CPU task finishing.
    pub fn task_end(&mut self, id: u64) {
        let now = self.now();
        self.mgr.finish_task(id, now);
        self.maybe_adjust(now);
    }

    /// Current working-set size (C0 cores).
    pub fn active_cores(&self) -> usize {
        self.mgr.cpu.active_count()
    }

    pub fn report(&mut self, policy_name: &str) -> ShadowReport {
        let now = self.now();
        let freqs = self.mgr.cpu.frequencies(now);
        let total_time: f64 =
            self.mgr.cpu.core_views().map(|c| c.active_time() + c.c6_time()).sum();
        let c6_time: f64 = self.mgr.cpu.core_views().map(|c| c.c6_time()).sum();
        ShadowReport {
            policy: policy_name.to_string(),
            n_cores: self.mgr.cpu.n_cores(),
            tasks_started: self.tasks_started,
            oversub_events: self.mgr.oversub_events,
            c6_fraction: if total_time > 0.0 { c6_time / total_time } else { 0.0 },
            mean_dvth: crate::util::stats::mean(
                &self.mgr.cpu.core_views().map(|c| c.dvth()).collect::<Vec<_>>(),
            ),
            freq_cv: crate::util::stats::coeff_of_variation(&freqs),
            idle: Summary::of(&self.idle_samples),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shadow_tracks_tasks_and_idles_cores() {
        let mut s = ShadowCpuManager::new(16, "proposed", 1).unwrap();
        // Simulate some bursts of work.
        for _ in 0..20 {
            let ids: Vec<u64> =
                (0..3).map(|_| s.task_begin(TaskKind::StartIteration)).collect();
            std::thread::sleep(std::time::Duration::from_millis(1));
            for id in ids {
                s.task_end(id);
            }
        }
        let r = s.report("proposed");
        assert_eq!(r.tasks_started, 60);
        assert_eq!(r.n_cores, 16);
        assert_eq!(r.idle.n, 60);
    }

    #[test]
    fn baselines_never_deep_idle_in_shadow() {
        let mut s = ShadowCpuManager::new(8, "linux", 2).unwrap();
        for _ in 0..10 {
            let id = s.task_begin(TaskKind::Submit);
            s.task_end(id);
        }
        assert_eq!(s.active_cores(), 8);
        let r = s.report("linux");
        assert_eq!(r.c6_fraction, 0.0);
    }

    #[test]
    fn proposed_shrinks_working_set_over_time() {
        let mut s = ShadowCpuManager::new(32, "proposed", 3);
        let s = s.as_mut().unwrap();
        // Force the periodic adjust by faking elapsed time via tasks with
        // sleeps: one adjust period is 1 s, too slow for a unit test, so
        // call the internals directly.
        s.mgr.adjust(10.0);
        assert!(s.mgr.cpu.c6_count() > 0);
        assert!(s.mgr.cpu.active_count() >= 1);
        // And it can recover under load.
        for _ in 0..64 {
            s.task_begin(TaskKind::StartIteration);
        }
        assert!(s.mgr.cpu.running_tasks() == 64);
    }
}
