//! The real mini serving stack — the end-to-end driver's engine.
//!
//! Architecture (all Rust, Python never on this path):
//!
//! ```text
//! client ──submit──▶ Server ──mpsc──▶ worker thread
//!                                    ├── Batcher (dynamic batching)
//!                                    ├── ServedModel (PJRT prefill/decode)
//!                                    ├── ByteTokenizer
//!                                    └── ShadowCpuManager (Alg. 1 + 2)
//! ```
//!
//! The worker owns the PJRT executables (they are not `Send`-safe to
//! share) and reports every CPU-side serving task to the shadow core
//! manager, so the paper's technique runs live against real inference
//! traffic while the PJRT model produces real tokens.

pub mod batcher;
pub mod shadow;
pub mod tokenizer;

pub use batcher::{Batcher, BatcherConfig};
pub use shadow::{ShadowCpuManager, ShadowReport};
pub use tokenizer::ByteTokenizer;

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cluster::TaskKind;
use crate::runtime::{Runtime, ServedModel};
use crate::util::stats::Summary;

/// An inference request.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
}

/// The served completion.
#[derive(Clone, Debug)]
pub struct ServeResponse {
    pub id: u64,
    pub text: String,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    /// Time to first token (prefill completion), seconds.
    pub ttft_s: f64,
    /// End-to-end latency, seconds.
    pub e2e_s: f64,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// Dynamic batching window.
    pub batch_window: Duration,
    /// Core-management policy run in shadow mode.
    pub policy: String,
    /// Shadow CPU size (cores).
    pub shadow_cores: usize,
    pub seed: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            artifacts_dir: Runtime::default_artifacts_dir(),
            batch_window: Duration::from_millis(10),
            policy: "proposed".into(),
            shadow_cores: 40,
            seed: 42,
        }
    }
}

/// Aggregate serving report.
#[derive(Clone, Debug)]
pub struct ServerReport {
    pub requests: usize,
    pub batches: usize,
    pub generated_tokens: usize,
    pub wall_s: f64,
    pub tokens_per_s: f64,
    pub requests_per_s: f64,
    pub ttft: Summary,
    pub e2e: Summary,
    /// Mean per-iteration decode latency (s).
    pub decode_step_s: f64,
    /// Mean prefill latency (s).
    pub prefill_s: f64,
    pub shadow: ShadowReport,
}

impl ServerReport {
    pub fn print(&self) {
        println!("── serving report ───────────────────────────────────────");
        println!("requests            {:>10}", self.requests);
        println!("batches             {:>10}", self.batches);
        println!("generated tokens    {:>10}", self.generated_tokens);
        println!("wall time           {:>10.2} s", self.wall_s);
        println!("throughput          {:>10.1} tok/s   {:>8.2} req/s", self.tokens_per_s, self.requests_per_s);
        println!("prefill latency     {:>10.2} ms (mean)", self.prefill_s * 1e3);
        println!("decode step         {:>10.2} ms (mean)", self.decode_step_s * 1e3);
        println!("TTFT   p50/p99      {:>10.2} / {:.2} ms", self.ttft.p50 * 1e3, self.ttft.p99 * 1e3);
        println!("E2E    p50/p99      {:>10.2} / {:.2} ms", self.e2e.p50 * 1e3, self.e2e.p99 * 1e3);
        let s = &self.shadow;
        println!("── shadow core manager ({} on {} cores) ──", s.policy, s.n_cores);
        println!("cpu tasks           {:>10}", s.tasks_started);
        println!("oversub events      {:>10}", s.oversub_events);
        println!("C6 (age-halt) time  {:>10.1} %", s.c6_fraction * 100.0);
        println!("mean ΔVth           {:>10.3e} V", s.mean_dvth);
        println!("idle p1/p50/p90     {:>7.3} / {:.3} / {:.3}", s.idle.p1, s.idle.p50, s.idle.p90);
    }
}

type Job = (ServeRequest, Instant, mpsc::Sender<ServeResponse>);

/// The server: spawns the worker thread that owns the PJRT model.
pub struct Server {
    tx: Option<mpsc::Sender<Job>>,
    handle: Option<std::thread::JoinHandle<ServerReport>>,
}

impl Server {
    /// Start the server; blocks until the model is loaded (or fails).
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let handle = std::thread::Builder::new()
            .name("carbon-sim-worker".into())
            .spawn(move || worker_main(cfg, rx, ready_tx))
            .context("spawning worker")?;
        ready_rx
            .recv()
            .context("worker died during startup")?
            .map_err(|e| anyhow::anyhow!("model load failed: {e}"))?;
        Ok(Server { tx: Some(tx), handle: Some(handle) })
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, req: ServeRequest) -> mpsc::Receiver<ServeResponse> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("server running")
            .send((req, Instant::now(), tx))
            .expect("worker alive");
        rx
    }

    /// Drain outstanding work and return the aggregate report.
    pub fn shutdown(mut self) -> ServerReport {
        drop(self.tx.take());
        self.handle.take().expect("not yet shut down").join().expect("worker panicked")
    }
}

// ------------------------------------------------------------------ worker

struct SlotState {
    req: ServeRequest,
    submitted: Instant,
    reply: mpsc::Sender<ServeResponse>,
    prompt_tokens: Vec<i32>,
    generated: Vec<i32>,
    ttft_s: f64,
    done: bool,
}

fn worker_main(
    cfg: ServerConfig,
    rx: mpsc::Receiver<Job>,
    ready_tx: mpsc::Sender<Result<(), String>>,
) -> ServerReport {
    let model = match Runtime::cpu(&cfg.artifacts_dir).and_then(ServedModel::load) {
        Ok(m) => {
            let _ = ready_tx.send(Ok(()));
            m
        }
        Err(e) => {
            let _ = ready_tx.send(Err(format!("{e:#}")));
            // Report is never observed: Server::start fails first.
            panic!("model load failed: {e:#}");
        }
    };
    let tokenizer = ByteTokenizer::new(model.dims.vocab);
    let mut shadow = ShadowCpuManager::new(cfg.shadow_cores, &cfg.policy, cfg.seed)
        .expect("valid shadow policy");
    let mut batcher: Batcher<Job> = Batcher::new(BatcherConfig {
        max_batch: model.dims.batch,
        window: cfg.batch_window,
    });

    let started = Instant::now();
    let mut ttfts = Vec::new();
    let mut e2es = Vec::new();
    let mut batches = 0usize;
    let mut requests = 0usize;
    let mut generated_tokens = 0usize;
    let mut prefill_times = Vec::new();
    let mut decode_times = Vec::new();
    let mut disconnected = false;

    while !(disconnected && batcher.is_empty()) {
        // Fill the batcher until ready (or the channel closes).
        while !batcher.ready(Instant::now()) && !disconnected {
            let timeout = batcher
                .next_deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(Duration::from_millis(50));
            match rx.recv_timeout(timeout) {
                Ok(job) => batcher.push(job, Instant::now()),
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if batcher.is_empty() {
                        continue;
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => disconnected = true,
            }
        }
        let Some(batch) = batcher.pop_batch(Instant::now()) else {
            continue;
        };
        batches += 1;
        requests += batch.len();
        let (gen, pf_s, dc_s) =
            process_batch(&model, &tokenizer, &mut shadow, batch, &mut ttfts, &mut e2es);
        generated_tokens += gen;
        prefill_times.push(pf_s);
        decode_times.extend(dc_s);
    }

    let wall_s = started.elapsed().as_secs_f64();
    ServerReport {
        requests,
        batches,
        generated_tokens,
        wall_s,
        tokens_per_s: generated_tokens as f64 / wall_s.max(1e-9),
        requests_per_s: requests as f64 / wall_s.max(1e-9),
        ttft: Summary::of(&ttfts),
        e2e: Summary::of(&e2es),
        decode_step_s: crate::util::stats::mean(&decode_times),
        prefill_s: crate::util::stats::mean(&prefill_times),
        shadow: shadow.report(&cfg.policy),
    }
}

/// Run one batch to completion: prefill, then greedy decode until every
/// slot hits its token budget (or the context limit).
fn process_batch(
    model: &ServedModel,
    tokenizer: &ByteTokenizer,
    shadow: &mut ShadowCpuManager,
    batch: Vec<Job>,
    ttfts: &mut Vec<f64>,
    e2es: &mut Vec<f64>,
) -> (usize, f64, Vec<f64>) {
    let dims = model.dims;
    let b = dims.batch;
    let s_max = dims.max_seq;

    // Scheduler bookkeeping tasks (shadow).
    let mut slots: Vec<Option<SlotState>> = Vec::with_capacity(b);
    for (req, submitted, reply) in batch {
        let t_sub = shadow.task_begin(TaskKind::Submit);
        let t_chain = shadow.task_begin(TaskKind::SubmitChain);
        let mut toks = tokenizer.encode(&req.prompt);
        let budget = req.max_new_tokens.min(s_max.saturating_sub(2));
        let max_prompt = s_max - budget.max(1) - 1;
        toks.truncate(max_prompt.max(1));
        if toks.is_empty() {
            toks.push(0);
        }
        slots.push(Some(SlotState {
            req,
            submitted,
            reply,
            prompt_tokens: toks,
            generated: Vec::new(),
            ttft_s: 0.0,
            done: false,
        }));
        shadow.task_end(t_sub);
        shadow.task_end(t_chain);
    }
    while slots.len() < b {
        slots.push(None); // padding slots
    }

    // Prefill.
    let mut tokens = vec![0i32; b * s_max];
    let mut lengths = vec![1i32; b];
    for (i, slot) in slots.iter().enumerate() {
        if let Some(st) = slot {
            for (j, &t) in st.prompt_tokens.iter().enumerate() {
                tokens[i * s_max + j] = t;
            }
            lengths[i] = st.prompt_tokens.len() as i32;
        }
    }
    let t_alloc = shadow.task_begin(TaskKind::AllocMemory);
    let pf_start = Instant::now();
    let pf = model.prefill(&tokens, &lengths).expect("prefill");
    let prefill_s = pf_start.elapsed().as_secs_f64();
    shadow.task_end(t_alloc);

    // First token from prefill logits.
    let mut cur_tokens = model.argmax_tokens(&pf.logits);
    let mut k = pf.k_cache;
    let mut v = pf.v_cache;
    for (i, slot) in slots.iter_mut().enumerate() {
        if let Some(st) = slot {
            st.ttft_s = st.submitted.elapsed().as_secs_f64();
            st.generated.push(cur_tokens[i]);
            if st.generated.len() >= st.req.max_new_tokens {
                st.done = true;
            }
        }
    }

    // Greedy decode loop: fused chunks when the artifact provides them
    // (§Perf — one PJRT dispatch per `decode_chunk_steps` tokens),
    // otherwise token-by-token.
    let mut decode_times = Vec::new();
    let chunk_steps = model.decode_chunk_steps;
    let mut remaining: Vec<i32> = slots
        .iter()
        .map(|s| s.as_ref().map_or(0, |st| (st.req.max_new_tokens.saturating_sub(1)) as i32))
        .collect();
    loop {
        let work_left = remaining.iter().any(|&r| r > 0);
        let ctx_full = lengths.iter().any(|&l| l as usize >= s_max - 1);
        if !work_left || ctx_full {
            break;
        }
        if chunk_steps > 0 {
            let t_iter = shadow.task_begin(TaskKind::StartIteration);
            let dc_start = Instant::now();
            let out = model
                .decode_chunk(&k, &v, &cur_tokens, &lengths, &remaining)
                .expect("decode_chunk");
            decode_times.push(dc_start.elapsed().as_secs_f64() / chunk_steps as f64);
            shadow.task_end(t_iter);
            k = out.k_cache;
            v = out.v_cache;
            lengths = out.lengths;
            remaining = out.remaining;
            for (i, slot) in slots.iter_mut().enumerate() {
                if let Some(st) = slot {
                    for step in 0..chunk_steps {
                        let tok = out.tokens[i * chunk_steps + step];
                        if tok >= 0 && !st.done {
                            st.generated.push(tok);
                            cur_tokens[i] = tok;
                            if st.generated.len() >= st.req.max_new_tokens {
                                st.done = true;
                            }
                        }
                    }
                }
            }
        } else {
            let t_iter = shadow.task_begin(TaskKind::StartIteration);
            let dc_start = Instant::now();
            let out = model.decode(&k, &v, &cur_tokens, &lengths).expect("decode");
            decode_times.push(dc_start.elapsed().as_secs_f64());
            shadow.task_end(t_iter);
            k = out.k_cache;
            v = out.v_cache;
            let next = model.argmax_tokens(&out.logits);
            for (i, slot) in slots.iter_mut().enumerate() {
                match slot {
                    Some(st) if !st.done => {
                        lengths[i] += 1;
                        cur_tokens[i] = next[i];
                        remaining[i] -= 1;
                        st.generated.push(next[i]);
                        if st.generated.len() >= st.req.max_new_tokens {
                            st.done = true;
                        }
                    }
                    _ => {} // finished/padding slots hold their position
                }
            }
        }
    }

    // Complete requests.
    let mut gen_total = 0usize;
    for slot in slots.into_iter().flatten() {
        let t_fin = shadow.task_begin(TaskKind::FinishRequest);
        let t_free = shadow.task_begin(TaskKind::FreeMemory);
        let e2e = slot.submitted.elapsed().as_secs_f64();
        ttfts.push(slot.ttft_s);
        e2es.push(e2e);
        gen_total += slot.generated.len();
        let resp = ServeResponse {
            id: slot.req.id,
            text: tokenizer.decode(&slot.generated),
            prompt_tokens: slot.prompt_tokens.len(),
            generated_tokens: slot.generated.len(),
            ttft_s: slot.ttft_s,
            e2e_s: e2e,
        };
        let _ = slot.reply.send(resp);
        shadow.task_end(t_fin);
        shadow.task_end(t_free);
    }
    (gen_total, prefill_s, decode_times)
}
