//! Child-process helpers for the shard-fleet orchestrator
//! ([`crate::experiments::orchestrate`]): launcher-template substitution
//! and running children while streaming their output line-by-line.
//!
//! `std::process` offers blocking `wait` (no output) or `output`
//! (all-or-nothing capture) — neither fits an orchestrator that must
//! relay a shard's progress lines *as they appear* over a multi-hour
//! sweep and still report a useful stderr excerpt when the child dies.
//! [`run_streaming_lines`] drains both pipes concurrently (two reader
//! threads feeding one **bounded** channel), hands every line to the
//! caller's callback on the calling thread in arrival order, and retains
//! only the last [`STDERR_TAIL_LINES`] stderr lines. Memory stays
//! bounded however chatty the child is: when the consumer is slower than
//! the child (stdout piped into a paused pager, say), the channel fills,
//! the reader threads stop draining, and the child blocks on its full
//! pipe — ordinary pipeline backpressure rather than unbounded
//! buffering. Within that bound both pipes are still drained eagerly, so
//! a child interleaving heavy stdout and stderr cannot deadlock the way
//! naive sequential `read_to_end` calls would.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader};
use std::process::{Command, ExitStatus, Stdio};
use std::sync::mpsc;

/// How many trailing stderr lines [`run_streaming_lines`] retains for
/// failure reports.
pub const STDERR_TAIL_LINES: usize = 10;

/// Relay-channel capacity (lines in flight between the pipe readers and
/// the consumer). Small enough that a stalled consumer caps memory at a
/// few KB per child, large enough that line-at-a-time hand-off never
/// throttles a healthy child.
const RELAY_CHANNEL_LINES: usize = 256;

/// Substitute `{key}` placeholders into a launcher template: every
/// occurrence of `{key}` is replaced by its paired value. Unrecognized
/// brace sequences pass through untouched, so templates can still use
/// shell syntax like `${VAR}` — the placeholder names themselves are
/// reserved, though: a literal `{shard}` cannot be written.
pub fn substitute(template: &str, subs: &[(&str, &str)]) -> String {
    let mut out = template.to_string();
    for (key, value) in subs {
        out = out.replace(&format!("{{{key}}}"), value);
    }
    out
}

/// The command a launcher template runs as: `sh -c <line>`.
pub fn shell_command(line: &str) -> Command {
    let mut cmd = Command::new("sh");
    cmd.arg("-c").arg(line);
    cmd
}

/// Spawn `cmd` and run it to completion, feeding each stdout/stderr line
/// to `on_line(line, is_stderr)` (called on this thread, in arrival
/// order, without the trailing newline). Returns the exit status plus
/// the last [`STDERR_TAIL_LINES`] stderr lines. stdin is closed — a
/// child that prompts would otherwise hang the fleet.
pub fn run_streaming_lines(
    cmd: &mut Command,
    on_line: &mut dyn FnMut(&str, bool),
) -> Result<(ExitStatus, Vec<String>), String> {
    cmd.stdin(Stdio::null()).stdout(Stdio::piped()).stderr(Stdio::piped());
    let mut child = cmd
        .spawn()
        .map_err(|e| format!("spawning {:?}: {e}", cmd.get_program()))?;
    let stdout = child.stdout.take().expect("stdout is piped");
    let stderr = child.stderr.take().expect("stderr is piped");
    let mut tail: VecDeque<String> = VecDeque::with_capacity(STDERR_TAIL_LINES);
    std::thread::scope(|scope| {
        let (tx, rx) = mpsc::sync_channel::<(String, bool)>(RELAY_CHANNEL_LINES);
        let tx_err = tx.clone();
        scope.spawn(move || {
            for line in BufReader::new(stdout).lines() {
                let Ok(line) = line else { break };
                if tx.send((line, false)).is_err() {
                    break;
                }
            }
        });
        scope.spawn(move || {
            for line in BufReader::new(stderr).lines() {
                let Ok(line) = line else { break };
                if tx_err.send((line, true)).is_err() {
                    break;
                }
            }
        });
        // Both senders drop when their pipe closes; the loop then ends.
        for (line, is_err) in rx {
            if is_err {
                if tail.len() == STDERR_TAIL_LINES {
                    tail.pop_front();
                }
                tail.push_back(line.clone());
            }
            on_line(&line, is_err);
        }
    });
    let status = child.wait().map_err(|e| format!("waiting for child: {e}"))?;
    Ok((status, tail.into_iter().collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn substitute_replaces_every_occurrence_of_known_keys_only() {
        let t = "run {shard} of {spec} into {out_dir} (again: {shard}); keep ${HOME} and {nope}";
        let got = substitute(
            t,
            &[("shard", "1/3"), ("spec", "s.json"), ("out_dir", "/tmp/o")],
        );
        assert_eq!(got, "run 1/3 of s.json into /tmp/o (again: 1/3); keep ${HOME} and {nope}");
    }

    #[test]
    fn streams_both_pipes_and_reports_exit_and_tail() {
        let mut lines = Vec::new();
        let (status, tail) = run_streaming_lines(
            &mut shell_command("echo out-a; echo err-b >&2; echo out-c; exit 3"),
            &mut |line, is_err| lines.push((line.to_string(), is_err)),
        )
        .unwrap();
        assert_eq!(status.code(), Some(3));
        assert_eq!(tail, vec!["err-b".to_string()]);
        assert!(lines.contains(&("out-a".to_string(), false)), "{lines:?}");
        assert!(lines.contains(&("out-c".to_string(), false)), "{lines:?}");
        assert!(lines.contains(&("err-b".to_string(), true)), "{lines:?}");
    }

    #[test]
    fn stderr_tail_keeps_only_the_last_lines() {
        let (status, tail) = run_streaming_lines(
            &mut shell_command("for i in $(seq 1 25); do echo line-$i >&2; done"),
            &mut |_, _| {},
        )
        .unwrap();
        assert!(status.success());
        assert_eq!(tail.len(), STDERR_TAIL_LINES);
        assert_eq!(tail.first().map(String::as_str), Some("line-16"));
        assert_eq!(tail.last().map(String::as_str), Some("line-25"));
    }

    #[test]
    fn spawn_failure_is_an_error_not_a_panic() {
        let mut cmd = Command::new("/nonexistent/definitely-not-a-binary");
        let err = run_streaming_lines(&mut cmd, &mut |_, _| {}).unwrap_err();
        assert!(err.contains("spawning"), "{err}");
    }
}
