//! Miniature property-based testing harness (the `proptest` crate is not
//! available offline). Generates many random cases from a seeded RNG and,
//! on failure, retries with "smaller" cases to report a reduced example.
//!
//! Usage:
//! ```ignore
//! forall(1000, seed, |g| {
//!     let n = g.size(1, 128);
//!     let xs = g.vec_f64(n, 0.0, 1.0);
//!     check(some_invariant(&xs), format!("xs={xs:?}"))
//! });
//! ```

use super::rng::Rng;

/// Case generator handed to each property iteration. `scale` in (0, 1]
/// shrinks the magnitude of generated sizes/values for reduction attempts.
pub struct Gen {
    pub rng: Rng,
    pub scale: f64,
}

impl Gen {
    /// A size in [lo, hi], scaled down during shrink attempts.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.scale).round() as usize;
        lo + if span == 0 { 0 } else { self.rng.usize(span + 1) }
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let span = (hi - lo) * self.scale;
        lo + self.rng.f64() * span
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, n: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..n).map(|_| self.size(lo, hi)).collect()
    }
}

/// Outcome of a single property check.
pub enum Check {
    Pass,
    Fail(String),
}

/// Assert-style helper producing a [`Check`].
pub fn check(cond: bool, msg: impl Into<String>) -> Check {
    if cond {
        Check::Pass
    } else {
        Check::Fail(msg.into())
    }
}

/// Env var overriding the seed passed to every [`forall`] call, so a CI
/// property failure is reproducible locally with one command:
/// `CARBON_SIM_PROPTEST_SEED=<seed> cargo test -q <test-name>`.
pub const SEED_ENV: &str = "CARBON_SIM_PROPTEST_SEED";

/// Env var overriding the case count of every [`forall`] call, so CI can
/// run the property suites at greater depth without a code change.
pub const CASES_ENV: &str = "CARBON_SIM_PROPTEST_CASES";

fn parse_override(var: &str, raw: &str) -> u64 {
    match raw.trim().parse::<u64>() {
        Ok(v) => v,
        Err(e) => panic!("{var}={raw:?} is not a valid u64: {e}"),
    }
}

fn env_override(var: &str) -> Option<u64> {
    std::env::var(var).ok().map(|raw| parse_override(var, &raw))
}

/// Run `cases` random cases of `prop`. Panics with the failing case's
/// message (after shrink attempts) if any case fails; the panic names the
/// effective seed so `CARBON_SIM_PROPTEST_SEED=<seed>` replays it exactly.
/// `CARBON_SIM_PROPTEST_CASES` overrides the case count (CI runs the
/// suites at depth this way).
pub fn forall<F: FnMut(&mut Gen) -> Check>(cases: u32, seed: u64, prop: F) {
    forall_with(cases, seed, env_override(SEED_ENV), env_override(CASES_ENV), prop)
}

/// [`forall`] with the env overrides passed explicitly. Tests exercise the
/// override wiring through this entry point so they never mutate
/// process-global env state (other tests' `forall` calls read it
/// concurrently — cargo runs tests in threads, not processes).
fn forall_with<F: FnMut(&mut Gen) -> Check>(
    cases: u32,
    seed: u64,
    seed_override: Option<u64>,
    cases_override: Option<u64>,
    mut prop: F,
) {
    let seed = seed_override.unwrap_or(seed);
    let cases = cases_override.map(|c| c.min(u32::MAX as u64) as u32).unwrap_or(cases);
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let case_rng = root.fork(case as u64);
        let mut g = Gen { rng: case_rng.clone(), scale: 1.0 };
        if let Check::Fail(msg) = prop(&mut g) {
            // Shrink: replay the same stream at smaller scales; keep the
            // smallest scale that still fails.
            let mut best = (1.0_f64, msg);
            for &scale in &[0.5, 0.25, 0.1, 0.05] {
                let mut g2 = Gen { rng: case_rng.clone(), scale };
                if let Check::Fail(m2) = prop(&mut g2) {
                    best = (scale, m2);
                }
            }
            panic!(
                "property failed (seed={seed}, case={case}, shrink-scale={}):\n{}\n\
                 reproduce with: {SEED_ENV}={seed} cargo test -q",
                best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(200, 1, |g| {
            let n = g.size(0, 50);
            let xs = g.vec_f64(n, -10.0, 10.0);
            let sum: f64 = xs.iter().sum();
            let sum_rev: f64 = xs.iter().rev().sum();
            check((sum - sum_rev).abs() < 1e-9, "sum should be order-insensitive")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(100, 2, |g| {
            let x = g.f64(0.0, 100.0);
            check(x < 50.0, format!("x={x} >= 50"))
        });
    }

    #[test]
    fn gen_size_respects_bounds() {
        let mut g = Gen { rng: Rng::new(3), scale: 1.0 };
        for _ in 0..1000 {
            let s = g.size(2, 7);
            assert!((2..=7).contains(&s));
        }
    }

    #[test]
    fn shrink_scale_reduces_sizes() {
        let mut g_small = Gen { rng: Rng::new(4), scale: 0.1 };
        for _ in 0..100 {
            assert!(g_small.size(0, 100) <= 11);
        }
    }

    // The override wiring is tested through `forall_with` rather than by
    // setting the real env vars: cargo runs tests in threads, and other
    // tests' `forall` calls read the env concurrently.
    #[test]
    fn seed_override_is_applied_and_named_in_the_panic() {
        let panic = std::panic::catch_unwind(|| {
            forall_with(50, 999, Some(12345), None, |g| {
                let x = g.f64(0.0, 1.0);
                check(x < 0.0, format!("x={x}"))
            })
        })
        .expect_err("always-false property must fail");
        let msg = panic
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| panic.downcast_ref::<&str>().unwrap_or(&"").to_string());
        assert!(msg.contains("seed=12345"), "panic message was: {msg}");
        assert!(msg.contains(&format!("{SEED_ENV}=12345")), "panic message was: {msg}");
    }

    #[test]
    fn case_count_override_is_applied() {
        // With 0 cases even an always-false property never runs; without
        // the override it fails immediately.
        forall_with(1000, 7, None, Some(0), |_g| check(false, "never reached"));
        let unforced = std::panic::catch_unwind(|| {
            forall_with(1000, 7, None, None, |_g| check(false, "reached"))
        });
        assert!(unforced.is_err());
    }

    #[test]
    #[should_panic(expected = "is not a valid u64")]
    fn malformed_override_fails_loudly() {
        parse_override(CASES_ENV, "not-a-number");
    }
}
