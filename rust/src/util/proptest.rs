//! Miniature property-based testing harness (the `proptest` crate is not
//! available offline). Generates many random cases from a seeded RNG and,
//! on failure, retries with "smaller" cases to report a reduced example.
//!
//! Usage:
//! ```ignore
//! forall(1000, seed, |g| {
//!     let n = g.size(1, 128);
//!     let xs = g.vec_f64(n, 0.0, 1.0);
//!     check(some_invariant(&xs), format!("xs={xs:?}"))
//! });
//! ```

use super::rng::Rng;

/// Case generator handed to each property iteration. `scale` in (0, 1]
/// shrinks the magnitude of generated sizes/values for reduction attempts.
pub struct Gen {
    pub rng: Rng,
    pub scale: f64,
}

impl Gen {
    /// A size in [lo, hi], scaled down during shrink attempts.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.scale).round() as usize;
        lo + if span == 0 { 0 } else { self.rng.usize(span + 1) }
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let span = (hi - lo) * self.scale;
        lo + self.rng.f64() * span
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, n: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..n).map(|_| self.size(lo, hi)).collect()
    }
}

/// Outcome of a single property check.
pub enum Check {
    Pass,
    Fail(String),
}

/// Assert-style helper producing a [`Check`].
pub fn check(cond: bool, msg: impl Into<String>) -> Check {
    if cond {
        Check::Pass
    } else {
        Check::Fail(msg.into())
    }
}

/// Run `cases` random cases of `prop`. Panics with the failing case's
/// message (after shrink attempts) if any case fails.
pub fn forall<F: FnMut(&mut Gen) -> Check>(cases: u32, seed: u64, mut prop: F) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let case_rng = root.fork(case as u64);
        let mut g = Gen { rng: case_rng.clone(), scale: 1.0 };
        if let Check::Fail(msg) = prop(&mut g) {
            // Shrink: replay the same stream at smaller scales; keep the
            // smallest scale that still fails.
            let mut best = (1.0_f64, msg);
            for &scale in &[0.5, 0.25, 0.1, 0.05] {
                let mut g2 = Gen { rng: case_rng.clone(), scale };
                if let Check::Fail(m2) = prop(&mut g2) {
                    best = (scale, m2);
                }
            }
            panic!(
                "property failed (seed={seed}, case={case}, shrink-scale={}):\n{}",
                best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(200, 1, |g| {
            let n = g.size(0, 50);
            let xs = g.vec_f64(n, -10.0, 10.0);
            let sum: f64 = xs.iter().sum();
            let sum_rev: f64 = xs.iter().rev().sum();
            check((sum - sum_rev).abs() < 1e-9, "sum should be order-insensitive")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(100, 2, |g| {
            let x = g.f64(0.0, 100.0);
            check(x < 50.0, format!("x={x} >= 50"))
        });
    }

    #[test]
    fn gen_size_respects_bounds() {
        let mut g = Gen { rng: Rng::new(3), scale: 1.0 };
        for _ in 0..1000 {
            let s = g.size(2, 7);
            assert!((2..=7).contains(&s));
        }
    }

    #[test]
    fn shrink_scale_reduces_sizes() {
        let mut g_small = Gen { rng: Rng::new(4), scale: 0.1 };
        for _ in 0..100 {
            assert!(g_small.size(0, 100) <= 11);
        }
    }
}
