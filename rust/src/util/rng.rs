//! Deterministic pseudo-random number generation for the simulator.
//!
//! The offline toolchain does not ship the `rand` crate, so we implement a
//! small, well-tested PCG64-style generator (xoshiro256** core seeded via
//! SplitMix64) plus the distribution samplers the simulator needs: uniform,
//! exponential (Poisson arrivals), Gaussian (process variation), and
//! log-normal (trace token counts).
//!
//! Determinism matters: every experiment takes an explicit `seed`, and
//! paired policy comparisons (same process-variation sample, same trace)
//! derive child streams via [`Rng::fork`].

/// SplitMix64: used for seeding and as a cheap stream-splitter.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** deterministic PRNG.
///
/// Passes BigCrush; period 2^256 − 1. All simulator randomness flows
/// through this type so experiments are exactly reproducible from a seed.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream. Used to give each machine /
    /// module its own stream so adding draws in one place does not perturb
    /// another (critical for paired baseline comparisons).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        assert!(n > 0, "usize(0) is meaningless");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Inter-arrival times
    /// of Poisson request processes.
    #[inline]
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        // 1 - f64() in (0,1] avoids ln(0).
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Log-normal parameterized by the *underlying* normal's mu/sigma.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must sum to > 0");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let k = r.usize(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
