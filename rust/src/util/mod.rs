//! Dependency-free substrate utilities.
//!
//! The offline build environment vendors only the crates the `xla` FFI
//! needs, so the project carries its own small implementations of the
//! usual ecosystem pieces: RNG + distributions ([`rng`]), statistics
//! ([`stats`]), dense linear algebra for correlated sampling ([`linalg`]),
//! JSON ([`json`]), CLI parsing ([`cli`]), a criterion-style bench harness
//! ([`bench`]), a property-testing harness ([`proptest`]), a scoped
//! worker pool for parallel experiment sweeps ([`pool`]), and
//! line-streaming child-process handling for the shard-fleet
//! orchestrator ([`proc`]).

pub mod bench;
pub mod cli;
pub mod json;
pub mod linalg;
pub mod pool;
pub mod proc;
pub mod proptest;
pub mod rng;
pub mod stats;
