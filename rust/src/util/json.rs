//! A small, dependency-free JSON implementation (the offline toolchain has
//! no serde). Handles everything the project needs: config files, JSONL
//! trace files, the AOT weights manifest written by `python/compile/aot.py`,
//! and machine-readable experiment results.
//!
//! **Non-finite numbers.** Strict JSON has no NaN/Inf; rewriting them as
//! `null` (the usual dodge) silently corrupts a metric and breaks the
//! sweep engine's byte-identity contract once a value round-trips through
//! a spill file. This writer instead emits the bare tokens `NaN`,
//! `Infinity`, and `-Infinity`, and the parser restores them losslessly —
//! the same extension Python's `json` module uses by default, so every
//! downstream consumer in `python/` keeps working.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; Null on anything else.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `obj.get(key)` with a typed default — config ergonomics.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_usize).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Value {
        Value::Num(x)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Value {
        Value::Num(x as f64)
    }
}
impl From<bool> for Value {
    fn from(x: bool) -> Value {
        Value::Bool(x)
    }
}
impl From<&str> for Value {
    fn from(x: &str) -> Value {
        Value::Str(x.to_string())
    }
}
impl From<String> for Value {
    fn from(x: String) -> Value {
        Value::Str(x)
    }
}

// ---------------------------------------------------------------- emit

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn emit_num(x: f64, out: &mut String) {
    if x.is_nan() {
        out.push_str("NaN"); // restored losslessly by this module's parser
    } else if x.is_infinite() {
        out.push_str(if x > 0.0 { "Infinity" } else { "-Infinity" });
    } else if x == x.trunc() && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

impl Value {
    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => emit_num(*x, out),
            Value::Str(s) => escape_into(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    escape_into(k, out);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !o.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    /// Pretty-print into `out` as if this value sat at nesting depth
    /// `indent` of a larger pretty-printed document (two spaces per
    /// level). This is what lets the streaming report assembler emit
    /// rows one at a time and still produce output byte-identical to
    /// [`Value::to_string_pretty`] on the whole document.
    pub fn write_pretty_at(&self, out: &mut String, indent: usize) {
        self.write(out, indent, true);
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

// ---------------------------------------------------------------- parse

pub fn parse(input: &str) -> Result<Value, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing characters at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(c) = self.peek() {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.pos))
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Value::Null),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            // The writer's non-finite tokens (see module docs).
            Some(b'N') => self.lit("NaN", Value::Num(f64::NAN)),
            Some(b'I') => self.lit("Infinity", Value::Num(f64::INFINITY)),
            Some(b'"') => self.string().map(Value::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected character '{}' at byte {}", c as char, self.pos)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
            if self.peek() == Some(b'I') {
                return self.lit("Infinity", Value::Num(f64::NEG_INFINITY));
            }
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Value::Num).map_err(|e| format!("bad number '{text}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape '{hex}'"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| "invalid utf-8 in string")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\""] {
            let v = parse(src).unwrap();
            let again = parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, again);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\n\"y\""}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Value::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\n\"y\""));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str(), Some("éA"));
    }

    #[test]
    fn typed_getters_with_defaults() {
        let v = parse(r#"{"x": 2.5, "n": 7, "flag": true, "s": "hey"}"#).unwrap();
        assert_eq!(v.f64_or("x", 0.0), 2.5);
        assert_eq!(v.usize_or("n", 0), 7);
        assert_eq!(v.usize_or("missing", 3), 3);
        assert!(v.bool_or("flag", false));
        assert_eq!(v.str_or("s", ""), "hey");
        assert_eq!(v.str_or("nope", "dflt"), "dflt");
    }

    #[test]
    fn pretty_parses_back() {
        let v = Value::obj(vec![
            ("arr", Value::from_f64_slice(&[1.0, 2.0, 3.5])),
            ("name", "test".into()),
        ]);
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn write_pretty_at_matches_the_nested_document() {
        // A value pretty-printed standalone at depth 2 must be byte-equal
        // to how it appears inside a depth-0 document that nests it two
        // levels deep (object -> array -> value).
        let inner = Value::obj(vec![("a", 1.0.into()), ("b", "x".into())]);
        let doc = Value::obj(vec![("outer", Value::Arr(vec![inner.clone()]))]);
        let pretty = doc.to_string_pretty();
        let mut frag = String::new();
        inner.write_pretty_at(&mut frag, 2);
        assert!(pretty.contains(&frag), "fragment not found:\n{pretty}\n---\n{frag}");
    }

    #[test]
    fn compact_roundtrip_preserves_pretty_output() {
        // parse(compact(v)) must pretty-print identically to v — the
        // property the streaming assembler's byte-identity rests on.
        let v = Value::obj(vec![
            ("f", Value::Num(0.1234567890123)),
            ("i", Value::Num(42.0)),
            ("neg", Value::Num(-7.5e-9)),
            ("s", "a\"b\\c\n".into()),
            ("nan", Value::Num(f64::NAN)),
        ]);
        let round = parse(&v.to_string_compact()).unwrap();
        assert_eq!(round.to_string_pretty(), v.to_string_pretty());
        assert_eq!(round.get("f").unwrap().to_string_compact(), "0.1234567890123");
        assert_eq!(round.get("i").unwrap().to_string_compact(), "42");
    }

    #[test]
    fn nonfinite_numbers_roundtrip_losslessly() {
        assert_eq!(Value::Num(f64::NAN).to_string_compact(), "NaN");
        assert_eq!(Value::Num(f64::INFINITY).to_string_compact(), "Infinity");
        assert_eq!(Value::Num(f64::NEG_INFINITY).to_string_compact(), "-Infinity");
        assert!(parse("NaN").unwrap().as_f64().unwrap().is_nan());
        assert_eq!(parse("Infinity").unwrap().as_f64(), Some(f64::INFINITY));
        assert_eq!(parse("-Infinity").unwrap().as_f64(), Some(f64::NEG_INFINITY));
        // Inside a document, and re-emitted byte-identically.
        let doc = r#"{"a":NaN,"b":[-Infinity,Infinity,1.5]}"#;
        let v = parse(doc).unwrap();
        assert!(v.get("a").unwrap().as_f64().unwrap().is_nan());
        assert_eq!(v.to_string_compact(), doc);
        // Near-miss tokens are still rejected.
        assert!(parse("Nan").is_err());
        assert!(parse("-Inf").is_err());
        assert!(parse("NaNx").is_err());
    }
}
