//! Minimal criterion-style benchmark harness (`criterion` is not available
//! on the offline toolchain). `cargo bench` runs each bench target as a
//! plain binary (`harness = false`); those binaries use this module both
//! for wall-clock micro-benchmarks (§Perf) and to print the figure/table
//! reproduction rows.

use std::time::Instant;

/// Timing statistics of a measured closure.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub iters: u64,
    pub total_s: f64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchStats {
    pub fn throughput_per_s(&self) -> f64 {
        if self.mean_ns == 0.0 {
            0.0
        } else {
            1e9 / self.mean_ns
        }
    }
}

/// Measure `f`, auto-calibrating the iteration count to fill roughly
/// `target_s` seconds of wall time (criterion-like behaviour).
pub fn bench<F: FnMut()>(name: &str, target_s: f64, mut f: F) -> BenchStats {
    // Warm up + calibrate.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_s / once).clamp(1.0, 5_000_000.0)) as u64;

    // Batched sampling: split iterations into up to 100 samples.
    let samples = (iters.min(100)).max(1);
    let per_sample = (iters / samples).max(1);
    let mut sample_ns: Vec<f64> = Vec::with_capacity(samples as usize);
    let total_t = Instant::now();
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..per_sample {
            f();
        }
        sample_ns.push(t.elapsed().as_nanos() as f64 / per_sample as f64);
    }
    let total_s = total_t.elapsed().as_secs_f64();
    sample_ns.sort_by(f64::total_cmp);
    let mean_ns = sample_ns.iter().sum::<f64>() / sample_ns.len() as f64;
    let stats = BenchStats {
        iters: samples * per_sample,
        total_s,
        mean_ns,
        p50_ns: super::stats::percentile_sorted(&sample_ns, 50.0),
        p99_ns: super::stats::percentile_sorted(&sample_ns, 99.0),
        min_ns: sample_ns[0],
        max_ns: *sample_ns.last().unwrap(),
    };
    println!(
        "bench {name:<42} {:>12.1} ns/iter  (p50 {:>10.1}, p99 {:>10.1})  {:>14.0} it/s",
        stats.mean_ns,
        stats.p50_ns,
        stats.p99_ns,
        stats.throughput_per_s()
    );
    stats
}

/// Section header used by the figure-reproduction bench binaries.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a table row with fixed column widths.
pub fn row(cells: &[String]) {
    let line: Vec<String> = cells.iter().map(|c| format!("{c:<16}")).collect();
    println!("{}", line.join(" "));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut acc = 0u64;
        let s = bench("noop-ish", 0.02, || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.iters >= 1);
        assert!(s.mean_ns >= 0.0);
        assert!(s.min_ns <= s.max_ns);
    }

    #[test]
    fn throughput_inverse_of_mean() {
        let s = BenchStats {
            iters: 1,
            total_s: 1.0,
            mean_ns: 100.0,
            p50_ns: 100.0,
            p99_ns: 100.0,
            min_ns: 100.0,
            max_ns: 100.0,
        };
        assert!((s.throughput_per_s() - 1e7).abs() < 1.0);
    }
}
