//! Minimal dense linear algebra: just enough for the process-variation
//! model — building the spatial correlation matrix `rho_ij,kl =
//! exp(-alpha * dist)` over the N_chip x N_chip grid and sampling
//! correlated Gaussians via a Cholesky factorization.

/// Row-major square matrix.
#[derive(Clone, Debug)]
pub struct Matrix {
    pub n: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(n: usize) -> Matrix {
        Matrix { n, data: vec![0.0; n * n] }
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.n + j] = v;
    }

    /// Lower-triangular Cholesky factor L with A = L L^T.
    ///
    /// Adds a tiny jitter to the diagonal on near-singular inputs (the
    /// correlation matrix of a fine grid with slowly decaying correlation
    /// is numerically borderline-PSD).
    pub fn cholesky(&self) -> Result<Matrix, String> {
        let n = self.n;
        let mut l = Matrix::zeros(n);
        let jitter = 1e-10;
        for i in 0..n {
            for j in 0..=i {
                let mut sum = self.get(i, j);
                for k in 0..j {
                    sum -= l.get(i, k) * l.get(j, k);
                }
                if i == j {
                    let d = sum + jitter;
                    if d <= 0.0 {
                        return Err(format!("matrix not positive definite at row {i} (d={d})"));
                    }
                    l.set(i, j, d.sqrt());
                } else {
                    l.set(i, j, sum / l.get(j, j));
                }
            }
        }
        Ok(l)
    }

    /// y = A x (x.len() == n).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0.0; self.n];
        for i in 0..self.n {
            let row = &self.data[i * self.n..(i + 1) * self.n];
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x.iter()) {
                acc += a * b;
            }
            y[i] = acc;
        }
        y
    }

    /// y = L x exploiting lower-triangular structure (Cholesky sampling).
    pub fn lower_matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0.0; self.n];
        for i in 0..self.n {
            let row = &self.data[i * self.n..i * self.n + i + 1];
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x.iter()) {
                acc += a * b;
            }
            y[i] = acc;
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn cholesky_of_identity() {
        let i4 = Matrix::identity(4);
        let l = i4.cholesky().unwrap();
        for i in 0..4 {
            for j in 0..4 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((l.get(i, j) - expect).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn cholesky_reconstructs() {
        // A = B B^T + n I is SPD for random B.
        let n = 8;
        let mut rng = Rng::new(99);
        let mut b = Matrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                b.set(i, j, rng.gaussian());
            }
        }
        let mut a = Matrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b.get(i, k) * b.get(j, k);
                }
                a.set(i, j, s + if i == j { n as f64 } else { 0.0 });
            }
        }
        let l = a.cholesky().unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += l.get(i, k) * l.get(j, k);
                }
                assert!((s - a.get(i, j)).abs() < 1e-8, "mismatch at {i},{j}");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Matrix::identity(2);
        a.set(0, 0, -1.0);
        assert!(a.cholesky().is_err());
    }

    #[test]
    fn correlated_samples_have_target_correlation() {
        // 2x2 correlation 0.8: empirical correlation of L z should match.
        let mut a = Matrix::identity(2);
        a.set(0, 1, 0.8);
        a.set(1, 0, 0.8);
        let l = a.cholesky().unwrap();
        let mut rng = Rng::new(4);
        let n = 100_000;
        let (mut sx, mut sy, mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = [rng.gaussian(), rng.gaussian()];
            let v = l.lower_matvec(&z);
            sx += v[0];
            sy += v[1];
            sxy += v[0] * v[1];
            sxx += v[0] * v[0];
            syy += v[1] * v[1];
        }
        let nf = n as f64;
        let cov = sxy / nf - (sx / nf) * (sy / nf);
        let vx = sxx / nf - (sx / nf).powi(2);
        let vy = syy / nf - (sy / nf).powi(2);
        let corr = cov / (vx * vy).sqrt();
        assert!((corr - 0.8).abs() < 0.01, "corr={corr}");
    }

    #[test]
    fn matvec_basic() {
        let mut a = Matrix::zeros(2);
        a.set(0, 0, 1.0);
        a.set(0, 1, 2.0);
        a.set(1, 0, 3.0);
        a.set(1, 1, 4.0);
        let y = a.matvec(&[1.0, 1.0]);
        assert_eq!(y, vec![3.0, 7.0]);
    }
}
