//! Tiny command-line argument parser (clap is not available offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments (declared with [`Cli::pos`] so they show up in `--help`).
//! Each binary declares its options up front so `--help` output is
//! generated consistently.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments with typed accessors.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub values: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
    /// Option names the user explicitly passed (as opposed to values
    /// seeded from the declared defaults).
    pub given: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }
    /// True if `--key ...` appeared on the command line; lets a
    /// subcommand reject flag combinations even when the key also has a
    /// default (e.g. `sweep --spec` vs the axis flags).
    pub fn was_given(&self, key: &str) -> bool {
        self.given.iter().any(|k| k == key)
    }
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
    /// Strict typed accessor: parse `key`'s value, erroring (naming the
    /// key and the bad token) on malformed input. Unlike the lenient
    /// [`Args::usize_or`]-style accessors, a typo must not silently fall
    /// back to a default — at sweep scale that runs the wrong grid for
    /// hours (`sweep` and `orchestrate` parse every scalar this way).
    pub fn parsed<T: std::str::FromStr>(&self, key: &str) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        let s = self.str_or(key, "");
        s.parse::<T>().map_err(|e| format!("bad --{key} '{s}': {e}"))
    }
}

/// A declared positional argument (documentation only — the parser
/// collects positionals regardless; declaring one adds a usage line and
/// an "Arguments" help section).
#[derive(Clone, Debug)]
pub struct PosSpec {
    pub name: &'static str,
    pub help: &'static str,
}

/// Command-line parser for one (sub)command.
pub struct Cli {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positionals: Vec<PosSpec>,
}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Cli {
        Cli { name, about, opts: Vec::new(), positionals: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Cli {
        self.opts.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Cli {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    /// Declare a repeatable positional argument for the help text
    /// (`carbon-sim merge <shard-dir>...`).
    pub fn pos(mut self, name: &'static str, help: &'static str) -> Cli {
        self.positionals.push(PosSpec { name, help });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n", self.name, self.about);
        if !self.positionals.is_empty() {
            let args: Vec<String> =
                self.positionals.iter().map(|p| format!("<{}>...", p.name)).collect();
            s.push_str(&format!(
                "\nUsage: {} [options] {}\n\nArguments:\n",
                self.name,
                args.join(" ")
            ));
            for p in &self.positionals {
                s.push_str(&format!("  <{}>...\n      {}\n", p.name, p.help));
            }
        }
        s.push_str("\nOptions:\n");
        for o in &self.opts {
            let d = o.default.map(|d| format!(" (default: {d})")).unwrap_or_default();
            let kind = if o.is_flag { "" } else { " <value>" };
            s.push_str(&format!("  --{}{}\n      {}{}\n", o.name, kind, o.help, d));
        }
        s.push_str("  --help\n      Show this help\n");
        s
    }

    /// Parse a raw token list (without the program name).
    pub fn parse(&self, raw: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        // Seed defaults.
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    args.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .cloned()
                                .ok_or_else(|| format!("option --{key} needs a value"))?
                        }
                    };
                    args.given.push(key.clone());
                    args.values.insert(key, val);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Parse from the process environment (skipping argv[0]).
    pub fn parse_env(&self) -> Args {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&raw) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("test", "test tool")
            .opt("rate", "60", "request rate")
            .opt("policy", "proposed", "core policy")
            .flag("verbose", "print more")
    }

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse(&toks(&[])).unwrap();
        assert_eq!(a.f64_or("rate", 0.0), 60.0);
        assert_eq!(a.str_or("policy", ""), "proposed");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn values_and_flags() {
        let a = cli().parse(&toks(&["--rate", "100", "--verbose", "pos1"])).unwrap();
        assert_eq!(a.f64_or("rate", 0.0), 100.0);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_form() {
        let a = cli().parse(&toks(&["--policy=linux"])).unwrap();
        assert_eq!(a.str_or("policy", ""), "linux");
    }

    #[test]
    fn was_given_distinguishes_explicit_values_from_defaults() {
        let a = cli().parse(&toks(&["--rate", "100"])).unwrap();
        assert!(a.was_given("rate"));
        assert!(!a.was_given("policy"), "default-seeded value is not 'given'");
        let b = cli().parse(&toks(&["--policy=linux"])).unwrap();
        assert!(b.was_given("policy"), "--key=value form counts as given");
    }

    #[test]
    fn parsed_is_strict_where_the_lenient_accessors_default() {
        let a = cli().parse(&toks(&["--rate", "12O"])).unwrap(); // letter O typo
        assert_eq!(a.f64_or("rate", 60.0), 60.0, "lenient accessor falls back");
        let err = a.parsed::<f64>("rate").unwrap_err();
        assert!(err.contains("--rate") && err.contains("12O"), "{err}");
        let b = cli().parse(&toks(&["--rate", "100"])).unwrap();
        assert_eq!(b.parsed::<usize>("rate").unwrap(), 100);
        assert_eq!(b.parsed::<f64>("rate").unwrap(), 100.0);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse(&toks(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cli().parse(&toks(&["--rate"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = cli().parse(&toks(&["--help"])).unwrap_err();
        assert!(err.contains("request rate"));
    }

    #[test]
    fn declared_positionals_show_in_usage_and_still_parse() {
        let c = Cli::new("merge", "merge tool").pos("dir", "a shard directory").opt(
            "out",
            "",
            "output path",
        );
        let u = c.usage();
        assert!(u.contains("Usage: merge [options] <dir>..."), "{u}");
        assert!(u.contains("a shard directory"), "{u}");
        let a = c.parse(&toks(&["d1", "--out", "x", "d2"])).unwrap();
        assert_eq!(a.positional, vec!["d1", "d2"]);
        assert_eq!(a.str_or("out", ""), "x");
    }
}
