//! Minimal scoped worker pool (the offline toolchain has no `rayon`).
//!
//! [`run_indexed`] shards `n` independent jobs across a fixed number of
//! `std::thread` workers via an atomic work-stealing counter and returns
//! the results **in job-index order**, regardless of which worker ran
//! which job or in what order they finished. Combined with per-job seeds
//! derived from the job index (not from execution order), this makes the
//! sweep engine's output bit-identical at any thread count.
//!
//! [`run_streamed`] is the completion-callback variant underneath it:
//! instead of collecting results into a vector (O(jobs) memory), it hands
//! each finished job to a caller-supplied sink **as it completes**, on the
//! calling thread, and retains nothing — the streaming sweep engine spills
//! each cell to disk this way, keeping memory O(workers) for grids too big
//! to hold in memory. It also takes an explicit job-id list rather than a
//! `0..n` range, so a resumed sweep can run only its remaining cells and
//! the adaptive search (`sweep --search`) can submit one replica rung of
//! still-contested scenarios at a time.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;

/// Number of worker threads to use when the caller passes 0 ("auto").
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(0), f(1), ..., f(n-1)` on up to `threads` workers and collect
/// the results in index order. `threads == 0` means auto (one per
/// available core); `threads == 1` runs inline with no thread overhead.
///
/// Jobs must be independent: `f` is shared by reference across workers,
/// so it captures only `Sync` state. A panicking job fails the pool
/// fast: the dying worker raises an abort flag, surviving workers stop
/// picking up new jobs, the worker's panic message reaches stderr
/// (default panic hook), and the collector then panics on the missing
/// result slot.
pub fn run_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let jobs: Vec<usize> = (0..n).collect();
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    run_streamed(&jobs, threads, f, |i, result| {
        slots[i] = Some(result);
        true
    });
    slots
        .into_iter()
        .map(|s| s.expect("pool worker panicked (its message is above) — job has no result"))
        .collect()
}

/// Run `f(jobs[0]), f(jobs[1]), ...` on up to `threads` workers and feed
/// each result to `sink` **in completion order** (non-deterministic under
/// concurrency), on the calling thread. The hand-off channel is bounded
/// at `threads` entries, so a sink slower than the workers exerts
/// backpressure and peak memory really is O(threads) in-flight results,
/// independent of `jobs.len()`.
///
/// `sink` returns `true` to keep going; returning `false` stops the
/// pool: workers stop picking up new jobs and the remaining in-flight
/// results are discarded (the streaming sweep uses this to bail out on
/// the first disk-write error instead of simulating the rest of the
/// grid for nothing).
///
/// `threads == 0` means auto (one per available core); `threads == 1`
/// runs inline in `jobs` order with no thread overhead. Job ids are
/// caller-defined (they need not be dense or sorted) — a resumed sweep
/// passes only its still-pending cell indices.
///
/// Panic semantics match [`run_indexed`]: a panicking job aborts the
/// pool fast, the worker's panic message reaches stderr, and the caller
/// panics once the surviving workers have drained.
pub fn run_streamed<T, F, C>(jobs: &[usize], threads: usize, f: F, mut sink: C)
where
    T: Send,
    F: Fn(usize) -> T + Sync,
    C: FnMut(usize, T) -> bool,
{
    let n = jobs.len();
    let threads = if threads == 0 { available_threads() } else { threads };
    let threads = threads.min(n.max(1));
    if threads <= 1 || n <= 1 {
        for &i in jobs {
            let result = f(i);
            if !sink(i, result) {
                return;
            }
        }
        return;
    }

    let next = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let (tx, rx) = mpsc::sync_channel::<(usize, T)>(threads);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let next = &next;
            let abort = &abort;
            let f = &f;
            scope.spawn(move || {
                // Raises the abort flag if this worker unwinds out of a
                // panicking job, so the others stop draining the queue.
                struct AbortOnPanic<'a>(&'a AtomicBool);
                impl Drop for AbortOnPanic<'_> {
                    fn drop(&mut self) {
                        if std::thread::panicking() {
                            self.0.store(true, Ordering::Relaxed);
                        }
                    }
                }
                let _guard = AbortOnPanic(abort);
                loop {
                    if abort.load(Ordering::Relaxed) {
                        break;
                    }
                    let pos = next.fetch_add(1, Ordering::Relaxed);
                    if pos >= n {
                        break;
                    }
                    let i = jobs[pos];
                    let result = f(i);
                    if tx.send((i, result)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx); // the receive loop ends when the last worker finishes
        let mut sink_stopped = false;
        for (i, result) in rx {
            if !sink(i, result) {
                // Dropping the receiver (end of this loop) fails the
                // blocked senders fast; the flag stops idle workers from
                // claiming new jobs.
                sink_stopped = true;
                abort.store(true, Ordering::Relaxed);
                break;
            }
        }
        if !sink_stopped && abort.load(Ordering::Relaxed) {
            panic!("pool worker panicked (its message is above) — job has no result");
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_arrive_in_index_order() {
        for threads in [1, 2, 3, 8] {
            let out = run_indexed(20, threads, |i| i * i);
            assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn matches_sequential_for_any_thread_count() {
        let seq = run_indexed(13, 1, |i| format!("job-{i}"));
        for threads in [0, 2, 4, 16] {
            assert_eq!(run_indexed(13, threads, |i| format!("job-{i}")), seq);
        }
    }

    #[test]
    fn more_threads_than_jobs_is_fine() {
        assert_eq!(run_indexed(2, 64, |i| i), vec![0, 1]);
        assert_eq!(run_indexed(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(run_indexed(1, 4, |i| i + 7), vec![7]);
    }

    #[test]
    #[should_panic(expected = "pool worker panicked")]
    fn panicking_job_propagates_to_caller() {
        run_indexed(8, 2, |i| {
            if i == 0 {
                panic!("job zero exploded");
            }
            i
        });
    }

    #[test]
    fn streamed_covers_exactly_the_given_jobs() {
        for threads in [1, 2, 8] {
            let jobs = vec![3usize, 0, 7, 11, 4];
            let mut seen = Vec::new();
            run_streamed(&jobs, threads, |i| i * 10, |i, r| {
                seen.push((i, r));
                true
            });
            assert_eq!(seen.len(), jobs.len(), "threads={threads}");
            for &(i, r) in &seen {
                assert_eq!(r, i * 10);
                assert!(jobs.contains(&i));
            }
            let mut ids: Vec<usize> = seen.iter().map(|&(i, _)| i).collect();
            ids.sort_unstable();
            let mut expect = jobs.clone();
            expect.sort_unstable();
            assert_eq!(ids, expect, "threads={threads}");
        }
    }

    #[test]
    fn streamed_single_thread_preserves_job_order() {
        let jobs = vec![5usize, 2, 9];
        let mut order = Vec::new();
        run_streamed(&jobs, 1, |i| i, |i, _| {
            order.push(i);
            true
        });
        assert_eq!(order, jobs);
    }

    #[test]
    fn streamed_empty_job_list_is_a_noop() {
        let mut calls = 0;
        run_streamed(&[], 4, |i| i, |_, _| {
            calls += 1;
            true
        });
        assert_eq!(calls, 0);
    }

    #[test]
    fn streamed_sink_false_stops_early() {
        // Inline path: exactly one call.
        let jobs: Vec<usize> = (0..50).collect();
        let mut calls = 0;
        run_streamed(&jobs, 1, |i| i, |_, _| {
            calls += 1;
            false
        });
        assert_eq!(calls, 1);
        // Threaded path: the pool stops promptly — far fewer sink calls
        // than jobs (bounded by in-flight results, not the job count).
        let mut calls = 0;
        run_streamed(&jobs, 4, |i| i, |_, _| {
            calls += 1;
            false
        });
        assert_eq!(calls, 1, "sink must not be called again after returning false");
    }

    #[test]
    #[should_panic(expected = "pool worker panicked")]
    fn streamed_panicking_job_propagates_to_caller() {
        let jobs: Vec<usize> = (0..8).collect();
        run_streamed(
            &jobs,
            2,
            |i| {
                if i == 3 {
                    panic!("job three exploded");
                }
                i
            },
            |_, _| true,
        );
    }

    #[test]
    fn workers_actually_run_concurrently_on_shared_state() {
        use std::sync::atomic::AtomicU64;
        let total = AtomicU64::new(0);
        run_indexed(100, 4, |i| {
            total.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 99 * 100 / 2);
    }
}
