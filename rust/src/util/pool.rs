//! Minimal scoped worker pool (the offline toolchain has no `rayon`).
//!
//! [`run_indexed`] shards `n` independent jobs across a fixed number of
//! `std::thread` workers via an atomic work-stealing counter and returns
//! the results **in job-index order**, regardless of which worker ran
//! which job or in what order they finished. Combined with per-job seeds
//! derived from the job index (not from execution order), this makes the
//! sweep engine's output bit-identical at any thread count.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;

/// Number of worker threads to use when the caller passes 0 ("auto").
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(0), f(1), ..., f(n-1)` on up to `threads` workers and collect
/// the results in index order. `threads == 0` means auto (one per
/// available core); `threads == 1` runs inline with no thread overhead.
///
/// Jobs must be independent: `f` is shared by reference across workers,
/// so it captures only `Sync` state. A panicking job fails the pool
/// fast: the dying worker raises an abort flag, surviving workers stop
/// picking up new jobs, the worker's panic message reaches stderr
/// (default panic hook), and the collector then panics on the missing
/// result slot.
pub fn run_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = if threads == 0 { available_threads() } else { threads };
    let threads = threads.min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let next = &next;
            let abort = &abort;
            let f = &f;
            scope.spawn(move || {
                // Raises the abort flag if this worker unwinds out of a
                // panicking job, so the others stop draining the queue.
                struct AbortOnPanic<'a>(&'a AtomicBool);
                impl Drop for AbortOnPanic<'_> {
                    fn drop(&mut self) {
                        if std::thread::panicking() {
                            self.0.store(true, Ordering::Relaxed);
                        }
                    }
                }
                let _guard = AbortOnPanic(abort);
                loop {
                    if abort.load(Ordering::Relaxed) {
                        break;
                    }
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let result = f(i);
                    if tx.send((i, result)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx); // the receive loop ends when the last worker finishes
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, result) in rx {
            slots[i] = Some(result);
        }
        slots
            .into_iter()
            .map(|s| s.expect("pool worker panicked (its message is above) — job has no result"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_arrive_in_index_order() {
        for threads in [1, 2, 3, 8] {
            let out = run_indexed(20, threads, |i| i * i);
            assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn matches_sequential_for_any_thread_count() {
        let seq = run_indexed(13, 1, |i| format!("job-{i}"));
        for threads in [0, 2, 4, 16] {
            assert_eq!(run_indexed(13, threads, |i| format!("job-{i}")), seq);
        }
    }

    #[test]
    fn more_threads_than_jobs_is_fine() {
        assert_eq!(run_indexed(2, 64, |i| i), vec![0, 1]);
        assert_eq!(run_indexed(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(run_indexed(1, 4, |i| i + 7), vec![7]);
    }

    #[test]
    #[should_panic(expected = "pool worker panicked")]
    fn panicking_job_propagates_to_caller() {
        run_indexed(8, 2, |i| {
            if i == 0 {
                panic!("job zero exploded");
            }
            i
        });
    }

    #[test]
    fn workers_actually_run_concurrently_on_shared_state() {
        use std::sync::atomic::AtomicU64;
        let total = AtomicU64::new(0);
        run_indexed(100, 4, |i| {
            total.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 99 * 100 / 2);
    }
}
