//! Statistics helpers: percentiles, coefficient of variation, histograms.
//!
//! These back every metric the paper reports: frequency CV across cores
//! (Fig. 6), percentile bands across cluster machines (p1/p50/p90/p99 in
//! Figs. 6–8), and the violin-style distributions of Fig. 2 / Fig. 8.

/// Arithmetic mean. Empty input -> 0.0.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance. Empty input -> 0.0.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Coefficient of variation: sigma / mean. The paper's per-CPU aging
/// unevenness metric (Fig. 6). Returns 0 for empty/zero-mean inputs.
pub fn coeff_of_variation(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        return 0.0;
    }
    std_dev(xs) / m.abs()
}

/// Linear-interpolated percentile, `p` in [0, 100]. Sorts a copy.
///
/// NaN samples (e.g. a 0/0 ratio from an empty cell) are **ignored**: the
/// percentile is computed over the remaining values, and an empty or
/// all-NaN input returns 0.0. A `partial_cmp(..).unwrap()` sort here
/// would instead panic the whole sweep on the first NaN mid-grid.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice (ascending).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile out of range: {p}");
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Summary of a distribution: the percentile band the paper reports plus
/// mean/min/max. Produced by every experiment runner.
///
/// NaN samples are excluded from every statistic and counted in
/// [`Summary::nan_count`] instead; `n` is the number of samples the
/// statistics were actually computed over (`n + nan_count` = input
/// length). An all-NaN input summarizes like an empty one.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    /// Input samples that were NaN and therefore excluded.
    pub nan_count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p1: f64,
    pub p25: f64,
    pub p50: f64,
    pub p75: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
        let nan_count = xs.len() - v.len();
        if v.is_empty() {
            return Summary {
                n: 0,
                nan_count,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p1: 0.0,
                p25: 0.0,
                p50: 0.0,
                p75: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        v.sort_by(f64::total_cmp);
        Summary {
            n: v.len(),
            nan_count,
            mean: mean(&v),
            std: std_dev(&v),
            min: v[0],
            p1: percentile_sorted(&v, 1.0),
            p25: percentile_sorted(&v, 25.0),
            p50: percentile_sorted(&v, 50.0),
            p75: percentile_sorted(&v, 75.0),
            p90: percentile_sorted(&v, 90.0),
            p99: percentile_sorted(&v, 99.0),
            max: *v.last().unwrap(),
        }
    }

    /// Render one compact row, used by the bench harnesses.
    pub fn row(&self) -> String {
        format!(
            "n={:<8} mean={:<12.6} std={:<12.6} min={:<12.6} p1={:<12.6} p50={:<12.6} p90={:<12.6} p99={:<12.6} max={:<12.6}",
            self.n, self.mean, self.std, self.min, self.p1, self.p50, self.p90, self.p99, self.max
        )
    }
}

/// Fixed-width histogram over [lo, hi); values outside are clamped into the
/// edge bins. Used for the violin/distribution figures (Fig. 2, Fig. 8).
///
/// NaN samples are not binned (a NaN-to-int cast is 0, which would
/// silently pile them into bin 0 and skew the distributions); they are
/// counted in `nan_count` instead. ±Inf clamp into the edge bins like
/// any other out-of-range value.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub count: u64,
    /// NaN samples seen by [`Histogram::add`] and excluded from `bins`.
    pub nan_count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Histogram {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], count: 0, nan_count: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x.is_nan() {
            self.nan_count += 1;
            return;
        }
        let n = self.bins.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        // Saturating float→int casts send ±Inf to the isize extremes,
        // which the clamp folds into the edge bins.
        let idx = ((t * n as f64) as isize).clamp(0, n as isize - 1) as usize;
        self.bins[idx] += 1;
        self.count += 1;
    }

    /// Normalized bin densities (sum to 1).
    pub fn density(&self) -> Vec<f64> {
        if self.count == 0 {
            return vec![0.0; self.bins.len()];
        }
        self.bins.iter().map(|&b| b as f64 / self.count as f64).collect()
    }

    /// ASCII sparkline of the bins — the text-mode "violin plot".
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.bins.iter().copied().max().unwrap_or(0);
        if max == 0 {
            return " ".repeat(self.bins.len());
        }
        self.bins
            .iter()
            .map(|&b| {
                if b == 0 {
                    ' '
                } else {
                    let idx = ((b as f64 / max as f64) * 7.0).round() as usize;
                    GLYPHS[idx.min(7)]
                }
            })
            .collect()
    }
}

/// Streaming mean/variance (Welford). Used on the simulator hot path where
/// storing every sample would allocate.
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cv_scale_invariant() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [10.0, 20.0, 30.0];
        assert!((coeff_of_variation(&xs) - coeff_of_variation(&ys)).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_singleton_and_empty() {
        assert_eq!(percentile(&[5.0], 99.0), 5.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_and_summary_ignore_nans() {
        // Regression: one NaN sample used to panic the partial_cmp sort.
        let xs = [3.0, f64::NAN, 1.0, f64::NAN, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 3.0);
        assert!((percentile(&xs, 50.0) - 2.0).abs() < 1e-12);
        let s = Summary::of(&xs);
        assert_eq!(s.n, 3);
        assert_eq!(s.nan_count, 2);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
        // All-NaN input behaves like an empty one.
        let all = Summary::of(&[f64::NAN, f64::NAN]);
        assert_eq!((all.n, all.nan_count), (0, 2));
        assert_eq!(all.p50, 0.0);
        assert_eq!(percentile(&[f64::NAN], 50.0), 0.0);
        // NaN-free inputs are unaffected.
        assert_eq!(Summary::of(&[1.0, 2.0]).nan_count, 0);
    }

    #[test]
    fn summary_keeps_infinities_in_order() {
        let s = Summary::of(&[f64::NEG_INFINITY, 1.0, f64::INFINITY]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, f64::NEG_INFINITY);
        assert_eq!(s.max, f64::INFINITY);
        assert_eq!(s.p50, 1.0);
    }

    #[test]
    fn summary_orders() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.p1 < s.p50 && s.p50 < s.p90 && s.p90 < s.p99);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 999.0);
        assert!((s.p50 - 499.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_bins_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(0.5);
        h.add(9.5);
        h.add(-3.0); // clamps into bin 0
        h.add(42.0); // clamps into bin 9
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
        assert_eq!(h.count, 4);
        let d = h.density();
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_nan_separately_and_clamps_infinities() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(f64::NAN);
        h.add(5.0);
        h.add(f64::NEG_INFINITY);
        h.add(f64::INFINITY);
        assert_eq!(h.nan_count, 1, "NaN must not land in any bin");
        assert_eq!(h.bins[0], 1, "-inf clamps into the low edge bin");
        assert_eq!(h.bins[9], 1, "+inf clamps into the high edge bin");
        assert_eq!(h.bins[5], 1);
        assert_eq!(h.count, 3);
        let d = h.density();
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.variance() - variance(&xs)).abs() < 1e-9);
    }
}
