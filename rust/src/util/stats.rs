//! Statistics helpers: percentiles, coefficient of variation, histograms.
//!
//! These back every metric the paper reports: frequency CV across cores
//! (Fig. 6), percentile bands across cluster machines (p1/p50/p90/p99 in
//! Figs. 6–8), and the violin-style distributions of Fig. 2 / Fig. 8.

/// Arithmetic mean. Empty input -> 0.0.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance. Empty input -> 0.0.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Coefficient of variation: sigma / mean. The paper's per-CPU aging
/// unevenness metric (Fig. 6). Returns 0 for empty/zero-mean inputs.
pub fn coeff_of_variation(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        return 0.0;
    }
    std_dev(xs) / m.abs()
}

/// Linear-interpolated percentile, `p` in [0, 100]. Sorts a copy.
///
/// NaN samples (e.g. a 0/0 ratio from an empty cell) are **ignored**: the
/// percentile is computed over the remaining values, and an empty or
/// all-NaN input returns 0.0. A `partial_cmp(..).unwrap()` sort here
/// would instead panic the whole sweep on the first NaN mid-grid.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice (ascending).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile out of range: {p}");
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Summary of a distribution: the percentile band the paper reports plus
/// mean/min/max. Produced by every experiment runner.
///
/// NaN samples are excluded from every statistic and counted in
/// [`Summary::nan_count`] instead; `n` is the number of samples the
/// statistics were actually computed over (`n + nan_count` = input
/// length). An all-NaN input summarizes like an empty one.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    /// Input samples that were NaN and therefore excluded.
    pub nan_count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p1: f64,
    pub p25: f64,
    pub p50: f64,
    pub p75: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
        let nan_count = xs.len() - v.len();
        if v.is_empty() {
            return Summary {
                n: 0,
                nan_count,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p1: 0.0,
                p25: 0.0,
                p50: 0.0,
                p75: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        v.sort_by(f64::total_cmp);
        Summary {
            n: v.len(),
            nan_count,
            mean: mean(&v),
            std: std_dev(&v),
            min: v[0],
            p1: percentile_sorted(&v, 1.0),
            p25: percentile_sorted(&v, 25.0),
            p50: percentile_sorted(&v, 50.0),
            p75: percentile_sorted(&v, 75.0),
            p90: percentile_sorted(&v, 90.0),
            p99: percentile_sorted(&v, 99.0),
            max: *v.last().unwrap(),
        }
    }

    /// Render one compact row, used by the bench harnesses. NaN samples
    /// are excluded from every statistic, so a nonzero [`Summary::nan_count`]
    /// is surfaced explicitly (`nan=<k>`) instead of silently shrinking `n`.
    pub fn row(&self) -> String {
        let mut row = format!(
            "n={:<8} mean={:<12.6} std={:<12.6} min={:<12.6} p1={:<12.6} p50={:<12.6} p90={:<12.6} p99={:<12.6} max={:<12.6}",
            self.n, self.mean, self.std, self.min, self.p1, self.p50, self.p90, self.p99, self.max
        );
        if self.nan_count > 0 {
            row.push_str(&format!(" nan={}", self.nan_count));
        }
        row
    }
}

/// Fixed-width histogram over [lo, hi); values outside are clamped into the
/// edge bins. Used for the violin/distribution figures (Fig. 2, Fig. 8).
///
/// NaN samples are not binned (a NaN-to-int cast is 0, which would
/// silently pile them into bin 0 and skew the distributions); they are
/// counted in `nan_count` instead. ±Inf clamp into the edge bins like
/// any other out-of-range value.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub count: u64,
    /// NaN samples seen by [`Histogram::add`] and excluded from `bins`.
    pub nan_count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Histogram {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], count: 0, nan_count: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x.is_nan() {
            self.nan_count += 1;
            return;
        }
        let n = self.bins.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        // Saturating float→int casts send ±Inf to the isize extremes,
        // which the clamp folds into the edge bins.
        let idx = ((t * n as f64) as isize).clamp(0, n as isize - 1) as usize;
        self.bins[idx] += 1;
        self.count += 1;
    }

    /// Normalized bin densities (sum to 1).
    pub fn density(&self) -> Vec<f64> {
        if self.count == 0 {
            return vec![0.0; self.bins.len()];
        }
        self.bins.iter().map(|&b| b as f64 / self.count as f64).collect()
    }

    /// ASCII sparkline of the bins — the text-mode "violin plot".
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.bins.iter().copied().max().unwrap_or(0);
        if max == 0 {
            return " ".repeat(self.bins.len());
        }
        self.bins
            .iter()
            .map(|&b| {
                if b == 0 {
                    ' '
                } else {
                    let idx = ((b as f64 / max as f64) * 7.0).round() as usize;
                    GLYPHS[idx.min(7)]
                }
            })
            .collect()
    }
}

/// Streaming mean/variance (Welford). Used on the simulator hot path where
/// storing every sample would allocate.
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Unbiased (n−1 denominator) sample variance — what confidence
    /// intervals need, unlike the population [`Welford::variance`].
    /// Zero for fewer than two samples.
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Half-width of the two-sided Student-t confidence interval on the
    /// mean at the given `confidence` (e.g. 0.95): `t · s / √n` with
    /// `n − 1` degrees of freedom. `None` when fewer than two samples
    /// have been seen (no variance estimate) or `confidence` is not in
    /// (0, 1). The interval is `mean ± half_width`.
    pub fn mean_ci_half_width(&self, confidence: f64) -> Option<f64> {
        if self.n < 2 || !(confidence > 0.0 && confidence < 1.0) {
            return None;
        }
        let t = t_quantile(0.5 + confidence / 2.0, self.n - 1);
        Some(t * (self.sample_variance() / self.n as f64).sqrt())
    }
}

/// Quantile function (inverse CDF) of the standard normal, by Acklam's
/// rational approximation (absolute error < 1.2e-9 over (0, 1)). The
/// basis for [`t_quantile`] — no statistics crate is available offline.
/// `p` outside (0, 1) returns ±infinity at the endpoints and panics
/// beyond them.
pub fn normal_quantile(p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p), "normal_quantile: p={p} outside [0, 1]");
    if p == 0.0 {
        return f64::NEG_INFINITY;
    }
    if p == 1.0 {
        return f64::INFINITY;
    }
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    let tail = |q: f64| {
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    if p < P_LOW {
        tail((-2.0 * p.ln()).sqrt())
    } else if p > 1.0 - P_LOW {
        -tail((-2.0 * (1.0 - p).ln()).sqrt())
    } else {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    }
}

/// Quantile of Student's t with `df` degrees of freedom. Exact closed
/// forms for df 1 and 2; a four-term Cornish–Fisher expansion around
/// [`normal_quantile`] above (relative error under ~1e-3 at df = 3,
/// shrinking as df grows — ample for racing decisions whose inputs are
/// noisy simulation metrics).
pub fn t_quantile(p: f64, df: u64) -> f64 {
    assert!(df >= 1, "t_quantile: df must be ≥ 1");
    match df {
        1 => (std::f64::consts::PI * (p - 0.5)).tan(),
        2 => {
            let a = 2.0 * p - 1.0;
            a * (2.0 / (1.0 - a * a)).sqrt()
        }
        _ => {
            let v = df as f64;
            let z = normal_quantile(p);
            let z3 = z * z * z;
            let z5 = z3 * z * z;
            let z7 = z5 * z * z;
            let z9 = z7 * z * z;
            z + (z3 + z) / (4.0 * v)
                + (5.0 * z5 + 16.0 * z3 + 3.0 * z) / (96.0 * v * v)
                + (3.0 * z7 + 19.0 * z5 + 17.0 * z3 - 15.0 * z) / (384.0 * v * v * v)
                + (79.0 * z9 + 776.0 * z7 + 1482.0 * z5 - 1920.0 * z3 - 945.0 * z)
                    / (92160.0 * v * v * v * v)
        }
    }
}

/// Exact two-sided sign-test p-value: the probability, under the null
/// that positive and negative differences are equally likely, of a split
/// at least as lopsided as `(n_pos, n_neg)`. Computed from the exact
/// Binomial(n, ½) tail (no approximation), doubled and clamped to 1.
/// Ties carry no sign information and are dropped by the caller
/// ([`PairedDiff::add`]); zero observations return 1.0.
pub fn sign_test_two_sided(n_pos: u64, n_neg: u64) -> f64 {
    let n = n_pos + n_neg;
    if n == 0 {
        return 1.0;
    }
    let k = n_pos.min(n_neg);
    // P(X ≤ k) for X ~ Binomial(n, ½), accumulating the pmf
    // incrementally: pmf(0) = 2^-n, pmf(i+1) = pmf(i)·(n-i)/(i+1).
    // (2^-n underflows to 0 beyond n ≈ 1074 — at that replica count the
    // t interval decides long before the sign test matters.)
    let mut pmf = 0.5f64.powi(n.min(i32::MAX as u64) as i32);
    let mut tail = 0.0;
    for i in 0..=k {
        tail += pmf;
        pmf *= (n - i) as f64 / (i + 1) as f64;
    }
    (2.0 * tail).min(1.0)
}

/// Paired-difference statistics for the sweep search's racing decisions:
/// Welford state over per-replica metric differences `d = worse − better`
/// plus the sign counts for the exact sign test. A policy pair is
/// [`PairedDiff::decisive`] when either the Student-t CI on the mean
/// difference excludes zero or the sign test rejects at the same level —
/// the sign test is the small-n / heavy-tail fallback the t interval
/// needs (with 2–4 replicas the t critical values are huge, but 4–5
/// same-sign differences already reject at 90%).
#[derive(Clone, Copy, Debug, Default)]
pub struct PairedDiff {
    w: Welford,
    n_pos: u64,
    n_neg: u64,
}

impl PairedDiff {
    /// Record one paired difference. Exact zeros (ties) still update the
    /// mean/CI state but carry no sign information.
    pub fn add(&mut self, d: f64) {
        self.w.add(d);
        if d > 0.0 {
            self.n_pos += 1;
        } else if d < 0.0 {
            self.n_neg += 1;
        }
    }

    pub fn n(&self) -> u64 {
        self.w.count()
    }

    pub fn mean(&self) -> f64 {
        self.w.mean()
    }

    /// See [`Welford::mean_ci_half_width`].
    pub fn ci_half_width(&self, confidence: f64) -> Option<f64> {
        self.w.mean_ci_half_width(confidence)
    }

    /// See [`sign_test_two_sided`].
    pub fn sign_test_p(&self) -> f64 {
        sign_test_two_sided(self.n_pos, self.n_neg)
    }

    /// Every recorded difference was exactly zero (and there were at
    /// least two). No test can ever call such a pair decisive, but in a
    /// paired design repeated exact ties mean the two treatments are
    /// behaving identically — the search treats this as resolved rather
    /// than burning the full replica budget on a provable tie.
    pub fn all_ties(&self) -> bool {
        self.w.count() >= 2 && self.n_pos == 0 && self.n_neg == 0
    }

    /// Is the mean difference resolved away from zero at `confidence`?
    /// True when the t interval excludes zero, or the sign test's
    /// p-value is at most `1 − confidence`. Fewer than two samples are
    /// never decisive.
    pub fn decisive(&self, confidence: f64) -> bool {
        if self.w.count() < 2 {
            return false;
        }
        if let Some(h) = self.ci_half_width(confidence) {
            if self.w.mean().abs() > h {
                return true;
            }
        }
        self.sign_test_p() <= 1.0 - confidence
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cv_scale_invariant() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [10.0, 20.0, 30.0];
        assert!((coeff_of_variation(&xs) - coeff_of_variation(&ys)).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_singleton_and_empty() {
        assert_eq!(percentile(&[5.0], 99.0), 5.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_and_summary_ignore_nans() {
        // Regression: one NaN sample used to panic the partial_cmp sort.
        let xs = [3.0, f64::NAN, 1.0, f64::NAN, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 3.0);
        assert!((percentile(&xs, 50.0) - 2.0).abs() < 1e-12);
        let s = Summary::of(&xs);
        assert_eq!(s.n, 3);
        assert_eq!(s.nan_count, 2);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
        // All-NaN input behaves like an empty one.
        let all = Summary::of(&[f64::NAN, f64::NAN]);
        assert_eq!((all.n, all.nan_count), (0, 2));
        assert_eq!(all.p50, 0.0);
        assert_eq!(percentile(&[f64::NAN], 50.0), 0.0);
        // NaN-free inputs are unaffected.
        assert_eq!(Summary::of(&[1.0, 2.0]).nan_count, 0);
    }

    #[test]
    fn summary_keeps_infinities_in_order() {
        let s = Summary::of(&[f64::NEG_INFINITY, 1.0, f64::INFINITY]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, f64::NEG_INFINITY);
        assert_eq!(s.max, f64::INFINITY);
        assert_eq!(s.p50, 1.0);
    }

    #[test]
    fn summary_orders() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.p1 < s.p50 && s.p50 < s.p90 && s.p90 < s.p99);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 999.0);
        assert!((s.p50 - 499.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_bins_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(0.5);
        h.add(9.5);
        h.add(-3.0); // clamps into bin 0
        h.add(42.0); // clamps into bin 9
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
        assert_eq!(h.count, 4);
        let d = h.density();
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_nan_separately_and_clamps_infinities() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(f64::NAN);
        h.add(5.0);
        h.add(f64::NEG_INFINITY);
        h.add(f64::INFINITY);
        assert_eq!(h.nan_count, 1, "NaN must not land in any bin");
        assert_eq!(h.bins[0], 1, "-inf clamps into the low edge bin");
        assert_eq!(h.bins[9], 1, "+inf clamps into the high edge bin");
        assert_eq!(h.bins[5], 1);
        assert_eq!(h.count, 3);
        let d = h.density();
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.variance() - variance(&xs)).abs() < 1e-9);
        // Sample variance applies the n/(n-1) correction.
        let expect = variance(&xs) * xs.len() as f64 / (xs.len() - 1) as f64;
        assert!((w.sample_variance() - expect).abs() < 1e-9);
        assert_eq!(Welford::default().sample_variance(), 0.0);
    }

    #[test]
    fn summary_row_reports_nan_count_only_when_nonzero() {
        let clean = Summary::of(&[1.0, 2.0, 3.0]);
        assert!(!clean.row().contains("nan="), "{}", clean.row());
        let dirty = Summary::of(&[1.0, f64::NAN, 3.0, f64::NAN]);
        assert!(dirty.row().ends_with(" nan=2"), "{}", dirty.row());
        assert!(dirty.row().starts_with("n=2"), "{}", dirty.row());
    }

    #[test]
    fn normal_quantile_matches_known_values() {
        // Reference values from standard normal tables.
        for (p, z) in [
            (0.5, 0.0),
            (0.975, 1.959964),
            (0.95, 1.644854),
            (0.995, 2.575829),
            (0.841344746, 1.0),
            (0.0013498980316301, -3.0),
        ] {
            assert!(
                (normal_quantile(p) - z).abs() < 1e-5,
                "Φ⁻¹({p}) = {} want {z}",
                normal_quantile(p)
            );
        }
        // Symmetry and endpoint behavior.
        assert!((normal_quantile(0.3) + normal_quantile(0.7)).abs() < 1e-9);
        assert_eq!(normal_quantile(0.0), f64::NEG_INFINITY);
        assert_eq!(normal_quantile(1.0), f64::INFINITY);
    }

    #[test]
    fn t_quantile_matches_table_values() {
        // Classic two-sided critical values t_{p, df}. df 1 and 2 are
        // exact closed forms; df ≥ 3 uses the Cornish–Fisher expansion.
        for (p, df, t, tol) in [
            (0.975, 1, 12.7062, 1e-3),
            (0.975, 2, 4.30265, 1e-4),
            (0.975, 3, 3.18245, 2e-2),
            (0.95, 5, 2.01505, 5e-3),
            (0.975, 10, 2.22814, 2e-3),
            (0.995, 30, 2.75000, 2e-3),
            (0.975, 120, 1.97993, 1e-3),
        ] {
            let got = t_quantile(p, df);
            assert!((got - t).abs() < tol, "t_{{{p},{df}}} = {got} want {t}");
        }
        // t approaches the normal quantile as df grows.
        assert!((t_quantile(0.975, 1_000_000) - normal_quantile(0.975)).abs() < 1e-4);
        // Median is always zero; lower tail mirrors the upper.
        for df in [1, 2, 7] {
            assert!(t_quantile(0.5, df).abs() < 1e-12);
            assert!((t_quantile(0.1, df) + t_quantile(0.9, df)).abs() < 1e-9);
        }
    }

    #[test]
    fn sign_test_exact_values() {
        assert_eq!(sign_test_two_sided(0, 0), 1.0);
        assert_eq!(sign_test_two_sided(1, 1), 1.0);
        // 5-0 split: 2·(1/32) = 0.0625; 6-0: 2·(1/64) = 0.03125.
        assert!((sign_test_two_sided(5, 0) - 0.0625).abs() < 1e-12);
        assert!((sign_test_two_sided(0, 6) - 0.03125).abs() < 1e-12);
        // 7-1 split: 2·(C(8,0)+C(8,1))/2^8 = 2·9/256.
        assert!((sign_test_two_sided(7, 1) - 18.0 / 256.0).abs() < 1e-12);
        // Balanced splits are never significant.
        assert_eq!(sign_test_two_sided(10, 10), 1.0);
    }

    #[test]
    fn welford_ci_brackets_the_mean() {
        // 100 points from a deterministic ramp: the CI half-width must
        // match t · s/√n computed by hand.
        let mut w = Welford::default();
        for i in 0..100 {
            w.add(i as f64);
        }
        let h = w.mean_ci_half_width(0.95).unwrap();
        let s = w.sample_variance().sqrt();
        let expect = t_quantile(0.975, 99) * s / 100f64.sqrt();
        assert!((h - expect).abs() < 1e-9);
        assert!(h > 0.0);
        // Under two samples or out-of-range confidence: no interval.
        let mut w1 = Welford::default();
        w1.add(3.0);
        assert!(w1.mean_ci_half_width(0.95).is_none());
        assert!(w.mean_ci_half_width(0.0).is_none());
        assert!(w.mean_ci_half_width(1.0).is_none());
    }

    #[test]
    fn paired_diff_decisions() {
        // Consistent, well-separated differences: decisive quickly.
        let mut clear = PairedDiff::default();
        for d in [1.0, 1.1, 0.9, 1.05] {
            clear.add(d);
        }
        assert!(clear.decisive(0.95), "tight same-sign diffs must settle");
        assert!(clear.mean() > 0.0);
        assert!(clear.ci_half_width(0.95).unwrap() < clear.mean());
        // Sign-flipping differences around zero: never decisive.
        let mut noisy = PairedDiff::default();
        for d in [1.0, -1.1, 0.9, -1.05] {
            noisy.add(d);
        }
        assert!(!noisy.decisive(0.95));
        assert_eq!(noisy.sign_test_p(), 1.0);
        // Same-sign but wildly varying magnitudes: the t interval is
        // hopeless, the sign test takes over once n is large enough.
        let mut skewed = PairedDiff::default();
        for d in [0.001, 10.0, 0.002, 8.0, 0.003] {
            skewed.add(d);
        }
        assert!((skewed.sign_test_p() - 0.0625).abs() < 1e-12);
        assert!(skewed.decisive(0.9), "5 same-sign diffs reject at 90%");
        assert!(!skewed.decisive(0.99));
        // Fewer than two samples: never decisive.
        let mut one = PairedDiff::default();
        one.add(5.0);
        assert!(!one.decisive(0.5));
        // Exact ties only: no sign information, degenerate CI at zero —
        // never decisive, but recognizably a tie.
        let mut ties = PairedDiff::default();
        ties.add(0.0);
        ties.add(0.0);
        assert!(!ties.decisive(0.9));
        assert_eq!(ties.n(), 2);
        assert!(ties.all_ties());
        assert!(!clear.all_ties());
        assert!(!one.all_ties(), "one sample is not evidence of a tie");
    }
}
