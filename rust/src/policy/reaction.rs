//! The piecewise *reaction function* of Selective Core Idling (Algorithm 2
//! lines 10–14, Fig. 5).
//!
//! Input: the normalized error `e = (N − C_slp − T)/N` (positive =
//! underutilization, negative = oversubscription). Output in [−1, 1]:
//!
//! * `e ≥ 0`: `F(e) = tan(0.785·e)` — sub-unit slope near 0, so the
//!   controller reacts *slowly* to underutilization (aging is a slow,
//!   long-term process; no need to rush cores into C6).
//! * `e < 0`: `F(e) = arctan(1.55·e)` — ~1.55 slope near 0, so it reacts
//!   *fast* to oversubscription (latency impact is immediate).
//!
//! Both branches meet at F(0) = 0 and saturate to ±1 at e = ±1.

/// The paper's reaction-function coefficients.
#[derive(Clone, Copy, Debug)]
pub struct ReactionFunction {
    /// Underutilization branch coefficient (paper: 0.785 ≈ π/4).
    pub under_coeff: f64,
    /// Oversubscription branch coefficient (paper: 1.55).
    pub over_coeff: f64,
}

impl Default for ReactionFunction {
    fn default() -> Self {
        ReactionFunction { under_coeff: 0.785, over_coeff: 1.55 }
    }
}

impl ReactionFunction {
    /// Evaluate F(e) for a normalized error `e ∈ [−1, 1]`.
    #[inline]
    pub fn eval(&self, e: f64) -> f64 {
        if e >= 0.0 {
            (self.under_coeff * e).tan()
        } else {
            (self.over_coeff * e).atan()
        }
    }

    /// The integer core-count correction of Algorithm 2 lines 15–17:
    /// scale back by N and truncate toward zero. Positive = cores to put
    /// into C6; negative = cores to wake.
    #[inline]
    pub fn correction(&self, e_norm: f64, n_cores: usize) -> i64 {
        (n_cores as f64 * self.eval(e_norm)) as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_points() {
        let f = ReactionFunction::default();
        assert_eq!(f.eval(0.0), 0.0);
        assert!((f.eval(1.0) - 0.785f64.tan()).abs() < 1e-12);
        assert!((f.eval(-1.0) - (-1.55f64).atan()).abs() < 1e-12);
        // Saturation near ±1.
        assert!(f.eval(1.0) > 0.99 && f.eval(1.0) <= 1.0);
        assert!(f.eval(-1.0) < -0.99 && f.eval(-1.0) >= -1.0);
    }

    #[test]
    fn asymmetric_slopes() {
        // Reacts faster to oversubscription than to underutilization.
        let f = ReactionFunction::default();
        let e = 0.05;
        assert!(f.eval(-e).abs() > f.eval(e).abs());
    }

    #[test]
    fn monotone_increasing() {
        let f = ReactionFunction::default();
        let mut prev = f.eval(-1.0);
        let mut x = -1.0;
        while x <= 1.0 {
            let y = f.eval(x);
            assert!(y >= prev - 1e-12, "non-monotone at {x}");
            prev = y;
            x += 0.01;
        }
    }

    #[test]
    fn output_bounded() {
        let f = ReactionFunction::default();
        let mut x = -1.0;
        while x <= 1.0 {
            let y = f.eval(x);
            assert!((-1.0..=1.0).contains(&y), "F({x}) = {y} out of range");
            x += 0.001;
        }
    }

    #[test]
    fn correction_truncates_toward_zero() {
        let f = ReactionFunction::default();
        // Small positive error on a 40-core CPU: F(0.025) ≈ 0.0196 -> 0.
        assert_eq!(f.correction(1.0 / 40.0, 40), 0);
        // Full underutilization: leaves at least one active core.
        let c = f.correction(1.0, 40);
        assert!(c < 40, "must never idle all cores (got {c})");
        assert_eq!(c, 39);
        // Full oversubscription wakes almost everything.
        let w = f.correction(-1.0, 40);
        assert!(w <= -39);
    }

    #[test]
    fn never_idles_final_core() {
        // Property: for any N ≥ 2 and e ≤ 1, correction < N.
        for n in [2usize, 4, 12, 40, 80, 128] {
            let f = ReactionFunction::default();
            assert!(f.correction(1.0, n) < n as i64, "n={n}");
        }
    }
}
