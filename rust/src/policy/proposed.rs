//! The paper's proposed aging-aware policy: **Task-to-Core Mapping**
//! (Algorithm 1) + **Selective Core Idling** (Algorithm 2).
//!
//! Task-to-Core Mapping picks, among the *working set* (C0 cores) without
//! a task, the core with the highest *idle score* — the sum of its last
//! eight idle durations. A mostly-idle core has aged least recently, so
//! stress is spread least-aged-first without reading micro-architectural
//! aging sensors on the per-task fast path.
//!
//! Selective Core Idling runs periodically: it computes the normalized
//! slack `e = (N − C_slp − T)/N`, feeds it through the asymmetric
//! [`ReactionFunction`], and converts the output back to a core count.
//! Surplus cores are parked in C6 **most-aged first**; deficit cores are
//! woken **least-aged first** — complementing Algorithm 1's even-out
//! behaviour. Because this path is periodic (not per-task), it is also
//! where accurate aging values (ΔVth, as an aging sensor would report)
//! are consulted (§5).

use super::reaction::ReactionFunction;
use super::CorePolicy;
use crate::cpu::{CState, CpuPackage};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ProposedPolicy {
    pub reaction: ReactionFunction,
    /// Period of the Selective Core Idling loop (seconds).
    pub adjust_period_s: f64,
    /// Ablation switch: disable Selective Core Idling entirely, leaving
    /// only Task-to-Core Mapping (Algorithm 1). Exposed as the
    /// `proposed-taskmap` policy; the ablation bench quantifies how much
    /// of the paper's gain comes from age-halting vs even-out.
    pub enable_idling: bool,
    /// Future-work extension (§8): use accurate per-core aging telemetry
    /// (ΔVth, as a core-level aging sensor would report) for Algorithm 1's
    /// selection instead of the idle-duration estimate. Exposed as the
    /// `proposed-telemetry` policy; quantifies the headroom left by the
    /// paper's cheap estimator.
    pub use_telemetry: bool,
}

impl ProposedPolicy {
    pub fn new() -> ProposedPolicy {
        // 250 ms parking cadence: oversubscription is already handled
        // event-driven (the reaction function's fast arctan branch fires
        // the moment a task finds no core), so the periodic tick only
        // needs to keep up with load *decreases*. 4 Hz tracks the decay
        // of inference bursts without thrashing C6 transitions (whose
        // hardware latency is ~100 µs).
        ProposedPolicy {
            reaction: ReactionFunction::default(),
            adjust_period_s: 0.25,
            enable_idling: true,
            use_telemetry: false,
        }
    }

    /// Algorithm 1 only (ablation).
    pub fn task_mapping_only() -> ProposedPolicy {
        ProposedPolicy { enable_idling: false, ..ProposedPolicy::new() }
    }

    /// Aging-sensor-driven selection (future-work extension).
    pub fn with_telemetry() -> ProposedPolicy {
        ProposedPolicy { use_telemetry: true, ..ProposedPolicy::new() }
    }
}

impl Default for ProposedPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl CorePolicy for ProposedPolicy {
    fn name(&self) -> &'static str {
        "proposed"
    }

    /// Algorithm 1: highest idle score among free working-set cores
    /// (or lowest measured ΔVth in the telemetry variant).
    fn pick_core(&mut self, cpu: &CpuPackage, _now: f64, _rng: &mut Rng) -> Option<usize> {
        if self.use_telemetry {
            let mut selected: Option<(f64, usize)> = None;
            for core in &cpu.cores {
                if core.state != CState::C0 || core.task.is_some() {
                    continue;
                }
                match selected {
                    None => selected = Some((core.dvth, core.id)),
                    Some((d, _)) if core.dvth < d => selected = Some((core.dvth, core.id)),
                    _ => {}
                }
            }
            return selected.map(|(_, id)| id);
        }
        let mut selected: Option<usize> = None;
        let mut selected_score = 0.0f64;
        for core in &cpu.cores {
            if core.state != CState::C0 || core.task.is_some() {
                continue;
            }
            let idle_score = core.idle_history.score();
            if selected.is_none() || idle_score > selected_score {
                selected = Some(core.id);
                selected_score = idle_score;
            }
        }
        selected
    }

    /// Algorithm 2.
    fn adjust(&mut self, cpu: &mut CpuPackage, now: f64) {
        if !self.enable_idling {
            return;
        }
        let n = cpu.n_cores();
        let active = cpu.active_count();
        let normal_tasks = cpu.allocated_count();
        let oversub_tasks = cpu.oversub.len();

        let c_slp = n - active;
        let t_total = (normal_tasks + oversub_tasks).min(n);
        let e = n as f64 - c_slp as f64 - t_total as f64;
        let e_prd = e / n as f64;
        let e_corr = self.reaction.correction(e_prd, n);

        if e_corr > 0 {
            // Underutilization: park δ cores, most-aged first. Only
            // active, unallocated cores are candidates.
            let mut candidates: Vec<(f64, usize)> = cpu
                .cores
                .iter()
                .filter(|c| c.state == CState::C0 && c.task.is_none())
                .map(|c| (c.dvth, c.id))
                .collect();
            // Most aged first.
            candidates.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let delta = (e_corr as usize).min(candidates.len());
            for &(_, id) in candidates.iter().take(delta) {
                cpu.set_state(id, CState::C6, now);
            }
        } else if e_corr < 0 {
            // Oversubscription: wake δ cores, least-aged first.
            let mut candidates: Vec<(f64, usize)> = cpu
                .cores
                .iter()
                .filter(|c| c.state == CState::C6)
                .map(|c| (c.dvth, c.id))
                .collect();
            candidates.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let delta = ((-e_corr) as usize).min(candidates.len());
            for &(_, id) in candidates.iter().take(delta) {
                cpu.set_state(id, CState::C0, now);
            }
        }
    }

    fn adjust_period_s(&self) -> Option<f64> {
        if self.enable_idling {
            Some(self.adjust_period_s)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::{AgingParams, TemperatureModel};

    fn pkg(n: usize) -> CpuPackage {
        CpuPackage::uniform(n, AgingParams::paper_default(), TemperatureModel::paper_default())
    }

    #[test]
    fn alg1_prefers_most_idle_core() {
        let mut cpu = pkg(3);
        let mut p = ProposedPolicy::new();
        let mut rng = Rng::new(1);
        // Give cores different idle histories: core 2 idled longest.
        cpu.assign(0, 1, 10.0); // idle 0..10
        cpu.finish_task(1, 11.0);
        cpu.assign(1, 2, 30.0); // idle 0..30
        cpu.finish_task(2, 31.0);
        cpu.assign(2, 3, 90.0); // idle 0..90
        cpu.finish_task(3, 91.0);
        let picked = p.pick_core(&cpu, 100.0, &mut rng).unwrap();
        assert_eq!(picked, 2);
    }

    #[test]
    fn alg1_skips_allocated_and_idle_cores() {
        let mut cpu = pkg(3);
        let mut p = ProposedPolicy::new();
        let mut rng = Rng::new(1);
        cpu.assign(0, 1, 0.0);
        cpu.set_state(2, CState::C6, 0.0);
        let picked = p.pick_core(&cpu, 1.0, &mut rng).unwrap();
        assert_eq!(picked, 1);
        cpu.assign(1, 2, 1.0);
        assert!(p.pick_core(&cpu, 2.0, &mut rng).is_none());
    }

    #[test]
    fn alg2_idles_surplus_cores() {
        let mut cpu = pkg(40);
        let mut p = ProposedPolicy::new();
        // No tasks at all: e_prd = 1, F ≈ 1 -> 39 cores to C6.
        p.adjust(&mut cpu, 0.0);
        assert_eq!(cpu.c6_count(), 39);
        assert_eq!(cpu.active_count(), 1);
    }

    #[test]
    fn alg2_wakes_on_oversubscription() {
        let mut cpu = pkg(40);
        let mut p = ProposedPolicy::new();
        p.adjust(&mut cpu, 0.0); // 1 active core left
        let free = cpu.free_active_cores().next().unwrap().id;
        cpu.assign(free, 1, 1.0);
        for t in 2..8 {
            cpu.push_oversub(t);
        }
        // T = 7, active = 1 -> e = -6/40 -> wake some cores.
        p.adjust(&mut cpu, 2.0);
        assert!(cpu.active_count() > 1, "active={}", cpu.active_count());
        assert!(cpu.c6_count() < 39);
    }

    #[test]
    fn alg2_never_idles_allocated_cores() {
        let mut cpu = pkg(8);
        let mut p = ProposedPolicy::new();
        for t in 0..4 {
            cpu.assign(t as usize, t, 0.0);
        }
        p.adjust(&mut cpu, 1.0);
        for c in &cpu.cores {
            if c.task.is_some() {
                assert_eq!(c.state, CState::C0);
            }
        }
        assert_eq!(cpu.allocated_count(), 4);
    }

    #[test]
    fn alg2_parks_most_aged_first_wakes_least_aged_first() {
        let mut cpu = pkg(4);
        // Fabricate distinct ages.
        for (i, d) in [0.04, 0.01, 0.03, 0.02].iter().enumerate() {
            cpu.cores[i].dvth = *d;
        }
        let mut p = ProposedPolicy::new();
        // No tasks: e_prd=1 -> park 3 cores; survivors should be the least aged (core 1).
        p.adjust(&mut cpu, 0.0);
        assert_eq!(cpu.active_count(), 1);
        assert_eq!(cpu.cores[1].state, CState::C0, "least-aged core must stay awake");
        // Now oversubscribe so it wakes 2: least-aged sleepers first (3 then 2).
        cpu.assign(1, 100, 1.0);
        for t in 0..3 {
            cpu.push_oversub(t);
        }
        p.adjust(&mut cpu, 2.0);
        assert_eq!(cpu.cores[3].state, CState::C0, "least-aged sleeper wakes first");
    }

    #[test]
    fn telemetry_variant_picks_least_aged_by_dvth() {
        let mut cpu = pkg(4);
        for (i, d) in [0.04, 0.01, 0.03, 0.02].iter().enumerate() {
            cpu.cores[i].dvth = *d;
        }
        // Give the *most aged* core the best idle score to show the two
        // estimators disagree — telemetry must follow ΔVth.
        cpu.assign(0, 1, 100.0);
        cpu.finish_task(1, 101.0);
        let mut p_est = ProposedPolicy::new();
        let mut p_tel = ProposedPolicy::with_telemetry();
        let mut rng = Rng::new(1);
        assert_eq!(p_tel.pick_core(&cpu, 200.0, &mut rng), Some(1));
        assert_eq!(p_est.pick_core(&cpu, 200.0, &mut rng), Some(0));
    }

    #[test]
    fn taskmap_only_never_idles() {
        let mut cpu = pkg(8);
        let mut p = ProposedPolicy::task_mapping_only();
        p.adjust(&mut cpu, 5.0);
        assert_eq!(cpu.c6_count(), 0);
        assert_eq!(p.adjust_period_s(), None);
    }

    #[test]
    fn steady_state_working_set_tracks_load() {
        // With T tasks pinned, repeated adjust converges to a working set
        // close to T (within the tan() deadband).
        let mut cpu = pkg(40);
        let mut p = ProposedPolicy::new();
        for t in 0..10u64 {
            let core = p.pick_core(&cpu, 0.0, &mut Rng::new(0)).unwrap();
            cpu.assign(core, t, 0.0);
        }
        for step in 0..50 {
            p.adjust(&mut cpu, step as f64);
        }
        let active = cpu.active_count();
        assert!((10..=13).contains(&active), "active={active}");
    }
}
