//! The paper's proposed aging-aware policy: **Task-to-Core Mapping**
//! (Algorithm 1) + **Selective Core Idling** (Algorithm 2).
//!
//! Task-to-Core Mapping picks, among the *working set* (C0 cores) without
//! a task, the core with the highest *idle score* — the sum of its last
//! eight idle durations. A mostly-idle core has aged least recently, so
//! stress is spread least-aged-first without reading micro-architectural
//! aging sensors on the per-task fast path.
//!
//! Selective Core Idling runs periodically: it computes the normalized
//! slack `e = (N − C_slp − T)/N`, feeds it through the asymmetric
//! [`ReactionFunction`], and converts the output back to a core count.
//! Surplus cores are parked in C6 **most-aged first**; deficit cores are
//! woken **least-aged first** — complementing Algorithm 1's even-out
//! behaviour. Because this path is periodic (not per-task), it is also
//! where accurate aging values (equivalent stress time, as an aging
//! sensor would report ΔVth) are consulted (§5).
//!
//! # The 250 ms selective-idling tick
//!
//! Selective Core Idling runs at a fixed 4 Hz cadence. The rate is *not*
//! load-bearing for oversubscription: that is handled event-driven (the
//! reaction function's fast arctan branch fires the instant a task finds
//! no free core), so the periodic tick only needs to track load
//! *decreases*. 250 ms follows inference-burst decay without thrashing
//! C6 transitions (whose hardware entry/exit latency is ~100 µs), and
//! the cluster coalesces the per-machine ticks into one all-machine
//! event per period to keep the event queue flat.
//!
//! §Perf: `adjust` runs every 250 ms on every machine of every scenario
//! cell, so its candidate selection is allocation-free — a reusable
//! scratch buffer plus `select_nth_unstable_by` partial selection instead
//! of collect-then-full-sort. Ages are compared on the package's flat
//! canonical equivalent-stress-time slice
//! ([`CpuPackage::eq_times`]), which orders identically to ΔVth without
//! paying the `powf` snapshot per candidate.

use std::cmp::Ordering;

use super::reaction::ReactionFunction;
use super::CorePolicy;
use crate::cpu::{CState, CpuPackage};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ProposedPolicy {
    pub reaction: ReactionFunction,
    /// Period of the Selective Core Idling loop (seconds).
    pub adjust_period_s: f64,
    /// Ablation switch: disable Selective Core Idling entirely, leaving
    /// only Task-to-Core Mapping (Algorithm 1). Exposed as the
    /// `proposed-taskmap` policy; the ablation bench quantifies how much
    /// of the paper's gain comes from age-halting vs even-out.
    pub enable_idling: bool,
    /// Future-work extension (§8): use accurate per-core aging telemetry
    /// (ΔVth, as a core-level aging sensor would report) for Algorithm 1's
    /// selection instead of the idle-duration estimate. Exposed as the
    /// `proposed-telemetry` policy; quantifies the headroom left by the
    /// paper's cheap estimator.
    pub use_telemetry: bool,
    /// Reusable `(age_key, core_id)` scratch for `adjust`'s candidate
    /// selection (§Perf: the periodic tick allocates nothing).
    scratch: Vec<(f64, usize)>,
}

impl ProposedPolicy {
    pub fn new() -> ProposedPolicy {
        // 250 ms parking cadence: oversubscription is already handled
        // event-driven (the reaction function's fast arctan branch fires
        // the moment a task finds no core), so the periodic tick only
        // needs to keep up with load *decreases*. 4 Hz tracks the decay
        // of inference bursts without thrashing C6 transitions (whose
        // hardware latency is ~100 µs).
        ProposedPolicy {
            reaction: ReactionFunction::default(),
            adjust_period_s: 0.25,
            enable_idling: true,
            use_telemetry: false,
            scratch: Vec::new(),
        }
    }

    /// Algorithm 1 only (ablation).
    pub fn task_mapping_only() -> ProposedPolicy {
        ProposedPolicy { enable_idling: false, ..ProposedPolicy::new() }
    }

    /// Aging-sensor-driven selection (future-work extension).
    pub fn with_telemetry() -> ProposedPolicy {
        ProposedPolicy { use_telemetry: true, ..ProposedPolicy::new() }
    }

    /// Fill `self.scratch` with flat `(eq_time, id)` keys of every
    /// candidate core — parking candidates (free C0 cores) when `park`,
    /// wake candidates (C6 sleepers) otherwise — then partially select the
    /// `delta` extreme ones into `scratch[..delta]` (most-aged first for
    /// parking, least-aged first for waking; unordered within the prefix —
    /// callers apply an order-insensitive state flip). Returns the clamped
    /// delta.
    ///
    /// The comparator totally orders `(eq_time, id)` tuples, so the
    /// selected *set* is exactly the prefix a full sort would have taken,
    /// at O(n) instead of O(n log n) and with zero heap traffic after the
    /// first call.
    fn select_extreme(&mut self, cpu: &CpuPackage, delta: usize, park: bool) -> usize {
        self.scratch.clear();
        let eq = cpu.eq_times();
        if park {
            self.scratch.extend(cpu.free_active_cores().map(|c| (eq[c.id()], c.id())));
        } else {
            // Wake candidates: healthy sleepers only — a permanently
            // failed core is held in C6 and must never rejoin the
            // working set.
            self.scratch.extend(
                cpu.core_views()
                    .filter(|c| c.state() == CState::C6 && !c.failed())
                    .map(|c| (eq[c.id()], c.id())),
            );
        }
        let delta = delta.min(self.scratch.len());
        if delta > 0 && delta < self.scratch.len() {
            // `total_cmp` (not `partial_cmp(..).unwrap()`): a NaN aging
            // key must degrade, not panic the 250 ms tick of an entire
            // sweep. NaN orders above +inf, so a poisoned core counts as
            // most-aged — parked first, woken last — deterministically.
            if park {
                self.scratch.select_nth_unstable_by(delta - 1, |a, b| {
                    b.0.total_cmp(&a.0).then(b.1.cmp(&a.1))
                });
            } else {
                self.scratch.select_nth_unstable_by(delta - 1, |a, b| {
                    a.0.total_cmp(&b.0).then(a.1.cmp(&b.1))
                });
            }
        }
        delta
    }
}

impl Default for ProposedPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl CorePolicy for ProposedPolicy {
    fn name(&self) -> &'static str {
        "proposed"
    }

    /// Algorithm 1: highest idle score among free working-set cores
    /// (or lowest equivalent stress time in the telemetry variant).
    fn pick_core(&mut self, cpu: &CpuPackage, _now: f64, _rng: &mut Rng) -> Option<usize> {
        if self.use_telemetry {
            return super::min_free_core_by_key(cpu, cpu.eq_times());
        }
        let mut selected: Option<usize> = None;
        let mut selected_score = 0.0f64;
        for core in cpu.free_active_cores() {
            let idle_score = core.idle_score();
            if selected.is_none() || idle_score > selected_score {
                selected = Some(core.id());
                selected_score = idle_score;
            }
        }
        selected
    }

    /// Algorithm 2.
    fn adjust(&mut self, cpu: &mut CpuPackage, now: f64) {
        if !self.enable_idling {
            return;
        }
        // Algorithm 2 runs over the *usable* core count: permanently
        // failed cores are neither capacity nor sleepers (they can never
        // be woken), so a degraded package sizes its working set against
        // what it can actually deliver. With zero failures this is the
        // historical `n_cores()` exactly.
        let n = cpu.usable_cores();
        if n == 0 {
            return;
        }
        let active = cpu.active_count();
        let normal_tasks = cpu.allocated_count();
        let oversub_tasks = cpu.oversub.len();

        // Failed cores sit in C6 but are not sleepers Algorithm 2 can
        // recall, so they are excluded from C_slp.
        let c_slp = n - active;
        let t_total = (normal_tasks + oversub_tasks).min(n);
        let e = n as f64 - c_slp as f64 - t_total as f64;
        let e_prd = e / n as f64;
        let e_corr = self.reaction.correction(e_prd, n);

        match e_corr.cmp(&0) {
            Ordering::Greater => {
                // Underutilization: park δ cores, most-aged first. Only
                // active, unallocated cores are candidates.
                let delta = self.select_extreme(cpu, e_corr as usize, true);
                for &(_, id) in self.scratch.iter().take(delta) {
                    cpu.set_state(id, CState::C6, now);
                }
            }
            Ordering::Less => {
                // Oversubscription: wake δ cores, least-aged first.
                let delta = self.select_extreme(cpu, (-e_corr) as usize, false);
                for &(_, id) in self.scratch.iter().take(delta) {
                    cpu.set_state(id, CState::C0, now);
                }
            }
            Ordering::Equal => {}
        }
    }

    fn adjust_period_s(&self) -> Option<f64> {
        if self.enable_idling {
            Some(self.adjust_period_s)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::{AgingParams, TemperatureModel};

    fn pkg(n: usize) -> CpuPackage {
        CpuPackage::uniform(n, AgingParams::paper_default(), TemperatureModel::paper_default())
    }

    #[test]
    fn alg1_prefers_most_idle_core() {
        let mut cpu = pkg(3);
        let mut p = ProposedPolicy::new();
        let mut rng = Rng::new(1);
        // Give cores different idle histories: core 2 idled longest.
        cpu.assign(0, 1, 10.0); // idle 0..10
        cpu.finish_task(1, 11.0);
        cpu.assign(1, 2, 30.0); // idle 0..30
        cpu.finish_task(2, 31.0);
        cpu.assign(2, 3, 90.0); // idle 0..90
        cpu.finish_task(3, 91.0);
        let picked = p.pick_core(&cpu, 100.0, &mut rng).unwrap();
        assert_eq!(picked, 2);
    }

    #[test]
    fn alg1_skips_allocated_and_idle_cores() {
        let mut cpu = pkg(3);
        let mut p = ProposedPolicy::new();
        let mut rng = Rng::new(1);
        cpu.assign(0, 1, 0.0);
        cpu.set_state(2, CState::C6, 0.0);
        let picked = p.pick_core(&cpu, 1.0, &mut rng).unwrap();
        assert_eq!(picked, 1);
        cpu.assign(1, 2, 1.0);
        assert!(p.pick_core(&cpu, 2.0, &mut rng).is_none());
    }

    #[test]
    fn alg2_idles_surplus_cores() {
        let mut cpu = pkg(40);
        let mut p = ProposedPolicy::new();
        // No tasks at all: e_prd = 1, F ≈ 1 -> 39 cores to C6.
        p.adjust(&mut cpu, 0.0);
        assert_eq!(cpu.c6_count(), 39);
        assert_eq!(cpu.active_count(), 1);
    }

    #[test]
    fn alg2_wakes_on_oversubscription() {
        let mut cpu = pkg(40);
        let mut p = ProposedPolicy::new();
        p.adjust(&mut cpu, 0.0); // 1 active core left
        let free = cpu.free_active_cores().next().unwrap().id();
        cpu.assign(free, 1, 1.0);
        for t in 2..8 {
            cpu.push_oversub(t);
        }
        // T = 7, active = 1 -> e = -6/40 -> wake some cores.
        p.adjust(&mut cpu, 2.0);
        assert!(cpu.active_count() > 1, "active={}", cpu.active_count());
        assert!(cpu.c6_count() < 39);
    }

    #[test]
    fn alg2_never_idles_allocated_cores() {
        let mut cpu = pkg(8);
        let mut p = ProposedPolicy::new();
        for t in 0..4 {
            cpu.assign(t as usize, t, 0.0);
        }
        p.adjust(&mut cpu, 1.0);
        for c in cpu.core_views() {
            if c.task().is_some() {
                assert_eq!(c.state(), CState::C0);
            }
        }
        assert_eq!(cpu.allocated_count(), 4);
    }

    #[test]
    fn alg2_parks_most_aged_first_wakes_least_aged_first() {
        let mut cpu = pkg(4);
        // Fabricate distinct ages (equivalent stress time orders like ΔVth).
        for (i, eq) in [4.0e6, 1.0e6, 3.0e6, 2.0e6].iter().enumerate() {
            cpu.set_eq_time_s(i, *eq);
        }
        let mut p = ProposedPolicy::new();
        // No tasks: e_prd=1 -> park 3 cores; survivors should be the least aged (core 1).
        p.adjust(&mut cpu, 0.0);
        assert_eq!(cpu.active_count(), 1);
        assert_eq!(cpu.core(1).state(), CState::C0, "least-aged core must stay awake");
        // Now oversubscribe so it wakes 2: least-aged sleepers first (3 then 2).
        cpu.assign(1, 100, 1.0);
        for t in 0..3 {
            cpu.push_oversub(t);
        }
        p.adjust(&mut cpu, 2.0);
        assert_eq!(cpu.core(3).state(), CState::C0, "least-aged sleeper wakes first");
    }

    #[test]
    fn alg2_selection_matches_full_sort_with_ties() {
        // Equal ages: the partial selection must pick the same set a full
        // (age, id) sort would — ties break by id, deterministically.
        let mut cpu = pkg(6);
        for (i, eq) in [5.0, 5.0, 1.0, 5.0, 2.0, 5.0].iter().enumerate() {
            cpu.set_eq_time_s(i, *eq * 1e6);
        }
        let mut p = ProposedPolicy::new();
        // No tasks: park 5, keep 1 awake. Full sort descending on
        // (age, id) keeps the smallest tuple awake: core 2 (age 1.0).
        p.adjust(&mut cpu, 0.0);
        assert_eq!(cpu.active_count(), 1);
        assert_eq!(cpu.core(2).state(), CState::C0);
    }

    #[test]
    fn alg2_nan_aging_key_degrades_instead_of_panicking() {
        // Regression: `select_extreme` used `partial_cmp(..).unwrap()`,
        // so one NaN equivalent-stress-time key panicked the adjust tick
        // of an entire sweep. Under `total_cmp` NaN orders above +inf:
        // the poisoned core counts as most-aged — parked first, woken
        // last — and the tick completes deterministically.
        let mut cpu = pkg(4);
        for (i, eq) in [2.0e6, 1.0e6, 3.0e6, 4.0e6].iter().enumerate() {
            cpu.set_eq_time_s(i, *eq);
        }
        cpu.set_eq_time_s(2, f64::NAN);
        let mut p = ProposedPolicy::new();
        // No tasks: park 3 of 4. Descending (age, id) order is NaN(2),
        // 4e6(3), 2e6(0), 1e6(1) — the least-aged finite core survives.
        p.adjust(&mut cpu, 0.0);
        assert_eq!(cpu.active_count(), 1);
        assert_eq!(cpu.core(1).state(), CState::C0, "least-aged finite core stays awake");
        assert_eq!(cpu.core(2).state(), CState::C6, "NaN-keyed core parked as most-aged");
        // Oversubscribe so 2 of the 3 sleepers wake: the finite ages
        // (cores 0 and 3) wake first, the NaN core last — i.e. not yet.
        cpu.assign(1, 100, 1.0);
        for t in 0..3 {
            cpu.push_oversub(t);
        }
        p.adjust(&mut cpu, 2.0);
        assert_eq!(cpu.core(0).state(), CState::C0, "least-aged finite sleeper wakes");
        assert_eq!(cpu.core(3).state(), CState::C0, "next finite sleeper wakes");
        assert_eq!(cpu.core(2).state(), CState::C6, "NaN-keyed core wakes last of all");
    }

    #[test]
    fn alg2_never_wakes_failed_cores_and_sizes_against_usable_count() {
        let mut cpu = pkg(4);
        cpu.fail_core(3, 0.0);
        let mut p = ProposedPolicy::new();
        // No tasks: park the surplus of the 3 *usable* cores.
        p.adjust(&mut cpu, 0.0);
        assert_eq!(cpu.active_count(), 1);
        // Oversubscribe far beyond capacity: Algorithm 2 wakes every
        // healthy sleeper but must leave the failed core in C6.
        let free = cpu.free_active_cores().next().unwrap().id();
        cpu.assign(free, 100, 1.0);
        for t in 0..8 {
            cpu.push_oversub(t);
        }
        p.adjust(&mut cpu, 2.0);
        assert_eq!(cpu.core(3).state(), CState::C6, "failed core woken");
        assert_eq!(cpu.active_count(), 3, "all healthy cores awake");
    }

    #[test]
    fn telemetry_variant_picks_least_aged_by_age() {
        let mut cpu = pkg(4);
        for (i, eq) in [4.0e6, 1.0e6, 3.0e6, 2.0e6].iter().enumerate() {
            cpu.set_eq_time_s(i, *eq);
        }
        // Give the *most aged* core the best idle score to show the two
        // estimators disagree — telemetry must follow the aging sensor.
        cpu.assign(0, 1, 100.0);
        cpu.finish_task(1, 101.0);
        let mut p_est = ProposedPolicy::new();
        let mut p_tel = ProposedPolicy::with_telemetry();
        let mut rng = Rng::new(1);
        assert_eq!(p_tel.pick_core(&cpu, 200.0, &mut rng), Some(1));
        assert_eq!(p_est.pick_core(&cpu, 200.0, &mut rng), Some(0));
    }

    #[test]
    fn taskmap_only_never_idles() {
        let mut cpu = pkg(8);
        let mut p = ProposedPolicy::task_mapping_only();
        p.adjust(&mut cpu, 5.0);
        assert_eq!(cpu.c6_count(), 0);
        assert_eq!(p.adjust_period_s(), None);
    }

    #[test]
    fn steady_state_working_set_tracks_load() {
        // With T tasks pinned, repeated adjust converges to a working set
        // close to T (within the tan() deadband).
        let mut cpu = pkg(40);
        let mut p = ProposedPolicy::new();
        for t in 0..10u64 {
            let core = p.pick_core(&cpu, 0.0, &mut Rng::new(0)).unwrap();
            cpu.assign(core, t, 0.0);
        }
        for step in 0..50 {
            p.adjust(&mut cpu, step as f64);
        }
        let active = cpu.active_count();
        assert!((10..=13).contains(&active), "active={active}");
    }
}
