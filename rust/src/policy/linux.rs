//! The `linux` baseline (§6.1.1): task-to-core placement as observed on a
//! stock Linux LLM inference server.
//!
//! The paper builds a probabilistic placement model from CPU data captured
//! on a real inference server (Wilkins et al. '24). That dataset is not
//! public, so we reproduce the two properties the baseline contributes to
//! the evaluation (see DESIGN.md substitutions):
//!
//! 1. **Every core stays in C0.** The Linux scheduler time-shares system
//!    tasks across all cores, so every core keeps aging even when no
//!    inference task is pinned to it (the paper's key observation O1/O2
//!    discussion). No `adjust` hook.
//! 2. **Placement is age-oblivious and non-uniform.** CFS wake-affinity
//!    re-uses cache-warm cores: with probability `sticky_p` the most
//!    recently freed core is chosen again; otherwise placement is uniform
//!    over free cores. The stickiness concentrates stress and produces
//!    the uneven aging the paper measures for this baseline.

use super::CorePolicy;
use crate::cpu::{CState, CpuPackage};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct LinuxPolicy {
    /// Probability of re-using the most recently freed (cache-warm) core.
    pub sticky_p: f64,
    /// LRU stack of recently used cores (most recent last).
    recent: Vec<usize>,
}

impl LinuxPolicy {
    pub fn new() -> LinuxPolicy {
        LinuxPolicy { sticky_p: 0.7, recent: Vec::new() }
    }
}

impl Default for LinuxPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl CorePolicy for LinuxPolicy {
    fn name(&self) -> &'static str {
        "linux"
    }

    fn pick_core(&mut self, cpu: &CpuPackage, _now: f64, rng: &mut Rng) -> Option<usize> {
        // Wake-affinity: prefer the most recently used core if it is free.
        if rng.bool(self.sticky_p) {
            while let Some(&cand) = self.recent.last() {
                let core = cpu.core(cand);
                if core.state() == CState::C0 && core.task().is_none() {
                    self.recent.pop();
                    self.recent.push(cand); // stays most-recent
                    return Some(cand);
                }
                // Stale entry (core busy) — drop and fall through.
                self.recent.pop();
            }
        }
        // Uniform over free active cores — k-th free core in one pass,
        // no allocation (§Perf).
        let n_free = cpu.free_active_count();
        if n_free == 0 {
            return None;
        }
        let k = rng.usize(n_free);
        let pick = cpu
            .free_active_cores()
            .nth(k)
            .expect("free_active_count consistent with iterator")
            .id();
        self.recent.retain(|&c| c != pick);
        self.recent.push(pick);
        if self.recent.len() > 16 {
            self.recent.remove(0);
        }
        Some(pick)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::{AgingParams, TemperatureModel};

    fn pkg(n: usize) -> CpuPackage {
        CpuPackage::uniform(n, AgingParams::paper_default(), TemperatureModel::paper_default())
    }

    #[test]
    fn placement_is_sticky() {
        let mut cpu = pkg(16);
        let mut p = LinuxPolicy::new();
        let mut rng = Rng::new(1);
        // Start/finish a long task sequence; count how often the same core
        // is immediately reused.
        let mut reuse = 0;
        let mut last: Option<usize> = None;
        for t in 0..2000u64 {
            let c = p.pick_core(&cpu, t as f64, &mut rng).unwrap();
            cpu.assign(c, t, t as f64);
            cpu.finish_task(t, t as f64 + 0.5);
            if last == Some(c) {
                reuse += 1;
            }
            last = Some(c);
        }
        // With sticky_p=0.7 the immediate-reuse fraction must be far above
        // the uniform baseline of 1/16.
        assert!(reuse > 1000, "reuse={reuse}");
    }

    #[test]
    fn usage_is_uneven_across_cores() {
        let mut cpu = pkg(8);
        let mut p = LinuxPolicy::new();
        let mut rng = Rng::new(2);
        let mut counts = vec![0u64; 8];
        for t in 0..4000u64 {
            let c = p.pick_core(&cpu, t as f64, &mut rng).unwrap();
            counts[c] += 1;
            cpu.assign(c, t, t as f64);
            cpu.finish_task(t, t as f64 + 0.5);
        }
        // An age-aware balancer (least-aged) drives the spread to ~0; the
        // linux model must leave a clearly non-uniform footprint.
        let fcounts: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
        let cv = crate::util::stats::coeff_of_variation(&fcounts);
        assert!(cv > 0.05, "cv={cv} counts={counts:?}");
    }

    #[test]
    fn no_adjust_all_cores_stay_active() {
        let mut cpu = pkg(8);
        let mut p = LinuxPolicy::new();
        p.adjust(&mut cpu, 100.0); // default no-op
        assert_eq!(cpu.active_count(), 8);
        assert_eq!(p.adjust_period_s(), None);
    }

    #[test]
    fn falls_back_when_sticky_core_busy() {
        let mut cpu = pkg(2);
        let mut p = LinuxPolicy::new();
        let mut rng = Rng::new(3);
        let a = p.pick_core(&cpu, 0.0, &mut rng).unwrap();
        cpu.assign(a, 1, 0.0);
        let b = p.pick_core(&cpu, 1.0, &mut rng).unwrap();
        assert_ne!(a, b);
        cpu.assign(b, 2, 1.0);
        assert!(p.pick_core(&cpu, 2.0, &mut rng).is_none());
    }
}
