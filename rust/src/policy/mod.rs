//! Core-management policies: the paper's proposed technique and the two
//! baselines it is evaluated against (§6.1.1).
//!
//! A policy answers two questions: *which core runs the next inference
//! task* ([`CorePolicy::pick_core`]) and, optionally, *which cores should
//! be awake at all* ([`CorePolicy::adjust`], the Selective Core Idling
//! hook invoked periodically by the simulator / serving stack).
//!
//! [`CoreManager`] glues a policy to a [`CpuPackage`] and owns the
//! oversubscription queue: a task that finds no free active core runs
//! time-shared (counted in the Fig. 8 metric) until capacity appears.

pub mod least_aged;
pub mod linux;
pub mod proposed;
pub mod reaction;

pub use least_aged::LeastAgedPolicy;
pub use linux::LinuxPolicy;
pub use proposed::ProposedPolicy;
pub use reaction::ReactionFunction;

use crate::cpu::CpuPackage;
use crate::util::rng::Rng;

/// Free-working-set argmin over a flat per-core key slice — one pass, no
/// allocation (§Perf). Shared by the `least-aged` baseline
/// ([`CpuPackage::busy_times`]) and the `proposed-telemetry` variant
/// ([`CpuPackage::eq_times`]). Ties break to the lowest core id
/// (iteration order), matching the policies' historical behaviour.
pub(crate) fn min_free_core_by_key(cpu: &CpuPackage, key: &[f64]) -> Option<usize> {
    debug_assert_eq!(key.len(), cpu.n_cores());
    let mut best: Option<(f64, usize)> = None;
    for core in cpu.free_active_cores() {
        let k = key[core.id()];
        match best {
            None => best = Some((k, core.id())),
            Some((b, _)) if k < b => best = Some((k, core.id())),
            _ => {}
        }
    }
    best.map(|(_, id)| id)
}

/// A CPU core-management policy.
pub trait CorePolicy {
    fn name(&self) -> &'static str;

    /// Select an active, unallocated core for a new inference task.
    /// `None` means the task must oversubscribe the CPU.
    fn pick_core(&mut self, cpu: &CpuPackage, now: f64, rng: &mut Rng) -> Option<usize>;

    /// Periodic working-set adjustment (Selective Core Idling). Baselines
    /// keep every core active and leave this as a no-op.
    fn adjust(&mut self, _cpu: &mut CpuPackage, _now: f64) {}

    /// How often `adjust` should run, if at all.
    fn adjust_period_s(&self) -> Option<f64> {
        None
    }
}

/// Construct a policy by name — the CLI/config entry point.
pub fn by_name(name: &str) -> Result<Box<dyn CorePolicy>, String> {
    match name {
        "proposed" => Ok(Box::new(ProposedPolicy::new())),
        // Ablation: Task-to-Core Mapping (Alg. 1) without Selective Core
        // Idling (Alg. 2).
        "proposed-taskmap" => Ok(Box::new(ProposedPolicy::task_mapping_only())),
        // Future-work extension (§8): aging-sensor telemetry instead of
        // the idle-duration age estimate.
        "proposed-telemetry" => Ok(Box::new(ProposedPolicy::with_telemetry())),
        "linux" => Ok(Box::new(LinuxPolicy::new())),
        "least-aged" | "least_aged" => Ok(Box::new(LeastAgedPolicy::new())),
        other => Err(format!(
            "unknown policy '{other}' (try: proposed, proposed-taskmap, linux, least-aged)"
        )),
    }
}

/// All policy names, in the order the paper's figures list them.
pub const ALL_POLICIES: [&str; 3] = ["linux", "least-aged", "proposed"];

/// Binds a policy to a CPU package and manages task lifecycles, including
/// the oversubscription queue.
pub struct CoreManager {
    pub cpu: CpuPackage,
    pub policy: Box<dyn CorePolicy>,
    pub rng: Rng,
    /// Count of task-start events that had to oversubscribe (diagnostics).
    pub oversub_events: u64,
}

impl CoreManager {
    pub fn new(cpu: CpuPackage, policy: Box<dyn CorePolicy>, rng: Rng) -> CoreManager {
        CoreManager { cpu, policy, rng, oversub_events: 0 }
    }

    /// `assign_core_to_cpu_task` (§5): route a new inference task through
    /// the policy. Returns the chosen core, or `None` if oversubscribed.
    pub fn start_task(&mut self, task: u64, now: f64) -> Option<usize> {
        match self.policy.pick_core(&self.cpu, now, &mut self.rng) {
            Some(core) => {
                self.cpu.assign(core, task, now);
                Some(core)
            }
            None => {
                // Oversubscription is the latency-critical branch of the
                // reaction function: trigger Selective Core Idling
                // immediately (event-driven, on top of the periodic tick)
                // so deep-idle cores wake before the burst deepens.
                self.cpu.push_oversub(task);
                self.oversub_events += 1;
                self.policy.adjust(&mut self.cpu, now);
                self.promote_oversub(now);
                if self.cpu.oversub.contains(&task) {
                    None
                } else {
                    self.cpu.task_core_of(task)
                }
            }
        }
    }

    /// Finish a task; if it frees a core and oversubscribed tasks are
    /// waiting, promote one immediately (through the policy, so placement
    /// stays aging-aware).
    pub fn finish_task(&mut self, task: u64, now: f64) {
        let freed = self.cpu.finish_task(task, now);
        if freed.is_some() {
            self.promote_oversub(now);
        }
    }

    /// `adjust_sleeping_cores` (§5): run Selective Core Idling, then move
    /// any waiting oversubscribed tasks onto newly woken cores.
    pub fn adjust(&mut self, now: f64) {
        self.policy.adjust(&mut self.cpu, now);
        self.promote_oversub(now);
    }

    /// The cluster's periodic entry point: run [`CoreManager::adjust`]
    /// only if the package changed since the last tick. Returns whether
    /// the tick did any work (skip-ahead; see the dirty-flag contract in
    /// [`crate::cpu::package`]).
    ///
    /// Skipping is behaviour-preserving because `adjust` is a
    /// deterministic function of the package's discrete state — counts of
    /// active/sleeping cores and tasks, plus the *ordering* of candidate
    /// ages — and between mutations every parking candidate ages at the
    /// same unallocated rate while sleepers are frozen, so a clean
    /// package's adjust would recompute the identical no-op. The flag is
    /// cleared *before* running, so changes the adjust itself makes
    /// (parking, waking, promotions) re-arm the next tick and multi-tick
    /// convergence is untouched.
    pub fn adjust_tick(&mut self, now: f64) -> bool {
        if !self.cpu.is_dirty() {
            return false;
        }
        self.cpu.clear_dirty();
        self.adjust(now);
        true
    }

    /// Permanently fail a core (fault injection). A task pinned to the
    /// dying core is evicted back to the *front* of the oversubscription
    /// queue — it arrived (and was promoted) before every task still
    /// queued, so a front re-insert preserves the global arrival order
    /// the FIFO promotion contract pins. The policy then re-adjusts and
    /// promotion runs, so the evicted task lands on a healthy core right
    /// away when one is free. Returns false (and does nothing) when the
    /// core index is stale (beyond a replacement SKU's core count) or
    /// already failed.
    ///
    /// The evicted task's already-scheduled completion event stays valid:
    /// `finish_task` finds the task pinned-or-queued either way, so no
    /// task is lost or double-completed. The modeled approximation is
    /// that a failure does not extend in-flight task runtimes.
    pub fn fail_core(&mut self, core_idx: usize, now: f64) -> bool {
        if core_idx >= self.cpu.n_cores() || self.cpu.is_failed(core_idx) {
            return false;
        }
        if let Some(task) = self.cpu.fail_core(core_idx, now) {
            self.cpu.push_oversub_front(task);
        }
        self.policy.adjust(&mut self.cpu, now);
        self.promote_oversub(now);
        true
    }

    /// Swap in a replacement CPU package (machine retirement → new SKU).
    /// Every task the old package was running migrates to the new one's
    /// oversubscription queue — pinned tasks first, in core-id order,
    /// then the old queue, preserving relative arrival order — and the
    /// fresh policy immediately adjusts and promotes, so tasks re-pin to
    /// the new silicon at once. Scheduled completion events stay valid
    /// (`finish_task` resolves pinned-or-queued). The policy is replaced
    /// along with the package: its learned per-core state (sticky lists,
    /// age estimates) indexes the old core count.
    pub fn replace_package(
        &mut self,
        new_cpu: CpuPackage,
        new_policy: Box<dyn CorePolicy>,
        now: f64,
    ) {
        let old = std::mem::replace(&mut self.cpu, new_cpu);
        self.policy = new_policy;
        for core in old.core_views() {
            if let Some(task) = core.task() {
                self.cpu.push_oversub(task);
            }
        }
        for &task in old.oversub.iter() {
            self.cpu.push_oversub(task);
        }
        self.policy.adjust(&mut self.cpu, now);
        self.promote_oversub(now);
    }

    fn promote_oversub(&mut self, now: f64) {
        while !self.cpu.oversub.is_empty() && self.cpu.has_free_active_core() {
            if let Some(core) = self.policy.pick_core(&self.cpu, now, &mut self.rng) {
                let task = self.cpu.pop_oversub().expect("checked non-empty");
                self.cpu.assign(core, task, now);
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::{AgingParams, CpuPackage, TemperatureModel};

    fn mgr(n: usize, policy: &str) -> CoreManager {
        let cpu = CpuPackage::uniform(
            n,
            AgingParams::paper_default(),
            TemperatureModel::paper_default(),
        );
        CoreManager::new(cpu, by_name(policy).unwrap(), Rng::new(1))
    }

    #[test]
    fn by_name_resolves_all() {
        for p in ALL_POLICIES {
            assert!(by_name(p).is_ok(), "missing policy {p}");
        }
        assert!(by_name("nope").is_err());
    }

    #[test]
    fn tasks_fill_then_oversubscribe() {
        for p in ALL_POLICIES {
            let mut m = mgr(2, p);
            assert!(m.start_task(1, 0.0).is_some());
            assert!(m.start_task(2, 0.0).is_some());
            assert!(m.start_task(3, 0.0).is_none(), "policy {p} should oversubscribe");
            assert_eq!(m.cpu.running_tasks(), 3);
            assert_eq!(m.oversub_events, 1);
        }
    }

    #[test]
    fn finishing_promotes_oversubscribed() {
        for p in ALL_POLICIES {
            let mut m = mgr(2, p);
            m.start_task(1, 0.0);
            m.start_task(2, 0.0);
            m.start_task(3, 0.0);
            m.finish_task(1, 1.0);
            // Task 3 must now own a dedicated core.
            assert_eq!(m.cpu.oversub.len(), 0, "policy {p}");
            assert_eq!(m.cpu.allocated_count(), 2, "policy {p}");
        }
    }

    #[test]
    fn promotion_follows_arrival_order_after_mid_queue_finish() {
        // Regression for the `swap_remove_back` FIFO corruption: finish a
        // mid-queue oversubscribed task, then free cores one at a time —
        // the remaining queue must be promoted strictly in arrival order.
        let mut m = mgr(2, "linux");
        m.start_task(1, 0.0);
        m.start_task(2, 0.0);
        for t in [10, 11, 12, 13] {
            assert!(m.start_task(t, 0.1).is_none());
        }
        m.finish_task(11, 0.2); // still queued: finishes mid-queue
        let mut promoted = Vec::new();
        for (i, pinned) in [1u64, 2].iter().enumerate() {
            m.finish_task(*pinned, 1.0 + i as f64);
            for t in [10u64, 12, 13] {
                if m.cpu.task_core_of(t).is_some() && !promoted.contains(&t) {
                    promoted.push(t);
                }
            }
        }
        assert_eq!(promoted, vec![10, 12], "promotion order broke arrival order");
        assert_eq!(m.cpu.oversub.iter().copied().collect::<Vec<_>>(), vec![13]);
    }

    #[test]
    fn failure_during_oversubscription_preserves_fifo_order() {
        // Regression guarding the PR 6 FIFO fix against the core-failure
        // eviction path: fail a pinned core while the oversubscription
        // queue is non-empty. The evicted task re-queues at the *front*
        // (it arrived before everything still queued), and subsequent
        // promotions must follow global arrival order exactly.
        let mut m = mgr(2, "linux");
        m.start_task(1, 0.0);
        m.start_task(2, 0.0);
        for t in [10, 11, 12, 13] {
            assert!(m.start_task(t, 0.1).is_none());
        }
        let core1 = m.cpu.task_core_of(1).expect("task 1 pinned");
        assert!(m.fail_core(core1, 0.2));
        assert!(!m.fail_core(core1, 0.3), "double failure is a no-op");
        // One usable core left (running task 2): task 1 heads the queue.
        assert_eq!(
            m.cpu.oversub.iter().copied().collect::<Vec<_>>(),
            vec![1, 10, 11, 12, 13]
        );
        // Drain through the single surviving core; each finish promotes
        // the next task. The pin order must be the arrival order.
        let mut order = Vec::new();
        m.finish_task(2, 1.0);
        let mut clock = 1.0;
        while m.cpu.running_tasks() > 0 {
            let pinned = m.cpu.core_views().find_map(|c| c.task()).expect("one pinned task");
            assert_ne!(m.cpu.task_core_of(pinned), Some(core1), "failed core re-used");
            order.push(pinned);
            clock += 1.0;
            m.finish_task(pinned, clock);
        }
        assert_eq!(order, vec![1, 10, 11, 12, 13], "promotion broke arrival order");
    }

    #[test]
    fn replace_package_migrates_pinned_and_queued_tasks() {
        for p in ALL_POLICIES {
            let mut m = mgr(2, p);
            m.start_task(1, 0.0);
            m.start_task(2, 0.0);
            assert!(m.start_task(3, 0.1).is_none());
            // Retire onto a *smaller* SKU: 1 core. All three tasks must
            // survive the swap, one pinned and two queued in order.
            let new_cpu = CpuPackage::uniform(
                1,
                AgingParams::paper_default(),
                TemperatureModel::paper_default(),
            );
            m.replace_package(new_cpu, by_name(p).unwrap(), 0.2);
            assert_eq!(m.cpu.running_tasks(), 3, "policy {p} lost a task");
            assert_eq!(m.cpu.allocated_count(), 1, "policy {p}");
            // finish_task still resolves every migrated task.
            m.finish_task(1, 1.0);
            m.finish_task(2, 2.0);
            m.finish_task(3, 3.0);
            assert_eq!(m.cpu.running_tasks(), 0, "policy {p}");
        }
    }

    #[test]
    fn adjust_tick_skips_clean_packages() {
        let mut m = mgr(8, "proposed");
        // A fresh package is dirty: the first tick runs (and parks cores).
        assert!(m.adjust_tick(0.25));
        // Ticks keep running while the previous tick changed something;
        // once a tick is a no-op the package stays clean and later ticks
        // are skipped outright.
        let mut ticks = 0;
        while m.adjust_tick(0.5 + 0.25 * ticks as f64) {
            ticks += 1;
            assert!(ticks < 32, "adjust_tick never converged");
        }
        assert!(!m.adjust_tick(100.0));
        assert!(!m.adjust_tick(200.0), "clean package must keep skipping");
        // Any task event re-arms the tick.
        m.start_task(1, 300.0);
        assert!(m.adjust_tick(300.25));
    }

    #[test]
    fn unique_core_per_task() {
        for p in ALL_POLICIES {
            let mut m = mgr(8, p);
            let mut picked = Vec::new();
            for t in 0..8 {
                let c = m.start_task(t, t as f64 * 0.1).expect("core available");
                assert!(!picked.contains(&c), "policy {p} double-assigned core {c}");
                picked.push(c);
            }
        }
    }
}
