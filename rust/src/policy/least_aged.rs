//! The `least-aged` baseline (Zhao'23, "The Case of Unsustainable CPU
//! Affinity") — §6.1.1.
//!
//! Assigns tasks *away* from aged cores using **executed work** (cumulative
//! busy time) as the aging estimate, avoiding per-task CPU profiling. It
//! evens out aging across cores better than stock Linux, but keeps every
//! core in C0 — it has no age-halting mechanism, which is exactly the gap
//! the paper's Selective Core Idling fills (Table 3's "Dynamic
//! Age-halting" column).

use super::CorePolicy;
use crate::cpu::CpuPackage;
use crate::util::rng::Rng;

#[derive(Clone, Debug, Default)]
pub struct LeastAgedPolicy;

impl LeastAgedPolicy {
    pub fn new() -> LeastAgedPolicy {
        LeastAgedPolicy
    }
}

impl CorePolicy for LeastAgedPolicy {
    fn name(&self) -> &'static str {
        "least-aged"
    }

    /// Free active core with the least executed work — a single
    /// allocation-free pass over the package's flat busy-time slice
    /// (§Perf).
    fn pick_core(&mut self, cpu: &CpuPackage, _now: f64, _rng: &mut Rng) -> Option<usize> {
        super::min_free_core_by_key(cpu, cpu.busy_times())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::{AgingParams, TemperatureModel};

    fn pkg(n: usize) -> CpuPackage {
        CpuPackage::uniform(n, AgingParams::paper_default(), TemperatureModel::paper_default())
    }

    #[test]
    fn picks_least_worked_core() {
        let mut cpu = pkg(3);
        let mut p = LeastAgedPolicy::new();
        let mut rng = Rng::new(1);
        cpu.set_busy_time(0, 100.0);
        cpu.set_busy_time(1, 5.0);
        cpu.set_busy_time(2, 50.0);
        assert_eq!(p.pick_core(&cpu, 0.0, &mut rng), Some(1));
    }

    #[test]
    fn balances_work_over_time() {
        let mut cpu = pkg(4);
        let mut p = LeastAgedPolicy::new();
        let mut rng = Rng::new(2);
        // Sequential 1s tasks: work should spread evenly (round-robin-ish).
        let mut t_now = 0.0;
        for t in 0..400u64 {
            let c = p.pick_core(&cpu, t_now, &mut rng).unwrap();
            cpu.assign(c, t, t_now);
            t_now += 1.0;
            cpu.finish_task(t, t_now);
        }
        let works: Vec<f64> = cpu.busy_times().to_vec();
        let max = works.iter().cloned().fold(f64::MIN, f64::max);
        let min = works.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max - min <= 1.0 + 1e-9, "works={works:?}");
    }

    #[test]
    fn no_age_halting() {
        let mut cpu = pkg(4);
        let mut p = LeastAgedPolicy::new();
        p.adjust(&mut cpu, 10.0);
        assert_eq!(cpu.active_count(), 4);
        assert_eq!(cpu.c6_count(), 0);
        assert_eq!(p.adjust_period_s(), None);
    }

    #[test]
    fn none_when_all_busy() {
        let mut cpu = pkg(2);
        let mut p = LeastAgedPolicy::new();
        let mut rng = Rng::new(3);
        cpu.assign(0, 1, 0.0);
        cpu.assign(1, 2, 0.0);
        assert_eq!(p.pick_core(&cpu, 1.0, &mut rng), None);
    }
}
