//! A single CPU core: C-state machine, allocation status, idle history,
//! and lazily-advanced NBTI aging state.
//!
//! This is the *scalar reference implementation*. The cluster hot path
//! does not store `Core` structs — [`super::package::CpuPackage`] keeps
//! the same state structure-of-arrays so batch advances vectorize — but
//! the two must agree exactly: `tests/aging_parity.rs` pins this struct
//! against the closed-form recursion, and `tests/package_soa.rs` pins the
//! package's SoA path against the same reference.
//!
//! Aging is accounted lazily *and* transcendental-free: a core's state is
//! advanced only when its configuration (C-state or allocation) is about
//! to change, or when a caller explicitly snapshots frequencies. Between
//! changes the core sits at a constant (temperature, stress) operating
//! point, whose ADF is precomputed in [`AgingOps`], and aging is tracked
//! as *canonical equivalent stress time* — so one advance is a single
//! multiply-add, and ΔVth/frequency cost one `powf` only when actually
//! read (§Perf; see the [`AgingOps`] invariant docs).

use super::aging::AgingOps;

/// CPU core idle state. The paper's technique only distinguishes the
/// shallow-active and deepest-idle states (C0 vs C6, per the Linux cpuidle
/// framework): C6 clock- and power-gates the core, halting aging.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CState {
    /// Active: executing instructions (allocated inference task or OS
    /// system tasks time-sharing the core). The core ages.
    C0,
    /// Deep idle: power gated. The core does not age and cannot take work.
    C6,
}

/// Rolling window of the last 8 idle durations — the same depth the Linux
/// menu governor keeps, and the age-estimation signal of Algorithm 1.
#[derive(Clone, Copy, Debug, Default)]
pub struct IdleHistory {
    buf: [f64; 8],
    len: usize,
    pos: usize,
}

impl IdleHistory {
    pub fn push(&mut self, duration: f64) {
        self.buf[self.pos] = duration;
        self.pos = (self.pos + 1) % 8;
        if self.len < 8 {
            self.len += 1;
        }
    }

    /// Sum of the recorded idle durations — Algorithm 1's `idle_score`.
    pub fn score(&self) -> f64 {
        self.buf[..self.len].iter().sum()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Per-core state.
#[derive(Clone, Debug)]
pub struct Core {
    pub id: usize,
    /// Initial (process-variation) frequency in GHz.
    pub f0_ghz: f64,
    /// Canonical equivalent stress time (s): the length of continuous
    /// worst-case (C0, allocated) stress producing this core's current
    /// ΔVth. Monotone in ΔVth, so policies compare ages on it directly;
    /// the ΔVth value itself is a lazy snapshot ([`Core::dvth`]).
    pub eq_time_s: f64,
    pub state: CState,
    /// Inference task currently pinned to this core.
    pub task: Option<u64>,
    /// Recent idle durations (Algorithm 1 input).
    pub idle_history: IdleHistory,
    /// When the core last became task-free (for idle-history accounting).
    idle_since: f64,
    /// Last simulation time aging was advanced to.
    last_update: f64,
    /// Cumulative seconds with a task allocated (least-aged's work proxy).
    pub busy_time: f64,
    /// Cumulative seconds in C0.
    pub active_time: f64,
    /// Cumulative seconds in C6 (age-halted).
    pub c6_time: f64,
}

impl Core {
    pub fn new(id: usize, f0_ghz: f64) -> Core {
        Core {
            id,
            f0_ghz,
            eq_time_s: 0.0,
            state: CState::C0,
            task: None,
            idle_history: IdleHistory::default(),
            idle_since: 0.0,
            last_update: 0.0,
            busy_time: 0.0,
            active_time: 0.0,
            c6_time: 0.0,
        }
    }

    #[inline]
    pub fn is_allocated(&self) -> bool {
        self.task.is_some()
    }

    /// Advance aging to `now` under the current configuration.
    ///
    /// C0 intervals accrue equivalent stress time at the precomputed rate
    /// for the core's allocation status (worst-case stress Y = 1 when
    /// allocated, per §3.2); C6 intervals are age-halted and only
    /// accumulate wall-clock bookkeeping. No transcendentals (§Perf).
    pub fn advance(&mut self, now: f64, ops: &AgingOps) {
        debug_assert!(
            now >= self.last_update - 1e-9,
            "time went backwards: {} < {}",
            now,
            self.last_update
        );
        let tau = (now - self.last_update).max(0.0);
        if tau == 0.0 {
            return;
        }
        match self.state {
            CState::C0 => {
                if self.task.is_some() {
                    self.eq_time_s += tau;
                    self.busy_time += tau;
                } else {
                    self.eq_time_s += tau * ops.rate_unalloc;
                }
                self.active_time += tau;
            }
            CState::C6 => {
                // Age halted: equivalent stress time frozen.
                self.c6_time += tau;
            }
        }
        self.last_update = now;
    }

    /// Pin a task to this core. Must be free and active.
    pub fn assign(&mut self, task: u64, now: f64, ops: &AgingOps) {
        debug_assert!(self.task.is_none(), "core {} already allocated", self.id);
        debug_assert_eq!(self.state, CState::C0, "cannot assign to a deep-idle core");
        self.advance(now, ops);
        // Close out the idle period that ends now.
        self.idle_history.push((now - self.idle_since).max(0.0));
        self.task = Some(task);
    }

    /// Release the task pinned to this core.
    pub fn release(&mut self, now: f64, ops: &AgingOps) -> u64 {
        debug_assert!(self.task.is_some(), "core {} has no task", self.id);
        self.advance(now, ops);
        self.idle_since = now;
        self.task.take().unwrap()
    }

    /// Switch C-state. Putting an allocated core to C6 is a logic error.
    pub fn set_state(&mut self, state: CState, now: f64, ops: &AgingOps) {
        if state == self.state {
            return;
        }
        debug_assert!(
            !(state == CState::C6 && self.is_allocated()),
            "cannot deep-idle allocated core {}",
            self.id
        );
        self.advance(now, ops);
        self.state = state;
    }

    /// Accumulated ΔVth (V), *as of the last advance* — the lazy snapshot
    /// derived from equivalent stress time (one `powf`). Call
    /// [`Core::advance`] first for an up-to-date value.
    #[inline]
    pub fn dvth(&self, ops: &AgingOps) -> f64 {
        ops.dvth_of_eq(self.eq_time_s)
    }

    /// Current frequency in GHz, *as of the last advance*. Call
    /// [`Core::advance`] first for an up-to-date value.
    #[inline]
    pub fn freq_ghz(&self, ops: &AgingOps) -> f64 {
        ops.freq_ghz(self.f0_ghz, self.eq_time_s)
    }

    /// Absolute frequency reduction since t=0 (GHz).
    #[inline]
    pub fn freq_reduction_ghz(&self, ops: &AgingOps) -> f64 {
        self.f0_ghz - self.freq_ghz(ops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::{AgingParams, TemperatureModel};

    fn ops() -> AgingOps {
        AgingOps::new(&AgingParams::paper_default(), &TemperatureModel::paper_default())
    }

    #[test]
    fn idle_history_window_of_eight() {
        let mut h = IdleHistory::default();
        for i in 1..=10 {
            h.push(i as f64);
        }
        // Only 3..=10 retained.
        assert_eq!(h.len(), 8);
        assert!((h.score() - (3..=10).sum::<i64>() as f64).abs() < 1e-12);
    }

    #[test]
    fn c0_ages_c6_does_not() {
        let ops = ops();
        let mut active = Core::new(0, 2.6);
        let mut idle = Core::new(1, 2.6);
        idle.set_state(CState::C6, 0.0, &ops);
        active.advance(3600.0, &ops);
        idle.advance(3600.0, &ops);
        assert!(active.dvth(&ops) > 0.0);
        assert_eq!(idle.dvth(&ops), 0.0);
        assert_eq!(idle.c6_time, 3600.0);
        assert_eq!(active.active_time, 3600.0);
    }

    #[test]
    fn allocated_ages_faster_than_unallocated() {
        let ops = ops();
        let mut busy = Core::new(0, 2.6);
        let mut free = Core::new(1, 2.6);
        busy.assign(1, 0.0, &ops);
        busy.advance(3600.0, &ops);
        free.advance(3600.0, &ops);
        assert!(busy.dvth(&ops) > free.dvth(&ops));
        assert_eq!(busy.busy_time, 3600.0);
        assert_eq!(free.busy_time, 0.0);
    }

    #[test]
    fn assign_release_tracks_idle_history() {
        let ops = ops();
        let mut c = Core::new(0, 2.6);
        c.assign(10, 5.0, &ops); // idle 0..5
        let t = c.release(8.0, &ops);
        assert_eq!(t, 10);
        c.assign(11, 12.0, &ops); // idle 8..12
        assert_eq!(c.idle_history.len(), 2);
        assert!((c.idle_history.score() - 9.0).abs() < 1e-12);
    }

    #[test]
    fn freq_decreases_with_age() {
        let ops = ops();
        let mut c = Core::new(0, 2.6);
        let f_start = c.freq_ghz(&ops);
        c.advance(86_400.0, &ops);
        assert!(c.freq_ghz(&ops) < f_start);
        assert!(c.freq_reduction_ghz(&ops) > 0.0);
    }

    #[test]
    fn set_state_roundtrip_accumulates_times() {
        let ops = ops();
        let mut c = Core::new(0, 2.6);
        c.set_state(CState::C6, 10.0, &ops);
        c.set_state(CState::C0, 30.0, &ops);
        c.advance(35.0, &ops);
        assert_eq!(c.c6_time, 20.0);
        assert!((c.active_time - 15.0).abs() < 1e-12);
    }

    #[test]
    fn advance_matches_closed_form_recursion() {
        // The fast path must reproduce AgingParams::dvth_step across an
        // allocated → unallocated → C6 → allocated schedule.
        let p = AgingParams::paper_default();
        let t = TemperatureModel::paper_default();
        let ops = AgingOps::new(&p, &t);
        let mut c = Core::new(0, 2.6);
        c.assign(1, 0.0, &ops);
        c.advance(50_000.0, &ops); // 50ks allocated
        c.release(50_000.0, &ops);
        c.advance(80_000.0, &ops); // 30ks unallocated
        c.set_state(CState::C6, 80_000.0, &ops);
        c.advance(100_000.0, &ops); // 20ks halted
        c.set_state(CState::C0, 100_000.0, &ops);
        c.assign(2, 100_000.0, &ops);
        c.advance(130_000.0, &ops); // 30ks allocated
        let mut reference = p.dvth_step(0.0, ops.adf_alloc, 50_000.0);
        reference = p.dvth_step(reference, ops.adf_unalloc, 30_000.0);
        // C6: frozen.
        reference = p.dvth_step(reference, ops.adf_alloc, 30_000.0);
        let fast = c.dvth(&ops);
        assert!(
            (fast - reference).abs() / reference < 1e-12,
            "fast={fast} reference={reference}"
        );
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn cannot_deep_idle_allocated() {
        let ops = ops();
        let mut c = Core::new(0, 2.6);
        c.assign(1, 0.0, &ops);
        c.set_state(CState::C6, 1.0, &ops);
    }
}
