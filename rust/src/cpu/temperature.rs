//! Core temperature model — Table 1 of the paper, plus the first-order
//! thermal transient used to reproduce the Fig. 4 experiment.
//!
//! The paper derives three steady-state operating points from a
//! measurement campaign on a 12-core Intel Xeon (6 cores toggled between
//! C0 and C6 under 100 % utilization):
//!
//! | Idle state | C-state | Inference task | Temperature |
//! |------------|---------|----------------|-------------|
//! | Active     | C0      | Allocated      | 54.00 °C    |
//! | Active     | C0      | Unallocated    | 51.08 °C    |
//! | Deep idle  | C6      | n/a            | 48.00 °C    |
//!
//! The aging simulator consumes only the steady states; the transient RC
//! model (`TransientThermal`) reproduces the measured settle curves for
//! the Fig. 4 bench and is our substitute for the authors' hardware
//! experiment (see DESIGN.md, substitutions).

use super::core::CState;

/// Steady-state temperatures (°C) per (C-state, allocation) — Table 1.
#[derive(Clone, Copy, Debug)]
pub struct TemperatureModel {
    pub active_allocated_c: f64,
    pub active_unallocated_c: f64,
    pub deep_idle_c: f64,
}

impl TemperatureModel {
    pub fn paper_default() -> TemperatureModel {
        TemperatureModel {
            active_allocated_c: 54.0,
            active_unallocated_c: 51.08,
            deep_idle_c: 48.0,
        }
    }

    /// Steady-state temperature in °C for a core state.
    #[inline]
    pub fn steady_c(&self, state: CState, allocated: bool) -> f64 {
        match state {
            CState::C6 => self.deep_idle_c,
            CState::C0 => {
                if allocated {
                    self.active_allocated_c
                } else {
                    self.active_unallocated_c
                }
            }
        }
    }

    /// Steady-state temperature in Kelvin.
    #[inline]
    pub fn steady_k(&self, state: CState, allocated: bool) -> f64 {
        self.steady_c(state, allocated) + 273.15
    }
}

/// First-order thermal RC transient: `T(t) = T∞ + (T0 − T∞)·exp(−t/τ)`.
///
/// Used by the Fig. 4 reproduction to show the settle behaviour when half
/// the cores switch C-state. τ ≈ 30 s matches the settling time visible in
/// the paper's measurement plot (minutes-scale experiment, settle well
/// under a minute).
#[derive(Clone, Copy, Debug)]
pub struct TransientThermal {
    /// Thermal time constant in seconds.
    pub tau_s: f64,
    /// Current temperature (°C).
    pub temp_c: f64,
}

impl TransientThermal {
    pub fn new(initial_c: f64, tau_s: f64) -> TransientThermal {
        TransientThermal { tau_s, temp_c: initial_c }
    }

    /// Advance `dt` seconds toward the target steady-state temperature.
    pub fn step(&mut self, target_c: f64, dt: f64) -> f64 {
        let a = (-dt / self.tau_s).exp();
        self.temp_c = target_c + (self.temp_c - target_c) * a;
        self.temp_c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values() {
        let t = TemperatureModel::paper_default();
        assert_eq!(t.steady_c(CState::C0, true), 54.0);
        assert_eq!(t.steady_c(CState::C0, false), 51.08);
        assert_eq!(t.steady_c(CState::C6, false), 48.0);
        assert_eq!(t.steady_c(CState::C6, true), 48.0);
    }

    #[test]
    fn kelvin_conversion() {
        let t = TemperatureModel::paper_default();
        assert!((t.steady_k(CState::C0, true) - 327.15).abs() < 1e-9);
    }

    #[test]
    fn ordering_matches_paper() {
        let t = TemperatureModel::paper_default();
        assert!(t.steady_c(CState::C0, true) > t.steady_c(CState::C0, false));
        assert!(t.steady_c(CState::C0, false) > t.steady_c(CState::C6, false));
    }

    #[test]
    fn transient_converges_to_target() {
        let mut tr = TransientThermal::new(54.0, 30.0);
        for _ in 0..600 {
            tr.step(48.0, 1.0);
        }
        assert!((tr.temp_c - 48.0).abs() < 1e-6);
    }

    #[test]
    fn transient_monotone_when_cooling() {
        let mut tr = TransientThermal::new(54.0, 30.0);
        let mut prev = tr.temp_c;
        for _ in 0..100 {
            let t = tr.step(48.0, 1.0);
            assert!(t <= prev);
            prev = t;
        }
    }

    #[test]
    fn transient_time_constant() {
        // After exactly one time constant, 63.2% of the gap is closed.
        let mut tr = TransientThermal::new(54.0, 30.0);
        tr.step(48.0, 30.0);
        let expect = 48.0 + (54.0 - 48.0) * (-1.0f64).exp();
        assert!((tr.temp_c - expect).abs() < 1e-9);
    }
}
