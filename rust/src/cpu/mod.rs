//! CPU substrate: cores, C-states, temperatures, NBTI aging, and
//! manufacturing process variation — the paper's §3 system model.
//!
//! * [`aging`] — reaction–diffusion NBTI model (`ΔVth` recursion, ADF,
//!   frequency degradation), calibrated against the 22 nm 30 %-in-10-years
//!   datum.
//! * [`temperature`] — Table 1 steady states + the Fig. 4 thermal
//!   transient.
//! * [`procvar`] — spatially-correlated process variation producing each
//!   core's initial frequency `f0`.
//! * [`core`] — the standalone scalar core state machine (the reference
//!   implementation the SoA fast path is pinned against).
//! * [`package`] — the multi-core CPU the management policies operate on,
//!   with core state stored structure-of-arrays for batch advances.

pub mod aging;
pub mod core;
pub mod package;
pub mod procvar;
pub mod temperature;

pub use aging::{AgingOps, AgingParams};
pub use core::{CState, Core, IdleHistory};
pub use package::{CoreView, CpuPackage};
pub use procvar::{ProcVarParams, ProcVarSampler};
pub use temperature::{TemperatureModel, TransientThermal};
