//! The multi-core CPU package: the unit the paper's technique manages.
//!
//! Owns the per-core states of one inference server's CPU plus the list of
//! currently *oversubscribed* tasks — tasks that arrived while no active
//! free core existed. Oversubscribed tasks still execute (time-shared by
//! the OS) but degrade service quality; Algorithm 2 consumes their count
//! and the Fig. 8 metric integrates them.
//!
//! The package also owns the [`AgingOps`] operating-point cache: the ADFs
//! of the (C0, allocated) and (C0, unallocated) points are precomputed
//! here once, so the per-event core advances are transcendental-free
//! (§Perf).

use std::collections::{HashMap, VecDeque};

use super::aging::{AgingOps, AgingParams};
use super::core::{CState, Core};
use super::temperature::TemperatureModel;

/// A multi-core CPU with aging state.
#[derive(Clone, Debug)]
pub struct CpuPackage {
    pub cores: Vec<Core>,
    pub aging: AgingParams,
    pub temps: TemperatureModel,
    /// Precomputed operating-point cache (ADFs, eq-time rates) — derived
    /// from `aging` + `temps` at construction.
    pub ops: AgingOps,
    /// task id -> core index, for O(1) release.
    task_core: HashMap<u64, usize>,
    /// Tasks executing without a dedicated core (oversubscription).
    /// A deque so the FIFO pop is O(1) (§Perf).
    pub oversub: VecDeque<u64>,
    /// Cached count of cores in C0 (§Perf: the hot path queries counts on
    /// every task spawn; scanning all cores was the top profile entry).
    active_cnt: usize,
}

impl CpuPackage {
    /// Build a package from per-core initial frequencies (GHz).
    pub fn new(f0_ghz: Vec<f64>, aging: AgingParams, temps: TemperatureModel) -> CpuPackage {
        let cores: Vec<Core> =
            f0_ghz.into_iter().enumerate().map(|(i, f)| Core::new(i, f)).collect();
        let active_cnt = cores.len();
        let ops = AgingOps::new(&aging, &temps);
        CpuPackage {
            cores,
            aging,
            temps,
            ops,
            task_core: HashMap::new(),
            oversub: VecDeque::new(),
            active_cnt,
        }
    }

    /// Homogeneous package at the nominal frequency (tests, quickstart).
    pub fn uniform(n_cores: usize, aging: AgingParams, temps: TemperatureModel) -> CpuPackage {
        CpuPackage::new(vec![aging.f_nominal_ghz; n_cores], aging, temps)
    }

    #[inline]
    pub fn n_cores(&self) -> usize {
        self.cores.len()
    }

    /// Number of cores in C0 (the *working set* plus any active-but-free).
    #[inline]
    pub fn active_count(&self) -> usize {
        debug_assert_eq!(
            self.active_cnt,
            self.cores.iter().filter(|c| c.state == CState::C0).count()
        );
        self.active_cnt
    }

    /// Number of cores in C6.
    #[inline]
    pub fn c6_count(&self) -> usize {
        self.n_cores() - self.active_cnt
    }

    /// Number of cores with a pinned task.
    pub fn allocated_count(&self) -> usize {
        self.task_core.len()
    }

    /// Total running inference tasks = pinned + oversubscribed.
    pub fn running_tasks(&self) -> usize {
        self.task_core.len() + self.oversub.len()
    }

    /// Indices of active, unallocated cores (assignment candidates).
    pub fn free_active_cores(&self) -> impl Iterator<Item = &Core> {
        self.cores.iter().filter(|c| c.state == CState::C0 && c.task.is_none())
    }

    #[inline]
    pub fn has_free_active_core(&self) -> bool {
        // Allocated cores are always C0, so the difference counts free
        // active cores directly.
        self.active_cnt > self.task_core.len()
    }

    /// Number of free active cores, O(1).
    #[inline]
    pub fn free_active_count(&self) -> usize {
        self.active_cnt - self.task_core.len()
    }

    /// Pin `task` to `core_idx`.
    pub fn assign(&mut self, core_idx: usize, task: u64, now: f64) {
        let ops = self.ops;
        self.cores[core_idx].assign(task, now, &ops);
        self.task_core.insert(task, core_idx);
    }

    /// Record `task` as oversubscribed (no dedicated core available).
    pub fn push_oversub(&mut self, task: u64) {
        self.oversub.push_back(task);
    }

    /// Finish a task wherever it runs. Returns the freed core index when
    /// the task had a dedicated core.
    pub fn finish_task(&mut self, task: u64, now: f64) -> Option<usize> {
        if let Some(core_idx) = self.task_core.remove(&task) {
            let ops = self.ops;
            self.cores[core_idx].release(now, &ops);
            Some(core_idx)
        } else if let Some(pos) = self.oversub.iter().position(|&t| t == task) {
            self.oversub.swap_remove_back(pos);
            None
        } else {
            panic!("finish_task: unknown task {task}");
        }
    }

    /// Which core runs `task`, if it has a dedicated one.
    pub fn task_core_of(&self, task: u64) -> Option<usize> {
        self.task_core.get(&task).copied()
    }

    /// Pop one pending oversubscribed task (FIFO), if any — O(1).
    pub fn pop_oversub(&mut self) -> Option<u64> {
        self.oversub.pop_front()
    }

    /// Switch a core's C-state.
    pub fn set_state(&mut self, core_idx: usize, state: CState, now: f64) {
        let ops = self.ops;
        let before = self.cores[core_idx].state;
        self.cores[core_idx].set_state(state, now, &ops);
        match (before, state) {
            (CState::C0, CState::C6) => self.active_cnt -= 1,
            (CState::C6, CState::C0) => self.active_cnt += 1,
            _ => {}
        }
    }

    /// Advance aging of every core to `now` (metrics snapshots; also the
    /// paper's periodic "accurate frequency from aging sensors" moment).
    pub fn advance_all(&mut self, now: f64) {
        let ops = self.ops;
        for c in &mut self.cores {
            c.advance(now, &ops);
        }
    }

    /// Per-core frequencies (GHz) as of `now`.
    pub fn frequencies(&mut self, now: f64) -> Vec<f64> {
        self.advance_all(now);
        let ops = self.ops;
        self.cores.iter().map(|c| c.freq_ghz(&ops)).collect()
    }

    /// Per-core absolute frequency reductions (GHz) as of `now`.
    pub fn freq_reductions(&mut self, now: f64) -> Vec<f64> {
        self.advance_all(now);
        let ops = self.ops;
        self.cores.iter().map(|c| c.freq_reduction_ghz(&ops)).collect()
    }

    /// Relative execution-time dilation for a task on `core_idx`:
    /// `f_nominal / f_core` (≥ ~1 once aged). The simulator stretches CPU
    /// task durations by this factor (§5: "execution time ... adjusted
    /// according to the operating frequency").
    pub fn slowdown(&self, core_idx: usize) -> f64 {
        let f = self.cores[core_idx].freq_ghz(&self.ops);
        if f <= 0.0 {
            f64::INFINITY
        } else {
            self.ops.f_nominal_ghz / f
        }
    }

    /// Normalized idle cores — the Fig. 8 x-axis:
    /// `(active − running_tasks) / N`. Positive = underutilization,
    /// negative = oversubscription.
    pub fn normalized_idle(&self) -> f64 {
        (self.active_count() as f64 - self.running_tasks() as f64) / self.n_cores() as f64
    }

    /// Normalized idle as seen by a task that is about to be placed
    /// (itself included in the running count).
    pub fn normalized_idle_for_extra_task(&self) -> f64 {
        (self.active_count() as f64 - (self.running_tasks() + 1) as f64) / self.n_cores() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pkg(n: usize) -> CpuPackage {
        CpuPackage::uniform(n, AgingParams::paper_default(), TemperatureModel::paper_default())
    }

    #[test]
    fn counts_track_assignments() {
        let mut p = pkg(4);
        assert_eq!(p.active_count(), 4);
        assert_eq!(p.allocated_count(), 0);
        p.assign(0, 100, 0.0);
        p.assign(2, 101, 0.0);
        assert_eq!(p.allocated_count(), 2);
        assert_eq!(p.running_tasks(), 2);
        assert_eq!(p.free_active_cores().count(), 2);
        let freed = p.finish_task(100, 1.0);
        assert_eq!(freed, Some(0));
        assert_eq!(p.allocated_count(), 1);
    }

    #[test]
    fn oversub_lifecycle() {
        let mut p = pkg(2);
        p.assign(0, 1, 0.0);
        p.assign(1, 2, 0.0);
        p.push_oversub(3);
        assert_eq!(p.running_tasks(), 3);
        assert!((p.normalized_idle() - (-0.5)).abs() < 1e-12);
        assert_eq!(p.finish_task(3, 1.0), None);
        assert_eq!(p.running_tasks(), 2);
    }

    #[test]
    fn pop_oversub_fifo() {
        let mut p = pkg(1);
        p.push_oversub(7);
        p.push_oversub(8);
        assert_eq!(p.pop_oversub(), Some(7));
        assert_eq!(p.pop_oversub(), Some(8));
        assert_eq!(p.pop_oversub(), None);
    }

    #[test]
    fn c6_removes_from_working_set() {
        let mut p = pkg(4);
        p.set_state(3, CState::C6, 0.0);
        assert_eq!(p.active_count(), 3);
        assert_eq!(p.c6_count(), 1);
        assert!((p.normalized_idle() - 0.75).abs() < 1e-12);
        p.set_state(3, CState::C0, 5.0);
        assert_eq!(p.active_count(), 4);
    }

    #[test]
    fn frequencies_degrade_over_time() {
        let mut p = pkg(2);
        p.assign(0, 1, 0.0);
        let fs = p.frequencies(36_000.0);
        // Allocated core 0 degraded more than free core 1.
        assert!(fs[0] < fs[1]);
        assert!(fs[1] < p.aging.f_nominal_ghz);
        let reds = p.freq_reductions(36_000.0);
        assert!(reds[0] > reds[1]);
    }

    #[test]
    fn slowdown_grows_with_age() {
        let mut p = pkg(1);
        assert!((p.slowdown(0) - 1.0).abs() < 1e-12);
        p.advance_all(864_000.0);
        assert!(p.slowdown(0) > 1.0);
    }

    #[test]
    #[should_panic(expected = "unknown task")]
    fn finishing_unknown_task_panics() {
        let mut p = pkg(1);
        p.finish_task(42, 0.0);
    }
}
