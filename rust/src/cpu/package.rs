//! The multi-core CPU package: the unit the paper's technique manages.
//!
//! Owns the per-core states of one inference server's CPU plus the list of
//! currently *oversubscribed* tasks — tasks that arrived while no active
//! free core existed. Oversubscribed tasks still execute (time-shared by
//! the OS) but degrade service quality; Algorithm 2 consumes their count
//! and the Fig. 8 metric integrates them. The queue is strictly FIFO:
//! tasks are promoted to dedicated cores in arrival order, and a task that
//! finishes while still queued is removed *order-preservingly*
//! ([`VecDeque::remove`], not `swap_remove_back`).
//!
//! # Structure-of-arrays layout (§Perf)
//!
//! Core state lives in flat per-field slices owned by the package, not in
//! an array of `Core` structs. The hot fields — `eq_time_s` (canonical
//! equivalent stress time), `eq_rate` (the core's current operating-point
//! accrual rate), `last_update`, the cumulative time integrals, and the
//! two f64 occupancy masks — are each one contiguous `Vec<f64>`, so
//! [`CpuPackage::advance_all`] is a single branchless multiply-add loop
//! the compiler can vectorize:
//!
//! ```text
//! tau          = max(now - last_update[i], 0)
//! eq_time_s[i] += tau * eq_rate[i]          // 1.0 | rate_unalloc | 0.0
//! busy_time[i] += tau * busy_m[i]           // 1.0 iff task pinned
//! active_time[i] += tau * active_m[i]       // 1.0 iff C0
//! c6_time[i]   += tau * (1.0 - active_m[i])
//! last_update[i] = now
//! ```
//!
//! `eq_rate` folds the three operating points of the
//! [equivalent-stress-time invariant](super::aging::AgingOps) into one
//! multiplier per core — (C0, allocated) = 1, (C0, unallocated) =
//! `rate_unalloc`, C6 = 0 — maintained at the (rare) configuration-change
//! edges (`assign`/`finish_task`/`set_state`) so the (frequent) advances
//! never branch on C-state or allocation. Cold metadata (`f0_ghz`, the
//! task slot, idle histories) stays in parallel slices read only on the
//! slow paths. Policies and tests access per-core state through the
//! borrowed [`CoreView`] accessor or through the flat key slices
//! ([`CpuPackage::eq_times`], [`CpuPackage::busy_times`]); the standalone
//! [`Core`](super::core::Core) struct remains as the scalar reference
//! implementation that `tests/aging_parity.rs` pins this layout against.
//!
//! # The dirty flag: skip-ahead for the coalesced adjust tick
//!
//! The cluster's 250 ms `Ev::Adjust` event ticks every machine. Most
//! machines see no task or C-state event between consecutive ticks, and
//! for them the adjust is provably a no-op: Algorithm 2's decision depends
//! only on discrete counts (active cores, sleepers, tasks) and on the
//! *ordering* of candidate ages — and between events every parking
//! candidate (free C0 core) accrues equivalent stress time at the same
//! `rate_unalloc` while every wake candidate (C6) is frozen, so orderings
//! and counts are time-invariant until the next mutation. The package
//! therefore keeps a `dirty` bit, set by every state-changing operation
//! (`assign`, `finish_task`, `push_oversub`, `pop_oversub`, an effective
//! `set_state`) and *not* by pure time advances; the manager's
//! `adjust_tick` returns immediately for clean packages
//! (`CoreManager::adjust_tick`), so untouched machines cost one branch per
//! tick instead of a full Algorithm 2 pass.

use std::collections::{HashMap, VecDeque};

use super::aging::{AgingOps, AgingParams};
use super::core::{CState, IdleHistory};
use super::temperature::TemperatureModel;

/// A multi-core CPU with aging state, stored structure-of-arrays (see the
/// module docs for the layout and the dirty-flag contract).
#[derive(Clone, Debug)]
pub struct CpuPackage {
    pub aging: AgingParams,
    pub temps: TemperatureModel,
    /// Precomputed operating-point cache (ADFs, eq-time rates) — derived
    /// from `aging` + `temps` at construction.
    pub ops: AgingOps,

    // ---- hot SoA slices (the batch-advance loop touches only these) ----
    /// Canonical equivalent stress time (s) per core.
    eq_time_s: Vec<f64>,
    /// Current operating-point accrual rate per core: 1.0 (C0, allocated),
    /// `ops.rate_unalloc` (C0, unallocated), or 0.0 (C6).
    eq_rate: Vec<f64>,
    /// Last simulation time each core's aging was advanced to.
    last_update: Vec<f64>,
    /// 1.0 iff the core is in C0 (f64 mask for branchless bookkeeping).
    active_m: Vec<f64>,
    /// 1.0 iff a task is pinned to the core (f64 mask).
    busy_m: Vec<f64>,
    /// Cumulative seconds with a task allocated (least-aged's work proxy).
    busy_time: Vec<f64>,
    /// Cumulative seconds in C0.
    active_time: Vec<f64>,
    /// Cumulative seconds in C6 (age-halted).
    c6_time: Vec<f64>,

    // ---- cold per-core slices (slow paths only) ----
    state: Vec<CState>,
    /// Inference task currently pinned to each core.
    task: Vec<Option<u64>>,
    /// Initial (process-variation) frequency in GHz.
    f0_ghz: Vec<f64>,
    /// Recent idle durations (Algorithm 1 input).
    idle_hist: Vec<IdleHistory>,
    /// When each core last became task-free.
    idle_since: Vec<f64>,

    // ---- package bookkeeping ----
    /// task id -> core index, for O(1) release.
    task_core: HashMap<u64, usize>,
    /// Tasks executing without a dedicated core (oversubscription).
    /// A deque so the FIFO pop is O(1) (§Perf).
    pub oversub: VecDeque<u64>,
    /// Cached count of cores in C0 (§Perf: the hot path queries counts on
    /// every task spawn; scanning all cores was the top profile entry).
    active_cnt: usize,
    /// Permanently failed cores (fault injection). A failed core is held
    /// in C6 forever: [`CpuPackage::set_state`] refuses to wake it, so it
    /// can never re-enter the working set or the allocation candidates.
    failed: Vec<bool>,
    /// Cached count of failed cores (`usable_cores` is on the hot
    /// normalized-idle path).
    failed_cnt: usize,
    /// Set by every state-changing operation, never by pure time advances
    /// — the adjust-tick skip-ahead bit (module docs).
    dirty: bool,
}

/// Borrowed per-core accessor over the package's SoA slices — the view
/// policies and tests read instead of a per-core struct.
#[derive(Clone, Copy)]
pub struct CoreView<'a> {
    pkg: &'a CpuPackage,
    idx: usize,
}

impl CoreView<'_> {
    #[inline]
    pub fn id(&self) -> usize {
        self.idx
    }

    /// Initial (process-variation) frequency in GHz.
    #[inline]
    pub fn f0_ghz(&self) -> f64 {
        self.pkg.f0_ghz[self.idx]
    }

    #[inline]
    pub fn state(&self) -> CState {
        self.pkg.state[self.idx]
    }

    /// Inference task currently pinned to this core.
    #[inline]
    pub fn task(&self) -> Option<u64> {
        self.pkg.task[self.idx]
    }

    #[inline]
    pub fn is_allocated(&self) -> bool {
        self.pkg.task[self.idx].is_some()
    }

    /// Canonical equivalent stress time (s), as of the last advance.
    #[inline]
    pub fn eq_time_s(&self) -> f64 {
        self.pkg.eq_time_s[self.idx]
    }

    /// Cumulative seconds with a task allocated, as of the last advance.
    #[inline]
    pub fn busy_time(&self) -> f64 {
        self.pkg.busy_time[self.idx]
    }

    /// Cumulative seconds in C0, as of the last advance.
    #[inline]
    pub fn active_time(&self) -> f64 {
        self.pkg.active_time[self.idx]
    }

    /// Cumulative seconds in C6 (age-halted), as of the last advance.
    #[inline]
    pub fn c6_time(&self) -> f64 {
        self.pkg.c6_time[self.idx]
    }

    /// Algorithm 1's idle score: sum of the last 8 idle durations.
    #[inline]
    pub fn idle_score(&self) -> f64 {
        self.pkg.idle_hist[self.idx].score()
    }

    #[inline]
    pub fn idle_history(&self) -> &IdleHistory {
        &self.pkg.idle_hist[self.idx]
    }

    /// Accumulated ΔVth (V), *as of the last advance* — the lazy `powf`
    /// snapshot derived from equivalent stress time.
    #[inline]
    pub fn dvth(&self) -> f64 {
        self.pkg.ops.dvth_of_eq(self.eq_time_s())
    }

    /// Current frequency in GHz, *as of the last advance*.
    #[inline]
    pub fn freq_ghz(&self) -> f64 {
        self.pkg.ops.freq_ghz(self.f0_ghz(), self.eq_time_s())
    }

    /// Absolute frequency reduction since t=0 (GHz).
    #[inline]
    pub fn freq_reduction_ghz(&self) -> f64 {
        self.f0_ghz() - self.freq_ghz()
    }

    /// True if this core has permanently failed (held in C6 forever).
    #[inline]
    pub fn failed(&self) -> bool {
        self.pkg.failed[self.idx]
    }
}

impl CpuPackage {
    /// Build a package from per-core initial frequencies (GHz).
    pub fn new(f0_ghz: Vec<f64>, aging: AgingParams, temps: TemperatureModel) -> CpuPackage {
        let n = f0_ghz.len();
        let ops = AgingOps::new(&aging, &temps);
        CpuPackage {
            aging,
            temps,
            ops,
            eq_time_s: vec![0.0; n],
            // All cores start (C0, unallocated).
            eq_rate: vec![ops.rate_unalloc; n],
            last_update: vec![0.0; n],
            active_m: vec![1.0; n],
            busy_m: vec![0.0; n],
            busy_time: vec![0.0; n],
            active_time: vec![0.0; n],
            c6_time: vec![0.0; n],
            state: vec![CState::C0; n],
            task: vec![None; n],
            f0_ghz,
            idle_hist: vec![IdleHistory::default(); n],
            idle_since: vec![0.0; n],
            task_core: HashMap::new(),
            oversub: VecDeque::new(),
            active_cnt: n,
            failed: vec![false; n],
            failed_cnt: 0,
            dirty: true,
        }
    }

    /// Homogeneous package at the nominal frequency (tests, quickstart).
    pub fn uniform(n_cores: usize, aging: AgingParams, temps: TemperatureModel) -> CpuPackage {
        CpuPackage::new(vec![aging.f_nominal_ghz; n_cores], aging, temps)
    }

    #[inline]
    pub fn n_cores(&self) -> usize {
        self.eq_time_s.len()
    }

    /// Accessor view over one core's SoA state.
    #[inline]
    pub fn core(&self, idx: usize) -> CoreView<'_> {
        debug_assert!(idx < self.n_cores());
        CoreView { pkg: self, idx }
    }

    /// Views over every core, in id order.
    pub fn core_views(&self) -> impl Iterator<Item = CoreView<'_>> + '_ {
        (0..self.n_cores()).map(move |idx| CoreView { pkg: self, idx })
    }

    /// The flat per-core equivalent-stress-time slice — the age key the
    /// proposed policy's candidate selection runs over (§Perf).
    #[inline]
    pub fn eq_times(&self) -> &[f64] {
        &self.eq_time_s
    }

    /// The flat per-core cumulative-busy-time slice (least-aged's key).
    #[inline]
    pub fn busy_times(&self) -> &[f64] {
        &self.busy_time
    }

    /// Number of cores in C0 (the *working set* plus any active-but-free).
    #[inline]
    pub fn active_count(&self) -> usize {
        debug_assert_eq!(
            self.active_cnt,
            self.state.iter().filter(|&&s| s == CState::C0).count()
        );
        self.active_cnt
    }

    /// Number of cores in C6 — *physical* count, failed cores included
    /// (a dead core is power-gated like any sleeper).
    #[inline]
    pub fn c6_count(&self) -> usize {
        self.n_cores() - self.active_cnt
    }

    /// Number of permanently failed cores.
    #[inline]
    pub fn failed_count(&self) -> usize {
        self.failed_cnt
    }

    /// Cores still usable for work: total minus permanently failed. This
    /// is the capacity denominator once fault injection is on — with no
    /// failures it equals `n_cores()` exactly.
    #[inline]
    pub fn usable_cores(&self) -> usize {
        self.n_cores() - self.failed_cnt
    }

    /// True if `core_idx` has permanently failed.
    #[inline]
    pub fn is_failed(&self, core_idx: usize) -> bool {
        self.failed[core_idx]
    }

    /// Number of cores with a pinned task.
    pub fn allocated_count(&self) -> usize {
        self.task_core.len()
    }

    /// Total running inference tasks = pinned + oversubscribed.
    pub fn running_tasks(&self) -> usize {
        self.task_core.len() + self.oversub.len()
    }

    /// Views of active, unallocated cores (assignment candidates).
    pub fn free_active_cores(&self) -> impl Iterator<Item = CoreView<'_>> + '_ {
        self.core_views().filter(|c| c.state() == CState::C0 && c.task().is_none())
    }

    #[inline]
    pub fn has_free_active_core(&self) -> bool {
        // Allocated cores are always C0, so the difference counts free
        // active cores directly.
        self.active_cnt > self.task_core.len()
    }

    /// Number of free active cores, O(1).
    #[inline]
    pub fn free_active_count(&self) -> usize {
        self.active_cnt - self.task_core.len()
    }

    /// True if a state-changing operation touched the package since the
    /// last [`CpuPackage::clear_dirty`] (skip-ahead contract: module docs).
    #[inline]
    pub fn is_dirty(&self) -> bool {
        self.dirty
    }

    /// Mark the package clean — called by the adjust tick before it runs,
    /// so mutations made *by* the adjust re-arm the next tick.
    #[inline]
    pub fn clear_dirty(&mut self) {
        self.dirty = false;
    }

    /// Advance one core's aging to `now` under its current configuration —
    /// the same multiply-add as the batch loop, on the slow (edge) paths.
    #[inline]
    fn advance_one(&mut self, i: usize, now: f64) {
        debug_assert!(
            now >= self.last_update[i] - 1e-9,
            "time went backwards: {} < {}",
            now,
            self.last_update[i]
        );
        let tau = (now - self.last_update[i]).max(0.0);
        if tau == 0.0 {
            return;
        }
        self.eq_time_s[i] += tau * self.eq_rate[i];
        self.busy_time[i] += tau * self.busy_m[i];
        self.active_time[i] += tau * self.active_m[i];
        self.c6_time[i] += tau * (1.0 - self.active_m[i]);
        self.last_update[i] = now;
    }

    /// Pin `task` to `core_idx`.
    pub fn assign(&mut self, core_idx: usize, task: u64, now: f64) {
        debug_assert!(self.task[core_idx].is_none(), "core {core_idx} already allocated");
        debug_assert_eq!(self.state[core_idx], CState::C0, "cannot assign to a deep-idle core");
        self.advance_one(core_idx, now);
        // Close out the idle period that ends now.
        self.idle_hist[core_idx].push((now - self.idle_since[core_idx]).max(0.0));
        self.task[core_idx] = Some(task);
        self.eq_rate[core_idx] = 1.0;
        self.busy_m[core_idx] = 1.0;
        self.task_core.insert(task, core_idx);
        self.dirty = true;
    }

    /// Record `task` as oversubscribed (no dedicated core available).
    pub fn push_oversub(&mut self, task: u64) {
        self.oversub.push_back(task);
        self.dirty = true;
    }

    /// Re-queue `task` at the *front* of the oversubscription queue.
    /// Used by the core-failure eviction path: a task evicted from a
    /// dedicated core arrived (and was promoted) before every task still
    /// queued behind it, so re-inserting at the front preserves the
    /// global arrival order the FIFO promotion contract pins.
    pub fn push_oversub_front(&mut self, task: u64) {
        self.oversub.push_front(task);
        self.dirty = true;
    }

    /// Permanently fail a core: its pinned task (if any) is evicted and
    /// returned, the core is forced into C6, and its aging freezes. The
    /// core never re-enters the working set — `set_state` refuses to wake
    /// it — so every policy's allocation candidates exclude it from now
    /// on. Panics if the core already failed (callers gate on
    /// [`CpuPackage::is_failed`]).
    pub fn fail_core(&mut self, core_idx: usize, now: f64) -> Option<u64> {
        assert!(!self.failed[core_idx], "core {core_idx} already failed");
        self.advance_one(core_idx, now);
        let evicted = self.task[core_idx].take();
        if let Some(task) = evicted {
            self.task_core.remove(&task);
            self.busy_m[core_idx] = 0.0;
            self.idle_since[core_idx] = now;
        }
        if self.state[core_idx] == CState::C0 {
            self.state[core_idx] = CState::C6;
            self.active_cnt -= 1;
            self.active_m[core_idx] = 0.0;
        }
        self.eq_rate[core_idx] = 0.0;
        self.failed[core_idx] = true;
        self.failed_cnt += 1;
        self.dirty = true;
        evicted
    }

    /// Finish a task wherever it runs. Returns the freed core index when
    /// the task had a dedicated core.
    pub fn finish_task(&mut self, task: u64, now: f64) -> Option<usize> {
        if let Some(core_idx) = self.task_core.remove(&task) {
            self.advance_one(core_idx, now);
            self.idle_since[core_idx] = now;
            self.task[core_idx] = None;
            // Freed cores stay C0 (unallocated operating point).
            self.eq_rate[core_idx] = self.ops.rate_unalloc;
            self.busy_m[core_idx] = 0.0;
            self.dirty = true;
            Some(core_idx)
        } else if let Some(pos) = self.oversub.iter().position(|&t| t == task) {
            // Order-preserving removal: the queue is promoted strictly
            // FIFO, so a mid-queue finish must not reorder later arrivals
            // (`swap_remove_back` did, moving the newest task forward).
            self.oversub.remove(pos);
            self.dirty = true;
            None
        } else {
            panic!("finish_task: unknown task {task}");
        }
    }

    /// Which core runs `task`, if it has a dedicated one.
    pub fn task_core_of(&self, task: u64) -> Option<usize> {
        self.task_core.get(&task).copied()
    }

    /// Pop one pending oversubscribed task (FIFO), if any — O(1).
    pub fn pop_oversub(&mut self) -> Option<u64> {
        let t = self.oversub.pop_front();
        if t.is_some() {
            self.dirty = true;
        }
        t
    }

    /// Switch a core's C-state. A no-op for permanently failed cores:
    /// they are pinned in C6 and can never be woken.
    pub fn set_state(&mut self, core_idx: usize, state: CState, now: f64) {
        if self.failed[core_idx] || state == self.state[core_idx] {
            return;
        }
        debug_assert!(
            !(state == CState::C6 && self.task[core_idx].is_some()),
            "cannot deep-idle allocated core {core_idx}"
        );
        self.advance_one(core_idx, now);
        self.state[core_idx] = state;
        match state {
            CState::C0 => {
                self.active_cnt += 1;
                self.active_m[core_idx] = 1.0;
                self.eq_rate[core_idx] = if self.task[core_idx].is_some() {
                    1.0
                } else {
                    self.ops.rate_unalloc
                };
            }
            CState::C6 => {
                self.active_cnt -= 1;
                self.active_m[core_idx] = 0.0;
                self.eq_rate[core_idx] = 0.0;
            }
        }
        self.dirty = true;
    }

    /// Advance aging of every core to `now` (metrics snapshots; also the
    /// paper's periodic "accurate frequency from aging sensors" moment).
    ///
    /// One branchless multiply-add pass over the hot SoA slices (module
    /// docs) — the compiler can vectorize it, and it is bitwise-identical
    /// to advancing each core individually at its operating point.
    pub fn advance_all(&mut self, now: f64) {
        let CpuPackage {
            eq_time_s,
            eq_rate,
            last_update,
            active_m,
            busy_m,
            busy_time,
            active_time,
            c6_time,
            ..
        } = self;
        for i in 0..eq_time_s.len() {
            debug_assert!(
                now >= last_update[i] - 1e-9,
                "time went backwards: {now} < {}",
                last_update[i]
            );
            let tau = (now - last_update[i]).max(0.0);
            eq_time_s[i] += tau * eq_rate[i];
            busy_time[i] += tau * busy_m[i];
            active_time[i] += tau * active_m[i];
            c6_time[i] += tau * (1.0 - active_m[i]);
            last_update[i] = now;
        }
    }

    /// Per-core frequencies (GHz) as of `now`.
    pub fn frequencies(&mut self, now: f64) -> Vec<f64> {
        self.advance_all(now);
        let ops = self.ops;
        self.f0_ghz.iter().zip(&self.eq_time_s).map(|(&f0, &eq)| ops.freq_ghz(f0, eq)).collect()
    }

    /// Per-core absolute frequency reductions (GHz) as of `now`.
    pub fn freq_reductions(&mut self, now: f64) -> Vec<f64> {
        self.advance_all(now);
        let ops = self.ops;
        self.f0_ghz
            .iter()
            .zip(&self.eq_time_s)
            .map(|(&f0, &eq)| f0 - ops.freq_ghz(f0, eq))
            .collect()
    }

    /// Relative execution-time dilation for a task on `core_idx`:
    /// `f_nominal / f_core` (≥ ~1 once aged). The simulator stretches CPU
    /// task durations by this factor (§5: "execution time ... adjusted
    /// according to the operating frequency").
    pub fn slowdown(&self, core_idx: usize) -> f64 {
        let f = self.ops.freq_ghz(self.f0_ghz[core_idx], self.eq_time_s[core_idx]);
        if f <= 0.0 {
            f64::INFINITY
        } else {
            self.ops.f_nominal_ghz / f
        }
    }

    /// Normalized idle cores — the Fig. 8 x-axis:
    /// `(active − running_tasks) / N_usable`. Positive = underutilization,
    /// negative = oversubscription. The denominator is the *usable* core
    /// count (total minus permanently failed), so the metric keeps its
    /// [−1, 1] range on a degraded package; with no failures it is the
    /// historical `/ n_cores()` exactly.
    pub fn normalized_idle(&self) -> f64 {
        (self.active_count() as f64 - self.running_tasks() as f64)
            / self.usable_cores().max(1) as f64
    }

    /// Normalized idle as seen by a task that is about to be placed
    /// (itself included in the running count).
    pub fn normalized_idle_for_extra_task(&self) -> f64 {
        (self.active_count() as f64 - (self.running_tasks() + 1) as f64)
            / self.usable_cores().max(1) as f64
    }

    /// Overwrite a core's canonical equivalent stress time — fixtures and
    /// state restoration (pairs with [`AgingOps::eq_of_dvth`]); not part
    /// of the simulation path.
    pub fn set_eq_time_s(&mut self, core_idx: usize, eq_time_s: f64) {
        self.eq_time_s[core_idx] = eq_time_s;
        self.dirty = true;
    }

    /// Overwrite a core's cumulative busy time (fixtures/tests only).
    pub fn set_busy_time(&mut self, core_idx: usize, busy_time: f64) {
        self.busy_time[core_idx] = busy_time;
        self.dirty = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pkg(n: usize) -> CpuPackage {
        CpuPackage::uniform(n, AgingParams::paper_default(), TemperatureModel::paper_default())
    }

    #[test]
    fn counts_track_assignments() {
        let mut p = pkg(4);
        assert_eq!(p.active_count(), 4);
        assert_eq!(p.allocated_count(), 0);
        p.assign(0, 100, 0.0);
        p.assign(2, 101, 0.0);
        assert_eq!(p.allocated_count(), 2);
        assert_eq!(p.running_tasks(), 2);
        assert_eq!(p.free_active_cores().count(), 2);
        let freed = p.finish_task(100, 1.0);
        assert_eq!(freed, Some(0));
        assert_eq!(p.allocated_count(), 1);
    }

    #[test]
    fn oversub_lifecycle() {
        let mut p = pkg(2);
        p.assign(0, 1, 0.0);
        p.assign(1, 2, 0.0);
        p.push_oversub(3);
        assert_eq!(p.running_tasks(), 3);
        assert!((p.normalized_idle() - (-0.5)).abs() < 1e-12);
        assert_eq!(p.finish_task(3, 1.0), None);
        assert_eq!(p.running_tasks(), 2);
    }

    #[test]
    fn pop_oversub_fifo() {
        let mut p = pkg(1);
        p.push_oversub(7);
        p.push_oversub(8);
        assert_eq!(p.pop_oversub(), Some(7));
        assert_eq!(p.pop_oversub(), Some(8));
        assert_eq!(p.pop_oversub(), None);
    }

    #[test]
    fn finish_mid_queue_preserves_fifo_order() {
        // Regression: `swap_remove_back` moved the newest arrival into the
        // removed slot, so [10, 11, 12, 13] minus 11 popped as 10, 13, 12.
        let mut p = pkg(1);
        p.assign(0, 1, 0.0);
        for t in [10, 11, 12, 13] {
            p.push_oversub(t);
        }
        assert_eq!(p.finish_task(11, 1.0), None);
        assert_eq!(p.pop_oversub(), Some(10));
        assert_eq!(p.pop_oversub(), Some(12));
        assert_eq!(p.pop_oversub(), Some(13));
        assert_eq!(p.pop_oversub(), None);
    }

    #[test]
    fn dirty_flag_tracks_mutations_not_advances() {
        let mut p = pkg(4);
        assert!(p.is_dirty(), "fresh package must start dirty");
        p.clear_dirty();
        p.advance_all(10.0);
        assert!(!p.is_dirty(), "pure time advance must not re-arm the tick");
        p.assign(0, 1, 10.0);
        assert!(p.is_dirty());
        p.clear_dirty();
        p.finish_task(1, 11.0);
        assert!(p.is_dirty());
        p.clear_dirty();
        p.set_state(2, CState::C6, 11.0);
        assert!(p.is_dirty());
        p.clear_dirty();
        p.set_state(2, CState::C6, 12.0); // already C6: no state change
        assert!(!p.is_dirty());
        p.push_oversub(9);
        assert!(p.is_dirty());
        p.clear_dirty();
        assert_eq!(p.pop_oversub(), Some(9));
        assert!(p.is_dirty());
    }

    #[test]
    fn batch_advance_matches_views() {
        // advance_all and the per-core edge advances must agree exactly.
        let mut p = pkg(3);
        p.assign(0, 1, 0.0);
        p.set_state(2, CState::C6, 0.0);
        p.advance_all(1000.0);
        let eq_alloc = p.core(0).eq_time_s();
        let eq_free = p.core(1).eq_time_s();
        assert_eq!(eq_alloc, 1000.0);
        assert_eq!(eq_free, 1000.0 * p.ops.rate_unalloc);
        assert_eq!(p.core(2).eq_time_s(), 0.0);
        assert_eq!(p.core(2).c6_time(), 1000.0);
        assert_eq!(p.core(0).busy_time(), 1000.0);
        assert_eq!(p.core(1).busy_time(), 0.0);
    }

    #[test]
    fn c6_removes_from_working_set() {
        let mut p = pkg(4);
        p.set_state(3, CState::C6, 0.0);
        assert_eq!(p.active_count(), 3);
        assert_eq!(p.c6_count(), 1);
        assert!((p.normalized_idle() - 0.75).abs() < 1e-12);
        p.set_state(3, CState::C0, 5.0);
        assert_eq!(p.active_count(), 4);
    }

    #[test]
    fn frequencies_degrade_over_time() {
        let mut p = pkg(2);
        p.assign(0, 1, 0.0);
        let fs = p.frequencies(36_000.0);
        // Allocated core 0 degraded more than free core 1.
        assert!(fs[0] < fs[1]);
        assert!(fs[1] < p.aging.f_nominal_ghz);
        let reds = p.freq_reductions(36_000.0);
        assert!(reds[0] > reds[1]);
    }

    #[test]
    fn slowdown_grows_with_age() {
        let mut p = pkg(1);
        assert!((p.slowdown(0) - 1.0).abs() < 1e-12);
        p.advance_all(864_000.0);
        assert!(p.slowdown(0) > 1.0);
    }

    #[test]
    #[should_panic(expected = "unknown task")]
    fn finishing_unknown_task_panics() {
        let mut p = pkg(1);
        p.finish_task(42, 0.0);
    }

    #[test]
    fn failed_core_is_evicted_gated_and_never_wakes() {
        let mut p = pkg(4);
        p.assign(1, 100, 0.0);
        assert_eq!(p.fail_core(1, 1.0), Some(100));
        assert!(p.is_failed(1));
        assert!(p.core(1).failed());
        assert_eq!(p.failed_count(), 1);
        assert_eq!(p.usable_cores(), 3);
        assert_eq!(p.core(1).state(), CState::C6);
        assert_eq!(p.core(1).task(), None);
        assert_eq!(p.active_count(), 3);
        assert_eq!(p.allocated_count(), 0, "evicted task left the pin map");
        // A failed core can never be woken back into the working set.
        p.set_state(1, CState::C0, 2.0);
        assert_eq!(p.core(1).state(), CState::C6);
        assert_eq!(p.active_count(), 3);
        assert!(p.free_active_cores().all(|c| c.id() != 1));
        // And its aging is frozen from the failure instant on.
        let eq_at_fail = p.core(1).eq_time_s();
        p.advance_all(1000.0);
        assert_eq!(p.core(1).eq_time_s(), eq_at_fail);
    }

    #[test]
    fn failing_an_idle_c6_core_keeps_counts_consistent() {
        let mut p = pkg(3);
        p.set_state(2, CState::C6, 0.0);
        assert_eq!(p.fail_core(2, 1.0), None);
        assert_eq!(p.active_count(), 2);
        assert_eq!(p.c6_count(), 1);
        assert_eq!(p.usable_cores(), 2);
        // Denominators follow the usable count, not the physical one.
        assert!((p.normalized_idle() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn push_oversub_front_heads_the_queue() {
        let mut p = pkg(1);
        p.push_oversub(10);
        p.push_oversub(11);
        p.push_oversub_front(9);
        assert_eq!(p.pop_oversub(), Some(9));
        assert_eq!(p.pop_oversub(), Some(10));
        assert_eq!(p.pop_oversub(), Some(11));
    }

    #[test]
    #[should_panic(expected = "already failed")]
    fn double_failure_panics() {
        let mut p = pkg(2);
        p.fail_core(0, 0.0);
        p.fail_core(0, 1.0);
    }
}
