//! Manufacturing process-variation model for initial core frequencies —
//! §3.2 of the paper, following Raghunathan'13 ("Cherry-picking").
//!
//! The chip is an `N_chip × N_chip` grid; each cell `kl` carries a
//! Gaussian random variable `p_kl` with spatial correlation
//! `ρ_ij,kl = exp(−α·sqrt((i−k)² + (j−l)²))`. Critical paths live inside
//! cells, and a core's initial frequency is
//! `f0 = K' · min_{kl ∈ core cells}(1 / p_kl)`.
//!
//! The mean of `p` is solved such that a variation-free chip
//! (`p ≡ mean`) yields exactly the nominal frequency: `mean = K'/f_nom`
//! (the paper's normalization). Correlated samples are drawn via a
//! Cholesky factor of the grid covariance, computed once and reused for
//! every chip in the cluster.

use crate::util::linalg::Matrix;
use crate::util::rng::Rng;

/// Parameters of the process-variation model.
#[derive(Clone, Copy, Debug)]
pub struct ProcVarParams {
    /// Grid dimension N_chip (paper: 10).
    pub n_chip: usize,
    /// Spatial correlation decay rate α (paper: set per Raghunathan'13).
    pub alpha: f64,
    /// Relative standard deviation of `p` (σ/μ).
    pub sigma_rel: f64,
    /// Technology constant K' (paper: 1).
    pub k_prime: f64,
    /// Nominal frequency (GHz) of a variation-free core.
    pub f_nominal_ghz: f64,
}

impl ProcVarParams {
    pub fn paper_default() -> ProcVarParams {
        ProcVarParams {
            n_chip: 10,
            alpha: 0.5,
            sigma_rel: 0.04,
            k_prime: 1.0,
            f_nominal_ghz: 2.6,
        }
    }

    /// Process-variation preset for a named hardware generation — the
    /// vocabulary the fleet config's `generation` key accepts.
    ///
    /// `"paper"`/`"gen1"` is the paper's process node exactly. `"gen2"`
    /// and `"gen3"` are hypothetical successor nodes for heterogeneity
    /// studies: tighter variation (smaller `sigma_rel`) and a higher
    /// nominal frequency, the usual trajectory of a process shrink.
    pub fn for_generation(name: &str) -> Result<ProcVarParams, String> {
        match name {
            "paper" | "gen1" => Ok(ProcVarParams::paper_default()),
            "gen2" => Ok(ProcVarParams {
                sigma_rel: 0.03,
                f_nominal_ghz: 2.8,
                ..ProcVarParams::paper_default()
            }),
            "gen3" => Ok(ProcVarParams {
                sigma_rel: 0.025,
                f_nominal_ghz: 3.0,
                ..ProcVarParams::paper_default()
            }),
            other => Err(format!(
                "unknown process generation '{other}' (known: paper, gen1, gen2, gen3)"
            )),
        }
    }
}

/// Sampler producing per-core initial frequencies for whole chips.
pub struct ProcVarSampler {
    pub params: ProcVarParams,
    /// Cholesky factor of the grid covariance (n_chip² × n_chip²).
    chol: Matrix,
    mean_p: f64,
}

impl ProcVarSampler {
    pub fn new(params: ProcVarParams) -> ProcVarSampler {
        let n = params.n_chip * params.n_chip;
        let mean_p = params.k_prime / params.f_nominal_ghz;
        let sigma = params.sigma_rel * mean_p;
        let mut cov = Matrix::zeros(n);
        for a in 0..n {
            let (i, j) = (a / params.n_chip, a % params.n_chip);
            for b in 0..n {
                let (k, l) = (b / params.n_chip, b % params.n_chip);
                let d = (((i as f64 - k as f64).powi(2)) + ((j as f64 - l as f64).powi(2))).sqrt();
                let rho = (-params.alpha * d).exp();
                cov.set(a, b, sigma * sigma * rho);
            }
        }
        let chol = cov.cholesky().expect("grid covariance must be SPD");
        ProcVarSampler { params, chol, mean_p }
    }

    /// Draw the correlated grid variables `p_kl` for one chip.
    pub fn sample_grid(&self, rng: &mut Rng) -> Vec<f64> {
        let n = self.params.n_chip * self.params.n_chip;
        let z: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let corr = self.chol.lower_matvec(&z);
        corr.iter()
            .map(|&c| {
                let p = self.mean_p + c;
                // Physical guard: p is a path-delay proxy, strictly positive.
                p.max(self.mean_p * 0.5)
            })
            .collect()
    }

    /// Sample initial frequencies (GHz) for a chip with `n_cores` cores.
    ///
    /// Grid cells are assigned to cores in contiguous runs (cores are
    /// physically contiguous regions); each core's f0 is `K'·min(1/p)`
    /// over its cells, i.e. its slowest critical path.
    pub fn sample_chip(&self, rng: &mut Rng, n_cores: usize) -> Vec<f64> {
        assert!(n_cores > 0);
        let grid = self.sample_grid(rng);
        let n_cells = grid.len();
        let cells_per_core = (n_cells / n_cores).max(1);
        (0..n_cores)
            .map(|c| {
                let start = (c * cells_per_core) % n_cells;
                let mut worst_p: f64 = 0.0;
                for off in 0..cells_per_core {
                    let p = grid[(start + off) % n_cells];
                    if p > worst_p {
                        worst_p = p;
                    }
                }
                self.params.k_prime / worst_p
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn sampler() -> ProcVarSampler {
        ProcVarSampler::new(ProcVarParams::paper_default())
    }

    #[test]
    fn variation_free_chip_is_nominal() {
        // Directly check the normalization: p == mean ⇒ f0 == nominal.
        let s = sampler();
        let f0 = s.params.k_prime / s.mean_p;
        assert!((f0 - s.params.f_nominal_ghz).abs() < 1e-12);
    }

    #[test]
    fn frequencies_near_nominal() {
        let s = sampler();
        let mut rng = Rng::new(42);
        let f0 = s.sample_chip(&mut rng, 40);
        assert_eq!(f0.len(), 40);
        for &f in &f0 {
            assert!(f > 1.8 && f < 3.4, "f0={f} out of plausible band");
        }
        // min-of-cells biases f0 slightly below nominal on average.
        let m = stats::mean(&f0);
        assert!(m < s.params.f_nominal_ghz * 1.02);
        assert!(m > s.params.f_nominal_ghz * 0.85);
    }

    #[test]
    fn deterministic_given_seed() {
        let s = sampler();
        let a = s.sample_chip(&mut Rng::new(7), 80);
        let b = s.sample_chip(&mut Rng::new(7), 80);
        assert_eq!(a, b);
    }

    #[test]
    fn chips_differ_across_draws() {
        let s = sampler();
        let mut rng = Rng::new(7);
        let a = s.sample_chip(&mut rng, 40);
        let b = s.sample_chip(&mut rng, 40);
        assert_ne!(a, b);
    }

    #[test]
    fn cv_scales_with_sigma() {
        let mut lo = ProcVarParams::paper_default();
        lo.sigma_rel = 0.01;
        let mut hi = ProcVarParams::paper_default();
        hi.sigma_rel = 0.08;
        let (s_lo, s_hi) = (ProcVarSampler::new(lo), ProcVarSampler::new(hi));
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        // Average CV over several chips.
        let cv = |s: &ProcVarSampler, r: &mut Rng| -> f64 {
            let cvs: Vec<f64> =
                (0..20).map(|_| stats::coeff_of_variation(&s.sample_chip(r, 40))).collect();
            stats::mean(&cvs)
        };
        assert!(cv(&s_hi, &mut r2) > 2.0 * cv(&s_lo, &mut r1));
    }

    #[test]
    fn generation_presets_resolve_and_reject() {
        let paper = ProcVarParams::for_generation("paper").unwrap();
        assert!((paper.f_nominal_ghz - 2.6).abs() < 1e-12);
        let gen3 = ProcVarParams::for_generation("gen3").unwrap();
        assert!(gen3.sigma_rel < paper.sigma_rel);
        assert!(gen3.f_nominal_ghz > paper.f_nominal_ghz);
        let err = ProcVarParams::for_generation("90nm").unwrap_err();
        assert!(err.contains("90nm"), "error names the bad generation: {err}");
    }

    #[test]
    fn neighbor_cells_more_correlated_than_distant() {
        let s = sampler();
        let mut rng = Rng::new(9);
        let n = 4000;
        let mut near = (0.0, 0.0, 0.0, 0.0, 0.0); // sums for corr(cell0, cell1)
        let mut far = (0.0, 0.0, 0.0, 0.0, 0.0); // sums for corr(cell0, cell99)
        for _ in 0..n {
            let g = s.sample_grid(&mut rng);
            let (a, b, c) = (g[0], g[1], g[99]);
            near = (near.0 + a, near.1 + b, near.2 + a * b, near.3 + a * a, near.4 + b * b);
            far = (far.0 + a, far.1 + c, far.2 + a * c, far.3 + a * a, far.4 + c * c);
        }
        let corr = |(sx, sy, sxy, sxx, syy): (f64, f64, f64, f64, f64)| {
            let nf = n as f64;
            let cov = sxy / nf - (sx / nf) * (sy / nf);
            let vx = sxx / nf - (sx / nf).powi(2);
            let vy = syy / nf - (sy / nf).powi(2);
            cov / (vx * vy).sqrt()
        };
        assert!(corr(near) > corr(far) + 0.2, "near={} far={}", corr(near), corr(far));
    }
}
