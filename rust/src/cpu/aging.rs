//! NBTI (Negative Bias Temperature Instability) aging model — §3.2 of the
//! paper.
//!
//! Frequency model:            `f(t) = f0 · (1 − ΔVth / (Vdd − Vth))`
//! Per-interval ΔVth recursion (reaction–diffusion, Moghaddasi'19):
//!     `ΔVth(t_p) = ADF_p · [ (ΔVth(t_{p−1}) / ADF_p)^{1/n} + τ_p ]^n`
//! Aging-and-Duty factor:
//!     `ADF(T, Vdd, Y) = K · exp(−E0/(kB·T)) · exp(B·Vdd/(tox·kB·T)) · Y^n`
//!
//! `K` is calibrated in closed form against the 22 nm datum used by the
//! paper (Ansari'23): 10 years of continuous worst-case stress at the
//! allocated-core temperature (54 °C) produce a 30 % frequency reduction.
//! Under constant stress the recursion collapses to `ΔVth = ADF · t^n`, so
//! `K = 0.3·(Vdd−Vth) / (exp-terms · (10 yr)^n)`.
//!
//! Deep idle (C6) clock- and power-gates the core: no transistor switching
//! stress, so an interval spent in C6 contributes **zero** stress time and
//! ΔVth is frozen (the paper's age-halting premise).
//!
//! # The equivalent-stress-time invariant (§Perf)
//!
//! The recursion above costs two `exp` + three `powf` per evaluation if
//! applied literally on every core event. Instead, per-core aging state
//! is kept as **canonical equivalent stress time** (`Core::eq_time_s` in
//! [`super::core`]): the length of continuous worst-case (C0, allocated,
//! Y = 1) stress that would produce the core's current ΔVth, i.e.
//! `ΔVth = ADF_alloc · eq_time^n`. A core only ever occupies one of three
//! operating points — (C0, allocated), (C0, unallocated), or C6 — and
//! substituting the invariant into the recursion shows that `τ`
//! wall-seconds at a point with factor `ADF_p` advance canonical time by
//! `τ · (ADF_p / ADF_alloc)^{1/n}`, a **constant rate** precomputed once
//! per configuration by [`AgingOps`]. The per-event advance is therefore
//! a single multiply-add with zero transcendentals; C6 advances nothing;
//! ΔVth and frequency are lazy snapshots costing one `powf` only when
//! metrics are read ([`AgingOps::dvth_of_eq`], [`AgingOps::freq_ghz`]).
//! `eq_time_s` is monotone in ΔVth, so policies compare core ages on it
//! directly. The fast path is pinned against the retained closed-form
//! reference [`AgingParams::dvth_step`] to 1e-12 relative error by
//! `tests/aging_parity.rs`.

/// Boltzmann constant in eV/K.
pub const K_B_EV: f64 = 8.617_333e-5;
/// Seconds per (365-day) year.
pub const SECONDS_PER_YEAR: f64 = 365.0 * 24.0 * 3600.0;

/// Physical parameters of the NBTI model (22 nm technology node).
#[derive(Clone, Copy, Debug)]
pub struct AgingParams {
    /// Supply voltage (V).
    pub vdd: f64,
    /// Nominal threshold voltage (V).
    pub vth: f64,
    /// Time exponent `n` of the reaction–diffusion model (≈ 1/6).
    pub n: f64,
    /// Activation energy E0 (eV).
    pub e0_ev: f64,
    /// Field-acceleration term `B·Vdd/tox`, folded into eV units.
    pub beta_ev: f64,
    /// Fitting constant K, calibrated by [`AgingParams::paper_default`].
    pub k: f64,
    /// Stress `Y` of an *unallocated but active* (C0) core: the OS
    /// time-shares light system tasks onto it (§2.2), so it keeps aging,
    /// but below the worst-case Y = 1 an allocated inference task incurs.
    pub unallocated_stress: f64,
    /// Nominal (pre-variation, pre-aging) core frequency in GHz.
    pub f_nominal_ghz: f64,
    /// Calibration lifetime (seconds of continuous stress).
    pub calib_lifetime_s: f64,
    /// Frequency reduction fraction reached at `calib_lifetime_s`.
    pub calib_reduction: f64,
    /// Temperature (K) at which the calibration datum holds.
    pub calib_temp_k: f64,
}

impl AgingParams {
    /// The paper's configuration: 22 nm node, K fitted so that 10 years of
    /// continuous allocated-state stress (54 °C, Y = 1) costs 30 % of f0.
    pub fn paper_default() -> AgingParams {
        let mut p = AgingParams {
            vdd: 1.0,
            vth: 0.3,
            n: 1.0 / 6.0,
            e0_ev: 0.1897,
            beta_ev: 0.075,
            k: 0.0,
            // Calibrated so the cluster-level embodied-carbon reduction
            // lands in the paper's reported band (§6.2, EXPERIMENTS.md).
            unallocated_stress: 0.3,
            f_nominal_ghz: 2.6,
            calib_lifetime_s: 10.0 * SECONDS_PER_YEAR,
            calib_reduction: 0.30,
            calib_temp_k: celsius(54.0),
        };
        p.k = p.solve_k();
        p
    }

    /// Closed-form calibration of K (see module docs).
    fn solve_k(&self) -> f64 {
        let target_dvth = self.calib_reduction * (self.vdd - self.vth);
        let exp_terms = (-self.e0_ev / (K_B_EV * self.calib_temp_k)).exp()
            * (self.beta_ev / (K_B_EV * self.calib_temp_k)).exp();
        target_dvth / (exp_terms * self.calib_lifetime_s.powf(self.n))
    }

    /// ADF(T, Y): the time-independent aging factor for an interval at
    /// temperature `temp_k` under stress `y` ∈ (0, 1].
    #[inline]
    pub fn adf(&self, temp_k: f64, y: f64) -> f64 {
        debug_assert!(temp_k > 0.0 && y > 0.0);
        self.k
            * (-self.e0_ev / (K_B_EV * temp_k)).exp()
            * (self.beta_ev / (K_B_EV * temp_k)).exp()
            * y.powf(self.n)
    }

    /// One recursion step: ΔVth after an interval of `tau_s` seconds at a
    /// given ADF, starting from `dvth_prev`.
    #[inline]
    pub fn dvth_step(&self, dvth_prev: f64, adf: f64, tau_s: f64) -> f64 {
        debug_assert!(tau_s >= 0.0);
        if tau_s == 0.0 {
            return dvth_prev;
        }
        let eq_time = if dvth_prev <= 0.0 {
            0.0
        } else {
            (dvth_prev / adf).powf(1.0 / self.n)
        };
        adf * (eq_time + tau_s).powf(self.n)
    }

    /// Frequency (GHz) of a core with initial frequency `f0_ghz` and
    /// accumulated threshold shift `dvth`.
    #[inline]
    pub fn freq_ghz(&self, f0_ghz: f64, dvth: f64) -> f64 {
        f0_ghz * (1.0 - dvth / (self.vdd - self.vth))
    }

    /// Relative frequency reduction caused by `dvth` (unitless, 0..1).
    #[inline]
    pub fn rel_reduction(&self, dvth: f64) -> f64 {
        dvth / (self.vdd - self.vth)
    }
}

/// Convert Celsius to Kelvin.
#[inline]
pub fn celsius(c: f64) -> f64 {
    c + 273.15
}

/// Transcendental-free precomputation of [`AgingParams`] over the discrete
/// set of operating points the simulator visits (§Perf).
///
/// A core only ever sits at one of three operating points: (C0, allocated,
/// Y = 1), (C0, unallocated, Y = unallocated_stress), or C6 (age-halted).
/// The ADF of each C0 point is a constant of the configuration, so the two
/// `exp()` + two `powf()` of [`AgingParams::adf`] are paid once per
/// package instead of on every [`super::core::Core::advance`].
///
/// **Equivalent-stress-time invariant.** Per-core aging is stated in the
/// *canonical* domain of the (C0, allocated) point: `eq_time_s` is the
/// length of continuous worst-case stress that produces the core's current
/// ΔVth, i.e. `ΔVth = ADF_alloc · eq_time^n`. Substituting into the
/// reaction–diffusion recursion shows an interval of `τ` wall-seconds at
/// an operating point with factor `ADF_p` advances the canonical time by
/// `τ · (ADF_p / ADF_alloc)^{1/n}` — a constant rate per operating point.
/// The hot-path advance is therefore one multiply-add; C6 intervals add
/// nothing (age halting); ΔVth and frequency are derived lazily, with a
/// single `powf`, only when metrics are read.
#[derive(Clone, Copy, Debug)]
pub struct AgingOps {
    /// ADF at the canonical (C0, allocated, Y = 1) operating point.
    pub adf_alloc: f64,
    /// ADF at (C0, unallocated, Y = unallocated_stress).
    pub adf_unalloc: f64,
    /// Equivalent-stress-time accrual rate of the unallocated point, in
    /// canonical seconds per wall-clock second:
    /// `(ADF_unalloc / ADF_alloc)^{1/n}` (< 1).
    pub rate_unalloc: f64,
    /// Time exponent `n` of the reaction–diffusion model.
    pub n: f64,
    /// `1 / (Vdd − Vth)`.
    inv_headroom: f64,
    /// Nominal (pre-variation) frequency in GHz, for slowdown factors.
    pub f_nominal_ghz: f64,
}

impl AgingOps {
    pub fn new(p: &AgingParams, temps: &super::temperature::TemperatureModel) -> AgingOps {
        use super::core::CState;
        let adf_alloc = p.adf(temps.steady_k(CState::C0, true), 1.0);
        let adf_unalloc = p.adf(temps.steady_k(CState::C0, false), p.unallocated_stress);
        AgingOps {
            adf_alloc,
            adf_unalloc,
            rate_unalloc: (adf_unalloc / adf_alloc).powf(1.0 / p.n),
            n: p.n,
            inv_headroom: 1.0 / (p.vdd - p.vth),
            f_nominal_ghz: p.f_nominal_ghz,
        }
    }

    /// Canonical equivalent-stress-time accrued by one wall-clock second
    /// in C0 under the given allocation status.
    #[inline]
    pub fn eq_rate(&self, allocated: bool) -> f64 {
        if allocated {
            1.0
        } else {
            self.rate_unalloc
        }
    }

    /// ΔVth (V) of a core with canonical equivalent stress time
    /// `eq_time_s` — the lazy snapshot read (one `powf`).
    #[inline]
    pub fn dvth_of_eq(&self, eq_time_s: f64) -> f64 {
        if eq_time_s <= 0.0 {
            0.0
        } else {
            self.adf_alloc * eq_time_s.powf(self.n)
        }
    }

    /// Inverse of [`AgingOps::dvth_of_eq`] (fixtures, state restoration).
    #[inline]
    pub fn eq_of_dvth(&self, dvth: f64) -> f64 {
        if dvth <= 0.0 {
            0.0
        } else {
            (dvth / self.adf_alloc).powf(1.0 / self.n)
        }
    }

    /// Frequency (GHz): `f0 · (1 − ΔVth / (Vdd − Vth))`.
    #[inline]
    pub fn freq_ghz(&self, f0_ghz: f64, eq_time_s: f64) -> f64 {
        f0_ghz * (1.0 - self.dvth_of_eq(eq_time_s) * self.inv_headroom)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_hits_thirty_percent_at_ten_years() {
        let p = AgingParams::paper_default();
        let adf = p.adf(p.calib_temp_k, 1.0);
        let dvth = p.dvth_step(0.0, adf, p.calib_lifetime_s);
        let red = p.rel_reduction(dvth);
        assert!((red - 0.30).abs() < 1e-9, "reduction={red}");
    }

    #[test]
    fn recursion_composes_like_closed_form() {
        // Splitting a constant-ADF interval must equal the single step.
        let p = AgingParams::paper_default();
        let adf = p.adf(celsius(54.0), 1.0);
        let total = 1_000_000.0;
        let one = p.dvth_step(0.0, adf, total);
        let mut acc = 0.0;
        for _ in 0..10 {
            acc = p.dvth_step(acc, adf, total / 10.0);
        }
        assert!((one - acc).abs() / one < 1e-9);
    }

    #[test]
    fn hotter_ages_faster() {
        let p = AgingParams::paper_default();
        assert!(p.adf(celsius(54.0), 1.0) > p.adf(celsius(48.0), 1.0));
        assert!(p.adf(celsius(51.08), 1.0) > p.adf(celsius(48.0), 1.0));
    }

    #[test]
    fn lower_stress_ages_slower() {
        let p = AgingParams::paper_default();
        assert!(p.adf(celsius(54.0), 0.5) < p.adf(celsius(54.0), 1.0));
    }

    #[test]
    fn zero_interval_is_identity() {
        let p = AgingParams::paper_default();
        let adf = p.adf(celsius(54.0), 1.0);
        let d = p.dvth_step(0.0123, adf, 0.0);
        assert_eq!(d, 0.0123);
    }

    #[test]
    fn dvth_monotone_in_time() {
        let p = AgingParams::paper_default();
        let adf = p.adf(celsius(54.0), 1.0);
        let mut prev = 0.0;
        for step in 1..50 {
            let d = p.dvth_step(0.0, adf, step as f64 * 3600.0);
            assert!(d > prev);
            prev = d;
        }
    }

    #[test]
    fn sublinear_time_law() {
        // ΔVth ∝ t^(1/6): doubling time multiplies ΔVth by 2^(1/6).
        let p = AgingParams::paper_default();
        let adf = p.adf(celsius(54.0), 1.0);
        let d1 = p.dvth_step(0.0, adf, 1e6);
        let d2 = p.dvth_step(0.0, adf, 2e6);
        assert!((d2 / d1 - 2f64.powf(1.0 / 6.0)).abs() < 1e-9);
    }

    #[test]
    fn freq_degrades_from_f0() {
        let p = AgingParams::paper_default();
        let f = p.freq_ghz(2.6, 0.07);
        assert!((f - 2.6 * (1.0 - 0.1)).abs() < 1e-12);
    }

    #[test]
    fn ops_match_params_adf_at_both_operating_points() {
        let p = AgingParams::paper_default();
        let t = crate::cpu::TemperatureModel::paper_default();
        let ops = AgingOps::new(&p, &t);
        assert_eq!(ops.adf_alloc, p.adf(celsius(54.0), 1.0));
        assert_eq!(ops.adf_unalloc, p.adf(celsius(51.08), p.unallocated_stress));
        assert!(ops.rate_unalloc > 0.0 && ops.rate_unalloc < 1.0);
        assert_eq!(ops.eq_rate(true), 1.0);
        assert_eq!(ops.eq_rate(false), ops.rate_unalloc);
    }

    #[test]
    fn eq_time_accrual_equals_closed_form_step() {
        // τ wall-seconds at the unallocated point must advance dvth exactly
        // like one dvth_step at ADF_unalloc.
        let p = AgingParams::paper_default();
        let t = crate::cpu::TemperatureModel::paper_default();
        let ops = AgingOps::new(&p, &t);
        let tau = 123_456.0;
        let reference = p.dvth_step(0.0, ops.adf_unalloc, tau);
        let fast = ops.dvth_of_eq(tau * ops.rate_unalloc);
        assert!((fast - reference).abs() / reference < 1e-13, "{fast} vs {reference}");
        // And switching points composes: τ allocated then τ unallocated.
        let ref2 = p.dvth_step(p.dvth_step(0.0, ops.adf_alloc, tau), ops.adf_unalloc, tau);
        let fast2 = ops.dvth_of_eq(tau + tau * ops.rate_unalloc);
        assert!((fast2 - ref2).abs() / ref2 < 1e-13, "{fast2} vs {ref2}");
    }

    #[test]
    fn eq_of_dvth_inverts_dvth_of_eq() {
        let p = AgingParams::paper_default();
        let t = crate::cpu::TemperatureModel::paper_default();
        let ops = AgingOps::new(&p, &t);
        for eq in [0.0, 1.0, 3.6e3, 1e7, 3e8] {
            let rt = ops.eq_of_dvth(ops.dvth_of_eq(eq));
            assert!((rt - eq).abs() <= 1e-9 * eq.max(1.0), "{rt} vs {eq}");
        }
    }

    #[test]
    fn ops_freq_matches_params_freq() {
        let p = AgingParams::paper_default();
        let t = crate::cpu::TemperatureModel::paper_default();
        let ops = AgingOps::new(&p, &t);
        let eq = 5e7;
        let f_fast = ops.freq_ghz(2.6, eq);
        let f_ref = p.freq_ghz(2.6, ops.dvth_of_eq(eq));
        assert!((f_fast - f_ref).abs() < 1e-12);
    }

    #[test]
    fn age_halting_intervals_freeze_dvth() {
        // A C6 interval contributes no stress: simulate by simply not
        // stepping. Verify a 50%-halted schedule ends with less ΔVth than
        // an always-on schedule of the same wall-clock length.
        let p = AgingParams::paper_default();
        let adf = p.adf(celsius(54.0), 1.0);
        let on = p.dvth_step(0.0, adf, 2e6);
        let mut halted = 0.0;
        // 2e6 of wall clock, half of it frozen.
        halted = p.dvth_step(halted, adf, 0.5e6);
        // frozen 0.5e6 (no step)
        halted = p.dvth_step(halted, adf, 0.5e6);
        // frozen 0.5e6 (no step)
        assert!(halted < on);
        // And equals the compressed-time closed form.
        let compressed = p.dvth_step(0.0, adf, 1e6);
        assert!((halted - compressed).abs() / compressed < 1e-9);
    }
}
