//! Configuration system: JSON config files for cluster simulations and
//! experiment sweeps, with defaults, validation, and round-tripping.
//!
//! Every CLI entry point accepts `--config <file.json>`; flags override
//! file values, which override the paper defaults. Sweep grids are
//! declarative too: `carbon-sim sweep --spec <file.json>` loads a full
//! [`SweepSpec`] via [`sweep_from_file`] (examples under
//! `examples/specs/`). All parsers reject unknown keys (typo
//! protection), and every validation error names the offending key.

use std::path::Path;

use crate::cluster::{
    ClusterConfig, CoreFailure, FleetConfig, LifecycleConfig, MachineGroup, MaintenanceWindow,
};
use crate::cpu::{AgingParams, ProcVarParams};
use crate::experiments::search::SearchConfig;
use crate::experiments::sweep::SweepSpec;
use crate::experiments::Scale;
use crate::model::PerfModel;
use crate::trace::azure::Workload;
use crate::util::json::{parse, Value};

/// Load a [`ClusterConfig`] from a JSON file. Unknown keys are rejected
/// (typo protection); missing keys keep the paper defaults.
pub fn cluster_from_file(path: &Path) -> Result<ClusterConfig, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path:?}: {e}"))?;
    let v = parse(&text).map_err(|e| format!("{path:?}: {e}"))?;
    cluster_from_value(&v)
}

const CLUSTER_KEYS: &[&str] = &[
    "n_prompt",
    "n_token",
    "cores_per_cpu",
    "policy",
    "sample_period_s",
    "max_batch",
    "kv_capacity_tokens",
    "seed",
    "queue",
    "aging",
    "procvar",
    "perf",
    "fleet",
    "lifecycle",
];

/// Build a [`ClusterConfig`] from a parsed JSON object.
pub fn cluster_from_value(v: &Value) -> Result<ClusterConfig, String> {
    let obj = v.as_obj().ok_or("cluster config must be a JSON object")?;
    for key in obj.keys() {
        if !CLUSTER_KEYS.contains(&key.as_str()) {
            return Err(format!(
                "unknown cluster config key '{key}' (known: {CLUSTER_KEYS:?})"
            ));
        }
    }
    let mut cfg = ClusterConfig {
        n_prompt: v.usize_or("n_prompt", 5),
        n_token: v.usize_or("n_token", 17),
        cores_per_cpu: v.usize_or("cores_per_cpu", 40),
        policy: v.str_or("policy", "proposed").to_string(),
        sample_period_s: v.f64_or("sample_period_s", 0.1),
        max_batch: v.usize_or("max_batch", 64),
        kv_capacity_tokens: v.f64_or("kv_capacity_tokens", 400_000.0) as u64,
        seed: v.f64_or("seed", 42.0) as u64,
        ..ClusterConfig::default()
    };
    if let Some(q) = v.get("queue") {
        let s = q
            .as_str()
            .ok_or("cluster config key 'queue' must be the string \"calendar\" or \"heap\"")?;
        cfg.queue = crate::sim::QueueKind::parse(s)?;
    }
    if let Some(a) = v.get("aging") {
        cfg.aging = aging_from_value(a)?;
    }
    if let Some(p) = v.get("procvar") {
        cfg.procvar = procvar_from_value(p)?;
    }
    if let Some(p) = v.get("perf") {
        cfg.perf = perf_from_value(p)?;
    }
    if let Some(f) = v.get("fleet") {
        cfg.fleet = Some(fleet_from_value(f)?);
    }
    if let Some(l) = v.get("lifecycle") {
        cfg.lifecycle = Some(lifecycle_from_value(l)?);
    }
    validate_cluster(&cfg)?;
    Ok(cfg)
}

fn aging_from_value(v: &Value) -> Result<AgingParams, String> {
    let mut a = AgingParams::paper_default();
    a.vdd = v.f64_or("vdd", a.vdd);
    a.vth = v.f64_or("vth", a.vth);
    a.n = v.f64_or("n", a.n);
    a.e0_ev = v.f64_or("e0_ev", a.e0_ev);
    a.beta_ev = v.f64_or("beta_ev", a.beta_ev);
    a.unallocated_stress = v.f64_or("unallocated_stress", a.unallocated_stress);
    a.f_nominal_ghz = v.f64_or("f_nominal_ghz", a.f_nominal_ghz);
    // Re-derive K unless explicitly pinned.
    let mut recalib = AgingParams { k: 0.0, ..a };
    recalib.calib_lifetime_s = v.f64_or("calib_lifetime_s", a.calib_lifetime_s);
    recalib.calib_reduction = v.f64_or("calib_reduction", a.calib_reduction);
    recalib.k = {
        // Same closed form as paper_default.
        let target = recalib.calib_reduction * (recalib.vdd - recalib.vth);
        let kb_t = crate::cpu::aging::K_B_EV * recalib.calib_temp_k;
        let exp_terms = (-recalib.e0_ev / kb_t).exp() * (recalib.beta_ev / kb_t).exp();
        target / (exp_terms * recalib.calib_lifetime_s.powf(recalib.n))
    };
    if let Some(k) = v.get("k").and_then(Value::as_f64) {
        recalib.k = k;
    }
    if recalib.vdd <= recalib.vth {
        return Err("aging: vdd must exceed vth".into());
    }
    if !(0.0..=1.0).contains(&recalib.unallocated_stress) || recalib.unallocated_stress <= 0.0 {
        return Err("aging: unallocated_stress must be in (0, 1]".into());
    }
    Ok(recalib)
}

fn procvar_from_value(v: &Value) -> Result<ProcVarParams, String> {
    let mut p = ProcVarParams::paper_default();
    p.n_chip = v.usize_or("n_chip", p.n_chip);
    p.alpha = v.f64_or("alpha", p.alpha);
    p.sigma_rel = v.f64_or("sigma_rel", p.sigma_rel);
    p.k_prime = v.f64_or("k_prime", p.k_prime);
    p.f_nominal_ghz = v.f64_or("f_nominal_ghz", p.f_nominal_ghz);
    if p.n_chip == 0 || p.sigma_rel < 0.0 || p.sigma_rel > 0.5 {
        return Err("procvar: n_chip > 0 and sigma_rel in [0, 0.5] required".into());
    }
    Ok(p)
}

fn perf_from_value(v: &Value) -> Result<PerfModel, String> {
    let mut m = PerfModel::h100_70b();
    m.prompt_base_s = v.f64_or("prompt_base_s", m.prompt_base_s);
    m.prompt_per_token_s = v.f64_or("prompt_per_token_s", m.prompt_per_token_s);
    m.iter_base_s = v.f64_or("iter_base_s", m.iter_base_s);
    m.iter_per_seq_s = v.f64_or("iter_per_seq_s", m.iter_per_seq_s);
    m.iter_per_ctx_token_s = v.f64_or("iter_per_ctx_token_s", m.iter_per_ctx_token_s);
    m.kv_bytes_per_token = v.f64_or("kv_bytes_per_token", m.kv_bytes_per_token);
    m.link_bytes_per_s = v.f64_or("link_bytes_per_s", m.link_bytes_per_s);
    m.link_latency_s = v.f64_or("link_latency_s", m.link_latency_s);
    if m.prompt_base_s < 0.0 || m.iter_base_s <= 0.0 || m.link_bytes_per_s <= 0.0 {
        return Err("perf: nonpositive timing parameters".into());
    }
    Ok(m)
}

fn validate_cluster(cfg: &ClusterConfig) -> Result<(), String> {
    if cfg.n_prompt == 0 || cfg.n_token == 0 {
        return Err("cluster needs at least one prompt and one token machine".into());
    }
    if cfg.cores_per_cpu == 0 {
        return Err("cores_per_cpu must be positive".into());
    }
    if cfg.max_batch == 0 {
        return Err("max_batch must be positive".into());
    }
    crate::policy::by_name(&cfg.policy).map(|_| ())?;
    if cfg.sample_period_s <= 0.0 {
        return Err("sample_period_s must be positive".into());
    }
    if cfg.lifecycle.is_some() && cfg.fleet.is_none() {
        return Err("a lifecycle block requires a fleet block".into());
    }
    if let Some(fleet) = &cfg.fleet {
        fleet.validate(cfg.n_prompt + cfg.n_token)?;
        if let Some(lc) = &cfg.lifecycle {
            lc.validate(fleet)?;
        }
    }
    Ok(())
}

/// Load an experiment [`Scale`] from a JSON file.
pub fn scale_from_file(path: &Path) -> Result<Scale, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path:?}: {e}"))?;
    let v = parse(&text).map_err(|e| format!("{path:?}: {e}"))?;
    scale_from_value(&v)
}

pub fn scale_from_value(v: &Value) -> Result<Scale, String> {
    let mut s = Scale::paper();
    if let Some(rates) = v.get("rates").and_then(Value::as_arr) {
        s.rates = rates.iter().filter_map(Value::as_f64).collect();
    }
    if let Some(cores) = v.get("core_counts").and_then(Value::as_arr) {
        s.core_counts = cores.iter().filter_map(Value::as_usize).collect();
    }
    s.duration_s = v.f64_or("duration_s", s.duration_s);
    s.n_prompt = v.usize_or("n_prompt", s.n_prompt);
    s.n_token = v.usize_or("n_token", s.n_token);
    s.seed = v.f64_or("seed", s.seed as f64) as u64;
    if let Some(w) = v.get("workload").and_then(Value::as_str) {
        s.workload = Workload::parse(w)?;
    }
    if s.rates.is_empty() || s.core_counts.is_empty() || s.duration_s <= 0.0 {
        return Err("scale: rates, core_counts and duration_s must be non-empty/positive".into());
    }
    Ok(s)
}

const SWEEP_KEYS: &[&str] = &[
    "base",
    "rates",
    "core_counts",
    "policies",
    "workloads",
    "replicas",
    "duration_s",
    "n_prompt",
    "n_token",
    "seed",
    "search",
    "fleet",
    "lifecycle",
];

const SEARCH_KEYS: &[&str] = &["confidence", "min_replicas", "max_replicas", "metric"];

/// Load a [`SweepSpec`] from a JSON file (`carbon-sim sweep --spec`).
/// Any `search` block is validated but dropped — plain sweep entry
/// points share spec files with `sweep --search` without caring.
pub fn sweep_from_file(path: &Path) -> Result<SweepSpec, String> {
    sweep_search_from_file(path).map(|(spec, _)| spec)
}

/// Load a [`SweepSpec`] plus its optional `search` block
/// (`carbon-sim sweep --search --spec`).
pub fn sweep_search_from_file(path: &Path) -> Result<(SweepSpec, Option<SearchConfig>), String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path:?}: {e}"))?;
    let v = parse(&text).map_err(|e| format!("{path:?}: {e}"))?;
    sweep_search_from_value(&v).map_err(|e| format!("{path:?}: {e}"))
}

/// Build a [`SweepSpec`] from a parsed JSON object, dropping any
/// (still validated) `search` block.
pub fn sweep_from_value(v: &Value) -> Result<SweepSpec, String> {
    sweep_search_from_value(v).map(|(spec, _)| spec)
}

/// Build a [`SweepSpec`] and its optional [`SearchConfig`] from a parsed
/// JSON object. Starts from the `"base"` preset (`"paper"`, the default,
/// or `"smoke"`), overrides whichever axes the object sets, and
/// validates the result. Unknown keys are rejected, and every error
/// names the offending key. A `search` object configures
/// `sweep --search` (defaults from [`SearchConfig::defaults_for`] for
/// whatever it leaves unset); `None` means the spec has no search block
/// and `--search` falls back to full defaults.
pub fn sweep_search_from_value(v: &Value) -> Result<(SweepSpec, Option<SearchConfig>), String> {
    let obj = v.as_obj().ok_or("sweep spec must be a JSON object")?;
    for key in obj.keys() {
        if !SWEEP_KEYS.contains(&key.as_str()) {
            return Err(format!("unknown sweep spec key '{key}' (known: {SWEEP_KEYS:?})"));
        }
    }
    let base = match v.get("base") {
        None => "paper",
        Some(b) => b
            .as_str()
            .ok_or("sweep spec key 'base' must be the string \"paper\" or \"smoke\"")?,
    };
    let mut s = match base {
        "paper" => SweepSpec::paper(),
        "smoke" => SweepSpec::smoke(),
        other => {
            return Err(format!("sweep spec key 'base' must be \"paper\" or \"smoke\", got '{other}'"))
        }
    };
    if let Some(x) = v.get("rates") {
        s.rates = f64_array(x, "rates")?;
    }
    if let Some(x) = v.get("core_counts") {
        s.core_counts = usize_array(x, "core_counts")?;
    }
    if let Some(x) = v.get("policies") {
        s.policies = string_array(x, "policies")?;
    }
    if let Some(x) = v.get("workloads") {
        s.workloads = string_array(x, "workloads")?
            .iter()
            .map(|w| Workload::parse(w).map_err(|e| format!("sweep spec key 'workloads': {e}")))
            .collect::<Result<_, _>>()?;
    }
    if let Some(x) = v.get("replicas") {
        s.replicas = usize_scalar(x, "replicas")?;
    }
    if let Some(x) = v.get("duration_s") {
        s.duration_s = f64_scalar(x, "duration_s")?;
    }
    if let Some(x) = v.get("n_prompt") {
        s.n_prompt = usize_scalar(x, "n_prompt")?;
    }
    if let Some(x) = v.get("n_token") {
        s.n_token = usize_scalar(x, "n_token")?;
    }
    if let Some(x) = v.get("seed") {
        s.seed = u64_scalar(x, "seed")?;
    }
    if let Some(x) = v.get("fleet") {
        s.fleet = Some(fleet_from_value(x)?);
    }
    if let Some(x) = v.get("lifecycle") {
        s.lifecycle = Some(lifecycle_from_value(x)?);
    }
    s.validate()?;
    let search = match v.get("search") {
        None => None,
        Some(x) => Some(search_from_value(x, &s)?),
    };
    Ok((s, search))
}

/// Parse a spec's `search` block on top of [`SearchConfig::defaults_for`].
fn search_from_value(v: &Value, spec: &SweepSpec) -> Result<SearchConfig, String> {
    let obj = v.as_obj().ok_or("sweep spec key 'search' must be a JSON object")?;
    for key in obj.keys() {
        if !SEARCH_KEYS.contains(&key.as_str()) {
            return Err(format!("unknown search key 'search.{key}' (known: {SEARCH_KEYS:?})"));
        }
    }
    let mut cfg = SearchConfig::defaults_for(spec);
    if let Some(x) = v.get("confidence") {
        cfg.confidence = f64_scalar(x, "search.confidence")?;
    }
    if let Some(x) = v.get("min_replicas") {
        cfg.min_replicas = usize_scalar(x, "search.min_replicas")?;
    }
    if let Some(x) = v.get("max_replicas") {
        cfg.max_replicas = usize_scalar(x, "search.max_replicas")?;
    }
    if let Some(x) = v.get("metric") {
        cfg.metric = x
            .as_str()
            .ok_or("sweep spec key 'search.metric' must be a string")?
            .to_string();
    }
    cfg.validate()?;
    Ok(cfg)
}

const FLEET_KEYS: &[&str] = &["groups"];

const GROUP_KEYS: &[&str] = &[
    "count",
    "cores",
    "generation",
    "embodied_kg",
    "lifetime_yr",
    "commission_age_yr",
];

const LIFECYCLE_KEYS: &[&str] = &[
    "maintenance",
    "failures",
    "failure_rate_per_core_year",
    "age_limit_yr",
    "dvth_guard_band_v",
    "check_period_s",
    "replacement_group",
];

const MAINTENANCE_KEYS: &[&str] = &["machine", "start_s", "duration_s"];

const FAILURE_KEYS: &[&str] = &["machine", "core", "time_s"];

/// Parse a `fleet` block (heterogeneous machine groups). Shared between
/// cluster configs and sweep specs; cross-checks against the machine
/// count happen later in `FleetConfig::validate`, not here.
pub fn fleet_from_value(v: &Value) -> Result<FleetConfig, String> {
    let obj = v.as_obj().ok_or("spec key 'fleet' must be a JSON object")?;
    for key in obj.keys() {
        if !FLEET_KEYS.contains(&key.as_str()) {
            return Err(format!("unknown fleet key 'fleet.{key}' (known: {FLEET_KEYS:?})"));
        }
    }
    let groups = v
        .get("groups")
        .ok_or("fleet: missing required key 'fleet.groups'")?
        .as_arr()
        .ok_or("spec key 'fleet.groups' must be an array of objects")?
        .iter()
        .enumerate()
        .map(|(i, g)| group_from_value(g, i))
        .collect::<Result<Vec<_>, _>>()?;
    Ok(FleetConfig { groups })
}

fn group_from_value(v: &Value, i: usize) -> Result<MachineGroup, String> {
    let obj = v
        .as_obj()
        .ok_or_else(|| format!("spec key 'fleet.groups[{i}]' must be a JSON object"))?;
    for key in obj.keys() {
        if !GROUP_KEYS.contains(&key.as_str()) {
            return Err(format!(
                "unknown fleet key 'fleet.groups[{i}].{key}' (known: {GROUP_KEYS:?})"
            ));
        }
    }
    let require = |field: &str| {
        v.get(field)
            .ok_or_else(|| format!("fleet.groups[{i}]: missing required key '{field}'"))
    };
    let mut g = MachineGroup {
        count: usize_scalar(require("count")?, &format!("fleet.groups[{i}].count"))?,
        cores: usize_scalar(require("cores")?, &format!("fleet.groups[{i}].cores"))?,
        ..MachineGroup::default()
    };
    if let Some(x) = v.get("generation") {
        g.generation = x
            .as_str()
            .ok_or_else(|| format!("sweep spec key 'fleet.groups[{i}].generation' must be a string"))?
            .to_string();
    }
    if let Some(x) = v.get("embodied_kg") {
        g.embodied_kg = f64_scalar(x, &format!("fleet.groups[{i}].embodied_kg"))?;
    }
    if let Some(x) = v.get("lifetime_yr") {
        g.lifetime_yr = f64_scalar(x, &format!("fleet.groups[{i}].lifetime_yr"))?;
    }
    if let Some(x) = v.get("commission_age_yr") {
        g.commission_age_yr = f64_scalar(x, &format!("fleet.groups[{i}].commission_age_yr"))?;
    }
    Ok(g)
}

/// Parse a `lifecycle` block (maintenance windows, core failures,
/// retirement triggers). Range checks and fleet cross-references happen
/// later in `LifecycleConfig::validate`.
pub fn lifecycle_from_value(v: &Value) -> Result<LifecycleConfig, String> {
    let obj = v.as_obj().ok_or("spec key 'lifecycle' must be a JSON object")?;
    for key in obj.keys() {
        if !LIFECYCLE_KEYS.contains(&key.as_str()) {
            return Err(format!(
                "unknown lifecycle key 'lifecycle.{key}' (known: {LIFECYCLE_KEYS:?})"
            ));
        }
    }
    let mut lc = LifecycleConfig::default();
    if let Some(x) = v.get("maintenance") {
        lc.maintenance = x
            .as_arr()
            .ok_or("spec key 'lifecycle.maintenance' must be an array of objects")?
            .iter()
            .enumerate()
            .map(|(i, w)| maintenance_from_value(w, i))
            .collect::<Result<Vec<_>, _>>()?;
    }
    if let Some(x) = v.get("failures") {
        lc.failures = x
            .as_arr()
            .ok_or("spec key 'lifecycle.failures' must be an array of objects")?
            .iter()
            .enumerate()
            .map(|(i, f)| core_failure_from_value(f, i))
            .collect::<Result<Vec<_>, _>>()?;
    }
    if let Some(x) = v.get("failure_rate_per_core_year") {
        lc.failure_rate_per_core_year = f64_scalar(x, "lifecycle.failure_rate_per_core_year")?;
    }
    if let Some(x) = v.get("age_limit_yr") {
        lc.age_limit_yr = Some(f64_scalar(x, "lifecycle.age_limit_yr")?);
    }
    if let Some(x) = v.get("dvth_guard_band_v") {
        lc.dvth_guard_band_v = Some(f64_scalar(x, "lifecycle.dvth_guard_band_v")?);
    }
    if let Some(x) = v.get("check_period_s") {
        lc.check_period_s = f64_scalar(x, "lifecycle.check_period_s")?;
    }
    if let Some(x) = v.get("replacement_group") {
        lc.replacement_group = usize_scalar(x, "lifecycle.replacement_group")?;
    }
    Ok(lc)
}

fn maintenance_from_value(v: &Value, i: usize) -> Result<MaintenanceWindow, String> {
    let obj = v
        .as_obj()
        .ok_or_else(|| format!("spec key 'lifecycle.maintenance[{i}]' must be a JSON object"))?;
    for key in obj.keys() {
        if !MAINTENANCE_KEYS.contains(&key.as_str()) {
            return Err(format!(
                "unknown lifecycle key 'lifecycle.maintenance[{i}].{key}' \
                 (known: {MAINTENANCE_KEYS:?})"
            ));
        }
    }
    let require = |field: &str| {
        v.get(field)
            .ok_or_else(|| format!("lifecycle.maintenance[{i}]: missing required key '{field}'"))
    };
    Ok(MaintenanceWindow {
        machine: usize_scalar(require("machine")?, &format!("lifecycle.maintenance[{i}].machine"))?,
        start_s: f64_scalar(require("start_s")?, &format!("lifecycle.maintenance[{i}].start_s"))?,
        duration_s: f64_scalar(
            require("duration_s")?,
            &format!("lifecycle.maintenance[{i}].duration_s"),
        )?,
    })
}

fn core_failure_from_value(v: &Value, i: usize) -> Result<CoreFailure, String> {
    let obj = v
        .as_obj()
        .ok_or_else(|| format!("spec key 'lifecycle.failures[{i}]' must be a JSON object"))?;
    for key in obj.keys() {
        if !FAILURE_KEYS.contains(&key.as_str()) {
            return Err(format!(
                "unknown lifecycle key 'lifecycle.failures[{i}].{key}' (known: {FAILURE_KEYS:?})"
            ));
        }
    }
    let require = |field: &str| {
        v.get(field)
            .ok_or_else(|| format!("lifecycle.failures[{i}]: missing required key '{field}'"))
    };
    Ok(CoreFailure {
        machine: usize_scalar(require("machine")?, &format!("lifecycle.failures[{i}].machine"))?,
        core: usize_scalar(require("core")?, &format!("lifecycle.failures[{i}].core"))?,
        time_s: f64_scalar(require("time_s")?, &format!("lifecycle.failures[{i}].time_s"))?,
    })
}

// Typed extraction helpers whose errors name the offending key — unlike
// the lenient `f64_or`-style accessors, a sweep spec typo must fail
// loudly instead of silently running the wrong grid for hours.

/// 2^53: every integer below is exactly representable as f64; at and
/// above, distinct written literals collapse to the same f64 (and every
/// huge f64 passes `fract() == 0.0`, so a bound is the only way to catch
/// a fat-fingered exponent before `as` saturates it).
const MAX_EXACT_INT_F64: f64 = 9_007_199_254_740_992.0;

fn f64_array(v: &Value, key: &str) -> Result<Vec<f64>, String> {
    let arr = v
        .as_arr()
        .ok_or_else(|| format!("sweep spec key '{key}' must be an array of numbers"))?;
    arr.iter()
        .map(|x| {
            x.as_f64()
                .ok_or_else(|| format!("sweep spec key '{key}' must contain only numbers"))
        })
        .collect()
}

fn usize_array(v: &Value, key: &str) -> Result<Vec<usize>, String> {
    f64_array(v, key)?
        .into_iter()
        .map(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x < MAX_EXACT_INT_F64 {
                Ok(x as usize)
            } else {
                Err(format!("sweep spec key '{key}' must contain non-negative integers < 2^53"))
            }
        })
        .collect()
}

fn string_array(v: &Value, key: &str) -> Result<Vec<String>, String> {
    let arr = v
        .as_arr()
        .ok_or_else(|| format!("sweep spec key '{key}' must be an array of strings"))?;
    arr.iter()
        .map(|x| {
            x.as_str()
                .map(|s| s.to_string())
                .ok_or_else(|| format!("sweep spec key '{key}' must contain only strings"))
        })
        .collect()
}

fn f64_scalar(v: &Value, key: &str) -> Result<f64, String> {
    v.as_f64().ok_or_else(|| format!("sweep spec key '{key}' must be a number"))
}

fn usize_scalar(v: &Value, key: &str) -> Result<usize, String> {
    let x = f64_scalar(v, key)?;
    if x >= 0.0 && x.fract() == 0.0 && x < MAX_EXACT_INT_F64 {
        Ok(x as usize)
    } else {
        Err(format!("sweep spec key '{key}' must be a non-negative integer < 2^53"))
    }
}

/// u64 seeds exceed f64's 2^53 integer range, so `"seed"` accepts either
/// a JSON number (rejected beyond 2^53, where the JSON parser's f64
/// representation already lost precision — accepting it would silently
/// run a different seed than the user wrote) or a decimal string (the
/// report serializes it back as a string for the same reason).
fn u64_scalar(v: &Value, key: &str) -> Result<u64, String> {
    match v {
        Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x < MAX_EXACT_INT_F64 => {
            Ok(*x as u64)
        }
        Value::Num(_) => Err(format!(
            "sweep spec key '{key}' must be a non-negative integer < 2^53; write larger \
             seeds as decimal strings (JSON numbers lose precision there)"
        )),
        Value::Str(s) => s
            .parse::<u64>()
            .map_err(|e| format!("sweep spec key '{key}': bad u64 '{s}': {e}")),
        _ => Err(format!(
            "sweep spec key '{key}' must be a non-negative integer or decimal string"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_object_gives_paper_defaults() {
        let cfg = cluster_from_value(&parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.n_prompt, 5);
        assert_eq!(cfg.n_token, 17);
        assert_eq!(cfg.cores_per_cpu, 40);
        assert_eq!(cfg.policy, "proposed");
    }

    #[test]
    fn overrides_apply_and_k_recalibrates() {
        let v = parse(
            r#"{"cores_per_cpu": 80, "policy": "least-aged",
                "aging": {"unallocated_stress": 0.5, "calib_reduction": 0.2}}"#,
        )
        .unwrap();
        let cfg = cluster_from_value(&v).unwrap();
        assert_eq!(cfg.cores_per_cpu, 80);
        assert_eq!(cfg.policy, "least-aged");
        assert_eq!(cfg.aging.unallocated_stress, 0.5);
        // K must satisfy the new 20%-in-10-years calibration.
        let adf = cfg.aging.adf(cfg.aging.calib_temp_k, 1.0);
        let dvth = cfg.aging.dvth_step(0.0, adf, cfg.aging.calib_lifetime_s);
        assert!((cfg.aging.rel_reduction(dvth) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn queue_key_selects_the_implementation() {
        use crate::sim::QueueKind;
        let cfg = cluster_from_value(&parse(r#"{"queue": "heap"}"#).unwrap()).unwrap();
        assert_eq!(cfg.queue, QueueKind::Heap);
        let cfg = cluster_from_value(&parse(r#"{"queue": "calendar"}"#).unwrap()).unwrap();
        assert_eq!(cfg.queue, QueueKind::Calendar);
        assert!(cluster_from_value(&parse(r#"{"queue": "fifo"}"#).unwrap()).is_err());
        assert!(cluster_from_value(&parse(r#"{"queue": 3}"#).unwrap()).is_err());
    }

    #[test]
    fn unknown_keys_rejected() {
        let v = parse(r#"{"cores_per_cpuu": 80}"#).unwrap();
        let err = cluster_from_value(&v).unwrap_err();
        assert!(err.contains("unknown cluster config key"));
    }

    #[test]
    fn invalid_values_rejected() {
        for bad in [
            r#"{"n_prompt": 0}"#,
            r#"{"policy": "nope"}"#,
            r#"{"aging": {"vdd": 0.2}}"#,
            r#"{"aging": {"unallocated_stress": 0.0}}"#,
            r#"{"procvar": {"sigma_rel": 0.9}}"#,
            r#"{"perf": {"iter_base_s": 0.0}}"#,
        ] {
            assert!(cluster_from_value(&parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn scale_parsing() {
        let v = parse(
            r#"{"rates": [20, 40], "core_counts": [16], "duration_s": 30,
                "workload": "conv", "seed": 9}"#,
        )
        .unwrap();
        let s = scale_from_value(&v).unwrap();
        assert_eq!(s.rates, vec![20.0, 40.0]);
        assert_eq!(s.core_counts, vec![16]);
        assert_eq!(s.workload, Workload::Conversation);
        assert_eq!(s.seed, 9);
        assert!(scale_from_value(&parse(r#"{"rates": []}"#).unwrap()).is_err());
    }

    #[test]
    fn sweep_empty_object_is_the_paper_grid() {
        let s = sweep_from_value(&parse("{}").unwrap()).unwrap();
        let paper = SweepSpec::paper();
        assert_eq!(s.rates, paper.rates);
        assert_eq!(s.core_counts, paper.core_counts);
        assert_eq!(s.policies, paper.policies);
        assert_eq!(s.seed, paper.seed);
        assert_eq!(s.spec_hash(), paper.spec_hash());
    }

    #[test]
    fn sweep_base_smoke_with_overrides() {
        let v = parse(
            r#"{"base": "smoke", "rates": [4, 8], "workloads": ["diurnal", "bursty"],
                "replicas": 2, "seed": 99}"#,
        )
        .unwrap();
        let s = sweep_from_value(&v).unwrap();
        assert_eq!(s.rates, vec![4.0, 8.0]);
        assert_eq!(s.core_counts, SweepSpec::smoke().core_counts);
        assert_eq!(s.workloads, vec![Workload::Diurnal, Workload::Bursty]);
        assert_eq!(s.replicas, 2);
        assert_eq!(s.seed, 99);
    }

    #[test]
    fn sweep_seed_accepts_decimal_string_beyond_2_53() {
        let v = parse(r#"{"seed": "18446744073709551615"}"#).unwrap();
        assert_eq!(sweep_from_value(&v).unwrap().seed, u64::MAX);
    }

    #[test]
    fn sweep_errors_name_the_offending_key() {
        for (bad, named) in [
            (r#"{"ratez": [40]}"#, "ratez"),
            (r#"{"rates": "40"}"#, "rates"),
            (r#"{"rates": [40, "x"]}"#, "rates"),
            (r#"{"core_counts": [1.5]}"#, "core_counts"),
            (r#"{"replicas": 4.6e18}"#, "replicas"),
            (r#"{"policies": [40]}"#, "policies"),
            (r#"{"workloads": ["frob"]}"#, "workloads"),
            (r#"{"replicas": 1.5}"#, "replicas"),
            (r#"{"duration_s": "long"}"#, "duration_s"),
            (r#"{"seed": -3}"#, "seed"),
            // Above 2^53 a JSON number has already lost precision in the
            // f64 parse; only the string form is accepted there.
            (r#"{"seed": 9007199254740993}"#, "seed"),
            (r#"{"base": "huge"}"#, "base"),
            (r#"{"base": 5}"#, "base"),
        ] {
            let err = sweep_from_value(&parse(bad).unwrap()).unwrap_err();
            assert!(err.contains(named), "error for {bad} should name '{named}': {err}");
        }
        // Non-object specs and post-parse validation failures still error.
        assert!(sweep_from_value(&parse("[1, 2]").unwrap()).is_err());
        assert!(sweep_from_value(&parse(r#"{"rates": []}"#).unwrap()).is_err());
        assert!(sweep_from_value(&parse(r#"{"policies": ["nope"]}"#).unwrap()).is_err());
        assert!(sweep_from_value(&parse(r#"{"replicas": 0}"#).unwrap()).is_err());
    }

    #[test]
    fn sweep_search_block_parses_with_defaults_and_overrides() {
        // No block: spec parses, search is None.
        let (_, search) = sweep_search_from_value(&parse(r#"{"base": "smoke"}"#).unwrap()).unwrap();
        assert!(search.is_none());
        // Empty block: full defaults for the spec.
        let (spec, search) = sweep_search_from_value(
            &parse(r#"{"base": "smoke", "replicas": 8, "search": {}}"#).unwrap(),
        )
        .unwrap();
        let cfg = search.unwrap();
        assert_eq!(cfg, SearchConfig::defaults_for(&spec));
        assert_eq!(cfg.max_replicas, 8, "budget defaults to the spec's replicas");
        // Overrides apply field by field.
        let (_, search) = sweep_search_from_value(
            &parse(
                r#"{"base": "smoke", "replicas": 8,
                    "search": {"confidence": 0.9, "min_replicas": 2,
                               "max_replicas": 6, "metric": "e2e_p99_s"}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let cfg = search.unwrap();
        assert_eq!(cfg.confidence, 0.9);
        assert_eq!(cfg.min_replicas, 2);
        assert_eq!(cfg.max_replicas, 6);
        assert_eq!(cfg.metric, "e2e_p99_s");
        // Plain sweep loaders accept — and drop — the block.
        let spec = sweep_from_value(
            &parse(r#"{"base": "smoke", "search": {"confidence": 0.9}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(spec.spec_hash(), SweepSpec::smoke().spec_hash());
    }

    #[test]
    fn sweep_search_block_errors_name_the_offending_key() {
        for (bad, named) in [
            (r#"{"search": 3}"#, "search"),
            (r#"{"search": {"confidance": 0.9}}"#, "search.confidance"),
            (r#"{"search": {"confidence": "high"}}"#, "search.confidence"),
            (r#"{"search": {"confidence": 1.5}}"#, "confidence"),
            (r#"{"search": {"min_replicas": 1}}"#, "min_replicas"),
            (r#"{"search": {"min_replicas": 2.5}}"#, "search.min_replicas"),
            (r#"{"search": {"max_replicas": 2}}"#, "max_replicas"),
            (r#"{"search": {"metric": "policy"}}"#, "metric"),
            (r#"{"search": {"metric": 7}}"#, "search.metric"),
        ] {
            let err = sweep_search_from_value(&parse(bad).unwrap()).unwrap_err();
            assert!(err.contains(named), "error for {bad} should name '{named}': {err}");
        }
    }

    #[test]
    fn fleet_and_lifecycle_blocks_parse_with_defaults_and_overrides() {
        let v = parse(
            r#"{"base": "smoke", "n_prompt": 2, "n_token": 2,
                "fleet": {"groups": [
                    {"count": 2, "cores": 16},
                    {"count": 2, "cores": 12, "generation": "gen2",
                     "embodied_kg": 240.0, "lifetime_yr": 4.0,
                     "commission_age_yr": 2.5}]},
                "lifecycle": {
                    "maintenance": [{"machine": 0, "start_s": 1.0, "duration_s": 0.5}],
                    "failures": [{"machine": 1, "core": 3, "time_s": 2.0}],
                    "failure_rate_per_core_year": 0.01,
                    "age_limit_yr": 3.0,
                    "check_period_s": 2.0,
                    "replacement_group": 1}}"#,
        )
        .unwrap();
        let s = sweep_from_value(&v).unwrap();
        let fleet = s.fleet.as_ref().expect("fleet parsed");
        assert_eq!(fleet.n_machines(), 4);
        // Omitted group fields keep the paper defaults.
        assert_eq!(fleet.groups[0].generation, "paper");
        assert_eq!(fleet.groups[0].embodied_kg, 278.3);
        assert_eq!(fleet.groups[0].lifetime_yr, 3.0);
        assert_eq!(fleet.groups[0].commission_age_yr, 0.0);
        assert_eq!(fleet.groups[1].generation, "gen2");
        assert_eq!(fleet.groups[1].lifetime_yr, 4.0);
        let lc = s.lifecycle.as_ref().expect("lifecycle parsed");
        assert_eq!(lc.maintenance.len(), 1);
        assert_eq!(lc.failures[0].core, 3);
        assert_eq!(lc.age_limit_yr, Some(3.0));
        assert_eq!(lc.dvth_guard_band_v, None);
        assert_eq!(lc.replacement_group, 1);
        assert!(lc.retirement_armed());

        // The same blocks work in cluster configs.
        let v = parse(
            r#"{"n_prompt": 1, "n_token": 1,
                "fleet": {"groups": [{"count": 2, "cores": 8}]}}"#,
        )
        .unwrap();
        let cfg = cluster_from_value(&v).unwrap();
        assert_eq!(cfg.fleet.as_ref().unwrap().n_machines(), 2);
        assert!(cfg.lifecycle.is_none());
    }

    #[test]
    fn fleet_and_lifecycle_errors_name_the_offending_key() {
        // A fleet whose parse succeeds, for reaching the lifecycle parser.
        let fleet_ok = r#""fleet": {"groups": [{"count": 3, "cores": 8}]}"#;
        for (bad, named) in [
            (r#"{"fleet": 3}"#.to_string(), "fleet"),
            (r#"{"fleet": {"groupz": []}}"#.to_string(), "fleet.groupz"),
            (r#"{"fleet": {}}"#.to_string(), "fleet.groups"),
            (r#"{"fleet": {"groups": [5]}}"#.to_string(), "fleet.groups[0]"),
            (r#"{"fleet": {"groups": [{"cores": 8}]}}"#.to_string(), "count"),
            (
                r#"{"fleet": {"groups": [{"count": 3, "coars": 8}]}}"#.to_string(),
                "fleet.groups[0].coars",
            ),
            (
                r#"{"fleet": {"groups": [{"count": 3, "cores": 1.5}]}}"#.to_string(),
                "fleet.groups[0].cores",
            ),
            // Validation (not parse) failures still name the key.
            (
                r#"{"fleet": {"groups": [{"count": 3, "cores": 8, "generation": "9nm"}]}}"#
                    .to_string(),
                "generation",
            ),
            (
                r#"{"fleet": {"groups": [{"count": 3, "cores": 8, "embodied_kg": -1}]}}"#
                    .to_string(),
                "embodied_kg",
            ),
            // A lifecycle block without a fleet is rejected up front.
            (r#"{"lifecycle": {}}"#.to_string(), "fleet"),
            (format!(r#"{{{fleet_ok}, "lifecycle": 7}}"#), "lifecycle"),
            (
                format!(r#"{{{fleet_ok}, "lifecycle": {{"maintenancez": []}}}}"#),
                "lifecycle.maintenancez",
            ),
            (
                format!(r#"{{{fleet_ok}, "lifecycle": {{"maintenance": [{{"machine": 0}}]}}}}"#),
                "start_s",
            ),
            (
                format!(
                    r#"{{{fleet_ok}, "lifecycle": {{"failures": [
                        {{"machine": 0, "core": 1, "tine_s": 2.0}}]}}}}"#
                ),
                "lifecycle.failures[0].tine_s",
            ),
            (
                format!(r#"{{{fleet_ok}, "lifecycle": {{"age_limit_yr": "soon"}}}}"#),
                "lifecycle.age_limit_yr",
            ),
            // Cross-reference validation: failure on a machine the fleet
            // doesn't have.
            (
                format!(
                    r#"{{{fleet_ok}, "lifecycle": {{"failures": [
                        {{"machine": 9, "core": 0, "time_s": 1.0}}]}}}}"#
                ),
                "machine",
            ),
        ] {
            // Base smoke has n_prompt 1 + n_token 2 = 3 machines, matching
            // fleet_ok's count.
            let spec = format!(r#"{{"base": "smoke", {}"#, &bad[1..]);
            let err = sweep_from_value(&parse(&spec).unwrap()).unwrap_err();
            assert!(err.contains(named), "error for {spec} should name '{named}': {err}");
            // The same blocks go through the cluster-config path.
            let cluster = format!(r#"{{"n_prompt": 1, "n_token": 2, {}"#, &bad[1..]);
            let err = cluster_from_value(&parse(&cluster).unwrap()).unwrap_err();
            assert!(err.contains(named), "cluster error for {cluster} should name '{named}': {err}");
        }
    }

    #[test]
    fn fleet_group_count_must_match_the_machine_count() {
        let v = parse(
            r#"{"base": "smoke", "fleet": {"groups": [{"count": 2, "cores": 8}]}}"#,
        )
        .unwrap();
        // Smoke is 1 prompt + 2 token = 3 machines; a 2-machine fleet
        // cannot cover it.
        let err = sweep_from_value(&v).unwrap_err();
        assert!(err.contains("fleet"), "{err}");
    }

    #[test]
    fn sweep_file_errors_name_the_file() {
        let dir = std::env::temp_dir().join("carbon_sim_sweep_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("broken.json");
        std::fs::write(&p, "{not json").unwrap();
        let err = sweep_from_file(&p).unwrap_err();
        assert!(err.contains("broken.json"), "{err}");
        assert!(sweep_from_file(Path::new("/nonexistent_spec.json")).is_err());
    }

    #[test]
    fn shipped_example_specs_load_and_match_presets() {
        let specs = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/specs");
        let paper = sweep_from_file(&specs.join("paper.json")).unwrap();
        assert_eq!(paper.spec_hash(), SweepSpec::paper().spec_hash(), "examples/specs/paper.json drifted from SweepSpec::paper()");
        let smoke = sweep_from_file(&specs.join("smoke.json")).unwrap();
        assert_eq!(smoke.spec_hash(), SweepSpec::smoke().spec_hash(), "examples/specs/smoke.json drifted from SweepSpec::smoke()");
        let stress = sweep_from_file(&specs.join("diurnal_stress.json")).unwrap();
        assert!(stress.validate().is_ok());
        assert!(stress.workloads.contains(&Workload::Diurnal));
        assert!(stress.n_cells() > SweepSpec::paper().n_cells());
        // The README's --search quickstart spec: smoke grid, replica
        // budget forced high, a search block that settles early.
        let (smoke_search, cfg) =
            sweep_search_from_file(&specs.join("search_smoke.json")).unwrap();
        let cfg = cfg.expect("examples/specs/search_smoke.json must carry a search block");
        assert_eq!(
            smoke_search.spec_hash(),
            SweepSpec { replicas: 8, ..SweepSpec::smoke() }.spec_hash(),
            "examples/specs/search_smoke.json drifted from the smoke preset at 8 replicas"
        );
        assert_eq!((cfg.confidence, cfg.min_replicas, cfg.max_replicas), (0.9, 3, 8));
        assert!(cfg.validate().is_ok());
        assert!(
            cfg.grid(&smoke_search).n_cells() == smoke_search.n_cells(),
            "the search budget must equal the spec's own replicas so the exhaustive \
             comparison in CI is against the same grid"
        );
        // The lifecycle quickstart spec: a smoke-sized grid whose fleet
        // retires the over-age gen2 group at the first check and loses
        // cores to both scripted failures.
        let lifecycle = sweep_from_file(&specs.join("lifecycle_smoke.json")).unwrap();
        assert!(lifecycle.validate().is_ok());
        let fleet = lifecycle.fleet.as_ref().expect("lifecycle_smoke.json must carry a fleet");
        assert_eq!(fleet.n_machines(), lifecycle.n_prompt + lifecycle.n_token);
        assert_eq!(fleet.groups.len(), 2);
        let lc = lifecycle.lifecycle.as_ref().expect("lifecycle_smoke.json must carry a lifecycle");
        assert!(lc.retirement_armed(), "the spec must exercise retirement");
        assert_eq!(lc.failures.len(), 2, "the spec must exercise core failures");
        assert_eq!(lc.maintenance.len(), 1, "the spec must exercise maintenance");
        assert!(
            fleet.groups[1].commission_age_yr > lc.age_limit_yr.unwrap(),
            "group 1 must enter service past the age limit so the first \
             retirement check retires it deterministically"
        );
    }

    #[test]
    fn file_roundtrip_and_missing_file() {
        let dir = std::env::temp_dir().join("carbon_sim_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.json");
        std::fs::write(&p, r#"{"seed": 123, "cores_per_cpu": 8}"#).unwrap();
        let cfg = cluster_from_file(&p).unwrap();
        assert_eq!(cfg.seed, 123);
        assert_eq!(cfg.cores_per_cpu, 8);
        assert!(cluster_from_file(Path::new("/nonexistent.json")).is_err());
    }
}
