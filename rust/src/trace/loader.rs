//! JSONL trace file IO.
//!
//! Format: one JSON object per line —
//! `{"id": 0, "arrival_s": 0.013, "prompt_tokens": 980, "output_tokens": 120}`.
//! A leading header object `{"duration_s": ...}` is optional; when absent,
//! the last arrival time is used as the duration.

use std::io::{BufRead, Write};
use std::path::Path;

use super::{Request, Trace};
use crate::util::json::{parse, Value};

/// Write a trace to a JSONL file.
pub fn save(trace: &Trace, path: &Path) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    let header = Value::obj(vec![("duration_s", trace.duration_s.into())]);
    writeln!(w, "{}", header.to_string_compact())?;
    for r in &trace.requests {
        let v = Value::obj(vec![
            ("id", (r.id as usize).into()),
            ("arrival_s", r.arrival_s.into()),
            ("prompt_tokens", (r.prompt_tokens as usize).into()),
            ("output_tokens", (r.output_tokens as usize).into()),
        ]);
        writeln!(w, "{}", v.to_string_compact())?;
    }
    Ok(())
}

/// Load a trace from a JSONL file.
pub fn load(path: &Path) -> Result<Trace, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("open {path:?}: {e}"))?;
    let reader = std::io::BufReader::new(f);
    let mut trace = Trace::default();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.map_err(|e| format!("read {path:?}:{lineno}: {e}"))?;
        if line.trim().is_empty() {
            continue;
        }
        let v = parse(&line).map_err(|e| format!("{path:?}:{}: {e}", lineno + 1))?;
        if let Some(d) = v.get("duration_s").and_then(Value::as_f64) {
            if v.get("id").is_none() {
                trace.duration_s = d;
                continue;
            }
        }
        let req = Request {
            id: v.get("id").and_then(Value::as_u64).ok_or(format!("line {}: no id", lineno + 1))?,
            arrival_s: v
                .get("arrival_s")
                .and_then(Value::as_f64)
                .ok_or(format!("line {}: no arrival_s", lineno + 1))?,
            prompt_tokens: v
                .get("prompt_tokens")
                .and_then(Value::as_u64)
                .ok_or(format!("line {}: no prompt_tokens", lineno + 1))?
                as u32,
            output_tokens: v
                .get("output_tokens")
                .and_then(Value::as_u64)
                .ok_or(format!("line {}: no output_tokens", lineno + 1))?
                as u32,
        };
        trace.requests.push(req);
    }
    if trace.duration_s == 0.0 {
        trace.duration_s = trace.requests.last().map(|r| r.arrival_s).unwrap_or(0.0);
    }
    trace.validate()?;
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::azure::{AzureTraceGen, TraceParams, Workload};

    #[test]
    fn roundtrip() {
        let t = AzureTraceGen::new(TraceParams {
            rate_rps: 50.0,
            duration_s: 10.0,
            workload: Workload::Mixed,
            seed: 1,
        })
        .generate();
        let dir = std::env::temp_dir().join("carbon_sim_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        save(&t, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.duration_s, t.duration_s);
        assert_eq!(loaded.requests.len(), t.requests.len());
        for (a, b) in loaded.requests.iter().zip(t.requests.iter()) {
            assert_eq!(a.id, b.id);
            assert!((a.arrival_s - b.arrival_s).abs() < 1e-9);
            assert_eq!(a.prompt_tokens, b.prompt_tokens);
            assert_eq!(a.output_tokens, b.output_tokens);
        }
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(load(Path::new("/nonexistent/file.jsonl")).is_err());
    }

    #[test]
    fn load_rejects_malformed() {
        let dir = std::env::temp_dir().join("carbon_sim_trace_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"id\": 0}\n").unwrap();
        assert!(load(&path).is_err());
    }
}
