//! Synthetic Azure-like LLM inference trace generator.
//!
//! Substitutes for the Splitwise production traces (see DESIGN.md). The
//! published Splitwise trace analysis reports, per workload:
//!
//! * **Conversation**: median prompt ≈ 1020 tokens, median output ≈ 129
//!   tokens, both heavy-tailed.
//! * **Coding**: median prompt ≈ 1930 tokens, median output ≈ 13–30 tokens
//!   (short completions).
//!
//! We model token counts as clamped log-normals matching those medians
//! with realistic tails, and arrivals as a Poisson process at the target
//! throughput — the x-axis of Figs. 2/6/7/8.

use super::{Request, Trace};
use crate::util::rng::Rng;

/// Which Azure workload mix to synthesize.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    Conversation,
    Coding,
    /// Production-like blend: 70 % conversation, 30 % coding.
    Mixed,
}

impl Workload {
    pub fn parse(s: &str) -> Result<Workload, String> {
        match s {
            "conv" | "conversation" => Ok(Workload::Conversation),
            "code" | "coding" => Ok(Workload::Coding),
            "mixed" => Ok(Workload::Mixed),
            other => Err(format!("unknown workload '{other}' (conv|code|mixed)")),
        }
    }
}

/// Generator parameters.
#[derive(Clone, Copy, Debug)]
pub struct TraceParams {
    /// Offered load in requests per second (cluster-wide).
    pub rate_rps: f64,
    /// Trace length in seconds.
    pub duration_s: f64,
    pub workload: Workload,
    pub seed: u64,
}

/// Log-normal spec in (median, sigma) form with clamping.
#[derive(Clone, Copy, Debug)]
struct TokenDist {
    median: f64,
    sigma: f64,
    min: u32,
    max: u32,
}

impl TokenDist {
    fn sample(&self, rng: &mut Rng) -> u32 {
        let mu = self.median.ln();
        let x = rng.lognormal(mu, self.sigma);
        (x.round() as u32).clamp(self.min, self.max)
    }
}

const CONV_PROMPT: TokenDist = TokenDist { median: 1020.0, sigma: 1.0, min: 4, max: 8192 };
const CONV_OUTPUT: TokenDist = TokenDist { median: 129.0, sigma: 0.8, min: 1, max: 1024 };
const CODE_PROMPT: TokenDist = TokenDist { median: 1930.0, sigma: 0.7, min: 16, max: 8192 };
const CODE_OUTPUT: TokenDist = TokenDist { median: 28.0, sigma: 0.9, min: 1, max: 512 };

/// The trace generator.
pub struct AzureTraceGen {
    pub params: TraceParams,
}

impl AzureTraceGen {
    pub fn new(params: TraceParams) -> AzureTraceGen {
        AzureTraceGen { params }
    }

    /// Generate a trace with a diurnal load profile: an inhomogeneous
    /// Poisson process `λ(t) = rate·(1 + amplitude·sin(2πt/period))`
    /// sampled by thinning. Production Azure traffic follows day/night
    /// cycles; this stresses Selective Core Idling's tracking of load
    /// *decreases* (the periodic branch of the controller).
    pub fn generate_diurnal(&self, amplitude: f64, period_s: f64) -> Trace {
        assert!((0.0..=1.0).contains(&amplitude), "amplitude in [0,1]");
        assert!(period_s > 0.0);
        let p = &self.params;
        let mut rng = Rng::new(p.seed ^ 0xD1_0C);
        let lambda_max = p.rate_rps * (1.0 + amplitude);
        let mut requests = Vec::new();
        let mut t = 0.0;
        let mut id = 0u64;
        loop {
            t += rng.exp(lambda_max);
            if t >= p.duration_s {
                break;
            }
            let lambda_t = p.rate_rps
                * (1.0 + amplitude * (2.0 * std::f64::consts::PI * t / period_s).sin());
            if !rng.bool(lambda_t / lambda_max) {
                continue; // thinned
            }
            let coding = match p.workload {
                Workload::Conversation => false,
                Workload::Coding => true,
                Workload::Mixed => rng.bool(0.3),
            };
            let (pt, ot) = if coding {
                (CODE_PROMPT.sample(&mut rng), CODE_OUTPUT.sample(&mut rng))
            } else {
                (CONV_PROMPT.sample(&mut rng), CONV_OUTPUT.sample(&mut rng))
            };
            requests.push(Request { id, arrival_s: t, prompt_tokens: pt, output_tokens: ot });
            id += 1;
        }
        Trace { requests, duration_s: p.duration_s }
    }

    /// Generate a full trace.
    pub fn generate(&self) -> Trace {
        let mut rng = Rng::new(self.params.seed);
        let mut requests = Vec::new();
        let mut t = 0.0;
        let mut id = 0u64;
        loop {
            t += rng.exp(self.params.rate_rps);
            if t >= self.params.duration_s {
                break;
            }
            let coding = match self.params.workload {
                Workload::Conversation => false,
                Workload::Coding => true,
                Workload::Mixed => rng.bool(0.3),
            };
            let (p, o) = if coding {
                (CODE_PROMPT.sample(&mut rng), CODE_OUTPUT.sample(&mut rng))
            } else {
                (CONV_PROMPT.sample(&mut rng), CONV_OUTPUT.sample(&mut rng))
            };
            requests.push(Request { id, arrival_s: t, prompt_tokens: p, output_tokens: o });
            id += 1;
        }
        Trace { requests, duration_s: self.params.duration_s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn gen(rate: f64, dur: f64, w: Workload, seed: u64) -> Trace {
        AzureTraceGen::new(TraceParams { rate_rps: rate, duration_s: dur, workload: w, seed })
            .generate()
    }

    #[test]
    fn rate_matches_target() {
        let t = gen(60.0, 300.0, Workload::Mixed, 1);
        assert!((t.rate_rps() - 60.0).abs() < 3.0, "rate={}", t.rate_rps());
        assert!(t.validate().is_ok());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gen(40.0, 60.0, Workload::Mixed, 7);
        let b = gen(40.0, 60.0, Workload::Mixed, 7);
        assert_eq!(a.requests, b.requests);
        let c = gen(40.0, 60.0, Workload::Mixed, 8);
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn conv_medians_match_published_stats() {
        let t = gen(200.0, 300.0, Workload::Conversation, 2);
        let prompts: Vec<f64> = t.requests.iter().map(|r| r.prompt_tokens as f64).collect();
        let outputs: Vec<f64> = t.requests.iter().map(|r| r.output_tokens as f64).collect();
        let p50_p = stats::percentile(&prompts, 50.0);
        let p50_o = stats::percentile(&outputs, 50.0);
        assert!((p50_p - 1020.0).abs() < 150.0, "prompt median={p50_p}");
        assert!((p50_o - 129.0).abs() < 25.0, "output median={p50_o}");
    }

    #[test]
    fn coding_outputs_are_short() {
        let t = gen(200.0, 200.0, Workload::Coding, 3);
        let outputs: Vec<f64> = t.requests.iter().map(|r| r.output_tokens as f64).collect();
        let p50 = stats::percentile(&outputs, 50.0);
        assert!(p50 < 60.0, "coding output median={p50}");
        let prompts: Vec<f64> = t.requests.iter().map(|r| r.prompt_tokens as f64).collect();
        assert!(stats::percentile(&prompts, 50.0) > 1500.0);
    }

    #[test]
    fn interarrivals_are_exponential() {
        let t = gen(100.0, 200.0, Workload::Mixed, 4);
        let gaps: Vec<f64> =
            t.requests.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s).collect();
        let mean_gap = stats::mean(&gaps);
        // Poisson(100/s) -> mean gap 10 ms; CV of exponential = 1.
        assert!((mean_gap - 0.01).abs() < 0.002, "mean gap={mean_gap}");
        let cv = stats::coeff_of_variation(&gaps);
        assert!((cv - 1.0).abs() < 0.12, "cv={cv}");
    }

    #[test]
    fn diurnal_profile_modulates_rate() {
        let g = AzureTraceGen::new(TraceParams {
            rate_rps: 100.0,
            duration_s: 400.0,
            workload: Workload::Mixed,
            seed: 6,
        });
        // One full sine period: first half above base rate, second below.
        let t = g.generate_diurnal(0.8, 400.0);
        assert!(t.validate().is_ok());
        let first = t.requests.iter().filter(|r| r.arrival_s < 200.0).count() as f64;
        let second = t.requests.len() as f64 - first;
        assert!(first > second * 1.8, "first={first} second={second}");
        // Total volume stays near the base rate (sine integrates to 0).
        assert!((t.rate_rps() - 100.0).abs() < 8.0, "rate={}", t.rate_rps());
    }

    #[test]
    fn diurnal_zero_amplitude_is_homogeneous() {
        let g = AzureTraceGen::new(TraceParams {
            rate_rps: 50.0,
            duration_s: 100.0,
            workload: Workload::Mixed,
            seed: 8,
        });
        let t = g.generate_diurnal(0.0, 100.0);
        assert!((t.rate_rps() - 50.0).abs() < 5.0);
        let first = t.requests.iter().filter(|r| r.arrival_s < 50.0).count() as f64;
        let second = t.requests.len() as f64 - first;
        assert!((first / second - 1.0).abs() < 0.25);
    }

    #[test]
    fn tokens_within_clamps() {
        let t = gen(100.0, 100.0, Workload::Mixed, 5);
        for r in &t.requests {
            assert!((1..=8192).contains(&r.prompt_tokens));
            assert!((1..=1024).contains(&r.output_tokens));
        }
    }
}
